#!/usr/bin/env python
"""Docs smoke-runner: the code fences in docs/*.md must actually execute.

Three checks, in document order:

  * ```python fences run in a subprocess with PYTHONPATH=src.
  * ```console fences: every ``$ python -m repro ...`` /
    ``$ python -m benchmarks...`` line runs and must exit 0.
  * The CheckpointOptions table in docs/ARCHITECTURE.md (field / env var /
    default) is diffed against the real dataclass, so it cannot drift.

Fences share per-document placeholder directories (RUN_DIR, ORCH_RUN,
PEER_STORE): a python fence that writes images into RUN_DIR feeds the
console commands that inspect it — the walkthroughs are executed as
written.  A fence preceded by ``<!-- check_docs: skip -->`` is skipped.

Usage:  python tools/check_docs.py [--skip-slow] [docs/FILE.md ...]
"""
from __future__ import annotations

import argparse
import ast
import glob
import os
import re
import subprocess
import sys
import tempfile

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SKIP_MARK = "<!-- check_docs: skip -->"
PLACEHOLDERS = ("RUN_DIR", "ORCH_RUN", "PEER_STORE", "CHAOS_RUN",
                "FLEET_RUN")
SLOW_TOKENS = ("orchestrate", "migrate", "chaos", "serve-fleet")
RUNNABLE_PREFIXES = ("python -m repro", "python -m benchmarks")

FENCE_RE = re.compile(r"```(\w*)\n(.*?)```", re.S)


def parse_fences(text):
    """(lang, body, skipped) for every fenced block, in order."""
    out = []
    for m in FENCE_RE.finditer(text):
        before = text[:m.start()].rstrip().splitlines()
        skipped = bool(before) and before[-1].strip() == SKIP_MARK
        out.append((m.group(1), m.group(2), skipped))
    return out


def run(cmd, env, timeout=600, label=""):
    r = subprocess.run(cmd, cwd=REPO, env=env, capture_output=True,
                       text=True, timeout=timeout)
    if r.returncode != 0:
        print(f"FAIL {label}\n  exit {r.returncode}\n"
              f"  stdout: {r.stdout[-2000:]}\n"
              f"  stderr: {r.stderr[-2000:]}")
        return False
    return True


def substitute(body, dirs):
    for name in PLACEHOLDERS:
        body = body.replace(name, dirs[name])
    return body


def check_doc(path, skip_slow):
    with open(path) as f:
        text = f.read()
    base = tempfile.mkdtemp(prefix="check_docs_")
    dirs = {name: os.path.join(base, name.lower())
            for name in PLACEHOLDERS}
    env = dict(os.environ,
               PYTHONPATH=os.path.join(REPO, "src")
               + os.pathsep + os.environ.get("PYTHONPATH", ""),
               JAX_PLATFORMS="cpu")
    failures = 0
    ran = 0
    tainted: set = set()      # dirs whose producer command was skipped
    for i, (lang, body, skipped) in enumerate(parse_fences(text)):
        label = f"{os.path.basename(path)} fence #{i} [{lang}]"
        if skipped or lang not in ("python", "console"):
            continue
        if lang == "python":
            ran += 1
            if not run([sys.executable, "-c", substitute(body, dirs)],
                       env, label=label):
                failures += 1
            continue
        for line in body.splitlines():
            if not line.startswith("$ "):
                continue
            cmd = substitute(line[2:].strip(), dirs)
            if not cmd.startswith(RUNNABLE_PREFIXES):
                continue
            if skip_slow and any(t in cmd for t in SLOW_TOKENS):
                print(f"skip (slow): {cmd}")
                tainted.update(d for d in dirs.values() if d in cmd)
                continue
            if any(d in cmd for d in tainted):
                print(f"skip (depends on skipped output): {cmd}")
                continue
            ran += 1
            if not run([sys.executable] + cmd.split()[1:], env,
                       label=f"{label}: {cmd}"):
                failures += 1
    return ran, failures


def check_options_table(path):
    """The ARCHITECTURE.md options table must match CheckpointOptions."""
    import dataclasses
    sys.path.insert(0, os.path.join(REPO, "src"))
    from repro.api.options import CheckpointOptions, _ENV_PREFIX
    with open(path) as f:
        text = f.read()
    rows = re.findall(
        r"^\| `(\w+)` \| `(REPRO_CKPT_\w+)` \| `(.+?)` \|$",
        text, re.M)
    documented = {name: (env, default) for name, env, default in rows}
    problems = []
    fields = {f.name: f for f in dataclasses.fields(CheckpointOptions)}
    for name, f in fields.items():
        if name not in documented:
            problems.append(f"field {name!r} missing from the table")
            continue
        env, default = documented[name]
        if env != _ENV_PREFIX + name.upper():
            problems.append(f"{name}: env var {env!r} != "
                            f"{_ENV_PREFIX + name.upper()!r}")
        try:
            doc_default = ast.literal_eval(default)
        except (ValueError, SyntaxError):
            problems.append(f"{name}: unparseable default {default!r}")
            continue
        if doc_default != f.default:
            problems.append(f"{name}: documented default {doc_default!r} "
                            f"!= actual {f.default!r}")
    for name in documented:
        if name not in fields:
            problems.append(f"table documents unknown field {name!r}")
    return problems


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("files", nargs="*",
                    default=sorted(glob.glob(os.path.join(REPO, "docs",
                                                          "*.md"))))
    ap.add_argument("--skip-slow", action="store_true",
                    help="skip orchestrate/migrate/chaos console "
                         "walkthroughs")
    args = ap.parse_args(argv)

    total_ran = total_failed = 0
    for path in args.files:
        ran, failed = check_doc(path, args.skip_slow)
        print(f"{path}: {ran} fence command(s) ran, {failed} failed")
        total_ran += ran
        total_failed += failed

    arch = os.path.join(REPO, "docs", "ARCHITECTURE.md")
    if os.path.exists(arch):
        problems = check_options_table(arch)
        for p in problems:
            print(f"ARCHITECTURE.md options table: {p}")
        total_failed += len(problems)
        print(f"options table: {'OK' if not problems else 'DRIFTED'}")

    if total_failed:
        print(f"\ncheck_docs FAILED ({total_failed} problem(s))")
        return 1
    print(f"\ncheck_docs OK ({total_ran} command(s) executed)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
