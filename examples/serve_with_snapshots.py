"""Serving-state snapshot demo: checkpoint a half-finished batched
generation (params + KV cache + decode cursor) and resume it token-exact
in a fresh server — the sub-second-cold-start story from the paper's
production deployments (Modal memory snapshots, §6).

    PYTHONPATH=src python examples/serve_with_snapshots.py
"""
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import CheckpointOptions
from repro.configs import get_smoke_config
from repro.data import TokenPipeline
from repro.launch.mesh import make_host_mesh
from repro.models.encdec import build_model
from repro.runtime.server import DecodeServer
from repro.sharding import get_policy


def main():
    cfg = get_smoke_config("qwen1.5-0.5b")
    mesh = make_host_mesh(data=len(jax.devices()))
    policy = get_policy("baseline")
    run_dir = tempfile.mkdtemp(prefix="serve_")

    srv = DecodeServer(cfg, policy, mesh, run_dir, max_seq=64,
                       options=CheckpointOptions())
    model = build_model(cfg, policy, mesh, compute_dtype=jnp.float32,
                        remat=False)
    srv.load(model.init(jax.random.key(0)))

    batch = TokenPipeline(cfg, 4, 12, seed=7).next()
    srv.start(batch)
    print("prefilled batch of 4 prompts (12 tokens each)")

    srv.decode(5)
    print(f"decoded 5 tokens; pos={srv.pos}")
    srv.checkpoint(0)
    print("serving snapshot taken mid-generation")
    expected = srv.decode(6).copy()
    print(f"uninterrupted continuation: {expected[0, -6:].tolist()}")

    print("=== fresh server: restore + continue ===")
    srv2 = DecodeServer(cfg, policy, mesh, run_dir, max_seq=64)
    srv2.load(srv.params)
    srv2.start(batch)          # build structures, then roll back
    pos = srv2.restore()
    print(f"restored at pos {pos}")
    got = srv2.decode(6)
    print(f"restored continuation:      {got[0, -6:].tolist()}")
    np.testing.assert_array_equal(expected, got)
    print("token-exact resume: OK")


if __name__ == "__main__":
    main()
