"""Quickstart: transparent unified checkpointing around an ordinary JAX
training loop — the 60-second tour of the public API.

    PYTHONPATH=src python examples/quickstart.py

Shows: (1) the training code contains no checkpoint logic; (2) a unified
snapshot captures device state (params/optimizer) + host state (data
cursor, step counter) in one image; (3) restore is deterministic — the
resumed run produces bitwise-identical losses.
"""
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.api import CheckpointOptions
from repro.configs import get_smoke_config
from repro.launch.mesh import make_host_mesh
from repro.runtime.trainer import TrainConfig, Trainer
from repro.sharding import get_policy


def main():
    cfg = get_smoke_config("qwen1.5-0.5b")      # reduced Qwen1.5 family
    mesh = make_host_mesh(data=len(jax.devices()))
    policy = get_policy("baseline")
    tcfg = TrainConfig(batch_size=4, seq_len=32, total_steps=30,
                       ckpt_every=10,
                       ckpt=CheckpointOptions(mode="async"),
                       compute_dtype=jnp.float32, remat=False)
    run_dir = (sys.argv[1] if len(sys.argv) > 1
               else tempfile.mkdtemp(prefix="quickstart_"))

    print("=== phase 1: train 20 steps with periodic unified snapshots ===")
    t = Trainer(cfg, tcfg, mesh, policy, run_dir)
    report = t.session.check()                    # `criu check` preflight
    print(f"preflight: ok={report.ok} "
          f"(backend={t.session.backend_name}, "
          f"jax {report.capabilities['jax']['version']})")
    assert report.ok, report.summary()
    out = t.run(20)
    print(f"steps={out['steps']} loss={out['loss']:.4f}")
    print(f"snapshots: {t.session.store.list_steps()}")
    ref_losses = t.metrics_history["loss"][10:]   # steps 11..20

    print("=== phase 2: fresh process state, restore, replay 10 steps ===")
    t2 = Trainer(cfg, tcfg, mesh, policy, run_dir)
    step = t2.restore()                            # newest valid image (20)
    print(f"restored at step {step}")
    # rewind demo: restore the *older* snapshot and re-train 11..20
    t3 = Trainer(cfg, tcfg, mesh, policy, run_dir)
    t3.restore(step=10)
    t3.run(10)
    got_losses = t3.metrics_history["loss"][-10:]

    bitwise = all(a == b for a, b in zip(ref_losses, got_losses))
    print(f"deterministic restore: losses bitwise identical = {bitwise}")
    assert bitwise
    print(f"images live in {run_dir} — inspect them offline with:")
    print(f"  python -m repro inspect {run_dir}")
    print("OK")


if __name__ == "__main__":
    main()
