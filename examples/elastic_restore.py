"""Elastic restore demo: a unified snapshot taken on an 8-device (4×2)
mesh restored onto a 4-device (2×2) mesh — the scale-down-after-node-loss
path that GPU-side CRIUgpu cannot do (the paper requires identical GPU
count/order; §4.4).

    python examples/elastic_restore.py        # sets its own XLA flags
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import CheckpointSession
from repro.configs import get_smoke_config
from repro.launch.mesh import make_mesh, use_mesh
from repro.models.encdec import build_model
from repro.optim import AdamW
from repro.optim.schedule import constant
from repro.runtime.elastic import elastic_restore
from repro.sharding import get_policy


def mesh_of(shape):
    return make_mesh(shape, ("data", "model"))


def main():
    cfg = get_smoke_config("qwen1.5-0.5b", d_model=64, num_heads=4,
                           num_kv_heads=4, head_dim=16)
    policy = get_policy("baseline")
    opt = AdamW(lr=constant(1e-3))
    run_dir = tempfile.mkdtemp(prefix="elastic_")

    print(f"devices: {len(jax.devices())}")
    mesh_a = mesh_of((4, 2))
    model_a = build_model(cfg, policy, mesh_a, compute_dtype=jnp.float32,
                          remat=False)
    with use_mesh(mesh_a):
        params = jax.jit(model_a.init,
                         out_shardings=model_a.param_shardings())(
            jax.random.key(0))
    opt_state = opt.init(params)

    session = CheckpointSession(run_dir, mesh=mesh_a)
    session.attach(lambda: {"train_state": {"params": params,
                                            "opt": opt_state}})
    session.register_host_state("trainer", lambda: {"step": 100},
                                lambda st: None)
    session.register_host_state("data_cursor", lambda: {"step": 100},
                                lambda st: None)
    session.checkpoint(100)
    print("snapshot taken on mesh (4,2): 8 devices")

    print("=== node loss: restore onto mesh (2,2) — 4 devices ===")
    mesh_b = mesh_of((2, 2))
    model_b = build_model(cfg, policy, mesh_b, compute_dtype=jnp.float32,
                          remat=False)
    out = elastic_restore(run_dir, mesh_b, model_b, opt)
    print(f"topology mode: {out['topology_mode']}   step: {out['step']}")

    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(out["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    n_dev = {b.sharding.mesh.devices.size
             for b in jax.tree.leaves(out["params"])}
    print(f"restored values bitwise identical; now sharded over {n_dev} "
          f"devices")

    # the restored state trains on the new mesh
    from repro.data import TokenPipeline
    batch = {k: jnp.asarray(v)
             for k, v in TokenPipeline(cfg, 4, 16).next().items()}
    with use_mesh(mesh_b):
        loss = jax.jit(lambda p, b: model_b.loss(p, b)[0])(out["params"],
                                                           batch)
    print(f"first loss on the replacement mesh: {float(loss):.4f}")
    print("OK")


if __name__ == "__main__":
    main()
