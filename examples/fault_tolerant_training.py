"""Fault-tolerant training demo: the full 1000-node failure story in
miniature — periodic + just-in-time snapshots, injected crashes, automatic
restart from the newest valid image, straggler detection.

    PYTHONPATH=src python examples/fault_tolerant_training.py
"""
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.api import CheckpointOptions
from repro.configs import get_smoke_config
from repro.core.snapshot_io import SnapshotStore
from repro.launch.mesh import make_host_mesh
from repro.runtime.fault import FailureDetector, StragglerMonitor
from repro.runtime.trainer import TrainConfig, Trainer, run_with_restarts
from repro.sharding import get_policy


def main():
    cfg = get_smoke_config("qwen1.5-0.5b")
    mesh = make_host_mesh(data=len(jax.devices()))
    policy = get_policy("baseline")
    run_dir = tempfile.mkdtemp(prefix="ft_train_")
    tcfg = TrainConfig(batch_size=4, seq_len=32, total_steps=40,
                       ckpt_every=5,
                       ckpt=CheckpointOptions(mode="async",
                                              incremental=True),
                       compute_dtype=jnp.float32, remat=False)

    def make_trainer():
        t = Trainer(cfg, tcfg, mesh, policy, run_dir)
        t.straggler = StragglerMonitor(min_samples=6, threshold=3.0)
        return t

    print("=== training to step 40 with crashes injected at 12 and 27 ===")
    out = run_with_restarts(make_trainer, total_steps=40,
                            failures={12: "node-failure",
                                      27: "node-failure"})
    print(f"steps={out['steps']} restarts={out['restarts']}")
    print(f"loss: {out['loss_history'][0]:.3f} -> "
          f"{out['loss_history'][-1]:.3f}")
    steps = SnapshotStore(run_dir).list_steps()
    print(f"snapshots on disk: {steps}")

    t = out["trainer"]
    print("=== straggler injection -> just-in-time snapshot ===")
    t.tcfg.ckpt_every = 0                       # periodic off; JIT only
    t.run(10, straggle_at=t.step + 8)
    print(f"JIT snapshots triggered at: {t.jit_ckpt.triggered}")

    print("=== heartbeat failure detector ===")
    fd = FailureDetector(deadline_s=0.2)
    for w in ("pod0/worker0", "pod0/worker1", "pod1/worker0"):
        fd.register(w)
    import time
    fd.heartbeat("pod0/worker0")
    fd.heartbeat("pod0/worker1")
    time.sleep(0.25)
    fd.heartbeat("pod0/worker0")
    fd.heartbeat("pod0/worker1")
    print(f"dead workers: {fd.dead_workers()}  -> restart those from the "
          f"newest valid image")
    print("OK")


if __name__ == "__main__":
    main()
