"""Paper Table 5 / Fig. 7 — HPC micro-benchmark checkpoint sizes and the
frozen / memory-dump / memory-write breakdown.

JAX ports of the ROCm-examples workloads the paper checkpoints on MI210:
each benchmark builds its working set on device, runs one iteration, and a
unified snapshot is taken mid-computation.  Sizes mirror the paper's
contrast: most kernels have small state (<10 MiB here, <1.2 GB there);
histogram / matmul / convolution carry large buffers.
"""
from __future__ import annotations

import shutil
import tempfile
from typing import Callable, Dict

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Timer, emit, mesh1
from repro.api import CheckpointSession

# scale factor: the container is CPU-only; the paper's GB-scale buffers
# become MiB-scale with identical relative ordering.
SMALL = 1 << 14        # vector lengths
BIG = 1 << 22          # "large state" workloads


def binomial_option_pricing():
    """CRR binomial tree over a batch of options."""
    n_opts, steps = 512, 64
    key = jax.random.key(0)
    S0 = jax.random.uniform(key, (n_opts,), minval=50, maxval=150)
    K = jnp.full((n_opts,), 100.0)
    u, d, p, disc = 1.01, 1 / 1.01, 0.51, jnp.float32(np.exp(-0.0005))

    @jax.jit
    def price(S0, K):
        j = jnp.arange(steps + 1, dtype=jnp.float32)
        ST = S0[:, None] * u ** (steps - j)[None, :] * d ** j[None, :]
        v = jnp.maximum(ST - K[:, None], 0.0)

        def back(v, _):
            v = disc * (p * v[:, :-1] + (1 - p) * v[:, 1:])
            v = jnp.pad(v, ((0, 0), (0, 1)))
            return v, None
        v, _ = jax.lax.scan(back, v, None, length=steps)
        return v[:, 0]

    return {"prices": price(S0, K), "S0": S0, "K": K}


def bitonic_sort():
    key = jax.random.key(1)
    x = jax.random.uniform(key, (SMALL,))

    @jax.jit
    def sort(x):
        n = x.shape[0]
        k = 2
        while k <= n:
            j = k // 2
            while j >= 1:
                ix = jnp.arange(n)
                partner = ix ^ j
                up = (ix & k) == 0
                a, b = x, x[partner]
                keep_min = (ix < partner) == up
                x = jnp.where(keep_min, jnp.minimum(a, b),
                              jnp.maximum(a, b))
                j //= 2
            k *= 2
        return x

    return {"sorted": sort(x), "input": x}


def dct():
    """Blockwise 8x8 discrete cosine transform (the image-processing
    workload class)."""
    key = jax.random.key(2)
    img = jax.random.uniform(key, (512, 512))
    k = jnp.arange(8, dtype=jnp.float32)
    C = jnp.sqrt(2 / 8) * jnp.cos((2 * k[None, :] + 1) * k[:, None]
                                  * jnp.pi / 16)
    C = C.at[0].mul(1 / jnp.sqrt(2.0))

    @jax.jit
    def apply(img):
        b = img.reshape(64, 8, 64, 8).transpose(0, 2, 1, 3)
        out = jnp.einsum("ij,bcjk,lk->bcil", C, b, C)
        return out.transpose(0, 2, 1, 3).reshape(512, 512)

    return {"coeffs": apply(img), "image": img}


def haar_wavelet():
    key = jax.random.key(3)
    x = jax.random.uniform(key, (SMALL,))

    @jax.jit
    def haar(x):
        levels = []
        cur = x
        for _ in range(4):
            a = (cur[0::2] + cur[1::2]) / jnp.sqrt(2.0)
            dcoef = (cur[0::2] - cur[1::2]) / jnp.sqrt(2.0)
            levels.append(dcoef)
            cur = a
        return cur, levels

    approx, details = haar(x)
    return {"approx": approx, "details": details, "input": x}


def fast_walsh():
    key = jax.random.key(4)
    x = jax.random.uniform(key, (SMALL,))

    @jax.jit
    def fwht(x):
        n = x.shape[0]
        h = 1
        while h < n:
            y = x.reshape(-1, 2 * h)
            a, b = y[:, :h], y[:, h:]
            x = jnp.concatenate([a + b, a - b], axis=1).reshape(n)
            h *= 2
        return x

    return {"transform": fwht(x), "input": x}


def floyd_warshall():
    n = 256
    key = jax.random.key(5)
    d0 = jax.random.uniform(key, (n, n), minval=1.0, maxval=10.0)
    d0 = jnp.where(jnp.eye(n, dtype=bool), 0.0, d0)

    @jax.jit
    def fw(d):
        def body(d, k):
            d = jnp.minimum(d, d[:, k][:, None] + d[k, :][None, :])
            return d, None
        d, _ = jax.lax.scan(body, d, jnp.arange(n))
        return d

    return {"dist": fw(d0), "graph": d0}


def prefix_sum():
    key = jax.random.key(6)
    x = jax.random.uniform(key, (SMALL,))
    return {"scan": jax.jit(jnp.cumsum)(x), "input": x}


def recursive_gaussian():
    key = jax.random.key(7)
    img = jax.random.uniform(key, (512, 512))

    @jax.jit
    def blur(img):
        a = 0.25
        def pass_(carry, row):
            y = a * row + (1 - a) * carry
            return y, y
        _, out = jax.lax.scan(pass_, img[0], img)
        return out

    return {"blurred": blur(img), "image": img}


def histogram():
    """Large state: big input + bins (paper: 16.6 GB)."""
    key = jax.random.key(8)
    x = jax.random.randint(key, (BIG,), 0, 256, dtype=jnp.int32)
    h = jax.jit(lambda x: jnp.bincount(x, length=256))(x)
    return {"hist": h, "data": x}


def matmul():
    """Large state: operand matrices (paper: 19.9 GB)."""
    key = jax.random.key(9)
    a = jax.random.normal(key, (1536, 1536))
    b = jax.random.normal(jax.random.fold_in(key, 1), (1536, 1536))
    c = jax.jit(jnp.matmul)(a, b)
    return {"a": a, "b": b, "c": c}


def convolution():
    """Large state: input + output feature maps (paper: 13.8 GB)."""
    key = jax.random.key(10)
    x = jax.random.normal(key, (8, 256, 256, 8))
    w = jax.random.normal(jax.random.fold_in(key, 1), (3, 3, 8, 8))
    y = jax.jit(lambda x, w: jax.lax.conv_general_dilated(
        x, w, (1, 1), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC")))(x, w)
    return {"x": x, "w": w, "y": y}


BENCHES: Dict[str, Callable] = {
    "binomial_option_pricing": binomial_option_pricing,
    "bitonic_sort": bitonic_sort,
    "dct": dct,
    "haar_wavelet": haar_wavelet,
    "fast_walsh": fast_walsh,
    "floyd_warshall": floyd_warshall,
    "prefix_sum": prefix_sum,
    "recursive_gaussian": recursive_gaussian,
    "histogram": histogram,
    "matmul": matmul,
    "convolution": convolution,
}


def run() -> None:
    mesh = mesh1()
    for name, fn in BENCHES.items():
        state = fn()
        jax.block_until_ready(state)
        run_dir = tempfile.mkdtemp(prefix=f"hpc_{name}_")
        try:
            eng = CheckpointSession(run_dir, mesh=mesh)
            eng.attach(lambda: {"hpc_state": state})
            with Timer() as t:
                eng.checkpoint(1)
            st = eng.last_stats
            emit(f"table5.{name}.size", st["written_bytes"] / 2**20, "MiB")
            emit(f"fig7.{name}.frozen", st["frozen_s"] * 1e3, "ms")
            emit(f"fig7.{name}.mem_dump",
                 st["device_to_host_s"] * 1e3, "ms")
            emit(f"fig7.{name}.mem_write", st["write_s"] * 1e3, "ms")

            eng2 = CheckpointSession(run_dir, mesh=mesh)
            eng2.attach(lambda: {"hpc_state": None})
            with Timer() as tr:
                restored = eng2.restore()
            # restore correctness per workload
            for k, v in state.items():
                got = restored["hpc_state"][k]
                if isinstance(v, list):
                    continue
                np.testing.assert_array_equal(np.asarray(got),
                                              np.asarray(v))
            emit(f"fig7.{name}.restore", tr.s * 1e3, "ms")
        finally:
            shutil.rmtree(run_dir, ignore_errors=True)


if __name__ == "__main__":
    run()
