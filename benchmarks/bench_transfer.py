"""Cross-host checkpoint transfer: cold CAS vs warm CAS vs full copy.

The paper's multi-tenant recovery story needs checkpoint images to reach
the host a preempted job restarts on; what this bench measures is the
content-addressed delta path against the copy-everything baseline on the
*incremental-chain workload* (one full image + K delta children, a fixed
fraction of entries mutated per step — the shape Check-N-Run-style
training checkpoints actually have):

  full   DirReplicator: whole files, the pre-CAS data path
  cold   DeltaReplicator into an empty CAS (first contact with the host)
  warm   DeltaReplicator into a CAS that already holds the chain up to
         step K-1 (the job was migrated or replicated there before) —
         only the newest delta's chunks move

plus the end-to-end recovery wall (transfer + restore on the target),
the number the orchestrator's RecoveryLog attributes to the transfer and
restore phases of a migration incident.

Byte counts are deterministic given ``--seed`` (the regression gate in CI
holds them to a tight tolerance); wall-clock is indicative on shared
runners (loose tolerance).

Usage::

    python -m benchmarks.bench_transfer --json BENCH_transfer.json
"""
from __future__ import annotations

import argparse
import json
import shutil
import tempfile
import time

import numpy as np

RECORDS: dict = {}


def _emit(name, value, unit=""):
    from benchmarks.common import emit
    emit(name, value, unit)
    RECORDS[name] = value


def build_chain(run_dir: str, steps: int, entries: int, entry_kb: int,
                mutate: float, seed: int):
    """One full image + (steps-1) incremental children; `mutate` of the
    entries get fresh content each step."""
    from repro.api import CheckpointOptions, CheckpointSession
    rng = np.random.default_rng(seed)
    state = {f"t{i:03d}": rng.integers(0, 8, size=entry_kb * 256)
             .astype(np.float32) for i in range(entries)}
    opts = CheckpointOptions(mode="sync", incremental=True, pack_format=2)
    session = CheckpointSession(run_dir, opts, backend="host")
    session.attach(lambda: {"train_state": state})
    n_mut = max(1, int(entries * mutate))
    names = sorted(state)
    for step in range(1, steps + 1):
        if step > 1:
            # rotate the mutation window so every chain step stays
            # referenced by the final image (a genuine delta chain, not
            # one hot entry set) — the closure then spans the whole chain
            start = ((step - 2) * n_mut) % entries
            for i in range(start, start + n_mut):
                k = names[i % entries]
                state[k] = rng.integers(0, 8, size=entry_kb * 256) \
                    .astype(np.float32)
        session.checkpoint(step)
    return session


def _restore_wall(run_dir: str) -> float:
    from repro.core.engine import SnapshotEngine
    eng = SnapshotEngine(run_dir, backend="host")
    eng.attach(lambda: {"train_state": None})
    t0 = time.perf_counter()
    eng.restore()
    return time.perf_counter() - t0


def run(steps: int = 6, entries: int = 16, entry_kb: int = 128,
        mutate: float = 0.25, seed: int = 0, repeats: int = 3,
        precopy: bool = False) -> None:
    from repro.core.replication import DirReplicator
    from repro.transfer import DeltaReplicator
    from repro.transfer.delta import transfer_closure

    for k, v in [("steps", steps), ("entries", entries),
                 ("entry_kb", entry_kb), ("mutate", mutate)]:
        _emit(f"transfer.workload.{k}", v)

    src = tempfile.mkdtemp(prefix="bench_xfer_src_")
    scratch = []
    try:
        session = build_chain(src, steps, entries, entry_kb, mutate, seed)
        final = session.latest_step()
        closure = transfer_closure(session.store, final)
        _emit("transfer.workload.closure_steps", len(closure))

        def best_of(fn):
            """min wall over `repeats` runs into fresh targets (shared
            boxes: the fastest run is the least contaminated), plus the
            last run's stats/target (byte counts are deterministic)."""
            walls, stats, target = [], None, None
            for _ in range(max(repeats, 1)):
                target = tempfile.mkdtemp(prefix="bench_xfer_dst_")
                scratch.append(target)
                wall, stats = fn(target)
                walls.append(wall)
            return min(walls), stats, target

        # ---- full copy (DirReplicator over the closure)
        def full_copy(target):
            rep = DirReplicator(target)
            t0 = time.perf_counter()
            nbytes = 0
            for s in closure:
                nbytes += rep.push(src, s)["bytes_copied"]
            return time.perf_counter() - t0, {"bytes": nbytes}

        full_wall, st, target = best_of(full_copy)
        full_bytes = st["bytes"]
        _emit("transfer.full.bytes", full_bytes, "B")
        _emit("transfer.full.wall_s", full_wall, "s")
        _emit("transfer.recovery.full_s",
              full_wall + _restore_wall(target), "s")

        # ---- cold CAS (first delta contact: everything ships, but the
        # CAS already dedups identical content across the chain)
        def cold(target):
            st = DeltaReplicator(target).push(src, final)
            return st["push_s"], st

        wall, st, target = best_of(cold)
        _emit("transfer.cold.bytes", st["bytes_sent"], "B")
        _emit("transfer.cold.dedup_bytes", st["bytes_reused"], "B")
        _emit("transfer.cold.wall_s", wall, "s")
        _emit("transfer.recovery.cold_s",
              wall + _restore_wall(target), "s")

        # ---- warm CAS (chain minus the newest delta already present:
        # the steady state of repeated migration/replication)
        def warm(target):
            rep = DeltaReplicator(target)
            rep.push(src, closure[-2] if len(closure) > 1 else final)
            st = rep.push(src, final)
            return st["push_s"], st

        wall, st, target = best_of(warm)
        _emit("transfer.warm.bytes", st["bytes_sent"], "B")
        _emit("transfer.warm.dedup_bytes", st["bytes_reused"], "B")
        _emit("transfer.warm.wall_s", wall, "s")
        _emit("transfer.recovery.warm_s",
              wall + _restore_wall(target), "s")

        # ---- the headline ratio the acceptance criteria gate on
        _emit("transfer.warm_vs_full.byte_ratio",
              st["bytes_sent"] / max(full_bytes, 1))
        _emit("transfer.cold_vs_full.byte_ratio",
              RECORDS["transfer.cold.bytes"] / max(full_bytes, 1))

        if precopy:
            _run_precopy(src, session, final, closure, best_of, seed)
    finally:
        shutil.rmtree(src, ignore_errors=True)
        for d in scratch:
            shutil.rmtree(d, ignore_errors=True)


def _run_precopy(src, session, final, closure, best_of, seed) -> None:
    """Pre-copy migration blackout vs stop-and-copy blackout.

    Stop-and-copy freezes the job for the entire cold push (the whole
    chain ships inside the blackout).  Pre-copy ships the chain's history
    as live rounds while the job keeps stepping — the blackout is only
    the frozen residual round, which carries the final delta.  Byte
    counts are deterministic given ``--seed``; the wall ratio
    ``transfer.precopy.blackout_vs_stopcopy`` is the CI-gated headline
    (residual push is O(delta), stop-and-copy is O(image)).
    """
    from repro.core.engine import SnapshotEngine
    from repro.transfer import DeltaReplicator, summarize_rounds

    # stop-and-copy blackout: one frozen cold push of the whole closure
    def stopcopy(target):
        st = DeltaReplicator(target).push(src, final)
        return st["push_s"], st

    sc_wall, sc_st, _t = best_of(stopcopy)
    sc_bytes = sc_st["bytes_sent"]
    _emit("transfer.stopcopy.blackout_s", sc_wall, "s")

    # pre-copy: the chain prefix ships as live rounds (the job would
    # still be stepping); only the residual round is frozen
    def precopy_run(target):
        rep = DeltaReplicator(target)
        tag = f"bench-{seed}"
        for s in closure[:-1]:
            rep.push_round(src, s, tag)
        resid = rep.push_round(src, final, tag, residual=True)
        summary = summarize_rounds(rep.round_state(tag))
        return resid["wall_s"], summary

    pc_wall, summary, target = best_of(precopy_run)

    # correctness, in-bench: the destination image is bit-exact and the
    # job resumes at the migrated step (zero replay)
    assert SnapshotEngine(target, backend="host").latest_step() == final, \
        "pre-copy destination lost the migrated step"
    eng_src = SnapshotEngine(src, backend="host")
    eng_dst = SnapshotEngine(target, backend="host")
    eng_src.attach(lambda: {"train_state": None})
    eng_dst.attach(lambda: {"train_state": None})
    a = eng_src.restore(step=final)["train_state"]
    b = eng_dst.restore(step=final)["train_state"]
    assert sorted(a) == sorted(b), "pre-copy destination entry set differs"
    for k in a:
        assert np.array_equal(a[k], b[k]), \
            f"pre-copy destination not bit-exact at entry {k!r}"

    _emit("transfer.precopy.rounds", summary["rounds_completed"])
    _emit("transfer.precopy.round_bytes_total",
          summary["precopy_bytes"], "B")
    _emit("transfer.precopy.residual_bytes",
          summary["residual_bytes"], "B")
    _emit("transfer.precopy.residual_bytes_ratio",
          summary["residual_bytes"] / max(sc_bytes, 1))
    _emit("transfer.precopy.blackout_s", pc_wall, "s")
    _emit("transfer.precopy.blackout_vs_stopcopy",
          pc_wall / max(sc_wall, 1e-9))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--steps", type=int, default=6,
                    help="chain length (1 full + N-1 deltas)")
    ap.add_argument("--entries", type=int, default=16)
    ap.add_argument("--entry-kb", type=int, default=128)
    ap.add_argument("--mutate", type=float, default=0.25,
                    help="fraction of entries rewritten per step")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--repeats", type=int, default=3,
                    help="timing repeats per mode (min wins)")
    ap.add_argument("--precopy", action="store_true",
                    help="also measure pre-copy migration blackout vs "
                         "stop-and-copy (transfer.precopy.* rows)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="dump all records as JSON (BENCH_transfer.json)")
    args = ap.parse_args(argv)
    run(steps=args.steps, entries=args.entries, entry_kb=args.entry_kb,
        mutate=args.mutate, seed=args.seed, repeats=args.repeats,
        precopy=args.precopy)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(RECORDS, f, indent=2)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
