"""Observability overhead: the plane's cost on the dump hot path.

Two gated headline metrics (compare_bench treats both as absolute
ceilings, like ``lazy.ttfs_vs_eager``):

  obs.trace_overhead_ratio           dump wall with the plane installed
                                     (tracing on, detail off — what
                                     ``repro orchestrate`` enables) over
                                     the same dump with no plane.
                                     Ceiling 1.03: tracing may cost at
                                     most 3%.
  obs.trace_overhead_ratio_disabled  modeled cost of the *disabled*
                                     plane — every span()/counter_add()
                                     call compiled down to a global load
                                     + ``None`` check — over a
                                     hypothetical uninstrumented build.
                                     Ceiling 1.005 (0.5%).

The disabled ratio is modeled, not measured wall-vs-wall, for a reason:
the uninstrumented build does not exist (the guards are compiled in),
and a sub-0.5% wall delta on a shared CI runner is indistinguishable
from scheduler noise.  Instead the bench measures the per-call cost of
each disabled primitive directly (tight loop, min over batches — fully
deterministic on a given machine), counts how many such call sites one
dump actually crosses (from the journal of an instrumented detail run),
and divides the product by the uninstrumented dump wall.  Every input to
the model is emitted alongside the ratio so a regression is attributable
to either "guards got slower" or "a hot loop grew guard sites".

Wall-clock measurements alternate off/on within each repeat; the gated
enabled ratio is the min of the *paired* per-repeat ratios (on/off
measured back-to-back), so a slow patch on a shared runner inflates both
sides of one pair instead of poisoning the ratio (same
least-contaminated-run rationale as ``bench_ckpt_restore._measure``).
"""
from __future__ import annotations

import json
import shutil
import tempfile
import time

RECORDS: dict = {}


def _emit(name, value, unit=""):
    from benchmarks.common import emit
    emit(name, value, unit)
    RECORDS[name] = value


def _time_dump(opts, state, run_dir) -> float:
    """Seconds for one checkpoint of `state` into a fresh session."""
    from repro.api import CheckpointSession

    s = CheckpointSession(run_dir, opts, backend="host")
    s.attach(lambda: {"train_state": state})
    t0 = time.perf_counter()
    s.checkpoint(1)
    return time.perf_counter() - t0


def _percall_ns(fn, calls: int = 50_000, batches: int = 5) -> float:
    """Min-over-batches per-call cost of `fn` in nanoseconds."""
    best = float("inf")
    for _ in range(batches):
        t0 = time.perf_counter()
        for _ in range(calls):
            fn()
        best = min(best, time.perf_counter() - t0)
    return best / calls * 1e9


def run_overhead(n_entries: int = 48, entry_kb: int = 256,
                 repeats: int = 5) -> dict:
    """Measure the enabled ratio, model the disabled ratio."""
    from repro.api import CheckpointOptions
    from repro.obs import journal as obs_journal
    from repro.obs import metrics as obs_metrics
    from repro.obs import trace as obs_trace
    from repro.obs.plane import observed
    from benchmarks.bench_ckpt_restore import _synthetic_state

    state = _synthetic_state(n_entries, entry_kb, seed=7)
    total_mb = sum(v.nbytes for v in state.values()) / 2**20
    _emit("obs.workload.entries", n_entries, "count")
    _emit("obs.workload.bytes", total_mb, "MiB")

    opts = CheckpointOptions(compress=True, pack_format=2)

    # -------- wall clock, plane off vs on (alternating within each rep)
    off_walls, on_walls, detail_walls = [], [], []
    span_events = other_events = detail_chunk_events = 0
    for rep in range(repeats):
        d_off = tempfile.mkdtemp(prefix="bench_obs_off_")
        d_on = tempfile.mkdtemp(prefix="bench_obs_on_")
        d_det = tempfile.mkdtemp(prefix="bench_obs_det_")
        try:
            off_walls.append(_time_dump(opts, state, d_off))
            with observed(d_on):
                on_walls.append(_time_dump(opts, state, d_on))
            with observed(d_det, detail=True):
                detail_walls.append(_time_dump(opts, state, d_det))
            if rep == 0:
                # call-site census for the disabled-cost model, from the
                # detail journal: what one dump actually crosses
                for ev in obs_journal.read_events(d_det):
                    if ev.get("kind") != "span":
                        other_events += 1
                    elif ev.get("name") in ("pack.compress", "pack.append"):
                        detail_chunk_events += 1
                    else:
                        span_events += 1
        finally:
            shutil.rmtree(d_off, ignore_errors=True)
            shutil.rmtree(d_on, ignore_errors=True)
            shutil.rmtree(d_det, ignore_errors=True)
    if span_events < 3:
        raise AssertionError(
            f"instrumented dump journaled only {span_events} spans — the "
            f"plane is not observing the dump path; ratio would be bogus")

    off_wall = min(off_walls)
    _emit("obs.dump_off_wall_ms", off_wall * 1e3, "ms")
    _emit("obs.dump_on_wall_ms", min(on_walls) * 1e3, "ms")
    _emit("obs.dump_detail_wall_ms", min(detail_walls) * 1e3, "ms")
    enabled_ratio = min(on / off for on, off in zip(on_walls, off_walls))
    _emit("obs.trace_overhead_ratio", enabled_ratio, "x")

    # -------- disabled-path model: per-call guard costs x sites per dump
    assert obs_trace.TRACER is None and obs_metrics.REGISTRY is None

    def disabled_span():
        with obs_trace.span("dump.capture", step=1):
            pass

    def disabled_counter():
        obs_metrics.counter_add("bench.obs.probe")

    def disabled_guard():
        tr = obs_trace.TRACER
        if tr is not None and tr.detail:       # pragma: no cover
            pass

    span_ns = _percall_ns(disabled_span)
    counter_ns = _percall_ns(disabled_counter)
    guard_ns = _percall_ns(disabled_guard)
    _emit("obs.model.disabled_span_ns", span_ns, "ns")
    _emit("obs.model.disabled_counter_ns", counter_ns, "ns")
    _emit("obs.model.disabled_guard_ns", guard_ns, "ns")

    # sites per dump: non-detail spans still *call* span() when disabled;
    # per-chunk detail sites reduce to the bare guard; counters/journal
    # emits are one disabled call each (journal emit cost ~ counter cost)
    span_sites = span_events
    guard_sites = detail_chunk_events
    counter_sites = detail_chunk_events + other_events + 8
    _emit("obs.model.span_sites", span_sites, "count")
    _emit("obs.model.guard_sites", guard_sites, "count")
    _emit("obs.model.counter_sites", counter_sites, "count")

    modeled_s = (span_sites * span_ns
                 + guard_sites * guard_ns
                 + counter_sites * counter_ns) * 1e-9
    disabled_ratio = 1.0 + modeled_s / off_wall
    _emit("obs.model.disabled_cost_us", modeled_s * 1e6, "us")
    _emit("obs.trace_overhead_ratio_disabled", disabled_ratio, "x")
    return {"trace_overhead_ratio": enabled_ratio,
            "trace_overhead_ratio_disabled": disabled_ratio}


def main(argv=None) -> int:
    import argparse
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--entries", type=int, default=48)
    ap.add_argument("--entry-kb", type=int, default=256)
    ap.add_argument("--repeats", type=int, default=5)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="dump all records as JSON (BENCH_obs.json)")
    args = ap.parse_args(argv)

    run_overhead(args.entries, args.entry_kb, args.repeats)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(RECORDS, f, indent=1, sort_keys=True)
        print(f"# wrote {args.json}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
