"""Generate the EXPERIMENTS.md §Dry-run / §Roofline markdown tables from
the dry-run artifacts.  Usage: PYTHONPATH=src:. python -m benchmarks.make_tables
"""
from __future__ import annotations

import glob
import json
import os

ART = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                   "artifacts", "dryrun")


def load_all():
    recs = []
    for f in sorted(glob.glob(os.path.join(ART, "*.json"))):
        recs.append(json.load(open(f)))
    return recs


def fmt(v, digits=3):
    if v == 0:
        return "0"
    if abs(v) >= 1000 or abs(v) < 0.001:
        return f"{v:.2e}"
    return f"{v:.{digits}g}"


def roofline_table(recs, mesh="pod", policy="baseline", variant="base"):
    rows = [r for r in recs if r["mesh"] == mesh and r["policy"] == policy
            and r.get("variant", "base") == variant]
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    out = ["| arch | shape | t_comp (s) | t_mem (s) | t_mem^flash | "
           "t_coll (s) | dominant | MODEL/HLO | frac | frac^flash |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {fmt(r['t_compute_s'])} | "
            f"{fmt(r['t_memory_s'])} | {fmt(r['t_memory_flash_s'])} | "
            f"{fmt(r['t_collective_s'])} | {r['dominant']} | "
            f"{fmt(r['useful_flops_ratio'])} | "
            f"{fmt(r.get('roofline_fraction', 0), 3)} | "
            f"{fmt(r.get('roofline_fraction_flash', 0), 3)} |")
    return "\n".join(out)


def memory_table(recs, mesh="pod"):
    rows = [r for r in recs if r["mesh"] == mesh
            and r["policy"] == "baseline"
            and r.get("variant", "base") == "base"]
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    out = ["| arch | shape | args (GiB/dev) | temp (GiB/dev) | "
           "compile (s) |", "|---|---|---|---|---|"]
    for r in rows:
        m = r.get("memory", {})
        out.append(
            f"| {r['arch']} | {r['shape']} | "
            f"{m.get('argument_size_in_bytes', 0)/2**30:.2f} | "
            f"{m.get('temp_size_in_bytes', 0)/2**30:.2f} | "
            f"{r.get('compile_s', 0):.1f} |")
    return "\n".join(out)


def perf_rows(recs):
    """All non-baseline runs (hillclimb iterations)."""
    rows = [r for r in recs if r["policy"] != "baseline"
            or r.get("variant", "base") != "base"]
    rows.sort(key=lambda r: (r["arch"], r["shape"], r["policy"],
                             r.get("variant", "")))
    out = ["| arch | shape | policy | variant | t_comp | t_mem | "
           "t_mem^fl | t_coll | frac | frac^fl |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['policy']} | "
            f"{r.get('variant','base')} | {fmt(r['t_compute_s'])} | "
            f"{fmt(r['t_memory_s'])} | {fmt(r['t_memory_flash_s'])} | "
            f"{fmt(r['t_collective_s'])} | "
            f"{fmt(r.get('roofline_fraction', 0))} | "
            f"{fmt(r.get('roofline_fraction_flash', 0))} |")
    return "\n".join(out)


def load_bench(patterns=("BENCH_*.json", "artifacts/bench/*.json")):
    """Perf-trajectory artifacts written by bench_ckpt_restore --json."""
    recs = []
    for pat in patterns:
        for f in sorted(glob.glob(pat)):
            recs.append((os.path.basename(f), json.load(open(f))))
    return recs


def dataplane_table(recs):
    """Serial-compat vs pipelined + stripes × io_threads sweep (from
    BENCH_*.json)."""
    out = []
    for name, r in recs:
        if "dataplane.speedup.write" in r:
            out.append(f"### {name}: serial-compat vs pipelined\n")
            out.append("| mode | write (ms) | restore (ms) | frozen (ms) |")
            out.append("|---|---|---|---|")
            for mode in ("serial", "pipelined"):
                out.append(
                    f"| {mode} | {fmt(r[f'dataplane.{mode}.write_s'])} | "
                    f"{fmt(r[f'dataplane.{mode}.restore_s'])} | "
                    f"{fmt(r[f'dataplane.{mode}.frozen_s'])} |")
            out.append(
                f"\nspeedup: write "
                f"{fmt(r['dataplane.speedup.write'])}x, restore "
                f"{fmt(r['dataplane.speedup.restore'])}x\n")
        sweep = r.get("sweep")
        if sweep:
            out.append(f"### {name}: stripes × io_threads sweep\n")
            out.append("| stripes | io_threads | write (ms) | restore (ms) |")
            out.append("|---|---|---|---|")
            for row in sweep:
                out.append(
                    f"| {row['stripes']} | {row['io_threads']} | "
                    f"{fmt(row['write_s'] * 1e3)} | "
                    f"{fmt(row['restore_s'] * 1e3)} |")
            out.append("")
    return "\n".join(out) if out else "(no BENCH_*.json artifacts found)"


def fmt_bytes(n):
    for unit in ("B", "KiB", "MiB", "GiB"):
        if abs(n) < 1024 or unit == "GiB":
            return f"{n:.0f}{unit}" if unit == "B" else f"{n:.2f}{unit}"
        n /= 1024


def transfer_table(recs):
    """Cross-host transfer & recovery-time table (from
    BENCH_transfer.json): bytes moved + recovery wall per transfer mode
    on the incremental-chain workload."""
    out = []
    for name, r in recs:
        if "transfer.full.bytes" not in r:
            continue
        out.append(f"### {name}: checkpoint transfer & migration "
                   f"(incremental chain, "
                   f"{r.get('transfer.workload.steps', '?')} steps, "
                   f"{r.get('transfer.workload.mutate', '?')} mutated "
                   f"per step)\n")
        out.append("| transfer | bytes moved | deduped | transfer wall "
                   "(ms) | recovery incl. restore (ms) |")
        out.append("|---|---|---|---|---|")
        for mode, label in (("full", "full copy"),
                            ("cold", "delta, cold CAS"),
                            ("warm", "delta, warm CAS")):
            out.append(
                f"| {label} | {fmt_bytes(r[f'transfer.{mode}.bytes'])} | "
                f"{fmt_bytes(r.get(f'transfer.{mode}.dedup_bytes', 0))} | "
                f"{fmt(r[f'transfer.{mode}.wall_s'] * 1e3)} | "
                f"{fmt(r[f'transfer.recovery.{mode}_s'] * 1e3)} |")
        ratio = r.get("transfer.warm_vs_full.byte_ratio")
        if ratio is not None:
            out.append(f"\nwarm-CAS delta moves {ratio:.1%} of the bytes "
                       f"of a full copy\n")
    return "\n".join(out) if out else "(no BENCH_transfer.json artifacts)"


def main():
    recs = load_all()
    print("## single-pod baseline roofline\n")
    print(roofline_table(recs, "pod"))
    print("\n## multi-pod baseline roofline\n")
    print(roofline_table(recs, "multipod"))
    print("\n## memory analysis (single-pod baseline)\n")
    print(memory_table(recs))
    print("\n## hillclimb iterations\n")
    print(perf_rows(recs))
    bench = load_bench()
    print("\n## snapshot data plane (serial vs pipelined)\n")
    print(dataplane_table(bench))
    print("\n## checkpoint transfer & migration\n")
    print(transfer_table(bench))


if __name__ == "__main__":
    main()
