"""Generate the EXPERIMENTS.md §Dry-run / §Roofline markdown tables from
the dry-run artifacts.  Usage: PYTHONPATH=src:. python -m benchmarks.make_tables

``--update-readme`` additionally renders the time-to-first-step table
(from ``BENCH_restore_lazy.json``, falling back to the committed
baseline) into README.md between the ``lazy-restore-table`` markers.
"""
from __future__ import annotations

import glob
import json
import os
import re

README = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "README.md")
LAZY_BEGIN = "<!-- lazy-restore-table:begin -->"
LAZY_END = "<!-- lazy-restore-table:end -->"
CHAOS_BEGIN = "<!-- chaos-table:begin -->"
CHAOS_END = "<!-- chaos-table:end -->"
OBS_BEGIN = "<!-- obs-table:begin -->"
OBS_END = "<!-- obs-table:end -->"

ART = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                   "artifacts", "dryrun")


def load_all():
    recs = []
    for f in sorted(glob.glob(os.path.join(ART, "*.json"))):
        recs.append(json.load(open(f)))
    return recs


def fmt(v, digits=3):
    if v == 0:
        return "0"
    if abs(v) >= 1000 or abs(v) < 0.001:
        return f"{v:.2e}"
    return f"{v:.{digits}g}"


def roofline_table(recs, mesh="pod", policy="baseline", variant="base"):
    rows = [r for r in recs if r["mesh"] == mesh and r["policy"] == policy
            and r.get("variant", "base") == variant]
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    out = ["| arch | shape | t_comp (s) | t_mem (s) | t_mem^flash | "
           "t_coll (s) | dominant | MODEL/HLO | frac | frac^flash |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {fmt(r['t_compute_s'])} | "
            f"{fmt(r['t_memory_s'])} | {fmt(r['t_memory_flash_s'])} | "
            f"{fmt(r['t_collective_s'])} | {r['dominant']} | "
            f"{fmt(r['useful_flops_ratio'])} | "
            f"{fmt(r.get('roofline_fraction', 0), 3)} | "
            f"{fmt(r.get('roofline_fraction_flash', 0), 3)} |")
    return "\n".join(out)


def memory_table(recs, mesh="pod"):
    rows = [r for r in recs if r["mesh"] == mesh
            and r["policy"] == "baseline"
            and r.get("variant", "base") == "base"]
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    out = ["| arch | shape | args (GiB/dev) | temp (GiB/dev) | "
           "compile (s) |", "|---|---|---|---|---|"]
    for r in rows:
        m = r.get("memory", {})
        out.append(
            f"| {r['arch']} | {r['shape']} | "
            f"{m.get('argument_size_in_bytes', 0)/2**30:.2f} | "
            f"{m.get('temp_size_in_bytes', 0)/2**30:.2f} | "
            f"{r.get('compile_s', 0):.1f} |")
    return "\n".join(out)


def perf_rows(recs):
    """All non-baseline runs (hillclimb iterations)."""
    rows = [r for r in recs if r["policy"] != "baseline"
            or r.get("variant", "base") != "base"]
    rows.sort(key=lambda r: (r["arch"], r["shape"], r["policy"],
                             r.get("variant", "")))
    out = ["| arch | shape | policy | variant | t_comp | t_mem | "
           "t_mem^fl | t_coll | frac | frac^fl |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['policy']} | "
            f"{r.get('variant','base')} | {fmt(r['t_compute_s'])} | "
            f"{fmt(r['t_memory_s'])} | {fmt(r['t_memory_flash_s'])} | "
            f"{fmt(r['t_collective_s'])} | "
            f"{fmt(r.get('roofline_fraction', 0))} | "
            f"{fmt(r.get('roofline_fraction_flash', 0))} |")
    return "\n".join(out)


def load_bench(patterns=("BENCH_*.json", "artifacts/bench/*.json")):
    """Perf-trajectory artifacts written by bench_ckpt_restore --json;
    falls back to the committed baselines so tables can render from a
    clean checkout."""
    recs = []
    for pat in patterns:
        for f in sorted(glob.glob(pat)):
            recs.append((os.path.basename(f), json.load(open(f))))
    if not recs:
        base = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                            "baselines", "*.json")
        for f in sorted(glob.glob(base)):
            recs.append((os.path.basename(f), json.load(open(f))))
    return recs


def dataplane_table(recs):
    """Serial-compat vs pipelined + stripes × io_threads sweep (from
    BENCH_*.json)."""
    out = []
    for name, r in recs:
        if "dataplane.speedup.write" in r:
            out.append(f"### {name}: serial-compat vs pipelined\n")
            out.append("| mode | write (ms) | restore (ms) | frozen (ms) |")
            out.append("|---|---|---|---|")
            for mode in ("serial", "pipelined"):
                out.append(
                    f"| {mode} | {fmt(r[f'dataplane.{mode}.write_s'])} | "
                    f"{fmt(r[f'dataplane.{mode}.restore_s'])} | "
                    f"{fmt(r[f'dataplane.{mode}.frozen_s'])} |")
            out.append(
                f"\nspeedup: write "
                f"{fmt(r['dataplane.speedup.write'])}x, restore "
                f"{fmt(r['dataplane.speedup.restore'])}x\n")
        sweep = r.get("sweep")
        if sweep:
            out.append(f"### {name}: stripes × io_threads sweep\n")
            out.append("| stripes | io_threads | write (ms) | restore (ms) |")
            out.append("|---|---|---|---|")
            for row in sweep:
                out.append(
                    f"| {row['stripes']} | {row['io_threads']} | "
                    f"{fmt(row['write_s'] * 1e3)} | "
                    f"{fmt(row['restore_s'] * 1e3)} |")
            out.append("")
    return "\n".join(out) if out else "(no BENCH_*.json artifacts found)"


def lazy_table(recs):
    """Time-to-first-step table (from BENCH_restore_lazy.json): lazy
    (resume-before-read) vs eager full materialization."""
    out = []
    for name, r in recs:
        if "lazy.ttfs_vs_eager" not in r:
            continue
        out.append(f"| restore | resumable at (ms) | first step at (ms) "
                   f"| full image at (ms) |")
        out.append("|---|---|---|---|")
        out.append(f"| eager | {fmt(r['lazy.eager_wall_s'])} | "
                   f"{fmt(r['lazy.eager_ttfs_s'])} | "
                   f"{fmt(r['lazy.eager_wall_s'])} |")
        out.append(f"| lazy | {fmt(r['lazy.lazy_resume_s'])} | "
                   f"{fmt(r['lazy.lazy_ttfs_s'])} | "
                   f"{fmt(r['lazy.lazy_full_s'])} |")
        out.append(
            f"\ntime-to-first-step: "
            f"**{r['lazy.ttfs_vs_eager']:.0%} of the eager wall** "
            f"({fmt(r['lazy.speedup.ttfs'])}x earlier) on a "
            f"{fmt(r['lazy.workload.bytes_total'])} MiB image with a "
            f"{fmt(r['lazy.workload.bytes_critical'])} MiB critical set "
            f"(`{name}`)")
        break
    return "\n".join(out) if out else "(no BENCH_restore_lazy.json found)"


def chaos_table(recs):
    """Per-fault-class survivability table (from BENCH_chaos.json): a
    seeded campaign's injected/survived/healed/quarantined counts and
    mean time to recover, per fault class."""
    out = []
    for name, r in recs:
        if "chaos.invariant.violation_ratio" not in r:
            continue
        # sync-mode classes only: chaos.<cls>.injected (the capture
        # sweep's chaos.concurrent.<cls>.* keys are a mode, not a class)
        classes = sorted({k.split(".")[1] for k in r
                          if k.startswith("chaos.")
                          and k.endswith(".injected")
                          and k.count(".") == 2})
        out.append("| fault class | injected | survived | healed | "
                   "quarantined | MTTR (s) |")
        out.append("|---|---|---|---|---|---|")
        for cls in classes:
            inj = r[f"chaos.{cls}.injected"]
            surv = 1.0 - r[f"chaos.{cls}.unsurvived_ratio"]
            mttr = r.get(f"chaos.{cls}.mttr_s")
            out.append(
                f"| {cls} | {inj} | {surv:.0%} | "
                f"{r[f'chaos.{cls}.healed']} | "
                f"{r[f'chaos.{cls}.quarantined_ratio']:.0%} | "
                f"{'—' if mttr is None else fmt(mttr)} |")
        held = r["chaos.invariant.violation_ratio"] == 0
        out.append(
            f"\n{r['chaos.workload.jobs']:.0f} jobs × "
            f"{r['chaos.workload.hosts']:.0f} hosts, seed "
            f"{r['chaos.workload.seed']:.0f}: "
            + ("**invariant held** — every job recovered bit-exact or "
               "landed in diagnosable quarantine" if held else
               "**INVARIANT VIOLATED**")
            + f" (`{name}`)")
        break
    return "\n".join(out) if out else "(no BENCH_chaos.json found)"


def obs_table(recs):
    """Observability overhead table (from BENCH_obs.json): dump wall
    with the plane off / on / on-with-detail, plus the two gated ratios
    and the disabled-path cost model inputs."""
    out = []
    for name, r in recs:
        if "obs.trace_overhead_ratio" not in r:
            continue
        out.append("| plane | dump wall (ms) |")
        out.append("|---|---|")
        out.append(f"| off (no plane installed) | "
                   f"{fmt(r['obs.dump_off_wall_ms'])} |")
        out.append(f"| tracing on | {fmt(r['obs.dump_on_wall_ms'])} |")
        out.append(f"| tracing on + per-chunk detail | "
                   f"{fmt(r['obs.dump_detail_wall_ms'])} |")
        out.append(
            f"\ntracing-on overhead "
            f"**{max(0.0, r['obs.trace_overhead_ratio'] - 1):.1%}** "
            f"(ceiling 3%); disabled-plane overhead "
            f"**{r['obs.trace_overhead_ratio_disabled'] - 1:.3%}** "
            f"(ceiling 0.5%), modeled from "
            f"{fmt(r['obs.model.disabled_span_ns'], 3)} ns/disabled span "
            f"× {r['obs.model.span_sites']:.0f} sites + "
            f"{fmt(r['obs.model.disabled_guard_ns'], 3)} ns/guard "
            f"× {r['obs.model.guard_sites']:.0f} per-chunk sites on a "
            f"{fmt(r['obs.workload.bytes'])} MiB dump (`{name}`)")
        break
    return "\n".join(out) if out else "(no BENCH_obs.json found)"


def update_readme(recs, path=README):
    """Render the lazy-restore and chaos tables into README between
    their markers."""
    with open(path) as f:
        text = f.read()
    for begin, end, table, label in (
            (LAZY_BEGIN, LAZY_END, lazy_table(recs), "lazy-restore"),
            (CHAOS_BEGIN, CHAOS_END, chaos_table(recs), "chaos"),
            (OBS_BEGIN, OBS_END, obs_table(recs), "obs")):
        if begin not in text or end not in text:
            raise SystemExit(f"{path}: missing {begin}/{end} markers")
        text = re.sub(
            re.escape(begin) + r".*?" + re.escape(end),
            begin + "\n" + table + "\n" + end,
            text, flags=re.S)
        print(f"updated {path} ({label} table)")
    with open(path, "w") as f:
        f.write(text)


def fmt_bytes(n):
    for unit in ("B", "KiB", "MiB", "GiB"):
        if abs(n) < 1024 or unit == "GiB":
            return f"{n:.0f}{unit}" if unit == "B" else f"{n:.2f}{unit}"
        n /= 1024


def transfer_table(recs):
    """Cross-host transfer & recovery-time table (from
    BENCH_transfer.json): bytes moved + recovery wall per transfer mode
    on the incremental-chain workload."""
    out = []
    for name, r in recs:
        if "transfer.full.bytes" not in r:
            continue
        out.append(f"### {name}: checkpoint transfer & migration "
                   f"(incremental chain, "
                   f"{r.get('transfer.workload.steps', '?')} steps, "
                   f"{r.get('transfer.workload.mutate', '?')} mutated "
                   f"per step)\n")
        out.append("| transfer | bytes moved | deduped | transfer wall "
                   "(ms) | recovery incl. restore (ms) |")
        out.append("|---|---|---|---|---|")
        for mode, label in (("full", "full copy"),
                            ("cold", "delta, cold CAS"),
                            ("warm", "delta, warm CAS")):
            out.append(
                f"| {label} | {fmt_bytes(r[f'transfer.{mode}.bytes'])} | "
                f"{fmt_bytes(r.get(f'transfer.{mode}.dedup_bytes', 0))} | "
                f"{fmt(r[f'transfer.{mode}.wall_s'] * 1e3)} | "
                f"{fmt(r[f'transfer.recovery.{mode}_s'] * 1e3)} |")
        ratio = r.get("transfer.warm_vs_full.byte_ratio")
        if ratio is not None:
            out.append(f"\nwarm-CAS delta moves {ratio:.1%} of the bytes "
                       f"of a full copy\n")
    return "\n".join(out) if out else "(no BENCH_transfer.json artifacts)"


def main(argv=None):
    import argparse
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--update-readme", action="store_true",
                    help="render the time-to-first-step table into "
                         "README.md between the lazy-restore markers")
    args = ap.parse_args(argv)
    bench = load_bench()
    if args.update_readme:
        update_readme(bench)
        return
    recs = load_all()
    print("## single-pod baseline roofline\n")
    print(roofline_table(recs, "pod"))
    print("\n## multi-pod baseline roofline\n")
    print(roofline_table(recs, "multipod"))
    print("\n## memory analysis (single-pod baseline)\n")
    print(memory_table(recs))
    print("\n## hillclimb iterations\n")
    print(perf_rows(recs))
    print("\n## snapshot data plane (serial vs pipelined)\n")
    print(dataplane_table(bench))
    print("\n## checkpoint transfer & migration\n")
    print(transfer_table(bench))
    print("\n## lazy restore: time-to-first-step\n")
    print(lazy_table(bench))
    print("\n## chaos campaign: per-fault-class survivability\n")
    print(chaos_table(bench))
    print("\n## observability plane: tracing overhead\n")
    print(obs_table(bench))


if __name__ == "__main__":
    main()
