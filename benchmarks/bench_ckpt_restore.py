"""Paper Fig. 5 / Fig. 6 / Table 2 — checkpoint & restore latency vs model
size, with the CRIU-style stage breakdown:

  lock (Fig.5 "lock")      — device quiesce
  ckpt (Fig.5 "ckpt")      — device→host snapshot
  frozen                   — total time the job is paused (sync mode)
  write                    — pack + commit to storage
  restore / unlock (Fig.6) — unified CPU+GPU restore, resume
    read / decompress / place — streaming-restore stage split (pack v2)

The model ladder stands in for GPT-2 124M→1.5B; sizes scale the same way
(checkpoint bytes ∝ params; paper's key curve).

Data-plane benchmarks (``--dataplane`` / ``--sweep``) compare the
serial-compat mode (pack v1, one writer thread, serial restore) against
the pipelined mode (pack v2: chunked packs, compress workers, striped
appenders, parallel chunk restore) on a synthetic multi-entry image, and
sweep stripes × io_threads.  ``--json PATH`` additionally dumps every
record as JSON (the ``BENCH_*.json`` perf-trajectory artifacts CI
uploads).
"""
from __future__ import annotations

import json
import shutil
import statistics
import tempfile
import time

import numpy as np

RECORDS: dict = {}


def _emit(name, value, unit=""):
    from benchmarks.common import emit
    emit(name, value, unit)
    RECORDS[name] = value


def run(sizes=("S", "M", "L", "XL")) -> None:
    import jax
    import jax.numpy as jnp

    from benchmarks.common import (POLICY, Timer, ladder_config,
                                   mesh1)
    from repro.api import CheckpointSession
    from repro.optim import AdamW
    from repro.optim.schedule import constant
    from repro.models.encdec import build_model

    mesh = mesh1()
    for size in sizes:
        cfg = ladder_config(size)
        model = build_model(cfg, POLICY, mesh, compute_dtype=jnp.float32,
                            remat=False)
        opt = AdamW(lr=constant(1e-3))
        params = model.init(jax.random.key(0))
        opt_state = opt.init(params)
        n_params = sum(x.size for x in jax.tree.leaves(params))
        _emit(f"fig5.{size}.params", n_params, "count")

        run_dir = tempfile.mkdtemp(prefix=f"bench_ckpt_{size}_")
        try:
            eng = CheckpointSession(run_dir, mesh=mesh)
            eng.attach(lambda: {"train_state": {"params": params,
                                                "opt": opt_state}})
            eng.register_host_state("cursor", lambda: {"step": 1},
                                    lambda st: None)
            with Timer() as t:
                eng.checkpoint(1)
            st = eng.last_stats
            _emit(f"fig5.{size}.lock", st["lock_s"] * 1e3, "ms")
            _emit(f"fig5.{size}.ckpt_dev2host",
                  st["device_to_host_s"] * 1e3, "ms")
            _emit(f"fig5.{size}.frozen", st["frozen_s"] * 1e3, "ms")
            _emit(f"fig5.{size}.write", st["write_s"] * 1e3, "ms")
            _emit(f"fig5.{size}.total", t.s * 1e3, "ms")
            _emit(f"fig5.{size}.bytes", st["written_bytes"] / 2**20, "MiB")

            eng2 = CheckpointSession(run_dir, mesh=mesh)
            eng2.attach(lambda: {"train_state": None})
            eng2.register_host_state("cursor", lambda: None, lambda st: None)
            with Timer() as t:
                eng2.restore()
            st2 = eng2.last_stats
            _emit(f"fig6.{size}.restore_total", t.s * 1e3, "ms")
            _emit(f"fig6.{size}.host2device",
                  st2["host_to_device_s"] * 1e3, "ms")
            # streaming-restore breakdown (thread-time across the pool)
            _emit(f"fig6.{size}.read", st2.get("read_s", 0) * 1e3, "ms")
            _emit(f"fig6.{size}.decompress",
                  st2.get("decompress_s", 0) * 1e3, "ms")
            _emit(f"fig6.{size}.place", st2.get("place_s", 0) * 1e3, "ms")
        finally:
            shutil.rmtree(run_dir, ignore_errors=True)


# ------------------------------------------------------------- data plane
def _synthetic_state(n_entries: int, entry_kb: int, seed: int = 0):
    """Low-entropy float payloads: compressible enough that compress=True
    exercises the codec stages (the pipelined plane's dominant cost)."""
    rng = np.random.default_rng(seed)
    return {f"t{i:03d}": rng.integers(0, 8, size=entry_kb * 256)
            .astype(np.float32)
            for i in range(n_entries)}


def _measure(opts, state, repeats: int = 3):
    """Best-of-`repeats` write/restore/frozen seconds for one options
    config (min, not median: the box running CI is shared, and the
    fastest run is the least contaminated by neighbors)."""
    from repro.api import CheckpointSession

    writes, restores, frozens, details = [], [], [], {}
    for rep in range(repeats):
        run_dir = tempfile.mkdtemp(prefix="bench_dp_")
        try:
            s = CheckpointSession(run_dir, opts, backend="host")
            s.attach(lambda: {"train_state": state})
            s.checkpoint(1)
            writes.append(s.last_stats["write_s"])
            frozens.append(s.last_stats["frozen_s"])
            r = CheckpointSession(run_dir, opts, backend="host")
            r.attach(lambda: {"train_state": None})
            t0 = time.perf_counter()
            r.restore()
            restores.append(time.perf_counter() - t0)
            details = {k: r.last_stats.get(k, 0.0)
                       for k in ("read_s", "decompress_s", "place_s")}
        finally:
            shutil.rmtree(run_dir, ignore_errors=True)
    return {"write_s": min(writes),
            "restore_s": min(restores),
            "frozen_s": statistics.median(frozens), **details}


def run_dataplane(n_entries: int = 64, entry_kb: int = 384,
                  repeats: int = 3) -> dict:
    """Serial-compat vs pipelined on a synthetic multi-entry image."""
    from repro.api import CheckpointOptions

    state = _synthetic_state(n_entries, entry_kb)
    total_mb = sum(v.nbytes for v in state.values()) / 2**20
    _emit("dataplane.entries", n_entries, "count")
    _emit("dataplane.bytes", total_mb, "MiB")

    configs = {
        "serial": CheckpointOptions(compress=True, pack_format=1,
                                    io_threads=1),
        "pipelined": CheckpointOptions(compress=True, pack_format=2),
    }
    out = {}
    for mode, opts in configs.items():
        res = _measure(opts, state, repeats)
        out[mode] = res
        for k, v in res.items():
            _emit(f"dataplane.{mode}.{k}", v * 1e3, "ms")
    _emit("dataplane.speedup.write",
          out["serial"]["write_s"] / out["pipelined"]["write_s"], "x")
    _emit("dataplane.speedup.restore",
          out["serial"]["restore_s"] / out["pipelined"]["restore_s"], "x")
    return out


def run_lazy(n_entries: int = 16, entry_kb: int = 384,
             repeats: int = 3) -> dict:
    """Time-to-first-step: priority-ordered lazy restore vs eager full
    materialization.

    The workload has the shape of a training checkpoint: hot params (the
    critical set, 1/3 of the bytes), cold optimizer slots m+v (2/3), and
    a small host blob.  The "first step" touches params only — exactly
    what a resumed job's forward pass does while the optimizer slots are
    still streaming in the background.  Lazy and eager results are
    asserted bit-identical before anything is emitted."""
    from repro.api import CheckpointOptions, CheckpointSession

    rng = np.random.default_rng(1)

    def block():
        return rng.integers(0, 8, size=entry_kb * 256).astype(np.float32)

    keys = [f"w{i:03d}" for i in range(n_entries)]
    state = {"params": {k: block() for k in keys},
             "opt": {"m": {k: block() for k in keys},
                     "v": {k: block() for k in keys}}}
    critical_bytes = sum(v.nbytes for v in state["params"].values())
    total_bytes = critical_bytes * 3
    _emit("lazy.workload.bytes_total", total_bytes / 2**20, "MiB")
    _emit("lazy.workload.bytes_critical", critical_bytes / 2**20, "MiB")

    run_dir = tempfile.mkdtemp(prefix="bench_lazy_")
    try:
        w = CheckpointSession(run_dir, CheckpointOptions(compress=True),
                              backend="host")
        w.attach(lambda: {"train_state": state})
        w.register_host_state("cursor", lambda: {"step": 1},
                              lambda st: None)
        w.checkpoint(1)

        def first_step(tree):
            # the resumed job's forward pass: reads every param once
            return float(sum(np.asarray(v).sum()
                             for v in tree["params"].values()))

        def check_exact(tree):
            for k in keys:
                np.testing.assert_array_equal(
                    np.asarray(tree["params"][k]), state["params"][k])
                np.testing.assert_array_equal(
                    np.asarray(tree["opt"]["m"][k]), state["opt"]["m"][k])
                np.testing.assert_array_equal(
                    np.asarray(tree["opt"]["v"][k]), state["opt"]["v"][k])

        eager_opts = CheckpointOptions(compress=True)
        lazy_opts = CheckpointOptions(
            compress=True, restore_mode="lazy",
            critical_states=("train_state/params",))

        eager_wall, eager_ttfs = [], []
        for _ in range(repeats):
            r = CheckpointSession(run_dir, eager_opts, backend="host")
            r.attach(lambda: {"train_state": None})
            r.register_host_state("cursor", lambda: None, lambda st: None)
            t0 = time.perf_counter()
            restored = r.restore()
            eager_wall.append(time.perf_counter() - t0)
            first_step(restored["train_state"])
            eager_ttfs.append(time.perf_counter() - t0)
            check_exact(restored["train_state"])

        lazy_ttfs, lazy_full, lazy_resume = [], [], []
        for _ in range(repeats):
            r = CheckpointSession(run_dir, lazy_opts, backend="host")
            r.attach(lambda: {"train_state": None})
            r.register_host_state("cursor", lambda: None, lambda st: None)
            t0 = time.perf_counter()
            restored = r.restore(wait="critical")
            lazy_resume.append(time.perf_counter() - t0)
            first_step(restored["train_state"])      # critical set only
            lazy_ttfs.append(time.perf_counter() - t0)
            full = r.restore_barrier()
            lazy_full.append(time.perf_counter() - t0)
            check_exact(full["train_state"])         # bit-exact vs dump

        out = {"eager_wall_s": min(eager_wall),
               "eager_ttfs_s": min(eager_ttfs),
               "lazy_resume_s": min(lazy_resume),
               "lazy_ttfs_s": min(lazy_ttfs),
               "lazy_full_s": min(lazy_full)}
        for k, v in out.items():
            _emit(f"lazy.{k}", v * 1e3, "ms")
        ratio = out["lazy_ttfs_s"] / out["eager_ttfs_s"]
        _emit("lazy.ttfs_vs_eager", ratio, "x")
        _emit("lazy.speedup.ttfs",
              out["eager_ttfs_s"] / out["lazy_ttfs_s"], "x")
        return {**out, "ttfs_vs_eager": ratio}
    finally:
        shutil.rmtree(run_dir, ignore_errors=True)


def run_concurrent(n_entries: int = 64, entry_kb: int = 384,
                   repeats: int = 3) -> dict:
    """Soft-freeze (concurrent) capture vs the sync dump it must match.

    Dumps the same synthetic image twice: once with the classic
    stop-the-world capture, once with ``capture="concurrent"`` (pin →
    speculate in background → validate → commit).  Asserts the two
    committed images are bit-identical — same per-entry CRCs, same
    restored bytes — before emitting anything, then reports the frozen
    windows.  ``concurrent.frozen_vs_sync`` is the gated headline: the
    soft-freeze pause must stay within 10% of the sync frozen window
    (compare_bench treats it as an absolute ceiling of 0.10).
    """
    from repro.api import CheckpointOptions, CheckpointSession
    from repro.runtime.interval import frozen_window_s

    state = _synthetic_state(n_entries, entry_kb, seed=3)
    total_mb = sum(v.nbytes for v in state.values()) / 2**20
    _emit("concurrent.workload.entries", n_entries, "count")
    _emit("concurrent.workload.bytes", total_mb, "MiB")

    base = dict(compress=True, pack_format=2, incremental=True)
    sync_frozen, conc_frozen = [], []
    pin, validate, speculate = [], [], []
    recaptured = 0
    for rep in range(repeats):
        sync_dir = tempfile.mkdtemp(prefix="bench_conc_sync_")
        conc_dir = tempfile.mkdtemp(prefix="bench_conc_soft_")
        try:
            s = CheckpointSession(
                sync_dir, CheckpointOptions(**base), backend="host")
            s.attach(lambda: {"train_state": state})
            s.checkpoint(1)
            sync_frozen.append(frozen_window_s(s.last_stats))

            c = CheckpointSession(
                conc_dir, CheckpointOptions(capture="concurrent", **base),
                backend="host")
            c.attach(lambda: {"train_state": state})
            handle = c.checkpoint_begin(1)
            handle.wait_speculated()      # the job would be stepping here
            c.checkpoint_finalize()
            st = c.last_stats
            conc_frozen.append(frozen_window_s(st))
            pin.append(st["pin_pause_s"])
            validate.append(st["validate_pause_s"])
            speculate.append(st["speculate_s"])
            recaptured += int(st.get("recaptured_entries", 0))

            # bit-exactness: identical per-entry CRCs, identical bytes
            ms = s.store.manifest(1)
            mc = c.store.manifest(1)
            if ms["entry_crcs"] != mc["entry_crcs"]:
                raise AssertionError(
                    "concurrent image entry CRCs diverge from sync dump")
            r = CheckpointSession(conc_dir, CheckpointOptions(**base),
                                  backend="host")
            r.attach(lambda: {"train_state": None})
            restored = r.restore()["train_state"]
            for k, v in state.items():
                np.testing.assert_array_equal(np.asarray(restored[k]), v)
        finally:
            shutil.rmtree(sync_dir, ignore_errors=True)
            shutil.rmtree(conc_dir, ignore_errors=True)

    out = {"sync_frozen_s": min(sync_frozen),
           "frozen_s": min(conc_frozen),
           "pin_pause_s": min(pin),
           "validate_pause_s": min(validate),
           "speculate_s": min(speculate)}
    for k, v in out.items():
        _emit(f"concurrent.{k[:-2]}_ms", v * 1e3, "ms")
    ratio = out["frozen_s"] / out["sync_frozen_s"]
    _emit("concurrent.frozen_vs_sync", ratio, "x")
    _emit("concurrent.recaptured_entries", recaptured, "count")
    return {**out, "frozen_vs_sync": ratio}


def run_sweep(n_entries: int = 64, entry_kb: int = 128,
              stripes=(1, 2, 4), threads=(1, 2, 4),
              repeats: int = 3) -> list:
    """stripes × io_threads grid on the pipelined plane (make_tables.py
    renders this as the data-plane sweep table)."""
    from repro.api import CheckpointOptions

    state = _synthetic_state(n_entries, entry_kb)
    rows = []
    for n_stripes in stripes:
        for n_threads in threads:
            opts = CheckpointOptions(compress=True, pack_format=2,
                                     stripes=n_stripes,
                                     io_threads=n_threads)
            res = _measure(opts, state, repeats)
            row = {"stripes": n_stripes, "io_threads": n_threads, **res}
            rows.append(row)
            _emit(f"sweep.s{n_stripes}.t{n_threads}.write",
                  res["write_s"] * 1e3, "ms")
            _emit(f"sweep.s{n_stripes}.t{n_threads}.restore",
                  res["restore_s"] * 1e3, "ms")
    RECORDS["sweep"] = rows
    return rows


def main(argv=None) -> int:
    import argparse
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--sizes", default="S,M,L,XL",
                    help="ladder sizes for the fig5/fig6 run ('' = skip)")
    ap.add_argument("--dataplane", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="serial-compat vs pipelined comparison")
    ap.add_argument("--sweep", action="store_true",
                    help="stripes x io_threads grid")
    ap.add_argument("--lazy", action="store_true",
                    help="time-to-first-step: lazy (resume-before-read) "
                         "vs eager full materialization")
    ap.add_argument("--concurrent", action="store_true",
                    help="soft-freeze capture: frozen window vs sync "
                         "dump (images asserted bit-identical)")
    ap.add_argument("--entries", type=int, default=64)
    ap.add_argument("--entry-kb", type=int, default=384)
    ap.add_argument("--repeats", type=int, default=4)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="dump all records as JSON (BENCH_*.json artifact)")
    args = ap.parse_args(argv)

    if args.sizes:
        run(sizes=tuple(s for s in args.sizes.split(",") if s))
    if args.dataplane:
        run_dataplane(args.entries, args.entry_kb, args.repeats)
    if args.sweep:
        run_sweep(repeats=args.repeats)
    if args.lazy:
        run_lazy(repeats=args.repeats)
    if args.concurrent:
        run_concurrent(args.entries, args.entry_kb, args.repeats)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(RECORDS, f, indent=1, sort_keys=True)
        print(f"# wrote {args.json}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
