"""Paper Fig. 5 / Fig. 6 / Table 2 — checkpoint & restore latency vs model
size, with the CRIU-style stage breakdown:

  lock (Fig.5 "lock")      — device quiesce
  ckpt (Fig.5 "ckpt")      — device→host snapshot
  frozen                   — total time the job is paused (sync mode)
  write                    — pack + commit to storage
  restore / unlock (Fig.6) — unified CPU+GPU restore, resume

The model ladder stands in for GPT-2 124M→1.5B; sizes scale the same way
(checkpoint bytes ∝ params; paper's key curve).
"""
from __future__ import annotations

import shutil
import tempfile

import jax
import jax.numpy as jnp

from benchmarks.common import LADDER, POLICY, Timer, emit, ladder_config, mesh1
from repro.api import CheckpointSession
from repro.optim import AdamW
from repro.optim.schedule import constant
from repro.models.encdec import build_model


def run(sizes=("S", "M", "L", "XL")) -> None:
    mesh = mesh1()
    for size in sizes:
        cfg = ladder_config(size)
        model = build_model(cfg, POLICY, mesh, compute_dtype=jnp.float32,
                            remat=False)
        opt = AdamW(lr=constant(1e-3))
        params = model.init(jax.random.key(0))
        opt_state = opt.init(params)
        n_params = sum(x.size for x in jax.tree.leaves(params))
        emit(f"fig5.{size}.params", n_params, "count")

        run_dir = tempfile.mkdtemp(prefix=f"bench_ckpt_{size}_")
        try:
            eng = CheckpointSession(run_dir, mesh=mesh)
            eng.attach(lambda: {"train_state": {"params": params,
                                                "opt": opt_state}})
            eng.register_host_state("cursor", lambda: {"step": 1},
                                    lambda st: None)
            with Timer() as t:
                eng.checkpoint(1)
            st = eng.last_stats
            emit(f"fig5.{size}.lock", st["lock_s"] * 1e3, "ms")
            emit(f"fig5.{size}.ckpt_dev2host",
                 st["device_to_host_s"] * 1e3, "ms")
            emit(f"fig5.{size}.frozen", st["frozen_s"] * 1e3, "ms")
            emit(f"fig5.{size}.write", st["write_s"] * 1e3, "ms")
            emit(f"fig5.{size}.total", t.s * 1e3, "ms")
            emit(f"fig5.{size}.bytes", st["written_bytes"] / 2**20, "MiB")

            eng2 = CheckpointSession(run_dir, mesh=mesh)
            eng2.attach(lambda: {"train_state": None})
            eng2.register_host_state("cursor", lambda: None, lambda st: None)
            with Timer() as t:
                eng2.restore()
            st2 = eng2.last_stats
            emit(f"fig6.{size}.restore_total", t.s * 1e3, "ms")
            emit(f"fig6.{size}.host2device",
                 st2["host_to_device_s"] * 1e3, "ms")
        finally:
            shutil.rmtree(run_dir, ignore_errors=True)


if __name__ == "__main__":
    run()
