"""Benchmark harness entry point: one module per paper table/figure.

  python -m benchmarks.run            # everything
  python -m benchmarks.run fig2 t3    # subset by tag

Prints ``name,value,unit`` CSV (stdout) — the EXPERIMENTS.md numbers are
generated from this stream.
"""
from __future__ import annotations

import sys
import time
import traceback

TAGS = {
    "fig2": ("benchmarks.bench_interception", "Fig 2: interception overhead"),
    "fig5": ("benchmarks.bench_ckpt_restore",
             "Fig 5/6 + Table 2: ckpt/restore vs model size"),
    "t3": ("benchmarks.bench_scaling", "Table 3: data-parallel scaling"),
    "t4": ("benchmarks.bench_size_breakdown",
           "Table 4: device/host split"),
    "t5": ("benchmarks.bench_hpc_micro", "Table 5/Fig 7: HPC micro"),
    "beyond": ("benchmarks.bench_beyond_paper",
               "Beyond-paper: async/incremental/compress/replicate"),
    "roofline": ("benchmarks.roofline", "§Roofline table from dry-run"),
}


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    tags = argv or list(TAGS)
    failures = []
    for tag in tags:
        mod_name, desc = TAGS[tag]
        print(f"# === {tag}: {desc} ===", flush=True)
        t0 = time.perf_counter()
        try:
            mod = __import__(mod_name, fromlist=["run"])
            mod.run()
        except Exception:
            traceback.print_exc()
            failures.append(tag)
        print(f"# --- {tag} done in {time.perf_counter() - t0:.1f}s ---",
              flush=True)
    if failures:
        print(f"# FAILURES: {failures}")
        return 1
    print("# all benchmarks OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
