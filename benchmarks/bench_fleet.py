"""Serving-fleet fan-out bench: K decode replicas from one image.

The serving-scale claim behind the paper's fast-restore story: one
committed ``DecodeServer`` image fans out into K replicas (default 50)
across simulated hosts, each boot paying only a warm-CAS negotiation and
a params-critical lazy restore.  Headline gated metrics:

  fleet.restore_bytes_vs_image   total delta-replication bytes across
                                 all K boots over the bytes of one
                                 committed image.  Absolute ceiling 2.0
                                 (the ISSUE's acceptance bound): K
                                 replicas must cost less than two full
                                 restores, i.e. CAS dedup makes fan-out
                                 sub-linear in K.
  fleet.ttft_vs_solo             warm-replica median time-to-first-token
                                 over a solo cold boot of the same image
                                 onto a fresh host (delta push + eager
                                 restore + one token) — the no-fleet
                                 alternative each replica is replacing.
                                 Absolute ceiling 2.0; a ratio of two
                                 walls from the same run, so runner
                                 speed cancels.

Byte metrics (image bytes, total/per-replica restore bytes) are
deterministic at fixed seed and baseline-gated at the usual bytes
tolerance; TTFT percentiles are informational wall clock.
"""
from __future__ import annotations

import json
import shutil
import tempfile
import time

RECORDS: dict = {}


def _emit(name, value, unit=""):
    from benchmarks.common import emit
    emit(name, value, unit)
    RECORDS[name] = value


def _solo_cold_boot_s(fleet, host: str = "solo") -> float:
    """Wall for the no-fleet path: push the image to a fresh host (cold
    CAS) and eager-restore a standalone server to its first token."""
    import os

    from repro.api import CheckpointOptions
    from repro.orchestrator.workloads import host_cas_dir, job_dir_for
    from repro.runtime.server import DecodeServer
    from repro.transfer import DeltaReplicator
    rep_dir = job_dir_for(fleet.run_dir, "solo", host)
    t0 = time.perf_counter()
    DeltaReplicator(rep_dir,
                    cas_dir=host_cas_dir(fleet.run_dir, host)
                    ).push(fleet.source_dir, fleet.image_step)
    srv = DecodeServer(fleet.cfg, fleet.policy, fleet.mesh, rep_dir,
                       max_seq=fleet.config.max_seq,
                       options=CheckpointOptions(restore_mode="eager"),
                       model=fleet.model)
    srv.restore(step=fleet.image_step)
    srv.decode(1)
    wall = time.perf_counter() - t0
    shutil.rmtree(os.path.join(fleet.run_dir, host), ignore_errors=True)
    return wall


def run_fleet_bench(replicas: int = 50, hosts: int = 2,
                    seed: int = 0) -> dict:
    from repro.orchestrator.fleet import FleetConfig, ServingFleet

    d = tempfile.mkdtemp(prefix="bench_fleet_")
    try:
        cfg = FleetConfig(replicas=replicas, hosts=hosts, seed=seed,
                          max_replicas=replicas + 16)
        fleet = ServingFleet(d, cfg)
        img = fleet.build_source_image()
        _emit("fleet.image_bytes", img["bytes"], "bytes")
        # solo cold-boot reference before the fleet warms any host CAS
        solo_s = min(_solo_cold_boot_s(fleet) for _ in range(3))
        _emit("fleet.solo_cold_boot_ms", solo_s * 1e3, "ms")

        fleet.boot_fleet()
        k = max(1, len(fleet.serving()))
        fleet.serve_trace([1, 1, 3 * k, 3 * k, 1, 0, 0, 0])
        s = fleet.summary()

        _emit("fleet.replicas", s["replicas"], "count")
        _emit("fleet.hosts", len(s["hosts"]), "count")
        _emit("fleet.total_restore_bytes", s["total_restore_bytes"],
              "bytes")
        _emit("fleet.restore_bytes_per_replica",
              s["restore_bytes_per_replica"], "bytes")
        _emit("fleet.restore_bytes_vs_image",
              s["restore_bytes_vs_image"], "x")
        _emit("fleet.dedup_ratio", s["dedup_ratio"], "x")
        _emit("fleet.ttft_p50_ms", s["ttft_p50_s"] * 1e3, "ms")
        _emit("fleet.ttft_p99_ms", s["ttft_p99_s"] * 1e3, "ms")
        # warm replicas (zero new chunks shipped) are the fan-out story;
        # every replica after each host's first qualifies at K >> hosts
        warm = sorted(r.ttft_s for r in fleet.replicas
                      if r.ttft_s is not None
                      and r.transfer.get("bytes_sent", 1) == 0)
        if not warm:
            raise AssertionError(
                "no warm-CAS replica boots — dedup is not happening")
        _emit("fleet.warm_replicas", len(warm), "count")
        warm_p50 = warm[len(warm) // 2]
        _emit("fleet.warm_ttft_p50_ms", warm_p50 * 1e3, "ms")
        _emit("fleet.ttft_vs_solo", warm_p50 / solo_s, "x")
        _emit("fleet.requests_served", s["requests_served"], "count")
        _emit("fleet.autoscale_boots", s["autoscale_boots"], "count")
        _emit("fleet.goodput", s["goodput_requests_per_replica_tick"],
              "req/replica-tick")
        if s["requests_unserved"]:
            raise AssertionError(
                f"{s['requests_unserved']} request(s) unserved — the "
                f"fleet wedged; metrics would be bogus")
        return dict(RECORDS)
    finally:
        shutil.rmtree(d, ignore_errors=True)


def main(argv=None) -> int:
    import argparse
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--replicas", type=int, default=50)
    ap.add_argument("--hosts", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="dump all records as JSON (BENCH_fleet.json)")
    args = ap.parse_args(argv)

    run_fleet_bench(args.replicas, args.hosts, args.seed)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(RECORDS, f, indent=1, sort_keys=True)
        print(f"# wrote {args.json}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
