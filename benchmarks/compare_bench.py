"""Bench regression gate: diff fresh BENCH_*.json against committed
baselines and fail on regression.

Baselines live in ``benchmarks/baselines/<same-basename>.json`` and are
ordinary bench outputs (flat ``{metric: value}``), so refreshing one is
just re-running the bench and committing the file.

Metrics are classified by name, because their stability differs:

  bytes   ``*bytes*`` / ``*.byte_ratio`` / ``*ratio*`` — deterministic
          given the bench's fixed seed; tight tolerance (``--tolerance``,
          default 10%); lower is better.
  speedup ``*speedup*`` — higher is better; time-class tolerance.
  time    ``*_s`` / ``*_ms`` / ``*wall*`` — absolute sub-second wall
          clock swings several-x run-to-run on shared runners, so it is
          informational by default (printed, never gated); pass
          ``--time-tolerance`` explicitly to gate it (2.0 = a 3x
          slowdown fails); lower is better.  ``speedup`` metrics are
          ratios of two times from the same run and stay gated.
  info    everything else (workload params, counts) — compared for
          *presence* only: a metric that disappears from the fresh run
          is a failure (a renamed metric must rename its baseline, not
          silently stop being gated).

Exit status: 0 when every gated metric is within tolerance, 1 otherwise.

Usage (what CI runs)::

    python -m benchmarks.compare_bench BENCH_transfer.json \\
        --baseline-dir benchmarks/baselines
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Dict, List, Optional, Tuple


def classify(name: str) -> str:
    low = name.lower()
    if "ttfs_vs_eager" in low:
        return "ttfs"     # lazy-restore acceptance bound: absolute gate
    if "frozen_vs_sync" in low:
        return "frozen"   # soft-freeze acceptance bound: absolute gate
    # must precede the generic "ratio" -> bytes branch below
    if "overhead_ratio_disabled" in low:
        return "obs_disabled"   # obs acceptance bound: absolute gate
    if "overhead_ratio" in low:
        return "obs_enabled"    # obs acceptance bound: absolute gate
    if "blackout_vs_stopcopy" in low:
        return "blackout"       # pre-copy acceptance bound: absolute gate
    if "restore_bytes_vs_image" in low:
        return "fleet_bytes"    # fleet fan-out bound: absolute gate
    if "ttft_vs_solo" in low:
        return "fleet_ttft"     # fleet TTFT bound: absolute gate
    if "speedup" in low:
        return "speedup"
    if "dedup" in low:
        return "info"     # more dedup is an improvement, never a regression
    if "bytes" in low or "ratio" in low:
        return "bytes"
    if low.endswith("_s") or low.endswith("_ms") or "wall" in low:
        return "time"
    return "info"


SPEEDUP_TOLERANCE = 2.0       # a speedup may halve-and-some before failing
# lazy restore's acceptance criterion: time-to-first-step must stay at or
# below this fraction of the eager full-materialization wall.  Gated as an
# absolute bound (not relative to the baseline) because the ratio is the
# contract — a run that degrades from 0.30 to 0.45 still honors it, one
# that hits 0.55 does not, regardless of what the baseline recorded.
TTFS_RATIO_CEILING = 0.5
# concurrent (soft-freeze) capture's acceptance criterion: the pause the
# job actually observes (pin + validate) must stay at or below this
# fraction of the stop-the-world sync frozen window.  Absolute for the
# same reason as the ttfs ceiling: the ratio *is* the contract.
FROZEN_RATIO_CEILING = 0.10
# observability acceptance criteria (ISSUE 8): a dump with tracing ON
# must cost at most 3% over tracing-off (1.03 as a wall ratio), and the
# *disabled* plane — spans compiled to no-ops — at most 0.5%.  Absolute
# ceilings: the ratios are the contract, not the baseline values.
OBS_ENABLED_RATIO_CEILING = 1.03
OBS_DISABLED_RATIO_CEILING = 1.005
# pre-copy live migration's acceptance criterion (ISSUE 9): the frozen
# residual push (the blackout the job observes) must stay at or below
# this fraction of the stop-and-copy wall — the whole point of shipping
# delta rounds while the job still steps.  Absolute, like the others:
# the ratio is the contract.
PRECOPY_BLACKOUT_CEILING = 0.20
# serving-fleet acceptance criteria (ISSUE 10), both absolute ceilings:
# booting K replicas from one image must ship less than 2x the image's
# bytes in total (CAS dedup makes fan-out sub-linear in K), and a
# warm-CAS replica's median time-to-first-token may cost at most 2x a
# solo cold boot of the same image (push + eager restore + one token).
FLEET_RESTORE_BYTES_CEILING = 2.0
FLEET_TTFT_RATIO_CEILING = 2.0


def check_metric(name: str, base: float, fresh: float,
                 tol_bytes: float, tol_time: Optional[float]
                 ) -> Tuple[bool, Optional[float]]:
    """(ok, relative regression).  Regression > 0 means worse than the
    baseline by that fraction in the metric's bad direction.
    ``tol_time=None`` leaves wall-clock metrics informational."""
    kind = classify(name)
    if kind == "info":
        return True, None
    if not isinstance(base, (int, float)) or \
            not isinstance(fresh, (int, float)):
        return True, None
    if base == 0:
        return (fresh == 0) if kind == "bytes" else True, None
    if kind == "ttfs":                        # absolute acceptance bound
        reg = fresh / base - 1
        return fresh <= TTFS_RATIO_CEILING, reg
    if kind == "frozen":                      # absolute acceptance bound
        reg = fresh / base - 1
        return fresh <= FROZEN_RATIO_CEILING, reg
    if kind == "obs_enabled":                 # absolute acceptance bound
        reg = fresh / base - 1
        return fresh <= OBS_ENABLED_RATIO_CEILING, reg
    if kind == "obs_disabled":                # absolute acceptance bound
        reg = fresh / base - 1
        return fresh <= OBS_DISABLED_RATIO_CEILING, reg
    if kind == "blackout":                    # absolute acceptance bound
        reg = fresh / base - 1
        return fresh <= PRECOPY_BLACKOUT_CEILING, reg
    if kind == "fleet_bytes":                 # absolute acceptance bound
        reg = fresh / base - 1
        return fresh <= FLEET_RESTORE_BYTES_CEILING, reg
    if kind == "fleet_ttft":                  # absolute acceptance bound
        reg = fresh / base - 1
        return fresh <= FLEET_TTFT_RATIO_CEILING, reg
    if kind == "speedup":                     # higher is better
        if fresh <= 0:
            return False, float("inf")
        reg = base / fresh - 1                # 4x -> 2x == 100% worse
        return reg <= SPEEDUP_TOLERANCE, reg
    reg = fresh / base - 1                    # lower is better
    if kind == "time":
        return (True if tol_time is None else reg <= tol_time), reg
    return reg <= tol_bytes, reg


def compare_file(fresh_path: str, base_path: str, tol_bytes: float,
                 tol_time: Optional[float]) -> List[str]:
    """Human-readable regression list (empty = gate passes)."""
    with open(fresh_path) as f:
        fresh = json.load(f)
    with open(base_path) as f:
        base = json.load(f)
    problems = []
    rows = []
    for name in sorted(base):
        if name not in fresh:
            problems.append(f"{name}: present in baseline, missing from "
                            f"fresh run (renamed without updating the "
                            f"baseline?)")
            continue
        b, fv = base[name], fresh[name]
        ok, reg = check_metric(name, b, fv, tol_bytes, tol_time)
        mark = "ok" if ok else "REGRESSION"
        if reg is not None:
            rows.append((name, b, fv, reg, mark))
        if not ok:
            kind = classify(name)
            if kind == "ttfs":
                problems.append(
                    f"{name}: fresh {fv:.3f} exceeds the lazy-restore "
                    f"acceptance ceiling {TTFS_RATIO_CEILING} "
                    f"(time-to-first-step vs eager wall)")
                continue
            if kind == "frozen":
                problems.append(
                    f"{name}: fresh {fv:.3f} exceeds the soft-freeze "
                    f"acceptance ceiling {FROZEN_RATIO_CEILING} "
                    f"(concurrent frozen window vs sync dump)")
                continue
            if kind == "blackout":
                problems.append(
                    f"{name}: fresh {fv:.3f} exceeds the pre-copy "
                    f"migration blackout ceiling "
                    f"{PRECOPY_BLACKOUT_CEILING} (frozen residual push "
                    f"vs stop-and-copy wall)")
                continue
            if kind == "fleet_bytes":
                problems.append(
                    f"{name}: fresh {fv:.3f} exceeds the fleet fan-out "
                    f"ceiling {FLEET_RESTORE_BYTES_CEILING} (total "
                    f"restore bytes vs one image — CAS dedup broke)")
                continue
            if kind == "fleet_ttft":
                problems.append(
                    f"{name}: fresh {fv:.3f} exceeds the fleet TTFT "
                    f"ceiling {FLEET_TTFT_RATIO_CEILING} (warm-replica "
                    f"median TTFT vs a solo cold boot)")
                continue
            if kind in ("obs_enabled", "obs_disabled"):
                ceil = (OBS_ENABLED_RATIO_CEILING
                        if kind == "obs_enabled"
                        else OBS_DISABLED_RATIO_CEILING)
                problems.append(
                    f"{name}: fresh {fv:.4f} exceeds the observability "
                    f"overhead ceiling {ceil} (dump wall with the plane "
                    f"{'on' if kind == 'obs_enabled' else 'disabled'} "
                    f"vs the uninstrumented path)")
                continue
            tol = (tol_bytes if kind == "bytes" else
                   SPEEDUP_TOLERANCE if kind == "speedup" else tol_time)
            problems.append(
                f"{name}: baseline {b:.6g} -> fresh {fv:.6g} "
                f"({reg:+.1%} worse, {kind} tolerance {tol:.0%})")
    if rows:
        w = max(len(r[0]) for r in rows)
        print(f"  {'metric'.ljust(w)}  {'baseline':>12}  {'fresh':>12} "
              f" {'delta':>8}")
        for name, b, fv, reg, mark in rows:
            print(f"  {name.ljust(w)}  {b:>12.6g}  {fv:>12.6g} "
                  f" {reg:>+7.1%}  {mark}")
    return problems


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("fresh", nargs="+",
                    help="freshly produced BENCH_*.json file(s)")
    ap.add_argument("--baseline-dir",
                    default=os.path.join(os.path.dirname(
                        os.path.abspath(__file__)), "baselines"),
                    help="directory of committed baseline JSONs")
    ap.add_argument("--tolerance", type=float, default=0.10,
                    help="relative tolerance for byte/ratio metrics")
    ap.add_argument("--time-tolerance", type=float, default=None,
                    help="gate wall-clock metrics at this relative "
                         "tolerance (2.0 = three times as slow fails); "
                         "default: informational only — sub-second wall "
                         "clock swings several-x on shared runners")
    args = ap.parse_args(argv)

    failures: Dict[str, List[str]] = {}
    for fresh_path in args.fresh:
        base_path = os.path.join(args.baseline_dir,
                                 os.path.basename(fresh_path))
        print(f"== {fresh_path} vs {base_path}")
        if not os.path.exists(base_path):
            failures[fresh_path] = [f"no baseline at {base_path} — "
                                    f"commit one to enable the gate"]
            continue
        problems = compare_file(fresh_path, base_path,
                                args.tolerance, args.time_tolerance)
        if problems:
            failures[fresh_path] = problems

    if failures:
        print("\nbench regression gate FAILED:", file=sys.stderr)
        for path, problems in failures.items():
            for p in problems:
                print(f"  {path}: {p}", file=sys.stderr)
        return 1
    print("\nbench regression gate OK "
          f"({len(args.fresh)} file(s) within tolerance)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
