"""Beyond-paper optimizations (paper §7 cites these as complementary; we
implement and measure them):

  * async two-phase checkpoints (CheckFreq) — frozen time vs sync
  * incremental/differential images (Check-N-Run) — bytes written when only
    a fraction of the state changed
  * zstd compression — image size ratio
  * peer replication (Gemini) — push cost
"""
from __future__ import annotations

import shutil
import tempfile

import jax
import jax.numpy as jnp

from benchmarks.common import POLICY, Timer, emit, ladder_config, mesh1
from repro.api import CheckpointOptions, CheckpointSession
from repro.core.replication import MemReplicator
from repro.models.encdec import build_model
from repro.optim import AdamW
from repro.optim.schedule import constant


def _state(size="L"):
    cfg = ladder_config(size)
    model = build_model(cfg, POLICY, None, compute_dtype=jnp.float32,
                        remat=False)
    params = model.init(jax.random.key(0))
    opt = AdamW(lr=constant(1e-3))
    return params, opt.init(params)


def run() -> None:
    mesh = mesh1()
    params, opt_state = _state()
    holder = {"s": {"params": params, "opt": opt_state}}

    # ---- sync vs async frozen time ----
    for mode in ("sync", "async"):
        d = tempfile.mkdtemp(prefix=f"bp_{mode}_")
        try:
            eng = CheckpointSession(d, CheckpointOptions(mode=mode), mesh=mesh)
            eng.attach(lambda: holder["s"])
            with Timer() as t:
                eng.checkpoint(1)
            blocked = t.s          # time the training loop was blocked
            eng.wait_pending()
            emit(f"beyond.{mode}.blocked", blocked * 1e3, "ms")
            st = eng.last_stats
            key = "frozen_s" if mode == "sync" else "locked_total_s"
            emit(f"beyond.{mode}.frozen", st.get(key, 0.0) * 1e3, "ms")
        finally:
            shutil.rmtree(d, ignore_errors=True)

    # ---- incremental: only the optimizer moments change ----
    d = tempfile.mkdtemp(prefix="bp_incr_")
    try:
        eng = CheckpointSession(d, CheckpointOptions(incremental=True),
                                mesh=mesh)
        eng.attach(lambda: {"train_state": holder["s"]})
        eng.checkpoint(1)
        full = eng.last_stats["written_bytes"]
        # touch 1/16 of the tensors
        leaves, treedef = jax.tree_util.tree_flatten(holder["s"])
        leaves = [l + 1.0 if i % 16 == 0 else l
                  for i, l in enumerate(leaves)]
        holder["s"] = jax.tree_util.tree_unflatten(treedef, leaves)
        eng.checkpoint(2)
        delta = eng.last_stats["written_bytes"]
        reused = eng.last_stats["reused_bytes"]
        emit("beyond.incremental.full", full / 2**20, "MiB")
        emit("beyond.incremental.delta", delta / 2**20, "MiB")
        emit("beyond.incremental.reused_pct",
             100.0 * reused / (reused + delta), "%")
    finally:
        shutil.rmtree(d, ignore_errors=True)

    # ---- compression ----
    for compress in (False, True):
        d = tempfile.mkdtemp(prefix="bp_z_")
        try:
            eng = CheckpointSession(d, CheckpointOptions(compress=compress),
                                    mesh=mesh)
            eng.attach(lambda: {"train_state": holder["s"]})
            with Timer() as t:
                eng.checkpoint(1)
            tag = "zstd" if compress else "raw"
            emit(f"beyond.compress.{tag}.bytes",
                 eng.last_stats["written_bytes"] / 2**20, "MiB")
            emit(f"beyond.compress.{tag}.time", t.s * 1e3, "ms")
        finally:
            shutil.rmtree(d, ignore_errors=True)

    # ---- replication push cost ----
    d = tempfile.mkdtemp(prefix="bp_rep_")
    try:
        rep = MemReplicator()
        eng = CheckpointSession(d, replicator=rep, mesh=mesh)
        eng.attach(lambda: {"train_state": holder["s"]})
        with Timer() as t:
            eng.checkpoint(1)
        emit("beyond.replication.ckpt_with_push", t.s * 1e3, "ms")
        emit("beyond.replication.images", len(rep.images), "count")
    finally:
        shutil.rmtree(d, ignore_errors=True)


if __name__ == "__main__":
    run()
