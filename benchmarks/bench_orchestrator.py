"""Multi-tenant recovery bench — the paper's Table-2 contrast, as a
reproducible scenario matrix.

Runs {interception-baseline, sync, async+pipelined} × {preemption,
failure, straggler-JIT} through the orchestrator and reports, per cell,
the per-phase recovery-time breakdown (detect → schedule → restore-read →
replay gap) and goodput (useful-step-seconds / wall-clock).  The
structural claims this reproduces:

  * interception restore *replays the call log* — recovery grows with
    progress, while the CRIUgpu-style engines restore in image-read time;
  * the async+pipelined engine shrinks the frozen window, so preemption
    costs the victim less useful time than the sync engine.

Usage:
    python -m benchmarks.bench_orchestrator [--quick] \
        [--json BENCH_orchestrator.json]
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile
from typing import Any, Dict, List, Optional

from benchmarks.common import emit

ENGINES: Dict[str, Optional[dict]] = {
    # engine name -> CheckpointOptions kwargs (None = interception kind)
    "interception": None,
    "sync": dict(mode="sync", pack_format=1, io_threads=1),
    "async_pipelined": dict(mode="async", pack_format=2, io_threads=0),
}
SCENARIOS = ("preemption", "failure", "straggler")


def run_cell(engine: str, scenario: str, steps: int,
             base_dir: str) -> Dict[str, Any]:
    from repro.api import CheckpointOptions
    from repro.orchestrator import run_scenario
    kw = ENGINES[engine]
    kind = "intercept" if kw is None else "train"
    options = None if kw is None else CheckpointOptions(**kw)
    run_dir = os.path.join(base_dir, f"{engine}_{scenario}")
    summary = run_scenario(scenario, run_dir, options=options,
                           total_steps=steps, kind=kind)

    phases = {k: 0.0 for k in ("detect_s", "schedule_s", "restore_s",
                               "restore_background_s", "replay_s",
                               "total_s")}
    incidents = 0
    goodputs: List[float] = []
    ckpts = jit = 0
    for j in summary["jobs"].values():
        tot = j["recovery_totals"]
        incidents += tot["incidents"]
        for k in phases:
            phases[k] += tot[k]
        goodputs.append(j["goodput"])
        ckpts += j["checkpoints"]
        jit += j["jit_checkpoints"]
    cell = {
        "engine": engine,
        "scenario": scenario,
        "all_done": summary["all_done"],
        "wall_s": summary["wall_s"],
        "cluster_goodput": summary["cluster_goodput"],
        "mean_job_goodput": sum(goodputs) / max(len(goodputs), 1),
        "incidents": incidents,
        "recovery": phases,
        "checkpoints": ckpts,
        "jit_checkpoints": jit,
        "jobs": summary["jobs"],
    }
    pre = f"orch.{engine}.{scenario}"
    emit(f"{pre}.all_done", int(summary["all_done"]), "bool")
    emit(f"{pre}.wall", summary["wall_s"], "s")
    emit(f"{pre}.goodput", summary["cluster_goodput"], "ratio")
    emit(f"{pre}.incidents", incidents, "count")
    for k, v in phases.items():
        emit(f"{pre}.recovery.{k[:-2]}", v, "s")
    return cell


def run(steps: int = 10, engines=None, scenarios=None,
        json_path: Optional[str] = None,
        base_dir: Optional[str] = None) -> Dict[str, Any]:
    engines = list(engines or ENGINES)
    scenarios = list(scenarios or SCENARIOS)
    base = base_dir or tempfile.mkdtemp(prefix="bench_orch_")
    cells = []
    for engine in engines:
        for scenario in scenarios:
            cells.append(run_cell(engine, scenario, steps, base))
    out = {"steps": steps, "engines": engines, "scenarios": scenarios,
           "cells": cells}
    if json_path:
        with open(json_path, "w") as f:
            json.dump(out, f, indent=2, default=str)
        print(f"wrote {json_path}")
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--quick", action="store_true",
                    help="fewer steps + only the preemption/failure rows")
    ap.add_argument("--engines", default=None,
                    help="comma list from: " + ",".join(ENGINES))
    ap.add_argument("--scenarios", default=None,
                    help="comma list from: " + ",".join(SCENARIOS))
    ap.add_argument("--json", default=None, metavar="PATH")
    ap.add_argument("--base-dir", default=None)
    args = ap.parse_args(argv)
    engines = args.engines.split(",") if args.engines else None
    scenarios = args.scenarios.split(",") if args.scenarios else None
    steps = args.steps
    if args.quick:
        steps = min(steps, 8)
        scenarios = scenarios or ["preemption", "failure"]
    out = run(steps=steps, engines=engines, scenarios=scenarios,
              json_path=args.json, base_dir=args.base_dir)
    return 0 if all(c["all_done"] for c in out["cells"]) else 1


if __name__ == "__main__":
    raise SystemExit(main())
