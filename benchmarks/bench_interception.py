"""Paper Fig. 2 — steady-state overhead of API-interception checkpointing.

Trains the same small network with and without the Cricket-style
interception layer for an increasing number of epochs; reports intercepted
call counts, per-call overhead, and total wall-time inflation.  The paper's
claim reproduced here: the overhead is on the critical path and grows with
iteration count, while CRIUgpu's steady state is exactly the baseline
(no interposition — nothing to measure).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Timer, emit


def _make_step():
    @jax.jit
    def step(w, x, y):
        def loss(w):
            h = jnp.tanh(x @ w["w1"])
            p = h @ w["w2"]
            return jnp.mean((p - y) ** 2)
        g = jax.grad(loss)(w)
        return jax.tree.map(lambda a, b: a - 0.01 * b, w, g)
    return step


def run(epochs_list=(1, 2, 4, 8, 16), iters_per_epoch=32) -> None:
    from repro.baselines.interception import InterceptionCheckpointer

    key = jax.random.key(0)
    w = {"w1": jax.random.normal(key, (10, 50)) * 0.1,
         "w2": jax.random.normal(key, (50, 1)) * 0.1}
    x = np.random.default_rng(0).normal(size=(64, 10)).astype(np.float32)
    y = np.random.default_rng(1).normal(size=(64, 1)).astype(np.float32)
    step = _make_step()
    v = w
    for _ in range(8):                     # compile + warm dispatch path
        v = step(v, x, y)
    jax.block_until_ready(v)

    for epochs in epochs_list:
        n = epochs * iters_per_epoch
        v = w
        with Timer() as tb:
            for _ in range(n):
                v = step(v, x, y)
            jax.block_until_ready(v)
        baseline_s = tb.s

        ic = InterceptionCheckpointer()
        ic.register_initial_state("w", w)
        wrapped = ic.wrap(step, "step")
        v = w
        with Timer() as ti:
            for _ in range(n):
                v = wrapped(v, x, y)
            jax.block_until_ready(v)
        intercepted_s = ti.s

        emit(f"fig2.epochs={epochs}.baseline", baseline_s, "s")
        emit(f"fig2.epochs={epochs}.intercepted", intercepted_s, "s")
        emit(f"fig2.epochs={epochs}.calls",
             ic.stats["intercepted_calls"], "calls")
        emit(f"fig2.epochs={epochs}.overhead",
             (intercepted_s - baseline_s) / max(n, 1) * 1e6, "us/call")
        emit(f"fig2.epochs={epochs}.logged_mb",
             ic.stats["logged_bytes"] / 2**20, "MiB")
        # CRIUgpu steady state == baseline by construction (no interposition)
        emit(f"fig2.epochs={epochs}.criugpu", baseline_s, "s")


if __name__ == "__main__":
    run()
