"""Paper Table 3 — checkpoint/restore scaling with data-parallel width.

The paper scales GPT-2 training to 1/2/4 GPUs (each holding a full model
replica) and finds checkpoint size and time grow ~linearly because every
GPU's copy is saved.  We reproduce the setup on 1/2/4 virtual devices
(subprocess per width, like the dry-run) and report BOTH:

  * paper-faithful mode — every replica's shards captured (size ∝ N);
  * CRIUgpu-adapted mode (ours) — replica-0 dedup at capture, the unified
    image stores one logical copy regardless of DP width (beyond-paper win
    recorded in EXPERIMENTS.md).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

from benchmarks.common import emit

_WORKER = textwrap.dedent("""
    import os, json, sys, tempfile, time
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + os.environ["NDEV"])
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from benchmarks.common import ladder_config, POLICY, Timer
    from repro.api import CheckpointSession
    from repro.launch.mesh import make_mesh
    from repro.core.device_plugin import capture_pytree
    from repro.models.encdec import build_model
    from repro.optim import AdamW
    from repro.optim.schedule import constant

    n = int(os.environ["NDEV"])
    mesh = make_mesh((n,), ("data",))
    cfg = ladder_config("L")
    model = build_model(cfg, POLICY, mesh, compute_dtype=jnp.float32,
                        remat=False)
    params = model.init(jax.random.key(0))
    # replicate over DP (the paper's module-level data parallelism)
    params = jax.device_put(params, NamedSharding(mesh, P()))
    opt = AdamW(lr=constant(1e-3))
    opt_state = jax.device_put(opt.init(params), NamedSharding(mesh, P()))

    run_dir = tempfile.mkdtemp(prefix=f"scale{n}_")
    eng = CheckpointSession(run_dir, mesh=mesh)
    eng.attach(lambda: {"train_state": {"params": params,
                                        "opt": opt_state}})
    with Timer() as t:
        eng.checkpoint(1)
    st = dict(eng.last_stats)

    # paper-faithful capture: count every replica's shard bytes
    naive = 0
    for name, tree in {"params": params, "opt": opt_state}.items():
        for leaf in jax.tree.leaves(tree):
            if isinstance(leaf, jax.Array):
                naive += sum(s.data.nbytes for s in leaf.addressable_shards)

    eng2 = CheckpointSession(run_dir, mesh=mesh)
    eng2.attach(lambda: {"train_state": None})
    with Timer() as tr:
        eng2.restore()

    print(json.dumps({
        "ndev": n,
        "ckpt_s": t.s,
        "frozen_s": st["frozen_s"],
        "write_mb": st["written_bytes"] / 2**20,
        "dedup_mb": st["device_bytes"] / 2**20,
        "naive_mb": naive / 2**20,
        "restore_s": tr.s,
    }))
""")


def run(widths=(1, 2, 4)) -> None:
    here = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    for n in widths:
        env = dict(os.environ, NDEV=str(n),
                   PYTHONPATH=os.path.join(here, "src") + ":" + here,
                   JAX_PLATFORMS="cpu")
        env.pop("XLA_FLAGS", None)
        r = subprocess.run([sys.executable, "-c", _WORKER],
                           capture_output=True, text=True, env=env,
                           timeout=600)
        if r.returncode != 0:
            emit(f"table3.dp{n}.error", 1, r.stderr.strip()[-200:])
            continue
        rec = json.loads(r.stdout.strip().splitlines()[-1])
        emit(f"table3.dp{n}.ckpt", rec["ckpt_s"] * 1e3, "ms")
        emit(f"table3.dp{n}.frozen", rec["frozen_s"] * 1e3, "ms")
        emit(f"table3.dp{n}.restore", rec["restore_s"] * 1e3, "ms")
        emit(f"table3.dp{n}.size_paper_faithful", rec["naive_mb"], "MiB")
        emit(f"table3.dp{n}.size_dedup_ours", rec["write_mb"], "MiB")


if __name__ == "__main__":
    run()
