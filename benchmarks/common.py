"""Shared helpers for the benchmark harness.

Models are reduced same-family configs at a ladder of sizes (the paper's
GPT-2 124M→1.5B ladder, scaled to what a CPU container trains in seconds);
every benchmark prints ``name,value,unit`` CSV rows so benchmarks.run can
tee one machine-readable stream.
"""
from __future__ import annotations

import time

from repro.configs import get_smoke_config
from repro.sharding import get_policy

POLICY = get_policy("baseline")

# size ladder: multiplier -> (d_model, layers, d_ff)
LADDER = {
    "S": dict(d_model=64, num_layers=2, d_ff=128),
    "M": dict(d_model=128, num_layers=4, d_ff=256),
    "L": dict(d_model=256, num_layers=4, d_ff=512),
    "XL": dict(d_model=384, num_layers=6, d_ff=768),
}


def ladder_config(size: str, arch: str = "qwen1.5-0.5b", **extra):
    kw = dict(LADDER[size])
    if arch == "qwen1.5-0.5b":
        kw["num_heads"] = kw["d_model"] // 16
        kw["num_kv_heads"] = kw["d_model"] // 16
        kw["head_dim"] = 16
    kw.update(extra)
    return get_smoke_config(arch, vocab_size=2048, **kw)


def mesh1():
    from repro.launch.mesh import make_mesh
    return make_mesh((1,), ("data",))


def emit(name: str, value, unit: str = "") -> None:
    if isinstance(value, float):
        print(f"{name},{value:.6g},{unit}", flush=True)
    else:
        print(f"{name},{value},{unit}", flush=True)


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.s = time.perf_counter() - self.t0
