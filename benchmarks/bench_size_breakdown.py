"""Paper Table 4 — unified checkpoint size and the device/host split.

For each architecture family (reduced configs), snapshot a real training
state (params + optimizer + data cursor + trainer metadata) and report the
total image size with the %GPU(device) / %CPU(host) proportions — the
paper's key observation (device state dominates, >80%) holds by
construction for any real training job.
"""
from __future__ import annotations

import shutil
import tempfile

import jax
import jax.numpy as jnp

from benchmarks.common import POLICY, emit, mesh1
from repro.configs import get_smoke_config
from repro.api import CheckpointSession
from repro.data import TokenPipeline
from repro.models.encdec import build_model
from repro.optim import AdamW
from repro.optim.schedule import constant

ARCHS = ["qwen1.5-0.5b", "mamba2-2.7b", "jamba-v0.1-52b",
         "qwen3-moe-30b-a3b", "whisper-tiny", "qwen2-vl-7b"]


def run() -> None:
    mesh = mesh1()
    for arch in ARCHS:
        cfg = get_smoke_config(arch)
        model = build_model(cfg, POLICY, mesh, compute_dtype=jnp.float32,
                            remat=False)
        opt = AdamW(lr=constant(1e-3))
        params = model.init(jax.random.key(0))
        opt_state = opt.init(params)
        pipe = TokenPipeline(cfg, 4, 64)
        hist = [float(i) for i in range(50)]       # metric history (host)

        run_dir = tempfile.mkdtemp(prefix="bench_t4_")
        try:
            eng = CheckpointSession(run_dir, mesh=mesh)
            eng.attach(lambda: {"train_state": {"params": params,
                                                "opt": opt_state}})
            eng.register_host_state("data_cursor", pipe.state,
                                    pipe.restore_state)
            eng.register_host_state(
                "trainer", lambda: {"step": 123, "loss_hist": hist},
                lambda st: None)
            eng.checkpoint(1)
            st = eng.last_stats
            dev = st["device_bytes"]
            host = st["host_bytes"]
            total = dev + host
            emit(f"table4.{arch}.total", total / 2**20, "MiB")
            emit(f"table4.{arch}.device_pct", 100.0 * dev / total, "%")
            emit(f"table4.{arch}.host_pct", 100.0 * host / total, "%")
        finally:
            shutil.rmtree(run_dir, ignore_errors=True)


if __name__ == "__main__":
    run()
