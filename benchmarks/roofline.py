"""§Roofline report generator — reads the dry-run artifacts and prints the
per-(arch × shape × mesh) three-term roofline table used in EXPERIMENTS.md.

  compute    = HLO_FLOPs / (chips · 197 TFLOP/s)
  memory     = HLO_bytes / (chips · 819 GB/s)
  collective = wire_bytes / (chips · 50 GB/s)   [per-device census]

Also derives MODEL_FLOPS/HLO_FLOPs (useful-compute ratio) and the roofline
fraction = max-term / sum-proxy the §Perf loop hillclimbs.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

ART = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                   "artifacts", "dryrun")


def load(policy: str = "baseline", mesh: str = "pod") -> List[Dict]:
    recs = []
    for f in sorted(glob.glob(os.path.join(ART, f"*__{mesh}__{policy}.json"))):
        recs.append(json.load(open(f)))
    return recs


def roofline_fraction(rec: Dict) -> float:
    """Fraction of the dominant-term bound actually 'useful': how close the
    compiled program is to a program that only did MODEL_FLOPS of compute at
    peak.  ideal_s = MODEL_FLOPS/(chips*peak); actual bound = max(term)."""
    if "roofline_fraction" in rec:
        return rec["roofline_fraction"]
    ideal = rec["model_flops"] / rec["n_devices"] / 197e12
    bound = rec["roofline_bound_s"]
    return ideal / bound if bound else 0.0


def fmt_row(rec: Dict) -> str:
    return (f"{rec['arch']:<22} {rec['shape']:<12} {rec['mesh']:<8} "
            f"{rec['t_compute_s']:>11.3e} {rec['t_memory_s']:>11.3e} "
            f"{rec['t_collective_s']:>11.3e} {rec['dominant']:<10} "
            f"{rec['useful_flops_ratio']:>7.3f} "
            f"{roofline_fraction(rec):>8.4f}")


HEADER = (f"{'arch':<22} {'shape':<12} {'mesh':<8} "
          f"{'t_compute':>11} {'t_memory':>11} {'t_coll':>11} "
          f"{'dominant':<10} {'useful':>7} {'frac':>8}")


def run(policy: str = "baseline") -> None:
    print(HEADER)
    for mesh in ("pod", "multipod"):
        for rec in load(policy, mesh):
            print(fmt_row(rec))


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--policy", default="baseline")
    args = ap.parse_args()
    run(args.policy)


if __name__ == "__main__":
    main()
