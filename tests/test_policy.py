"""Sharding-policy unit + property tests."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import make_mesh
from repro.sharding.policy import (POLICIES, ShardingPolicy, fit_sharding,
                                   get_policy)


def mesh_11():
    return make_mesh((1, 1), ("data", "model"))


def test_baseline_table_roles():
    p = get_policy("baseline")
    assert p.spec("batch") == P(("pod", "data"))
    assert p.spec("heads") == P("model")
    assert p.spec("d_model") == P("data")        # ZeRO-3 FSDP
    assert p.spec("experts") == P("model")       # EP
    assert p.spec(None, "vocab") == P(None, "model")


def test_spec_dedup_first_wins():
    p = get_policy("baseline")
    # batch takes (pod,data); cache_seq would also want data -> dropped
    s = p.spec("batch", "cache_seq")
    assert s == P(("pod", "data"), None)


def test_zero_stage_1_keeps_params_replicated():
    p = get_policy("tp_only")
    assert p.spec("d_model") == P(None)
    assert p.spec("heads") == P("model")


def test_for_mesh_drops_missing_axes():
    p = get_policy("baseline").for_mesh(mesh_11())
    assert p.dp == ("data",)                     # "pod" dropped
    assert p.tp == ("model",)


def test_unknown_logical_axis_raises():
    with pytest.raises(KeyError):
        get_policy("baseline").spec("nonsense")


def test_all_named_policies_build_specs():
    for name, p in POLICIES.items():
        for ax in ("batch", "heads", "d_model", "experts", "cache_seq",
                   "vocab", "ssm_inner"):
            p.spec(ax)


# -------------------------------------------------------- fit_sharding
@settings(max_examples=60, deadline=None)
@given(
    dim=st.integers(1, 64),
    data=st.sampled_from([1, 2, 4, 8, 16]),
    model=st.sampled_from([1, 2, 4, 8, 16]),
)
def test_fit_spec_divisibility_property(dim, data, model):
    """After fitting, every sharded dim is divisible by its axes product,
    and the kept prefix is maximal."""
    from repro.sharding.policy import fit_spec
    sizes = {"data": data, "model": model}
    fitted = fit_spec(P(("data", "model")), (dim,), sizes)
    spec = fitted[0]
    if spec is None:
        prod, kept = 1, ()
    elif isinstance(spec, str):
        prod, kept = sizes[spec], (spec,)
    else:
        prod, kept = int(np.prod([sizes[a] for a in spec])), tuple(spec)
    assert dim % prod == 0
    axes = ("data", "model")
    if len(kept) < len(axes):
        nxt = axes[len(kept)]
        assert dim % (prod * sizes[nxt]) != 0 or sizes[nxt] == 1 \
            or nxt in kept


def test_fit_sharding_pads_missing_spec_dims():
    mesh = mesh_11()
    sh = NamedSharding(mesh, P("data"))
    fitted = fit_sharding(sh, (2, 3, 4), mesh)
    assert len(fitted.spec) >= 1


def test_cache_policy_batch_vs_seq(monkeypatch):
    """_cache_policy picks batch sharding when divisible, else seq."""
    from repro.models.lm import _cache_policy

    class FakeMesh:
        axis_names = ("pod", "data", "model")
        shape = {"pod": 2, "data": 16, "model": 16}

    base = get_policy("baseline")
    p128 = _cache_policy(base, FakeMesh(), 128)     # 128 % 32 == 0
    assert p128.shard_seq_decode is False
    p1 = _cache_policy(base, FakeMesh(), 1)         # batch unshardable
    assert p1.dp == () and p1.seq == ("pod", "data")
    assert p1.shard_seq_decode is True
