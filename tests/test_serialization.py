"""Pack-format + integrity property tests (hypothesis)."""
import os

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.serialization.integrity import (atomic_write_json, crc32,
                                           file_crc32, read_json)
from repro.serialization.pack import (PackReader, PackWriter, dtype_from_str,
                                      dtype_to_str)

DTYPES = ["float32", "float16", "bfloat16", "int32", "int8", "uint8",
          "float64", "bool"]


def _arr(rng, dtype, shape):
    if dtype == "bool":
        return rng.random(shape) > 0.5
    if dtype.startswith(("int", "uint")):
        return rng.integers(0, 100, size=shape).astype(dtype)
    if dtype == "bfloat16":
        import ml_dtypes
        return rng.normal(size=shape).astype(ml_dtypes.bfloat16)
    return rng.normal(size=shape).astype(dtype)


@settings(max_examples=25, deadline=None)
@given(
    dtype=st.sampled_from(DTYPES),
    shape=st.lists(st.integers(0, 7), min_size=0, max_size=4).map(tuple),
    compress=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_pack_roundtrip_property(tmp_path_factory, dtype, shape, compress,
                                 seed):
    rng = np.random.default_rng(seed)
    a = _arr(rng, dtype, shape)
    path = str(tmp_path_factory.mktemp("pk") / "t.pack")
    with PackWriter(path, compress=compress) as w:
        w.add("a", a)
        w.add_bytes("raw", b"\x00\x01\x02")
    with PackReader(path) as r:
        b = r.read_array("a")
        assert r.read_bytes("raw") == b"\x00\x01\x02"
    assert b.dtype == np.asarray(a).dtype
    assert b.shape == tuple(shape)
    np.testing.assert_array_equal(np.asarray(a), b)


@settings(max_examples=20, deadline=None)
@given(dtype=st.sampled_from(DTYPES))
def test_dtype_str_roundtrip(dtype):
    import ml_dtypes
    dt = (np.dtype(ml_dtypes.bfloat16) if dtype == "bfloat16"
          else np.dtype(dtype))
    assert dtype_from_str(dtype_to_str(dt)) == dt


def test_corruption_detected(tmp_path):
    path = str(tmp_path / "t.pack")
    a = np.arange(1024, dtype=np.float32)
    with PackWriter(path) as w:
        w.add("a", a)
    with open(path, "r+b") as f:
        f.seek(100)
        f.write(b"\xff\xff")
    with PackReader(path) as r:
        with pytest.raises(IOError):
            r.read_array("a")
    # verify=False bypasses (used by benchmarks, not restore)
    with PackReader(path, verify=False) as r:
        r.read_array("a")


def test_failed_write_leaves_no_file(tmp_path):
    path = str(tmp_path / "t.pack")
    try:
        with PackWriter(path) as w:
            w.add("a", np.zeros(4))
            raise RuntimeError("boom")
    except RuntimeError:
        pass
    assert not os.path.exists(path)
    assert not os.path.exists(path + ".tmp")


def test_compression_reduces_size(tmp_path):
    a = np.zeros((1 << 16,), np.float32)          # highly compressible
    p1, p2 = str(tmp_path / "r.pack"), str(tmp_path / "c.pack")
    with PackWriter(p1) as w:
        w.add("a", a)
    with PackWriter(p2, compress=True) as w:
        w.add("a", a)
    assert os.path.getsize(p2) < os.path.getsize(p1) / 2
    with PackReader(p2) as r:
        np.testing.assert_array_equal(r.read_array("a"), a)


def test_atomic_json(tmp_path):
    p = str(tmp_path / "m.json")
    atomic_write_json(p, {"a": 1})
    assert read_json(p) == {"a": 1}
    assert not os.path.exists(p + ".tmp")


def test_zero_dim_and_scalar_arrays(tmp_path):
    path = str(tmp_path / "t.pack")
    with PackWriter(path) as w:
        w.add("scalar", np.float32(3.5))
        w.add("empty", np.zeros((0, 4), np.int32))
    with PackReader(path) as r:
        assert r.read_array("scalar") == np.float32(3.5)
        assert r.read_array("empty").shape == (0, 4)


@settings(max_examples=30, deadline=None)
@given(data=st.binary(max_size=256), prefix=st.binary(max_size=64))
def test_crc32_incremental_property(data, prefix):
    """crc32(prefix+data) == crc32(data, crc32(prefix)) — the streaming
    form file_crc32 relies on."""
    assert crc32(prefix + data) == crc32(data, crc32(prefix))


def test_file_crc_matches_bytes_crc(tmp_path):
    p = str(tmp_path / "f.bin")
    data = os.urandom(3 << 20)
    with open(p, "wb") as f:
        f.write(data)
    assert file_crc32(p) == crc32(data)
