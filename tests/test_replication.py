"""Peer-replication (beyond-paper, Gemini-style) tests."""
import os

import jax
import numpy as np

from repro.core import SnapshotEngine
from repro.core.replication import DirReplicator, MemReplicator
from repro.core.snapshot_io import MANIFEST


def _state():
    return {"w": jax.random.normal(jax.random.key(3), (16, 16))}


def test_dir_replicator_fallback_after_primary_loss(tmp_path):
    primary = str(tmp_path / "primary")
    peer = str(tmp_path / "peer")
    state = _state()
    eng = SnapshotEngine(primary, replicator=DirReplicator(peer))
    eng.attach(lambda: {"train_state": state})
    eng.checkpoint(5)
    # node loss: the primary run dir is wiped
    import shutil
    shutil.rmtree(os.path.join(primary, "snapshots"))

    eng2 = SnapshotEngine(primary, replicator=DirReplicator(peer))
    eng2.attach(lambda: {"train_state": None})
    restored = eng2.restore()
    np.testing.assert_array_equal(np.asarray(restored["train_state"]["w"]),
                                  np.asarray(state["w"]))


def test_mem_replicator_roundtrip(tmp_path):
    primary = str(tmp_path / "p")
    rep = MemReplicator()
    state = _state()
    eng = SnapshotEngine(primary, replicator=rep)
    eng.attach(lambda: {"train_state": state})
    eng.checkpoint(1)
    assert 1 in rep.images
    assert MANIFEST in rep.images[1]

    import shutil
    shutil.rmtree(os.path.join(primary, "snapshots"))
    eng2 = SnapshotEngine(primary, replicator=rep)
    eng2.attach(lambda: {"train_state": None})
    restored = eng2.restore()
    np.testing.assert_array_equal(np.asarray(restored["train_state"]["w"]),
                                  np.asarray(state["w"]))


def test_replicator_only_pushes_committed_images(tmp_path):
    """Push happens after manifest commit — a failed write replicates
    nothing."""
    from repro.core.engine import CheckpointAborted
    from repro.core.lock import DeviceLock, LockTimeout

    class SlowLock(DeviceLock):
        def lock(self, arrays):
            raise LockTimeout("injected")

    rep = MemReplicator()
    eng = SnapshotEngine(str(tmp_path / "p"), replicator=rep)
    eng.device_plugin.lock = SlowLock()
    eng.attach(lambda: {"train_state": _state()})
    try:
        eng.checkpoint(1)
    except CheckpointAborted:
        pass
    assert rep.images == {}
