"""MoE dispatch correctness: capacity math, drop semantics, EP shard_map
path vs single-device fallback parity."""
import jax
import jax.numpy as jnp
import numpy as np
from repro.configs import get_smoke_config
from repro.launch.mesh import make_mesh
from repro.models import moe as MOE
from repro.sharding import get_policy

POLICY = get_policy("baseline")


def _setup(E=4, k=2, d=16, f=32, T=24, seed=0, capacity_factor=8.0):
    import dataclasses
    cfg = get_smoke_config("qwen3-moe-30b-a3b",
                           moe_num_experts=E, moe_top_k=k,
                           d_model=d, moe_d_ff=f,
                           moe_capacity_factor=capacity_factor)
    ks = jax.random.split(jax.random.key(seed), 5)
    params = {
        "router": jax.random.normal(ks[0], (d, E), jnp.float32) * 0.1,
        "w_gate": jax.random.normal(ks[1], (E, d, f), jnp.float32) * 0.1,
        "w_up": jax.random.normal(ks[2], (E, d, f), jnp.float32) * 0.1,
        "w_down": jax.random.normal(ks[3], (E, f, d), jnp.float32) * 0.1,
    }
    x = jax.random.normal(ks[4], (2, T // 2, d), jnp.float32)
    return cfg, params, x


def _dense_moe_reference(params, cfg, x):
    """Dense (all-experts) reference: route, compute every expert for every
    token, mix top-k — no capacity, no dispatch tables."""
    B, S, d = x.shape
    xt = x.reshape(-1, d)
    logits = xt @ params["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, cfg.moe_top_k)
    top_w = top_w / top_w.sum(-1, keepdims=True)
    g = jnp.einsum("td,edf->tef", xt, params["w_gate"])
    u = jnp.einsum("td,edf->tef", xt, params["w_up"])
    h = jax.nn.silu(g) * u
    out_all = jnp.einsum("tef,efd->ted", h, params["w_down"])
    mix = jnp.zeros_like(xt)
    for j in range(cfg.moe_top_k):
        sel = jnp.take_along_axis(out_all, top_e[:, j][:, None, None],
                                  axis=1)[:, 0]
        mix = mix + top_w[:, j:j + 1] * sel
    return mix.reshape(B, S, d)


def test_fallback_matches_dense_reference():
    cfg, params, x = _setup()
    y, aux = MOE.moe_block(params, cfg, x, POLICY, None, dropless=True)
    ref = _dense_moe_reference(params, cfg, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
    assert np.isfinite(float(aux)) and float(aux) > 0


def test_shard_map_path_matches_fallback():
    cfg, params, x = _setup()
    mesh = make_mesh((1, 1), ("data", "model"))
    y0, aux0 = MOE.moe_block(params, cfg, x, POLICY, None, dropless=True)
    from repro.launch.mesh import use_mesh
    with use_mesh(mesh):
        y1, aux1 = MOE.moe_block(params, cfg, x, POLICY.for_mesh(mesh),
                                 mesh, dropless=True)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(float(aux0), float(aux1), rtol=1e-5)


def test_capacity_formula():
    assert MOE.capacity(tokens=64, k=2, num_experts=8, factor=1.0) == 16
    assert MOE.capacity(tokens=64, k=2, num_experts=8, factor=1.25) == 20
    # capped at tokens
    assert MOE.capacity(tokens=4, k=2, num_experts=1, factor=10.0) == 4
    # at least k
    assert MOE.capacity(tokens=2, k=2, num_experts=64, factor=1.0) >= 2


def test_tight_capacity_drops_tokens():
    """With factor << 1 some tokens overflow expert capacity and their
    contribution is dropped (GShard semantics) — output differs from the
    dropless run but stays finite."""
    cfg, params, x = _setup(capacity_factor=0.25)
    y_drop, _ = MOE.moe_block(params, cfg, x, POLICY, None, dropless=False)
    y_full, _ = MOE.moe_block(params, cfg, x, POLICY, None, dropless=True)
    assert bool(jnp.all(jnp.isfinite(y_drop)))
    assert float(jnp.max(jnp.abs(y_drop - y_full))) > 1e-6


def test_aux_loss_uniform_routing_is_one():
    """Perfectly uniform routing gives the Switch aux loss its minimum
    E * (1/E) * (1/E) * E = 1."""
    cfg, params, x = _setup(E=4)
    params = dict(params, router=jnp.zeros_like(params["router"]))
    _, aux = MOE.moe_block(params, cfg, x, POLICY, None, dropless=True)
    # ties in top_k break deterministically; P_e is exactly uniform
    assert 0.9 < float(aux) < 1.6


def test_moe_grads_flow_to_all_parts():
    cfg, params, x = _setup()

    def loss(p):
        y, aux = MOE.moe_block(p, cfg, x, POLICY, None, dropless=True)
        return jnp.sum(y ** 2) + 0.01 * aux

    g = jax.grad(loss)(params)
    for name in ("router", "w_gate", "w_up", "w_down"):
        assert float(jnp.max(jnp.abs(g[name]))) > 0, name
