"""Cricket-style interception baseline (paper §2): overhead exists on the
critical path, the log grows with call count, and restore == full replay."""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.baselines.interception import InterceptionCheckpointer


@jax.jit
def stepfn(w, x):
    w = w - 0.1 * jnp.tanh(w @ x) @ x.T
    return w


def test_log_grows_linearly_with_calls(tmp_path):
    ic = InterceptionCheckpointer(str(tmp_path))
    w = jnp.ones((8, 8))
    x = np.ones((8, 8), np.float32)
    ic.register_initial_state("w", w)
    f = ic.wrap(stepfn, "step")
    for _ in range(10):
        w = f(w, x)
    assert ic.stats["intercepted_calls"] == 10
    assert len(ic.log) == 10
    # H2D payloads are copied synchronously (the cudaMemcpy forwarding)
    assert ic.stats["logged_bytes"] == 10 * x.nbytes
    assert ic.stats["intercept_s"] > 0.0


def test_replay_reproduces_state_bitwise(tmp_path):
    ic = InterceptionCheckpointer(str(tmp_path))
    w0 = jax.random.normal(jax.random.key(0), (8, 8))
    x = np.asarray(jax.random.normal(jax.random.key(1), (8, 8)))
    ic.register_initial_state("w", w0)
    f = ic.wrap(stepfn, "step")
    w = w0
    for _ in range(5):
        w = f(w, x)
    path = ic.checkpoint(5)

    ic2 = InterceptionCheckpointer(str(tmp_path))
    results, stats = ic2.restore(path, {"step": stepfn})
    assert stats["replayed_calls"] == 5
    final = [v for v in results.values() if isinstance(v, jax.Array)][-1]
    np.testing.assert_array_equal(np.asarray(final), np.asarray(w))


def test_interception_adds_per_call_overhead(tmp_path):
    """The paper's Fig. 2 claim, reproduced in miniature: wrapped calls are
    strictly slower than unwrapped ones, and the gap persists per call."""
    ic = InterceptionCheckpointer(str(tmp_path))
    w = jnp.ones((16, 16))
    x = np.ones((16, 16), np.float32)
    ic.register_initial_state("w", w)
    wrapped = ic.wrap(stepfn, "step")

    stepfn(w, x).block_until_ready()          # compile once

    n = 50
    t0 = time.perf_counter()
    v = w
    for _ in range(n):
        v = stepfn(v, x)
    v.block_until_ready()
    base = time.perf_counter() - t0

    t0 = time.perf_counter()
    v = w
    for _ in range(n):
        v = wrapped(v, x)
    v.block_until_ready()
    intercepted = time.perf_counter() - t0

    assert intercepted > base
    assert len(ic.log) == n


def test_restore_cost_scales_with_log_length(tmp_path):
    """Replay-based restore re-executes the whole log — restore time grows
    with run length (the paper's prolonged-recovery criticism)."""
    x = np.ones((8, 8), np.float32)

    def run(n):
        ic = InterceptionCheckpointer(str(tmp_path / f"n{n}"))
        w = jnp.ones((8, 8))
        ic.register_initial_state("w", w)
        f = ic.wrap(stepfn, "step")
        for _ in range(n):
            w = f(w, x)
        path = ic.checkpoint(n)
        _, stats = InterceptionCheckpointer(
            str(tmp_path / f"n{n}")).restore(path, {"step": stepfn})
        return stats

    s_short = run(3)
    s_long = run(60)
    assert s_long["replayed_calls"] == 60
    assert s_long["log_entries"] > s_short["log_entries"]
