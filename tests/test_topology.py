"""Topology fingerprinting + GPUID-translation analogue tests."""
import jax
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.topology import (compatibility, mesh_fingerprint,
                                 resolve_sharding, sharding_descriptor,
                                 spec_from_json, spec_to_json)
from repro.launch.mesh import make_mesh


def mesh(names=("data",), shape=(1,)):
    return make_mesh(shape, names)


def test_fingerprint_fields():
    fp = mesh_fingerprint(mesh())
    assert fp["n_devices"] == 1
    assert fp["mesh_axes"] == ["data"]
    assert fp["mesh_shape"] == [1]
    assert fp["process_count"] == 1


def test_compatibility_modes():
    a = mesh_fingerprint(mesh())
    assert compatibility(a, dict(a)) == "identical"
    b = dict(a, kind="other-chip")
    assert compatibility(a, b) == "translated"      # same mesh, new devices
    c = dict(a, mesh_shape=[2], n_devices=2)
    assert compatibility(a, c) == "resharded"       # elastic restore


@pytest.mark.parametrize("spec", [
    P(), P("data"), P(None, "data"), P(("data", "model"), None),
    P(None, None, "model"),
])
def test_spec_json_roundtrip(spec):
    assert spec_from_json(spec_to_json(spec)) == spec


def test_resolve_sharding_drops_missing_axes():
    m1 = mesh(("data", "model"), (1, 1))
    arr = jax.device_put(jax.numpy.zeros((4, 4)),
                         NamedSharding(m1, P("data", "model")))
    desc = sharding_descriptor(arr)
    m2 = mesh(("data",), (1,))                     # scaled-down mesh
    sh = resolve_sharding(desc, m2)
    assert sh.spec == P("data", None)


def test_resolve_sharding_none_without_mesh():
    m1 = mesh()
    arr = jax.device_put(jax.numpy.zeros((4,)), NamedSharding(m1, P("data")))
    assert resolve_sharding(sharding_descriptor(arr), None) is None
