"""Multi-tenant preemption orchestrator: job lifecycle, scheduler,
signals, recovery accounting, and the end-to-end scenarios from the
acceptance criteria (preemption is bit-exact vs an unpreempted run)."""
import json
import os

import pytest

from repro.orchestrator import (InvalidTransition, JobRecord, JobSpec,
                                JobState, Orchestrator, OrchestratorConfig,
                                Scheduler, Signal, SignalChannel,
                                list_job_records, run_scenario)
from repro.orchestrator.recovery import GoodputMeter, RecoveryLog
from repro.orchestrator.workloads import (ServeWorkload, TrainWorkload,
                                          make_workload_factory)


# ------------------------------------------------------------- lifecycle
def test_job_state_machine_legal_path(run_dir):
    rec = JobRecord(JobSpec("j1"), run_dir)
    assert rec.state == JobState.PENDING
    for to in (JobState.RUNNING, JobState.FREEZING, JobState.PREEMPTED,
               JobState.RESTORING, JobState.RUNNING, JobState.DONE):
        rec.transition(to)
    assert rec.terminal
    assert [e["to"] for e in rec.events] == [
        "running", "freezing", "preempted", "restoring", "running", "done"]


def test_job_state_machine_rejects_illegal(run_dir):
    rec = JobRecord(JobSpec("j1"), run_dir)
    with pytest.raises(InvalidTransition):
        rec.transition(JobState.PREEMPTED)     # pending -> preempted
    rec.transition(JobState.RUNNING)
    with pytest.raises(InvalidTransition):
        rec.transition(JobState.RESTORING)     # running -> restoring
    rec.transition(JobState.DONE)
    with pytest.raises(InvalidTransition):
        rec.transition(JobState.RUNNING)       # done is terminal


def test_job_record_persists_and_loads_offline(run_dir):
    rec = JobRecord(JobSpec("alpha", priority=3, total_steps=12,
                            fail_at_step=5), run_dir)
    rec.transition(JobState.RUNNING)
    rec.step = 7
    rec.recovery.open("failure", 1.0, 1.5, 7, 6)
    rec.save()
    # a different process inspects the run dir without the orchestrator
    loaded = list_job_records(run_dir)
    assert len(loaded) == 1
    got = loaded[0]
    assert got.spec.priority == 3 and got.spec.fail_at_step == 5
    assert got.state == JobState.RUNNING and got.step == 7
    assert got.recovery.incidents[0]["cause"] == "failure"
    # the on-disk form is plain JSON (scripting contract)
    raw = json.load(open(os.path.join(run_dir, "jobs", "alpha.json")))
    assert raw["format"] == 1 and raw["spec"]["job_id"] == "alpha"


# --------------------------------------------------------------- signals
def test_signal_channel_delivery_and_handlers():
    ch = SignalChannel()
    seen = []
    ch.register("a", seen.append)
    ch.send("a", Signal.PREEMPT)
    assert seen == [Signal.PREEMPT]           # handler fired at send
    assert ch.pending("a") == Signal.PREEMPT  # peek is non-destructive
    assert ch.checker("a")()
    assert ch.consume("a") == Signal.PREEMPT
    assert ch.pending("a") is None
    assert not ch.checker("b")()


# -------------------------------------------------------------- scheduler
def _recs(*specs):
    return {s.job_id: JobRecord(s) for s in specs}


def test_scheduler_admits_by_priority_then_fifo():
    ch = SignalChannel()
    sched = Scheduler(capacity=2, channel=ch)
    recs = _recs(JobSpec("low", priority=0), JobSpec("hi", priority=9),
                 JobSpec("mid", priority=4))
    d = sched.plan(recs)
    assert d.admit == ["hi", "mid"]           # capacity 2, priority order
    assert d.preempt == []


def test_scheduler_preempts_lowest_priority_victim():
    ch = SignalChannel()
    sched = Scheduler(capacity=2, channel=ch)
    recs = _recs(JobSpec("a", priority=1), JobSpec("b", priority=2))
    for j in ("a", "b"):
        recs[j].transition(JobState.RUNNING)
        sched.allocate(j, 1)
    recs["vip"] = JobRecord(JobSpec("vip", priority=8))
    d = sched.plan(recs)
    assert d.preempt == ["a"]                 # lowest priority evicted
    assert ch.pending("a") == Signal.PREEMPT
    assert ch.pending("b") is None
    # a already-signalled victim is not signalled twice
    assert sched.plan(recs).preempt == []
    # capacity arrives only after the victim acknowledges (freeze+release)
    assert sched.free_capacity() == 0
    sched.release("a")
    recs["a"].transition(JobState.FREEZING)
    recs["a"].transition(JobState.PREEMPTED)
    assert sched.plan(recs).admit == ["vip"]


def test_scheduler_never_preempts_equal_or_higher_priority():
    ch = SignalChannel()
    sched = Scheduler(capacity=1, channel=ch)
    recs = _recs(JobSpec("a", priority=5))
    recs["a"].transition(JobState.RUNNING)
    sched.allocate("a", 1)
    recs["same"] = JobRecord(JobSpec("same", priority=5))
    d = sched.plan(recs)
    assert d.preempt == [] and d.admit == []


def test_scheduler_respects_arrival_tick():
    ch = SignalChannel()
    sched = Scheduler(capacity=1, channel=ch)
    recs = _recs(JobSpec("late", priority=9, arrive_tick=5))
    assert sched.plan(recs, tick=0).admit == []
    assert sched.plan(recs, tick=5).admit == ["late"]


# ------------------------------------------------------------- accounting
def test_recovery_log_phase_breakdown():
    log = RecoveryLog()
    log.open("failure", t_interrupt=10.0, t_detect=10.5,
             step_at_interrupt=9, last_ckpt_step=6)
    log.mark_scheduled(11.0)
    log.mark_restored(11.7, restored_step=6, read_s=0.6)
    log.mark_caught_up(12.9)
    (b,) = log.breakdown()
    assert b["detect_s"] == pytest.approx(0.5)
    assert b["schedule_s"] == pytest.approx(0.5)
    assert b["restore_s"] == pytest.approx(0.7)
    assert b["replay_s"] == pytest.approx(1.2)
    assert b["total_s"] == pytest.approx(2.9)
    assert b["steps_replayed"] == 3
    assert b["meta"]["read_s"] == 0.6
    assert log.totals()["incidents"] == 1


def test_goodput_counts_replayed_steps_once():
    m = GoodputMeter()
    m.record_slice(0, 4, wall_s=4.0)          # steps 0..4
    m.record_slice(2, 6, wall_s=4.0)          # restored to 2, replay 2
    assert m.steps_executed == 8
    assert m.useful_steps == 6
    assert m.useful_step_seconds() == pytest.approx(6.0)
    assert m.goodput(12.0) == pytest.approx(0.5)


# ------------------------------------------------------------ end-to-end
def _digests(summary):
    return {j: v["digest"] for j, v in summary["jobs"].items()}


@pytest.mark.slow
def test_preemption_recovers_bit_exact(tmp_path):
    """Acceptance scenario: low-priority training job preempted mid-run by
    a high-priority job, checkpoints on signal, reschedules, restores, and
    finishes with bit-exact train state vs an unpreempted run."""
    total = 6
    summary = run_scenario("preemption", str(tmp_path / "orch"),
                           total_steps=total)
    assert summary["all_done"]
    lo = summary["jobs"]["lo"]
    assert lo["step"] == total and lo["restarts"] >= 1
    (inc,) = [i for i in lo["recovery"] if i["cause"] == "preemption"]
    assert inc["total_s"] is not None         # closed incident
    # the same job, undisturbed, reaches the identical state
    ref = TrainWorkload(JobSpec("ref", total_steps=total),
                        str(tmp_path / "ref"), mesh=None)
    ref.start()
    while not ref.done:
        ref.run_slice(2)
    ref.finish()
    assert _digests(summary)["lo"] == ref.digest()
    # high-priority job ran to completion too
    assert summary["jobs"]["hi"]["state"] == "done"


@pytest.mark.slow
def test_failure_detected_and_recovered_with_breakdown(tmp_path):
    summary = run_scenario("failure", str(tmp_path / "orch"), total_steps=6)
    assert summary["all_done"]
    j = summary["jobs"]["crashy"]
    assert j["restarts"] == 1
    (inc,) = j["recovery"]
    assert inc["cause"] == "failure"
    # all four phases measured (heartbeat detection costs the deadline)
    for phase in ("detect_s", "schedule_s", "restore_s", "replay_s"):
        assert inc[phase] is not None and inc[phase] >= 0.0
    assert inc["detect_s"] > 0.0
    assert inc["steps_replayed"] >= 0
    # records are inspectable offline after the orchestrator exits
    from repro.cli import main
    assert main(["jobs", str(tmp_path / "orch")]) == 0
    assert main(["jobs", str(tmp_path / "orch"), "--job", "crashy"]) == 0


@pytest.mark.slow
def test_serve_job_preempted_and_resumed_token_exact(tmp_path):
    total = 6
    summary = run_scenario("preemption", str(tmp_path / "orch"),
                           total_steps=total, kind="serve")
    assert summary["all_done"]
    assert summary["jobs"]["lo"]["restarts"] >= 1
    ref = ServeWorkload(JobSpec("ref", kind="serve", total_steps=total),
                        str(tmp_path / "ref"), mesh=None)
    ref.start()
    while not ref.done:
        ref.run_slice(2)
    ref.finish()
    assert _digests(summary)["lo"] == ref.digest()


def test_interception_scenario_runs(tmp_path):
    """The baseline engine rides the same lifecycle: checkpoint = replay
    log, restore = re-execution."""
    summary = run_scenario("failure", str(tmp_path / "orch"),
                           total_steps=8, kind="intercept")
    assert summary["all_done"]
    j = summary["jobs"]["crashy"]
    assert j["restarts"] == 1 and j["step"] == 8


def test_orchestrate_cli_smoke(tmp_path):
    from repro.cli import main
    out = str(tmp_path / "cli_run")
    assert main(["orchestrate", out, "--scenario", "failure",
                 "--kind", "intercept", "--steps", "6"]) == 0
    assert main(["jobs", out]) == 0
    # --json emits raw values a script can consume
    import io
    from contextlib import redirect_stdout
    buf = io.StringIO()
    with redirect_stdout(buf):
        assert main(["jobs", out, "--json"]) == 0
    (row,) = json.loads(buf.getvalue())
    assert isinstance(row["step"], int) and isinstance(
        row["recovery_s"], float)


def test_run_scenario_refuses_stale_run_dir(tmp_path):
    """Re-running into a run_dir with previous job records would restore
    from another run's images — it must be rejected, not silently mixed."""
    d = str(tmp_path / "orch")
    run_scenario("failure", d, total_steps=6, kind="intercept")
    with pytest.raises(ValueError, match="fresh run_dir"):
        run_scenario("failure", d, total_steps=4, kind="intercept")


def test_orchestrator_rejects_impossible_device_demand(tmp_path):
    with pytest.raises(ValueError, match="never be scheduled"):
        Orchestrator(str(tmp_path), [JobSpec("big", devices=4)],
                     config=OrchestratorConfig(capacity=2))


# ----------------------------------------------------- write_error abort
@pytest.mark.slow
def test_write_error_aborts_trainer_promptly(tmp_path, mesh1, monkeypatch):
    from repro.api import CheckpointOptions, SnapshotWriteFailed
    from repro.configs import get_smoke_config
    from repro.runtime.trainer import TrainConfig, Trainer
    from repro.sharding import get_policy
    import jax.numpy as jnp

    tcfg = TrainConfig(batch_size=2, seq_len=32, total_steps=64,
                       warmup_steps=2, compute_dtype=jnp.float32,
                       remat=False, ckpt=CheckpointOptions(mode="async"))
    t = Trainer(get_smoke_config("qwen1.5-0.5b"), tcfg, mesh1,
                get_policy("baseline"), str(tmp_path / "r"))
    t.initialize()
    t.run(2)
    monkeypatch.setattr(t.engine, "_write",
                        lambda ctx: (_ for _ in ()).throw(
                            IOError("disk gone")))
    t.session.checkpoint(t.step)              # async dump fails in the bg
    t.engine._pending.join()                  # failure has landed
    with pytest.raises(SnapshotWriteFailed, match="disk gone"):
        t.run(4)                              # aborts at the next step,
    assert t.step <= 3                        # not at the next dump


@pytest.mark.slow
def test_write_error_marks_job_failed_in_orchestrator(tmp_path):
    from repro.api import CheckpointOptions

    base = str(tmp_path / "orch")
    inner = make_workload_factory(base,
                                  options=CheckpointOptions(mode="async"))

    def factory(spec, attempt):
        wl = inner(spec, attempt)
        wl.session.engine._write = lambda ctx: (_ for _ in ()).throw(
            IOError("dead disk"))
        return wl

    spec = JobSpec("doomed", total_steps=16, ckpt_every=2, max_restarts=0)
    orch = Orchestrator(base, [spec], workload_factory=factory,
                        config=OrchestratorConfig(capacity=1,
                                                  slice_steps=2))
    summary = orch.run()
    j = summary["jobs"]["doomed"]
    assert j["state"] == "failed"
    assert any(i["cause"] == "write_error" for i in j["recovery"])
    # the record on disk says why (offline triage)
    rec = list_job_records(base)[0]
    assert any("write_error" in e for e in rec.events)


# -------------------------------------------------------- planner glue
def test_session_auto_feeds_planner(run_dir):
    """Satellite: measured frozen-window cost flows into τ* with no
    hand-wiring — set_planner + checkpoint is all a caller does."""
    import numpy as np
    from repro.api import CheckpointOptions, CheckpointSession
    from repro.runtime.interval import IntervalPlanner

    state = {"w": np.ones((64, 64), np.float32)}
    planner = IntervalPlanner(mtbf_guess_s=3600.0)
    base = planner.interval_s()               # pessimistic 60 s default δ
    s = CheckpointSession(run_dir, CheckpointOptions(mode="sync"),
                          planner=planner)
    s.attach(lambda: {"train_state": state})
    s.checkpoint(1)
    assert len(planner._costs) == 1           # fed by checkpoint()
    with s.frozen(2):
        pass
    assert len(planner._costs) == 2           # fed by frozen() commit
    assert s.frozen_window_s is not None
    assert planner.ckpt_cost_s < 60.0         # not the pessimistic default
    # sub-second measured dumps shrink τ* vs the 60 s prior
    assert planner.interval_s() < base


def test_frozen_abort_does_not_feed_planner(run_dir):
    import numpy as np
    from repro.api import CheckpointOptions, CheckpointSession
    from repro.runtime.interval import IntervalPlanner

    planner = IntervalPlanner()
    s = CheckpointSession(run_dir, CheckpointOptions(mode="sync"))
    s.set_planner(planner)
    s.attach(lambda: {"train_state": {"w": np.zeros(4, np.float32)}})
    with s.frozen(1) as snap:
        snap.abort()
    assert planner._costs == []               # aborted dump: no sample


def test_interval_observe_prefers_blocked_window():
    from repro.runtime.interval import IntervalPlanner, frozen_window_s

    # async dump: the job was blocked only for locked_total_s
    assert frozen_window_s({"locked_total_s": 0.5, "total_s": 9.0,
                            "frozen_s": 0.2}) == 0.5
    # sync dump: blocked for the whole dump+write
    assert frozen_window_s({"total_s": 3.0, "frozen_s": 0.2}) == 3.0
    assert frozen_window_s({}) is None
    p = IntervalPlanner()
    assert p.observe({"locked_total_s": 1.25}) == 1.25
    assert p._costs == [1.25]
    assert p.observe({}) is None
    assert p._costs == [1.25]


# ------------------------------------------------- lazy restore incidents
@pytest.mark.slow
def test_preemption_with_lazy_restore_bit_exact_and_phase_split(tmp_path):
    """The preemption scenario on a lazy (resume-before-read) engine:
    recovery is still bit-exact vs an undisturbed run, and the incident's
    restore-read splits into restore-critical (the resume point) vs
    restore-background (the streamed cold tail, overlapping replay)."""
    from repro.api import CheckpointOptions
    total = 6
    opts = CheckpointOptions(restore_mode="lazy")   # Trainer defaults the
    summary = run_scenario("preemption", str(tmp_path / "orch"),
                           options=opts, total_steps=total)
    assert summary["all_done"]
    lo = summary["jobs"]["lo"]
    assert lo["step"] == total and lo["restarts"] >= 1
    (inc,) = [i for i in lo["recovery"] if i["cause"] == "preemption"]
    assert inc["total_s"] is not None
    assert inc["restore_s"] is not None                # critical resume
    assert inc["restore_critical_s"] == inc["restore_s"]
    assert inc["meta"].get("restore_mode") == "lazy"
    # the background stream was joined and accounted
    assert inc["restore_background_s"] is not None
    assert inc["restore_background_s"] >= 0.0
    assert lo["recovery_totals"]["restore_background_s"] >= 0.0
    # bit-exact vs an undisturbed run on an eager engine
    ref = TrainWorkload(JobSpec("ref", total_steps=total),
                        str(tmp_path / "ref"), mesh=None)
    ref.start()
    while not ref.done:
        ref.run_slice(2)
    ref.finish()
    assert _digests(summary)["lo"] == ref.digest()
