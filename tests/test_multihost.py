"""Multi-host two-phase commit + interval planner + parallel restore."""
import json
import os
import threading
import time

import jax
import numpy as np
import pytest

from repro.core import SnapshotEngine
from repro.core.multihost import (BarrierTimeout, MultiHostCommit,
                                  merge_host_manifests)
from repro.core.snapshot_io import (MANIFEST, SnapshotStore, SnapshotWriter,
                                    snapshot_dir)
from repro.runtime.interval import (IntervalPlanner, expected_overhead_fraction,
                                    young_daly)
from repro.serialization.integrity import atomic_write_json


# ---------------------------------------------------------------- 2PC
def _write_host_pack(run_dir, step, host_id, arr):
    w = SnapshotWriter(run_dir, step, host_id=host_id)
    w.write_states({"train_state": {
        f"w{host_id}": {"kind": "device_array",
                        "shape": list(arr.shape), "dtype": "<f4",
                        "sharding": {"type": "other", "mesh": None,
                                     "spec": None},
                        "shards": [{"index": [[0, s] for s in arr.shape],
                                    "data": arr}]}}})
    w.write_host_state({})
    w._writer.add_bytes("__commit_meta__", b"{}")
    w._writer.close()
    return {"locations": w.locations, "entry_crcs": w.entry_crcs,
            "states": sorted(w.meta), "files": [w.pack_name]}


def test_two_phase_commit_all_hosts(tmp_path):
    run = str(tmp_path)
    num_hosts = 4
    metas = {}
    commits = [MultiHostCommit(run, 1, h, num_hosts, deadline_s=10)
               for h in range(num_hosts)]

    def host_work(h):
        arr = np.full((4, 4), float(h), np.float32)
        metas[h] = _write_host_pack(run, 1, h, arr)
        time.sleep(0.02 * h)              # stagger phase-1 completion
        commits[h].prepare()

    threads = [threading.Thread(target=host_work, args=(h,))
               for h in range(1, num_hosts)]
    for t in threads:
        t.start()
    host_work(0)

    def writer():
        man = merge_host_manifests(run, 1, num_hosts, {"n_devices": 4},
                                   metas)
        path = snapshot_dir(run, 1)
        atomic_write_json(os.path.join(path, MANIFEST), man)
        return path

    path = commits[0].commit(writer)
    for t in threads:
        t.join()
    assert os.path.exists(os.path.join(path, MANIFEST))
    # markers cleaned after commit
    assert commits[0].prepared_hosts() == []
    # non-coordinators observe the commit
    commits[2].wait_committed()
    man = json.load(open(os.path.join(path, MANIFEST)))
    assert man["num_hosts"] == 4
    assert len(man["files"]) == 4
    # every host's entries are reachable in the merged locations table
    assert any("w2" in k for k in man["locations"])


def test_barrier_timeout_lists_missing_hosts(tmp_path):
    c = MultiHostCommit(str(tmp_path), 2, 0, num_hosts=3, deadline_s=0.2)
    os.makedirs(c.dir, exist_ok=True)
    c.prepare()                            # only host 0 prepares
    with pytest.raises(BarrierTimeout) as e:
        c.wait_all_prepared()
    assert "1, 2" in str(e.value)


def test_no_manifest_before_commit_means_no_snapshot(tmp_path):
    """Phase-1 crash: packs + markers present, no manifest → the snapshot
    is invisible to the store (torn-image guarantee across hosts)."""
    run = str(tmp_path)
    _write_host_pack(run, 5, 0, np.zeros((2, 2), np.float32))
    MultiHostCommit(run, 5, 0, 2).prepare()
    assert SnapshotStore(run).list_steps() == []


def test_wait_committed_times_out(tmp_path):
    c = MultiHostCommit(str(tmp_path), 3, 1, 2, deadline_s=0.2)
    os.makedirs(c.dir, exist_ok=True)
    with pytest.raises(BarrierTimeout):
        c.wait_committed()


def test_coordinator_commit_times_out_without_all_hosts(tmp_path):
    """The commit() path itself (barrier + manifest cut) raises
    BarrierTimeout when a host never prepares — and crucially no manifest
    is written, so the step does not exist."""
    run = str(tmp_path)
    _write_host_pack(run, 7, 0, np.zeros((2, 2), np.float32))
    c = MultiHostCommit(run, 7, 0, num_hosts=2, deadline_s=0.2)
    c.prepare()
    called = []
    with pytest.raises(BarrierTimeout):
        c.commit(lambda: called.append(1))
    assert not called                          # manifest writer never ran
    assert not c.committed()
    assert SnapshotStore(run).list_steps() == []


def test_phase2_crash_restores_previous_committed_snapshot(tmp_path):
    """Coordinator dies after the barrier but before cutting MANIFEST:
    the newer step is invisible and restore falls back to the previous
    committed snapshot (the cross-host torn-image guarantee, end to end
    through the engine)."""
    run = str(tmp_path)
    good = {"w": np.full((8, 8), 3.0, np.float32)}
    eng = SnapshotEngine(run)
    eng.attach(lambda: {"train_state": good})
    eng.checkpoint(1)                          # committed image at step 1

    # step 2: phase 1 completes on this host (pack + PREPARED marker),
    # then the coordinator crashes before phase 2 — no MANIFEST
    _write_host_pack(run, 2, 0, np.full((4, 4), 9.0, np.float32))
    MultiHostCommit(run, 2, 0, num_hosts=2).prepare()
    assert os.path.isdir(snapshot_dir(run, 2))

    store = SnapshotStore(run)
    assert store.list_steps() == [1]           # step 2 does not exist
    eng2 = SnapshotEngine(run)
    eng2.attach(lambda: {"train_state": None})
    restored = eng2.restore()                  # newest *valid* image
    np.testing.assert_array_equal(
        np.asarray(restored["train_state"]["w"]), good["w"])


# ---------------------------------------------------------------- τ*
def test_young_daly_formula():
    assert young_daly(60.0, 6 * 3600.0) == pytest.approx(
        (2 * 60 * 6 * 3600) ** 0.5)
    # async engine shrinks δ -> τ* shrinks with sqrt(δ)
    assert young_daly(1.0, 6 * 3600.0) == pytest.approx(
        young_daly(100.0, 6 * 3600.0) / 10.0)


def test_overhead_minimised_at_tau_star():
    d, m = 30.0, 4 * 3600.0
    tau = young_daly(d, m)
    f_star = expected_overhead_fraction(tau, d, m)
    for factor in (0.25, 0.5, 2.0, 4.0):
        assert f_star <= expected_overhead_fraction(tau * factor, d, m)


def test_planner_adapts_to_measurements():
    p = IntervalPlanner(mtbf_guess_s=3600.0)
    base = p.interval_s()
    for _ in range(4):
        p.record_checkpoint_cost(1.0)      # async-engine-class cost
    fast = p.interval_s()
    assert fast < base                     # cheaper ckpt -> shorter interval
    # two failures an hour apart -> MTBF measured at 1h
    p.record_failure(1000.0)
    p.record_failure(1000.0 + 3600.0)
    assert p.mtbf_s == pytest.approx(3600.0)
    assert p.steps_between_checkpoints(step_time_s=2.0) >= 1


def test_planner_clamps_interval():
    p = IntervalPlanner(min_interval_s=30, max_interval_s=60)
    p.record_checkpoint_cost(1e-9)
    assert p.interval_s() == 30
    p2 = IntervalPlanner(min_interval_s=30, max_interval_s=60,
                         mtbf_guess_s=1e12)
    p2.record_checkpoint_cost(1e6)
    assert p2.interval_s() == 60


# ---------------------------------------------------------------- ||-restore
def test_parallel_restore_bitwise_equal(tmp_path):
    state = {f"w{i}": jax.random.normal(jax.random.key(i), (32, 32))
             for i in range(12)}
    eng = SnapshotEngine(str(tmp_path))
    eng.attach(lambda: {"train_state": state})
    eng.checkpoint(1)

    eng_seq = SnapshotEngine(str(tmp_path), restore_threads=0)
    eng_seq.attach(lambda: {"train_state": None})
    seq = eng_seq.restore()

    eng_par = SnapshotEngine(str(tmp_path), restore_threads=8)
    eng_par.attach(lambda: {"train_state": None})
    par = eng_par.restore()

    for k in state:
        np.testing.assert_array_equal(
            np.asarray(seq["train_state"][k]),
            np.asarray(par["train_state"][k]))
        np.testing.assert_array_equal(
            np.asarray(par["train_state"][k]), np.asarray(state[k]))
