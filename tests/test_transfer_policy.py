"""TransferPolicy edge-case matrix: the shim's untested corners.

PR 9 landed the structured policy with a legacy-kwarg/env shim;
test_precopy covers validation basics, spec round-trips, and the kwargs
conflict.  This closes the rest: the structured env var overriding the
legacy env spellings (silently — the old vars are *ignored*, not
merged), the full invalid-field rejection matrix of ``from_spec``,
residual_bytes_cap rules, and that each deprecation path warns exactly
once per process.
"""
import warnings

import pytest

import repro.api.options as options_mod
from repro.api import CheckpointOptions, TransferPolicy
from repro.api.options import OptionsError

ENV = "REPRO_CKPT_"


@pytest.fixture
def fresh_warnings():
    """Reset the warn-once registry for the keys under test."""
    options_mod._WARNED.discard("options.transfer-kwargs")
    options_mod._WARNED.discard("options.transfer-env")
    yield
    options_mod._WARNED.discard("options.transfer-kwargs")
    options_mod._WARNED.discard("options.transfer-env")


# ------------------------------------------------------- env precedence
def test_env_policy_overrides_legacy_env_vars(fresh_warnings):
    """REPRO_CKPT_TRANSFER_POLICY wins outright: the legacy vars are
    dropped (not merged, not a conflict) and no deprecation fires."""
    env = {ENV + "TRANSFER_POLICY": "mode=delta,workers=3",
           ENV + "TRANSFER": "copy",            # would conflict if read
           ENV + "TRANSFER_WORKERS": "7"}
    with warnings.catch_warnings():
        warnings.simplefilter("error")           # any warning -> failure
        opts = CheckpointOptions.from_env(env)
    assert opts.transfer_policy == TransferPolicy(mode="delta", workers=3)
    # the legacy mirrors reflect the policy, not the ignored env vars
    assert opts.transfer == "delta"
    assert opts.transfer_workers == 3


def test_env_legacy_vars_alone_still_map_with_warning(fresh_warnings):
    env = {ENV + "TRANSFER": "delta", ENV + "TRANSFER_WORKERS": "2"}
    with pytest.warns(DeprecationWarning, match="TRANSFER_POLICY"):
        opts = CheckpointOptions.from_env(env)
    assert opts.transfer_policy == TransferPolicy(mode="delta", workers=2)


def test_env_policy_overrides_legacy_kwargs_via_replace(fresh_warnings):
    """An env-sourced policy applied over legacy-kwarg options wins: the
    stale kwarg mirrors are dropped rather than raising a conflict."""
    with pytest.warns(DeprecationWarning):
        legacy = CheckpointOptions(transfer="copy", transfer_workers=1)
    env_policy = CheckpointOptions.from_env(
        {ENV + "TRANSFER_POLICY": "mode=delta,workers=4"}).transfer_policy
    merged = legacy.replace(transfer_policy=env_policy)
    assert merged.transfer_policy == env_policy
    assert merged.transfer == "delta"
    assert merged.transfer_workers == 4


# ------------------------------------------------- from_spec rejection
@pytest.mark.parametrize("spec, match", [
    ("mode=delta,turbo=1", "unknown TransferPolicy spec key"),
    ("bogus", "must be k=v"),
    ("mode=delta,,workers", "must be k=v"),
    ("workers=two", "bad TransferPolicy spec value for workers"),
    ("precopy_rounds=1.5", "bad TransferPolicy spec value"),
    ("max_blackout_ms=soon", "bad TransferPolicy spec value"),
    ("residual_bytes_cap=1e6", "bad TransferPolicy spec value"),
    ("mode=teleport", "mode must be one of"),
    ("mode=copy,precopy_rounds=2", "requires mode='delta'"),
])
def test_from_spec_rejects_invalid(spec, match):
    with pytest.raises(OptionsError, match=match):
        TransferPolicy.from_spec(spec)


def test_from_spec_tolerates_whitespace_and_empty_parts():
    pol = TransferPolicy.from_spec(" mode = delta , workers = 2 ,")
    assert pol == TransferPolicy(mode="delta", workers=2)


def test_spec_roundtrip_with_all_fields():
    pol = TransferPolicy(mode="delta", workers=2, precopy_rounds=3,
                         max_blackout_ms=50.0, residual_bytes_cap=1 << 20)
    assert TransferPolicy.from_spec(pol.to_spec()) == pol


# ------------------------------------------------- field validation
@pytest.mark.parametrize("kw, match", [
    (dict(workers=-1), "workers must be an int"),
    (dict(workers=1.5), "workers must be an int"),
    (dict(precopy_rounds=-2), "precopy_rounds must be an int"),
    (dict(mode="delta", max_blackout_ms=0), "must be a number > 0"),
    (dict(mode="delta", max_blackout_ms=-5.0), "must be a number > 0"),
    (dict(mode="delta", precopy_rounds=1, residual_bytes_cap=0),
     "residual_bytes_cap must be an int > 0"),
    (dict(mode="delta", precopy_rounds=1, residual_bytes_cap=2.5),
     "residual_bytes_cap must be an int > 0"),
    (dict(mode="delta", residual_bytes_cap=1024),
     "set precopy_rounds > 0"),
    (dict(mode="delta", max_blackout_ms=10.0),
     "set precopy_rounds > 0"),
])
def test_field_validation_matrix(kw, match):
    with pytest.raises(OptionsError, match=match):
        TransferPolicy(**kw)


def test_policy_must_be_policy_instance():
    with pytest.raises(OptionsError, match="must be a TransferPolicy"):
        CheckpointOptions(transfer_policy="mode=delta")


# ------------------------------------------------- warn-once semantics
def test_kwargs_deprecation_fires_exactly_once(fresh_warnings):
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        CheckpointOptions(transfer="delta")
        CheckpointOptions(transfer="copy", transfer_workers=2)
        CheckpointOptions(transfer_workers=1)
    dep = [x for x in w if issubclass(x.category, DeprecationWarning)]
    assert len(dep) == 1
    assert "transfer_policy=TransferPolicy" in str(dep[0].message)


def test_env_deprecation_fires_exactly_once(fresh_warnings):
    env = {ENV + "TRANSFER": "delta"}
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        CheckpointOptions.from_env(env)
        CheckpointOptions.from_env({ENV + "TRANSFER_WORKERS": "3"})
    dep = [x for x in w if issubclass(x.category, DeprecationWarning)]
    assert len(dep) == 1
    assert "TRANSFER_POLICY" in str(dep[0].message)


def test_env_and_kwargs_paths_warn_independently(fresh_warnings):
    """The two deprecation paths are keyed separately: using both legacy
    spellings in one process yields one warning *each*."""
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        CheckpointOptions(transfer="delta")
        CheckpointOptions.from_env({ENV + "TRANSFER": "delta"})
    dep = [x for x in w if issubclass(x.category, DeprecationWarning)]
    assert len(dep) == 2
