"""Elastic / cross-topology restore (the GPUID-translation analogue, taken
further: restore onto a different device count — paper §3.1.2 / §4.4).

The multi-device cases run in a subprocess with 8 host devices so the main
test process keeps its single-device view (per the dry-run isolation rule).
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SnapshotEngine

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_single_device_topology_mode_identical(tmp_path, mesh1):
    from jax.sharding import NamedSharding, PartitionSpec as P
    state = {"w": jax.device_put(jnp.arange(16.0).reshape(4, 4),
                                 NamedSharding(mesh1, P("data")))}
    eng = SnapshotEngine(str(tmp_path), mesh=mesh1)
    eng.attach(lambda: {"train_state": state})
    eng.checkpoint(1)
    eng2 = SnapshotEngine(str(tmp_path), mesh=mesh1)
    eng2.attach(lambda: {"train_state": None})
    restored = eng2.restore(mesh=mesh1)
    assert eng2.last_stats["topology_mode"] == "identical"
    np.testing.assert_array_equal(np.asarray(restored["train_state"]["w"]),
                                  np.asarray(state["w"]))


_ELASTIC_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_mesh, use_mesh
    from repro.configs import get_smoke_config
    from repro.optim import AdamW
    from repro.optim.schedule import constant
    from repro.models.encdec import build_model
    from repro.runtime.elastic import elastic_restore
    from repro.sharding import get_policy
    from repro.core import SnapshotEngine

    run_dir = os.environ["RUN_DIR"]
    cfg = get_smoke_config("qwen1.5-0.5b", d_model=64, num_heads=4,
                           num_kv_heads=4, head_dim=16)
    policy = get_policy("baseline")
    opt = AdamW(lr=constant(1e-3))

    def build(mesh):
        model = build_model(cfg, policy, mesh, compute_dtype=jnp.float32,
                            remat=False)
        return model

    mesh_a = make_mesh((4, 2), ("data", "model"))
    model_a = build(mesh_a)
    with use_mesh(mesh_a):
        params = jax.jit(model_a.init,
                         out_shardings=model_a.param_shardings())(
            jax.random.key(0))
        opt_state = opt.init(params)
    engine = SnapshotEngine(run_dir, mesh=mesh_a)
    engine.attach(lambda: {"train_state": {"params": params,
                                           "opt": opt_state}})
    engine.register_host_state("trainer", lambda: {"step": 3},
                               lambda st: None)
    engine.register_host_state("data_cursor", lambda: {"step": 3},
                               lambda st: None)
    engine.checkpoint(3)

    # ---- restore onto a *smaller* mesh (scale-down after node loss) ----
    mesh_b = make_mesh((2, 2), ("data", "model"))
    model_b = build(mesh_b)
    out = elastic_restore(run_dir, mesh_b, model_b, opt)
    assert out["topology_mode"] == "resharded", out["topology_mode"]
    assert out["step"] == 3

    ref = jax.tree.leaves(params)
    got = jax.tree.leaves(out["params"])
    for a, b in zip(ref, got):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert b.sharding.mesh.devices.size == 4       # lives on mesh_b

    # restored state is *usable*: run a step on the new mesh
    from repro.data import TokenPipeline
    batch = {k: jnp.asarray(v)
             for k, v in TokenPipeline(cfg, 4, 16).next().items()}
    def loss_fn(p, b):
        return model_b.loss(p, b)[0]
    with use_mesh(mesh_b):
        loss, grads = jax.jit(jax.value_and_grad(loss_fn))(out["params"],
                                                           batch)
    assert np.isfinite(float(loss))

    # ---- identical-mesh restore keeps 1:1 shard placement -------------
    model_a2 = build(mesh_a)
    out2 = elastic_restore(run_dir, mesh_a, model_a2, opt)
    assert out2["topology_mode"] == "identical", out2["topology_mode"]
    for a, b in zip(ref, jax.tree.leaves(out2["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    print("ELASTIC_OK")
""")


@pytest.mark.slow
def test_elastic_restore_across_meshes(tmp_path):
    env = dict(os.environ, RUN_DIR=str(tmp_path / "run"),
               PYTHONPATH=os.path.join(REPO, "src"), JAX_PLATFORMS="cpu")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _ELASTIC_SCRIPT],
                       capture_output=True, text=True, env=env,
                       timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "ELASTIC_OK" in r.stdout
