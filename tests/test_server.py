"""Decode-server serving-state snapshots: checkpoint a half-finished
generation, restore into a fresh server, continue token-exact (the paper's
inference-side story — Modal/MemVerge cold-start snapshots)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.runtime.server import DecodeServer
from repro.sharding import get_policy

POLICY = get_policy("baseline")


def make_server(arch, run_dir, mesh):
    cfg = get_smoke_config(arch)
    srv = DecodeServer(cfg, POLICY, mesh, run_dir, max_seq=64)
    from repro.models.encdec import build_model
    model = build_model(cfg, POLICY, mesh, compute_dtype=jnp.float32,
                        remat=False)
    srv.load(model.init(jax.random.key(0)))
    return srv, cfg


def _prompt(cfg, B=2, S=12):
    from repro.data import TokenPipeline
    return TokenPipeline(cfg, B, S, seed=9).next()


@pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "mamba2-2.7b"])
def test_snapshot_mid_generation_token_exact(arch, tmp_path, mesh1):
    run = str(tmp_path / "srv")
    srv, cfg = make_server(arch, run, mesh1)
    batch = _prompt(cfg)
    srv.start(batch)
    srv.decode(3)
    srv.checkpoint(0)
    expected = srv.decode(4).copy()        # uninterrupted continuation

    srv2, _ = make_server(arch, run, mesh1)
    srv2.start(batch)                       # warm structures, then restore
    srv2.restore()
    assert srv2.pos == srv.pos - 4
    got = srv2.decode(4)
    np.testing.assert_array_equal(expected, got)


@pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "mamba2-2.7b"])
def test_cold_boot_restore_token_exact(arch, tmp_path, mesh1):
    """A *fresh* server — nothing loaded, never started — restores
    straight from the image: the decode cursor sizes an abstract cache
    skeleton, no prefill re-execution (the fleet fan-out path)."""
    run = str(tmp_path / "srv")
    srv, cfg = make_server(arch, run, mesh1)
    batch = _prompt(cfg)
    srv.start(batch)
    srv.decode(3)
    srv.checkpoint(0)
    expected = srv.decode(4).copy()

    srv2 = DecodeServer(get_smoke_config(arch), POLICY, mesh1, run,
                        max_seq=64)
    srv2.restore()                          # cold: no start(), no load()
    assert srv2.pos == srv.pos - 4
    got = srv2.decode(4)
    np.testing.assert_array_equal(expected, got)


def test_cold_boot_restore_lazy_token_exact(tmp_path, mesh1):
    """Cold boot under lazy restore: params place first, the cache
    skeleton is abstract until the first decode joins the stream."""
    from repro.api import CheckpointOptions
    run = str(tmp_path / "srv")
    srv, cfg = make_server("qwen1.5-0.5b", run, mesh1)
    batch = _prompt(cfg)
    srv.start(batch)
    srv.decode(3)
    srv.checkpoint(0)
    expected = srv.decode(4).copy()

    srv2 = DecodeServer(cfg, POLICY, mesh1, run, max_seq=64,
                        options=CheckpointOptions(restore_mode="lazy"))
    srv2.restore()
    assert srv2.params is not None          # critical set placed
    got = srv2.decode(4)                    # first decode joins the stream
    np.testing.assert_array_equal(expected, got)
    assert not srv2.session.lazy_pending


def test_greedy_decode_matches_model_argmax(tmp_path, mesh1):
    srv, cfg = make_server("qwen1.5-0.5b", str(tmp_path / "s"), mesh1)
    batch = _prompt(cfg, B=1, S=8)
    srv.start(batch)
    toks = srv.decode(2)
    assert toks.shape == (1, 8 + 1 + 2)
    assert int(toks.max()) < cfg.vocab_size    # padded vocab never sampled
