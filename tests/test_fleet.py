"""Snapshot-fork serving fleet: K replicas from one committed image.

The fleet-grade harness for the serving-scale story: every replica
booted from the image must decode token-identical to the solo unforked
server (under eager *and* lazy restore), CAS dedup must make fan-out
bytes sub-linear in K, a mid-boot ``host_kill`` must quarantine the dead
replica without taking the fleet down, and the autoscaler must both boot
on a spike and drain on idle — all deterministic, no wall-clock
assertions.
"""
import numpy as np
import pytest

from repro.chaos.injector import FaultInjector
from repro.chaos.plan import ChaosConfig, FaultEvent
from repro.orchestrator.fleet import FleetConfig, ServingFleet
from repro.orchestrator.workloads import host_cas_dir
from repro.transfer import ChunkStore


def _mini(**kw):
    base = dict(replicas=2, hosts=1, warm_tokens=2, max_seq=48)
    base.update(kw)
    return FleetConfig(**base)


@pytest.mark.parametrize("mode", ["eager", "lazy"])
def test_replicas_bit_exact_vs_solo(mode, run_dir, mesh1):
    """Every forked replica continues the generation token-identical to
    the solo server that never went through a restore."""
    fleet = ServingFleet(run_dir, _mini(restore_mode=mode), mesh=mesh1)
    fleet.build_source_image()
    # the unforked continuation: 5 more tokens past the image point
    solo = fleet.source.decode(5).copy()
    fleet.boot_fleet()
    assert len(fleet.serving()) == 2
    for rep in fleet.replicas:
        assert rep.status == "serving"
        assert rep.ttft_s is not None and rep.ttft_s > 0
        got = rep.server.decode(4)          # boot already decoded 1
        np.testing.assert_array_equal(solo, got)
        # the boot is one fully-phased recovery incident (TTFT window)
        (b,) = rep.recovery.breakdown()
        assert b["cause"] == "fleet_boot"
        assert b["total_s"] is not None
        assert b["transfer_s"] is not None
        assert b["restore_s"] is not None


def test_cold_boot_needs_no_prestarted_skeleton(run_dir, mesh1):
    """The satellite fix: a fresh DecodeServer restores straight from the
    image — no prefill re-execution, no hand-crafted cache skeleton."""
    fleet = ServingFleet(run_dir, _mini(replicas=1), mesh=mesh1)
    fleet.build_source_image()
    rep = fleet.boot_replica()
    srv = rep.server
    assert srv.pos == fleet.image_step + 1      # image point + first token
    assert srv.params is not None and srv.cache is not None


def test_host_kill_mid_boot_quarantines_replica(run_dir, mesh1):
    """A host dying mid-boot kills that replica's boot; the fleet keeps
    serving and the dead replica is diagnosably quarantined."""
    cfg = ChaosConfig(
        seed=0, hosts=1, counts={"host_kill": 1},
        events=[FaultEvent(kind="host_kill", job_id="r001",
                           at_step=0, seq=0)])
    inj = FaultInjector(cfg)
    fleet = ServingFleet(run_dir, _mini(replicas=3), mesh=mesh1)
    fleet.build_source_image()
    with inj.installed():
        fleet.boot_fleet()
    dead = fleet.quarantined()
    assert [r.rid for r in dead] == ["r001"]
    assert "chaos" in dead[0].diagnosis
    assert dead[0].server is None
    assert inj.injected_counts() == {"host_kill": 1}
    # the surviving replicas serve the whole trace
    live = fleet.serving()
    assert len(live) == 2
    stats = fleet.serve_trace([2, 2, 0, 0, 0])
    assert stats["requests_unserved"] == 0
    solo = fleet.source.decode(1).copy()
    got = live[0].server.tokens
    np.testing.assert_array_equal(solo, got[:, : solo.shape[1]])


def test_cas_dedup_makes_fanout_sublinear(run_dir, mesh1):
    """K replicas on one host: the first boot fills the host CAS, every
    later boot negotiates have/want and ships zero new chunk bytes —
    total restore bytes stay under 2x one restore for any K."""
    K = 6
    fleet = ServingFleet(run_dir, _mini(replicas=K), mesh=mesh1)
    fleet.build_source_image()
    fleet.boot_fleet()
    sent = [r.transfer["bytes_sent"] for r in fleet.replicas]
    assert sent[0] > 0                       # cold fill pays once
    assert all(s == 0 for s in sent[1:])     # warm boots ship nothing
    assert sum(sent) < 2 * sent[0]           # sub-linear in K
    # the host CAS's own transfer log agrees with our accounting
    log = ChunkStore(host_cas_dir(run_dir, "h0")).transfer_log()
    assert len(log) == K
    assert sum(t["bytes_sent"] for t in log) == sum(sent)
    assert all(t["chunks_reused"] > 0 for t in log[1:])
    s = fleet.summary()
    assert s["restore_bytes_vs_image"] < 2.0
    assert s["hosts"]["h0"]["cas_log_bytes_sent"] == sum(sent)


def test_serve_trace_autoscales_up_and_drains(run_dir, mesh1):
    """Queue spike boots a replica through the measured path; sustained
    idle drains back down — both visible in the summary."""
    fleet = ServingFleet(
        run_dir, _mini(replicas=2, scale_up_depth=2, drain_idle_ticks=1,
                       min_replicas=1, max_replicas=8), mesh=mesh1)
    fleet.build_source_image()
    fleet.boot_fleet()
    stats = fleet.serve_trace([1, 12, 0, 0, 0, 0])
    assert stats["requests_unserved"] == 0
    assert stats["requests_served"] == 13
    assert stats["autoscale_boots"] >= 1
    assert stats["drains"] >= 1
    assert stats["goodput_requests_per_replica_tick"] > 0
    booted = [r for r in fleet.replicas if r.autoscaled]
    assert booted and all(r.ttft_s is not None for r in booted)
    # deterministic: the same trace replays to the same counts
    fleet2 = ServingFleet(
        str(run_dir) + "_b",
        _mini(replicas=2, scale_up_depth=2, drain_idle_ticks=1,
              min_replicas=1, max_replicas=8), mesh=mesh1)
    fleet2.build_source_image()
    fleet2.boot_fleet()
    stats2 = fleet2.serve_trace([1, 12, 0, 0, 0, 0])
    for k in ("requests_served", "autoscale_boots", "drains", "ticks",
              "replica_ticks"):
        assert stats[k] == stats2[k]
