"""SnapshotEngine unit + integration tests: the paper's checkpoint/restore
workflow (lock → checkpoint → dump → unlock; restore), plugin hook ordering,
abort semantics, async mode, incremental mode, GC, corruption fallback."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SnapshotEngine
from repro.core.engine import CheckpointAborted
from repro.core.lock import DeviceLock, LockTimeout
from repro.core.plugins import Plugin
from repro.core.snapshot_io import MANIFEST, SnapshotStore, snapshot_dir


def make_state(key=0, n=4):
    ks = jax.random.split(jax.random.key(key), n)
    return {f"w{i}": jax.random.normal(ks[i], (8, 16), jnp.float32)
            for i in range(n)}


def attach_basic(engine, state_holder, host_holder):
    engine.attach(lambda: {"train_state": state_holder["state"]})
    engine.register_host_state(
        "host", lambda: host_holder["v"],
        lambda v: host_holder.__setitem__("v", v))


# ------------------------------------------------------------ round trip
def test_checkpoint_restore_bitwise(run_dir):
    state = make_state()
    holder = {"state": state}
    host = {"v": {"step": 7, "note": "hello"}}
    eng = SnapshotEngine(run_dir)
    attach_basic(eng, holder, host)
    path = eng.checkpoint(7)
    assert os.path.exists(os.path.join(path, MANIFEST))

    host2 = {"v": None}
    eng2 = SnapshotEngine(run_dir)
    attach_basic(eng2, {"state": None}, host2)
    restored = eng2.restore()
    assert host2["v"] == {"step": 7, "note": "hello"}
    for k, v in state.items():
        np.testing.assert_array_equal(
            np.asarray(restored["train_state"][k]), np.asarray(v))


def test_restore_into_preserves_types(run_dir):
    from repro.optim import AdamW
    from repro.optim.adamw import OptState
    from repro.optim.schedule import constant
    params = make_state()
    opt = AdamW(lr=constant(1e-3))
    opt_state = opt.init(params)
    holder = {"state": {"params": params, "opt": opt_state}}
    eng = SnapshotEngine(run_dir)
    eng.attach(lambda: {"train_state": holder["state"]})
    eng.checkpoint(1)

    eng2 = SnapshotEngine(run_dir)
    eng2.attach(lambda: {"train_state": None})
    template = {"params": params, "opt": opt.init(params)}
    out = eng2.restore_into(template, state="train_state")
    assert isinstance(out["opt"], OptState)
    np.testing.assert_array_equal(np.asarray(out["opt"].step),
                                  np.asarray(opt_state.step))


def test_missing_leaf_raises(run_dir):
    params = make_state()
    eng = SnapshotEngine(run_dir)
    eng.attach(lambda: {"train_state": {"params": params}})
    eng.checkpoint(1)
    eng2 = SnapshotEngine(run_dir)
    eng2.attach(lambda: {"train_state": None})
    bigger = {"params": dict(params, extra=jnp.zeros((2,)))}
    with pytest.raises(KeyError):
        eng2.restore_into(bigger, state="train_state")


# ------------------------------------------------------------ hook order
class OrderPlugin(Plugin):
    name = "order"

    def __init__(self, log):
        self.log = log

    def init(self, op):
        self.log.append(("init", op))

    def exit(self, op, success):
        self.log.append(("exit", op, success))

    def pause_devices(self, ctx):
        self.log.append("pause_devices")

    def checkpoint_devices(self, ctx):
        self.log.append("checkpoint_devices")

    def dump_ext_state(self, ctx):
        self.log.append("dump_ext_state")

    def restore_ext_state(self, ctx):
        self.log.append("restore_ext_state")

    def update_topology_map(self, ctx):
        self.log.append("update_topology_map")

    def resume_devices_late(self, ctx):
        self.log.append("resume_devices_late")


def test_hook_ordering_contract(run_dir):
    """The paper's workflow ordering (Fig. 4a): PAUSE → CHECKPOINT → DUMP
    on dump; RESTORE_EXT → UPDATE_TOPOLOGY → RESUME_LATE on restore."""
    log = []
    eng = SnapshotEngine(run_dir, plugins=[OrderPlugin(log)])
    eng.attach(lambda: {"train_state": make_state()})
    eng.checkpoint(1)
    assert log == [("init", "dump"), "pause_devices", "checkpoint_devices",
                   "dump_ext_state", ("exit", "dump", True)]
    log.clear()
    eng.restore()
    assert log == [("init", "restore"), "restore_ext_state",
                   "update_topology_map", "resume_devices_late",
                   ("exit", "restore", True)]


def test_lock_timeout_aborts_to_running(run_dir):
    """cuda-checkpoint's 10s lock timeout analogue: if the drain exceeds
    the deadline the checkpoint aborts and exit(success=False) fires."""
    log = []

    class SlowLock(DeviceLock):
        def lock(self, arrays):
            raise LockTimeout("injected")

    eng = SnapshotEngine(run_dir, plugins=[OrderPlugin(log)])
    eng.device_plugin.lock = SlowLock()
    eng.attach(lambda: {"train_state": make_state()})
    with pytest.raises(CheckpointAborted):
        eng.checkpoint(5)
    assert ("exit", "dump", False) in log
    assert SnapshotStore(run_dir).list_steps() == []     # nothing committed


def test_leftover_reference_warning(run_dir):
    """NVML-leftover analogue (§4.4): live device arrays outside the
    registered roots are detected and recorded, not captured."""
    leftover = jnp.ones((128, 128), jnp.float32)          # intentionally live
    eng = SnapshotEngine(run_dir)
    eng.attach(lambda: {"train_state": make_state()})
    eng.checkpoint(1)
    man = SnapshotStore(run_dir).manifest(1)
    assert man["stats"]["leftover_device_bytes"] >= leftover.nbytes
    assert any("outside the registered roots" in w
               for w in man.get("warnings", []))


# ------------------------------------------------------------ async mode
def test_async_checkpoint_resumes_before_write(run_dir):
    state = make_state()
    eng = SnapshotEngine(run_dir, mode="async")
    eng.attach(lambda: {"train_state": state})
    eng.checkpoint(3)
    # wait_pending joins the background writer; manifest must then exist
    eng.wait_pending()
    assert SnapshotStore(run_dir).list_steps() == [3]
    assert "locked_total_s" in eng.last_stats


def test_async_overlapping_checkpoints_serialize(run_dir):
    state = make_state()
    eng = SnapshotEngine(run_dir, mode="async")
    eng.attach(lambda: {"train_state": state})
    eng.checkpoint(1)
    eng.checkpoint(2)          # must join the pending write first
    eng.wait_pending()
    assert SnapshotStore(run_dir).list_steps() == [1, 2]


# ------------------------------------------------------------ incremental
def test_incremental_reuses_unchanged_entries(run_dir):
    state = make_state()
    holder = {"state": state}
    eng = SnapshotEngine(run_dir, incremental=True)
    eng.attach(lambda: {"train_state": holder["state"]})
    eng.checkpoint(1)
    # change exactly one tensor
    holder["state"] = dict(state, w0=state["w0"] + 1.0)
    eng.checkpoint(2)
    man2 = SnapshotStore(run_dir).manifest(2)
    assert man2["parent"] == 1
    assert man2["reused_bytes"] > 0
    # unchanged entries point at the step-1 pack
    locs = man2["locations"]
    assert any(loc.startswith("step_00000001") for loc in locs.values())
    assert any(loc.startswith("step_00000002") for loc in locs.values())

    # restore resolves the delta chain transparently
    eng2 = SnapshotEngine(run_dir)
    eng2.attach(lambda: {"train_state": None})
    restored = eng2.restore()
    np.testing.assert_array_equal(
        np.asarray(restored["train_state"]["w0"]),
        np.asarray(state["w0"] + 1.0))
    np.testing.assert_array_equal(
        np.asarray(restored["train_state"]["w1"]), np.asarray(state["w1"]))


def test_gc_preserves_incremental_parents(run_dir):
    state = make_state()
    holder = {"state": state}
    eng = SnapshotEngine(run_dir, incremental=True, keep=1)
    eng.attach(lambda: {"train_state": holder["state"]})
    eng.checkpoint(1)
    holder["state"] = dict(state, w0=state["w0"] + 1.0)
    eng.checkpoint(2)          # keep=1 would drop step 1, but 2 references it
    steps = SnapshotStore(run_dir).list_steps()
    assert 1 in steps and 2 in steps

    # a full (non-incremental) snapshot lets GC actually collect
    eng.incremental = False
    holder["state"] = dict(state, w0=state["w0"] + 2.0)
    eng.checkpoint(3)
    assert SnapshotStore(run_dir).list_steps() == [3]


# ------------------------------------------------------------ corruption
def _pack_file(run_dir, step):
    """First physical pack file of a snapshot (v1 single file or v2
    stripe 0 — both hold payload chunks right after the 16-byte header)."""
    from repro.serialization.pack import pack_files
    return pack_files(os.path.join(snapshot_dir(run_dir, step),
                                   "host0000.pack"))[0]


def test_restore_falls_back_past_torn_snapshot(run_dir):
    state = make_state()
    eng = SnapshotEngine(run_dir)
    eng.attach(lambda: {"train_state": state})
    eng.checkpoint(1)
    eng.checkpoint(2)
    # corrupt the newest image's payload (torn write)
    pack = _pack_file(run_dir, 2)
    with open(pack, "r+b") as f:
        f.seek(40)
        f.write(b"\xde\xad\xbe\xef" * 8)
    eng2 = SnapshotEngine(run_dir)
    eng2.attach(lambda: {"train_state": None})
    restored = eng2.restore()            # CRC check skips step 2 -> step 1
    for k, v in state.items():
        np.testing.assert_array_equal(
            np.asarray(restored["train_state"][k]), np.asarray(v))


def test_explicit_step_restore_rejects_torn_pack(run_dir):
    """An explicitly requested step must get the same CRC rigor as the
    newest-valid scan: a torn image raises instead of restoring garbage."""
    state = make_state()
    eng = SnapshotEngine(run_dir)
    eng.attach(lambda: {"train_state": state})
    eng.checkpoint(1)
    eng.checkpoint(2)
    pack = _pack_file(run_dir, 2)
    with open(pack, "r+b") as f:
        f.seek(40)
        f.write(b"\xde\xad\xbe\xef" * 8)
    eng2 = SnapshotEngine(run_dir)
    eng2.attach(lambda: {"train_state": None})
    with pytest.raises(Exception) as ei:
        eng2.restore(step=2)                 # explicit step, torn image
    assert "CRC" in str(ei.value) or "crc" in str(ei.value)
    # the untouched image still restores explicitly
    restored = eng2.restore(step=1)
    np.testing.assert_array_equal(
        np.asarray(restored["train_state"]["w0"]), np.asarray(state["w0"]))


def test_uncommitted_snapshot_is_invisible(run_dir):
    """No MANIFEST => the snapshot does not exist (atomic commit)."""
    state = make_state()
    eng = SnapshotEngine(run_dir)
    eng.attach(lambda: {"train_state": state})
    eng.checkpoint(1)
    d = snapshot_dir(run_dir, 99)
    os.makedirs(d)
    with open(os.path.join(d, "host0000.pack"), "wb") as f:
        f.write(b"garbage")
    assert SnapshotStore(run_dir).list_steps() == [1]


def test_manifest_inventory_flags(run_dir):
    eng = SnapshotEngine(run_dir)
    eng.attach(lambda: {"train_state": make_state()})
    eng.checkpoint(4)
    man = SnapshotStore(run_dir).manifest(4)
    assert man["has_device_state"] is True       # paper §3.1.1 inventory flag
    assert man["states"] == ["train_state"]
    assert man["step"] == 4
    assert "topology" in man and man["topology"]["n_devices"] >= 1
    assert man["stats"]["device_bytes"] > 0


def test_checkpoint_stats_reported(run_dir):
    eng = SnapshotEngine(run_dir)
    eng.attach(lambda: {"train_state": make_state()})
    eng.checkpoint(1)
    st = eng.last_stats
    for key in ("lock_s", "device_to_host_s", "frozen_s", "write_s",
                "written_bytes", "device_bytes"):
        assert key in st, key
