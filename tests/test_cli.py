"""`python -m repro` CLI against real image directories (the CRIT
analogue): check, inspect, verify, gc, restore --dry-run, and the
corresponding failure exit codes."""
import os

import jax
import jax.numpy as jnp
import pytest

from repro.api import CheckpointOptions, CheckpointSession
from repro.cli import main
from repro.core.snapshot_io import snapshot_dir


@pytest.fixture
def populated_run(run_dir):
    """Three incremental snapshots with device + host state."""
    ks = jax.random.split(jax.random.key(0), 2)
    state = {"w": jax.random.normal(ks[0], (8, 8), jnp.float32),
             "b": jax.random.normal(ks[1], (8,), jnp.float32)}
    s = CheckpointSession(run_dir, CheckpointOptions(incremental=True))
    s.attach(lambda: {"train_state": state})
    s.register_host_state("cursor", lambda: {"pos": 5}, lambda v: None)
    s.checkpoint(1)
    s.checkpoint(2)
    state["w"] = state["w"] + 1.0       # make step 3 actually differ
    s.checkpoint(3)
    return run_dir


def test_check_ok(capsys):
    assert main(["check"]) == 0
    out = capsys.readouterr().out
    assert "repro check: OK" in out
    assert "backends" in out or "jax" in out


def test_check_json(capsys, tmp_path):
    assert main(["check", "--run-dir", str(tmp_path / "x"),
                 "--json"]) == 0
    import json
    data = json.loads(capsys.readouterr().out)
    assert data["ok"] is True
    assert "backends" in data["capabilities"]


def test_inspect_table(populated_run, capsys):
    assert main(["inspect", populated_run]) == 0
    out = capsys.readouterr().out
    assert "3 snapshot(s)" in out
    for col in ("step", "written", "parent chain"):
        assert col in out
    # incremental deltas: step 2 reuses everything from step 1
    assert "2 -> 1" in out


def test_inspect_single_step(populated_run, capsys):
    assert main(["inspect", populated_run, "--step", "3"]) == 0
    out = capsys.readouterr().out
    assert "snapshot step 3" in out
    assert "parent chain: 3 -> 2 -> 1" in out
    assert "train_state" in out


def test_inspect_missing_dir(tmp_path):
    with pytest.raises(SystemExit):
        main(["inspect", str(tmp_path / "nope")])


def test_verify_ok_and_corrupt(populated_run, capsys):
    assert main(["verify", populated_run]) == 0
    assert "OK" in capsys.readouterr().out
    from repro.serialization.pack import pack_files
    pack = pack_files(os.path.join(snapshot_dir(populated_run, 3),
                                   "host0000.pack"))[0]
    with open(pack, "r+b") as f:
        f.seek(40)
        f.write(b"\xde\xad\xbe\xef" * 4)
    assert main(["verify", populated_run]) == 1
    out = capsys.readouterr().out
    assert "step 3: CORRUPT" in out
    assert "step 1: OK" in out
    # single-step form
    assert main(["verify", populated_run, "--step", "3"]) == 1


def test_restore_dry_run(populated_run, capsys):
    assert main(["restore", populated_run, "--dry-run"]) == 0
    out = capsys.readouterr().out
    assert "restore --dry-run OK" in out
    assert "train_state" in out
    assert "cursor" in out


def test_restore_without_dry_run_refuses(populated_run):
    with pytest.raises(SystemExit):
        main(["restore", populated_run])


def test_gc_keeps_referenced_parents(populated_run, capsys):
    # step 3 still reads unchanged entries out of step 1's pack, so gc
    # must keep 1; step 2's pack is referenced by nobody and goes.
    assert main(["gc", populated_run, "--keep", "1", "--dry-run"]) == 0
    out = capsys.readouterr().out
    assert "would remove 1 snapshot(s): [2]" in out
    assert main(["gc", populated_run, "--keep", "1"]) == 0
    out = capsys.readouterr().out
    assert "removed 1 snapshot(s): [2]" in out
    assert "remaining: [1, 3]" in out
    # the kept delta image still dry-run-restores after gc
    assert main(["restore", populated_run, "--dry-run", "--step", "3"]) == 0


def test_gc_removes_independent_images(run_dir, capsys):
    state = {"w": jnp.ones((4, 4))}
    s = CheckpointSession(run_dir)                 # full images, no deltas
    s.attach(lambda: {"train_state": state})
    for step in (1, 2, 3):
        s.checkpoint(step)
    assert main(["gc", run_dir, "--keep", "1"]) == 0
    out = capsys.readouterr().out
    assert "removed 2 snapshot(s): [1, 2]" in out
    assert s.store.list_steps() == [3]


def test_cli_unknown_command_exits():
    with pytest.raises(SystemExit):
        main(["frobnicate"])
