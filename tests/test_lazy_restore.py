"""Priority-ordered lazy restore ("resume-before-read"): schedule
recording, critical-set split, background materialization, the corruption
matrix (killed stream -> barrier raises -> retry falls back to eager;
torn background chunk healed from a replica), pinning vs gc, and the CLI
surfaces (`inspect` schedule breakdown, `restore --dry-run --lazy`)."""
import os
import threading

import numpy as np
import pytest

from repro.api import CheckpointOptions, CheckpointSession
from repro.core.lazy import LazyMaterializer, LazyRestoreError, \
    match_critical
from repro.core.snapshot_io import snapshot_dir
from repro.serialization.pack import open_pack, stripe_path


def _train_shape_state(n=4, kb=8, seed=0):
    rng = np.random.default_rng(seed)

    def block():
        return rng.integers(0, 9, size=kb * 256).astype(np.float32)

    keys = [f"w{i}" for i in range(n)]
    return {"params": {k: block() for k in keys},
            "opt": {"m": {k: block() for k in keys},
                    "v": {k: block() for k in keys}}}


def _session(run_dir, holder, **opts):
    s = CheckpointSession(run_dir, CheckpointOptions(**opts), backend="host")
    s.attach(lambda: {"train_state": holder["state"]})
    return s


LAZY = dict(restore_mode="lazy",
            critical_states=("train_state/params",))


def _assert_exact(restored, state):
    for k, v in state["params"].items():
        np.testing.assert_array_equal(
            np.asarray(restored["train_state"]["params"][k]), v)
    for slot in ("m", "v"):
        for k, v in state["opt"][slot].items():
            np.testing.assert_array_equal(
                np.asarray(restored["train_state"]["opt"][slot][k]), v)


def _corrupt_background_chunk(run_dir, step,
                              entry="train_state::opt/m/w0::np"):
    """Flip bytes inside a cold (non-critical) entry's first chunk."""
    base = os.path.join(snapshot_dir(run_dir, step), "host0000.pack")
    with open_pack(base, verify=False) as r:
        c = r.index[entry]["chunks"][0]
    path = stripe_path(base, c["stripe"])
    with open(path, "r+b") as f:
        f.seek(c["offset"] + 8)
        f.write(b"\xde\xad\xbe\xef")


# ------------------------------------------------------------- mechanics
def test_manifest_records_restore_order_and_entry_bytes(run_dir):
    state = _train_shape_state()
    s = _session(run_dir, {"state": state})
    s.register_host_state("cursor", lambda: {"step": 1}, lambda st: None)
    s.checkpoint(1)
    m = s.store.manifest(1)
    order = m["restore_order"]
    assert order[-1] == "__host__"           # host blobs restore last
    assert set(m["entry_bytes"]) == set(order)
    assert all(m["entry_bytes"][n] > 0 for n in order)
    # the pack reader exposes the same schedule, priority-sorted
    reader = s.store.reader(1, verify=False)
    try:
        sched = reader.entry_schedule()
        assert sched[0][0] == "train_state"
        names = reader.restore_order()
        assert names == order
    finally:
        reader.close()


def test_match_critical_specs():
    assert match_critical("train_state", "params/w0", ("train_state",))
    assert match_critical("train_state", "params/w0",
                          ("train_state/params",))
    assert not match_critical("train_state", "opt/m/w0",
                              ("train_state/params",))
    # prefix match is path-component-wise, not string-wise
    assert not match_critical("train_state", "params_ema/w0",
                              ("train_state/params",))
    assert not match_critical("other", "params/w0", ("train_state",))


def test_lazy_restore_bit_exact_and_barrier(run_dir):
    state = _train_shape_state()
    s = _session(run_dir, {"state": state})
    s.checkpoint(1)
    r = _session(run_dir, {"state": None}, **LAZY)
    restored = r.restore()
    # resumed on the critical set: params placed, engine still streaming
    assert "params" in restored["train_state"]
    assert r.lazy_pending
    st = r.last_stats
    assert st["restore_mode"] == "lazy"
    assert st["critical_entries"] == len(state["params"])
    assert "restore_critical_s" in st
    full = r.restore_barrier()
    assert not r.lazy_pending
    _assert_exact(full, state)
    assert r.last_stats["background_entries"] == 2 * len(state["params"])
    assert r.last_stats["restore_background_s"] >= 0.0
    # second barrier is a no-op returning the same tree
    assert r.restore_barrier() is full


def test_lazy_wait_all_equals_eager(run_dir):
    state = _train_shape_state()
    s = _session(run_dir, {"state": state})
    s.checkpoint(1)
    r = _session(run_dir, {"state": None}, **LAZY)
    full = r.restore(wait="all")             # lazy machinery, joined
    assert not r.lazy_pending
    _assert_exact(full, state)
    with pytest.raises(ValueError, match="wait"):
        r.restore(wait="sometimes")


def test_restore_into_joins_lazy_stream(run_dir):
    state = _train_shape_state()
    s = _session(run_dir, {"state": state})
    s.checkpoint(1)
    r = _session(run_dir, {"state": None}, **LAZY)
    template = {"params": {k: np.zeros_like(v)
                           for k, v in state["params"].items()},
                "opt": {slot: {k: np.zeros_like(v)
                               for k, v in state["opt"][slot].items()}
                        for slot in ("m", "v")}}
    out = r.restore_into(template, state="train_state")
    assert not r.lazy_pending                # template needed cold leaves
    np.testing.assert_array_equal(out["opt"]["v"]["w0"],
                                  state["opt"]["v"]["w0"])


# ------------------------------------------------------ corruption matrix
def test_torn_background_chunk_barrier_raises_retry_falls_back(run_dir):
    """A cold entry's chunk is torn: the critical-set resume succeeds
    (lazy pre-verify covers criticals only), the barrier raises, and the
    retry quarantines the image and falls back to the previous committed
    step — the same corruption guarantee as the eager path."""
    state1 = _train_shape_state(seed=0)
    holder = {"state": state1}
    s = _session(run_dir, holder)
    s.checkpoint(1)
    state2 = {"params": {k: v + 1.0 for k, v in state1["params"].items()},
              "opt": {slot: {k: v + 1.0
                             for k, v in state1["opt"][slot].items()}
                      for slot in ("m", "v")}}
    holder["state"] = state2
    s.checkpoint(2)
    _corrupt_background_chunk(run_dir, 2)

    r = _session(run_dir, {"state": None}, **LAZY)
    restored = r.restore()                   # criticals verify clean
    np.testing.assert_array_equal(
        np.asarray(restored["train_state"]["params"]["w0"]),
        state2["params"]["w0"])
    with pytest.raises(LazyRestoreError, match="opt/m/w0"):
        r.restore_barrier()
    # retry: step 2 is quarantined; falls back to the previous image
    again = r.restore()
    r.restore_barrier()
    _assert_exact(again, state1)


def test_killed_materializer_mid_stream_then_eager_retry(run_dir,
                                                         monkeypatch):
    state = _train_shape_state()
    holder = {"state": state}
    s = _session(run_dir, holder)
    s.checkpoint(1)
    holder["state"] = {"params": state["params"],
                       "opt": {slot: {k: v * 2.0
                                      for k, v in state["opt"][slot].items()}
                               for slot in ("m", "v")}}
    s.checkpoint(2)

    killed = threading.Event()
    gate = threading.Event()
    orig = LazyMaterializer._load_one

    def dying(self, state_name, path):
        # hold the background stream until the test decides its fate —
        # without the gate, a warm process can finish the whole stream
        # before killed is even set (criticals don't pass through here,
        # so restore() cannot deadlock on it)
        gate.wait(30)
        if killed.is_set():
            raise IOError("materializer killed mid-stream")
        return orig(self, state_name, path)

    monkeypatch.setattr(LazyMaterializer, "_load_one", dying)
    r = _session(run_dir, {"state": None}, **LAZY)
    r.restore()
    killed.set()                             # kill the stream mid-flight
    gate.set()
    with pytest.raises(LazyRestoreError, match="killed mid-stream"):
        r.restore_barrier()
    monkeypatch.setattr(LazyMaterializer, "_load_one", orig)
    # retry falls back (step 2 quarantined) and completes eagerly
    again = r.restore(wait="all")
    _assert_exact(again, state)


def test_torn_background_chunk_healed_from_replica(run_dir, tmp_path):
    """With replicate_to set, a torn background chunk is CRC-caught and
    healed from the replica: the stream completes and the restored run
    is bit-exact."""
    peer = str(tmp_path / "peer")
    state = _train_shape_state()
    s = _session(run_dir, {"state": state}, replicate_to=peer)
    s.checkpoint(1)
    _corrupt_background_chunk(run_dir, 1)

    r = _session(run_dir, {"state": None}, replicate_to=peer, **LAZY)
    restored = r.restore()
    full = r.restore_barrier()               # heals instead of dying
    assert full is restored
    _assert_exact(full, state)
    assert r.last_stats["healed_entries"] >= 1


def test_freeze_joins_pending_stream_before_dump(run_dir):
    """A dump while a lazy stream is outstanding must not capture a
    half-restored job: freeze() barriers first (and surfaces a dead
    stream as a dump failure)."""
    state = _train_shape_state()
    holder = {"state": state}
    s = _session(run_dir, holder)
    s.checkpoint(1)
    _corrupt_background_chunk(run_dir, 1)
    r = _session(run_dir, {"state": holder["state"]}, **LAZY)
    r.restore()
    with pytest.raises(LazyRestoreError):
        r.checkpoint(2)


# ------------------------------------------------------------ pin vs gc
def test_gc_skips_pinned_steps(run_dir):
    state = _train_shape_state(n=2, kb=1)
    holder = {"state": state}
    s = _session(run_dir, holder)
    for step in (1, 2, 3):
        s.checkpoint(step)
    store = s.store
    store.pin(1)
    assert store.gc(keep=1) == [2]           # 1 pinned, 3 kept
    assert store.list_steps() == [1, 3]
    store.unpin(1)
    assert store.gc(keep=1) == [1]
    assert store.list_steps() == [3]


def test_superseding_restore_abandons_stream(run_dir):
    state = _train_shape_state()
    s = _session(run_dir, {"state": state})
    s.checkpoint(1)
    r = _session(run_dir, {"state": None}, **LAZY)
    r.restore()
    # a new restore cancels the outstanding stream instead of raising
    full = r.restore(wait="all")
    _assert_exact(full, state)
    assert not r.lazy_pending


def test_wait_critical_opts_into_lazy_under_eager_options(run_dir):
    """session.restore(wait=\"critical\") is a per-call opt-in to
    resume-before-read even when options.restore_mode is eager."""
    state = _train_shape_state()
    s = _session(run_dir, {"state": state})
    s.checkpoint(1)
    r = _session(run_dir, {"state": None},
                 critical_states=("train_state/params",))
    restored = r.restore(wait="critical")
    assert r.lazy_pending                     # stream outstanding
    assert r.last_stats["restore_mode"] == "lazy"
    full = r.restore_barrier()
    assert full is restored
    _assert_exact(full, state)


def test_trainer_partial_critical_spec_does_not_crash(tmp_path, mesh1):
    """A user critical_states spec that does not cover params falls back
    to joining the stream instead of raising KeyError."""
    import jax.numpy as jnp
    from repro.configs import get_smoke_config
    from repro.runtime.trainer import TrainConfig, Trainer
    from repro.sharding import get_policy
    cfg = get_smoke_config("qwen1.5-0.5b")

    def make(restore_mode="eager", critical=None):
        tcfg = TrainConfig(batch_size=2, seq_len=16, total_steps=8,
                           warmup_steps=2, seed=0,
                           compute_dtype=jnp.float32, remat=False,
                           ckpt_every=4,
                           ckpt=CheckpointOptions(
                               restore_mode=restore_mode,
                               critical_states=critical))
        return Trainer(cfg, tcfg, mesh1, get_policy("baseline"),
                       str(tmp_path / "run"))

    tr = make()
    tr.run_until(5)                           # image at step 4
    lazy = make("lazy", critical=("train_state/opt",))   # params NOT critical
    assert lazy.restore() == 4
    assert lazy._pending_opt_template is None            # stream joined
    lazy.run_until(6)                         # still trains fine


# ------------------------------------------------------------ options
def test_lazy_options_validate_and_roundtrip():
    from repro.api.options import OptionsError
    o = CheckpointOptions(restore_mode="lazy",
                          critical_states=("a", "b/c/d"))
    assert CheckpointOptions.from_env(o.to_env()) == o
    assert CheckpointOptions(critical_states=["x"]).critical_states == ("x",)
    with pytest.raises(OptionsError):
        CheckpointOptions(restore_mode="sometimes")
    with pytest.raises(OptionsError):
        CheckpointOptions(critical_states=("", "ok"))


# ------------------------------------------------------------ CLI
def test_cli_inspect_shows_restore_schedule(run_dir, capsys):
    from repro.cli import main
    state = _train_shape_state()
    s = _session(run_dir, {"state": state})
    s.register_host_state("cursor", lambda: {"step": 1}, lambda st: None)
    s.checkpoint(1)
    assert main(["inspect", run_dir, "--step", "1"]) == 0
    out = capsys.readouterr().out
    assert "restore schedule" in out
    assert "train_state/params" in out and "train_state/opt" in out
    assert "(host blobs)" in out


def test_cli_restore_dry_run_lazy(run_dir, capsys):
    from repro.cli import main
    state = _train_shape_state()
    s = _session(run_dir, {"state": state})
    s.register_host_state("cursor", lambda: {"step": 1}, lambda st: None)
    s.checkpoint(1)
    assert main(["restore", run_dir, "--dry-run", "--lazy",
                 "--critical", "train_state/params"]) == 0
    out = capsys.readouterr().out
    assert "resumed on the critical set" in out
    assert "resume-before-read" in out
