"""Dry-run machinery tests: collective parsing, roofline terms, and a
reduced-mesh lower+compile through the real dryrun code path (subprocess,
8 host devices)."""
import json
import os
import subprocess
import sys

import pytest

from repro.launch.dryrun import DTYPE_BYTES, parse_collectives

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

HLO_SAMPLE = """
  %ag = bf16[8,256,1024]{2,1,0} all-gather(bf16[1,256,1024]{2,1,0} %p0), replica_groups=[16,8]<=[128] last
  %ar = f32[1024,1024]{1,0} all-reduce(f32[1024,1024]{1,0} %p1), replica_groups={{0,1,2,3}}, to_apply=%add
  %rs = f32[128,64]{1,0} reduce-scatter(f32[1024,64]{1,0} %p2), replica_groups=[2,8]<=[16]
  %a2a = bf16[64,64]{1,0} all-to-all(bf16[64,64]{1,0} %p3), replica_groups={{0,1,2,3,4,5,6,7}}
  %cp = u32[16]{0} collective-permute(u32[16]{0} %p4), source_target_pairs={{0,1}}
  %noise = f32[4]{0} add(f32[4]{0} %a, f32[4]{0} %b)
"""


def test_parse_collectives_counts_and_bytes():
    out = parse_collectives(HLO_SAMPLE, n_devices=128)
    assert out["all-gather"]["count"] == 1
    assert out["all-reduce"]["count"] == 1
    assert out["reduce-scatter"]["count"] == 1
    assert out["all-to-all"]["count"] == 1
    assert out["collective-permute"]["count"] == 1
    assert out["total_count"] == 5

    ag_bytes = 8 * 256 * 1024 * 2
    assert out["all-gather"]["bytes"] == ag_bytes
    # iota groups [16,8]: group size 8 -> ring wire = bytes*(g-1)/g
    assert abs(out["all-gather"]["wire_bytes"]
               - ag_bytes * 7 / 8) < 1
    ar_bytes = 1024 * 1024 * 4
    assert out["all-reduce"]["bytes"] == ar_bytes
    assert abs(out["all-reduce"]["wire_bytes"]
               - 2 * ar_bytes * 3 / 4) < 1
    # reduce-scatter result is the shard: wire = result*(g-1)
    assert out["reduce-scatter"]["wire_bytes"] == 128 * 64 * 4 * 7
    assert out["collective-permute"]["wire_bytes"] == 16 * 4


def test_parse_collectives_async_start_variant():
    hlo = ("%ags = (f32[4]{0}, f32[16]{0}) all-gather-start(f32[4]{0} %x), "
           "replica_groups={{0,1,2,3}}")
    out = parse_collectives(hlo, n_devices=4)
    assert out["all-gather"]["count"] == 1


def test_dtype_bytes_table():
    assert DTYPE_BYTES["bf16"] == 2
    assert DTYPE_BYTES["f32"] == 4
    assert DTYPE_BYTES["s32"] == 4
    assert DTYPE_BYTES["pred"] == 1


_DRYRUN_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import repro.launch.dryrun as dr

# shrink the production mesh for the in-test compile
import repro.launch.mesh as mesh_mod
def small_mesh(*, multi_pod=False):
    shape = (2, 2, 2) if multi_pod else (4, 2)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return mesh_mod.make_mesh(shape, axes)
mesh_mod.make_production_mesh = small_mesh

# reduce every config lookup to its smoke variant (fast compile)
import repro.configs as C
from repro.models.config import reduced
_real_get = C.get_config
def smoke_get(arch):
    return reduced(_real_get(arch), vocab_size=512)
C.get_config = smoke_get

# drive the real build_lowered/analyse path with small cells
from repro.launch.shapes import SHAPES, ShapeCell
SHAPES["smoke_train"] = ShapeCell("smoke_train", "train", 64, 8)
SHAPES["smoke_decode"] = ShapeCell("smoke_decode", "decode", 64, 8)
for mesh_kind in ("pod", "multipod"):
    mesh = small_mesh(multi_pod=(mesh_kind == "multipod"))
    for cell_name in ("smoke_train", "smoke_decode"):
        lowered, cfg, cell = dr.build_lowered(
            "qwen1.5-0.5b", cell_name, mesh, "baseline")
        compiled = lowered.compile()
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0]
        assert cost.get("flops", 0) > 0, (mesh_kind, cell_name)
        rec = dr.analyse(lowered, compiled, cfg, cell,
                         int(mesh.devices.size))
        assert rec["t_compute_s"] > 0
        assert rec["dominant"] in ("compute", "memory", "collective")
        assert rec["memory"].get("temp_size_in_bytes") is not None
print("DRYRUN_SMOKE_OK")
"""


@pytest.mark.slow
def test_dryrun_lowers_on_reduced_mesh(tmp_path):
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"),
               OUT_DIR=str(tmp_path), JAX_PLATFORMS="cpu")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _DRYRUN_SCRIPT],
                       capture_output=True, text=True, env=env, timeout=900)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "DRYRUN_SMOKE_OK" in r.stdout


def test_all_baseline_artifacts_present_and_ok():
    """The committed dry-run sweep must cover every assigned cell × both
    meshes (33 cells × 2) with ok=True."""
    art = os.path.join(REPO, "artifacts", "dryrun")
    if not os.path.isdir(art):
        pytest.skip("dry-run artifacts not generated yet")
    from repro.configs import ARCH_IDS, get_config
    from repro.launch.shapes import cells_for
    missing, bad = [], []
    for a in ARCH_IDS:
        for c in cells_for(get_config(a)):
            for mk in ("pod", "multipod"):
                p = os.path.join(art, f"{a}__{c}__{mk}__baseline.json")
                if not os.path.exists(p):
                    missing.append((a, c, mk))
                    continue
                rec = json.load(open(p))
                if not rec.get("ok"):
                    bad.append((a, c, mk))
    assert not missing, f"missing cells: {missing[:8]}"
    assert not bad, f"failed cells: {bad[:8]}"
