"""Pre-copy live migration: TransferPolicy API, convergence controller,
round ledger resumability, and the chaos-interleaved migration matrix.

Covers the tentpole acceptance criteria:

  * ``TransferPolicy`` replaces the stringly ``transfer=`` knobs with a
    validated, env-round-trippable dataclass (old kwargs keep working
    under a one-time DeprecationWarning);
  * delta rounds ship only content that changed since the previous round
    and the blackout (the frozen residual push) is a fraction of the
    stop-and-copy wall;
  * a fault mid-round (CAS partition, degraded I/O, source host kill)
    never tears the destination — the round ledger in the target CAS
    lets a fresh controller resume without re-sending landed chunks;
  * the orchestrated ``migrate`` scenario converges bit-exact with zero
    replay and per-round transfer records in the RecoveryLog.
"""
import json
import os
import time

import numpy as np
import pytest

from repro.api import CheckpointOptions, CheckpointSession, TransferPolicy
from repro.api.options import OptionsError
from repro.chaos import hooks as chaos_hooks
from repro.core.engine import SnapshotEngine
from repro.core.snapshot_io import SnapshotStore
from repro.transfer import (ChunkStore, DeltaReplicator, PrecopyController,
                            RoundDecision, summarize_rounds,
                            transfer_closure)


def _chain(run_dir, steps=5, entries=6, entry_kb=64, seed=0):
    rng = np.random.default_rng(seed)
    state = {f"t{i}": rng.integers(0, 8, size=entry_kb * 256)
             .astype(np.float32) for i in range(entries)}
    opts = CheckpointOptions(mode="sync", incremental=True, pack_format=2)
    s = CheckpointSession(run_dir, opts, backend="host")
    s.attach(lambda: {"train_state": state})
    names = sorted(state)
    for step in range(1, steps + 1):
        if step > 1:
            for i in range(2):
                k = names[(step * 2 + i) % entries]
                state[k] = rng.integers(0, 8, size=entry_kb * 256) \
                    .astype(np.float32)
        s.checkpoint(step)
    return s, state


def _restore_state(run_dir):
    eng = SnapshotEngine(run_dir, backend="host")
    eng.attach(lambda: {"train_state": None})
    return eng.restore()["train_state"]


def _assert_state_equal(got, want):
    assert sorted(got) == sorted(want)
    for k in want:
        np.testing.assert_array_equal(np.asarray(got[k]),
                                      np.asarray(want[k]))


# ---------------------------------------------------------- TransferPolicy
def test_transfer_policy_validates():
    TransferPolicy().validate()                      # defaults are legal
    p = TransferPolicy(mode="delta", precopy_rounds=4,
                       max_blackout_ms=250.0)
    p.validate()
    assert p.precopy_enabled
    with pytest.raises(OptionsError):
        TransferPolicy(mode="rsync").validate()
    with pytest.raises(OptionsError):
        # pre-copy rides on the content-addressed delta path
        TransferPolicy(mode="copy", precopy_rounds=2).validate()
    with pytest.raises(OptionsError):
        # a blackout budget is meaningless without rounds to converge in
        TransferPolicy(mode="delta", max_blackout_ms=100.0).validate()


def test_transfer_policy_spec_round_trip():
    p = TransferPolicy(mode="delta", workers=2, precopy_rounds=8,
                       max_blackout_ms=500.0, residual_bytes_cap=1 << 20)
    assert TransferPolicy.from_spec(p.to_spec()) == p
    # None fields are omitted from the spec string entirely
    spec = TransferPolicy(mode="delta").to_spec()
    assert "max_blackout_ms" not in spec


def test_options_carry_policy_through_env():
    p = TransferPolicy(mode="delta", precopy_rounds=3,
                       max_blackout_ms=100.0)
    opts = CheckpointOptions(transfer_policy=p)
    env = opts.to_env()
    assert "REPRO_CKPT_TRANSFER_POLICY" in env
    assert "REPRO_CKPT_TRANSFER" not in env          # no legacy vars out
    back = CheckpointOptions.from_env(env)
    assert back.transfer_policy == p
    # legacy mirrors stay readable for old call sites
    assert back.transfer == "delta"


def test_legacy_transfer_kwargs_warn_and_map():
    import warnings
    import repro.api.options as mod
    mod._WARNED.discard("options.transfer-kwargs")
    with pytest.warns(DeprecationWarning, match="transfer"):
        opts = CheckpointOptions(transfer="delta", transfer_workers=2)
    assert opts.transfer_policy == TransferPolicy(mode="delta", workers=2)
    # warn-once: a second construction is silent
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        CheckpointOptions(transfer="delta", transfer_workers=2)
    # conflicting legacy + structured settings refuse to guess
    with pytest.raises(OptionsError):
        CheckpointOptions(transfer="copy",
                          transfer_policy=TransferPolicy(mode="delta"))


def test_replicator_protocol_capabilities(tmp_path):
    from repro.core.replication import (DirReplicator, MemReplicator,
                                        Replicator)
    for rep in (DirReplicator(str(tmp_path / "d")), MemReplicator()):
        assert isinstance(rep, Replicator)
        assert rep.supports_rounds is False
    rep = DeltaReplicator(str(tmp_path / "p"))
    assert isinstance(rep, Replicator)
    assert rep.supports_rounds is True


# ------------------------------------------------------------- controller
def _policy(**kw):
    kw.setdefault("mode", "delta")
    kw.setdefault("precopy_rounds", 8)
    return TransferPolicy(**kw)


def test_controller_requires_precopy_policy():
    with pytest.raises(ValueError):
        PrecopyController(TransferPolicy(mode="delta"))


def test_controller_converges_on_zero_byte_round():
    c = PrecopyController(_policy())
    c.observe({"bytes_sent": 1000, "wall_s": 0.1})
    c.observe({"bytes_sent": 0, "wall_s": 0.01})
    d = c.decide()
    assert isinstance(d, RoundDecision)
    assert d.action == "freeze" and "converged" in d.reason


def test_controller_freezes_inside_blackout_budget():
    c = PrecopyController(_policy(max_blackout_ms=500.0))
    c.observe({"bytes_sent": 10_000_000, "wall_s": 1.0})  # 10 MB/s
    c.observe({"bytes_sent": 1_000_000, "wall_s": 0.1})   # ~100ms residual
    d = c.decide()
    assert d.action == "freeze"
    assert d.predicted_blackout_ms <= 500.0


def test_controller_fallback_on_round_cap():
    c = PrecopyController(_policy(precopy_rounds=2,
                                  max_blackout_ms=0.001))
    c.observe({"bytes_sent": 1000, "wall_s": 0.1})
    c.observe({"bytes_sent": 1000, "wall_s": 0.1})
    d = c.decide()
    assert d.action == "fallback" and "round cap" in d.reason


def test_controller_fallback_on_byte_cap():
    c = PrecopyController(_policy(max_blackout_ms=0.001,
                                  residual_bytes_cap=1500))
    c.observe({"bytes_sent": 1000, "wall_s": 0.1})
    c.observe({"bytes_sent": 1000, "wall_s": 0.1})
    d = c.decide()
    assert d.action == "fallback" and "cap" in d.reason


def test_controller_freezes_when_not_shrinking_without_budget():
    c = PrecopyController(_policy())                 # no blackout budget
    c.observe({"bytes_sent": 1000, "wall_s": 0.1})
    c.observe({"bytes_sent": 1000, "wall_s": 0.1})
    assert c.decide().action == "freeze"


def test_controller_seed_skips_residual_rounds():
    c = PrecopyController(_policy())
    c.seed([{"bytes_sent": 1000, "wall_s": 0.1, "residual": False},
            {"bytes_sent": 200, "wall_s": 0.02, "residual": True}])
    assert len(c.rounds) == 1                        # residuals terminal


# ----------------------------------------------------------- round ledger
def test_round_ledger_persists_and_clears(tmp_path):
    store = ChunkStore(str(tmp_path / "cas"))
    assert store.round_state("mig") == []
    store.append_round("mig", {"round": 0, "bytes_sent": 10})
    store.append_round("mig", {"round": 1, "bytes_sent": 0})
    led = store.round_state("mig")
    assert [r["round"] for r in led] == [0, 1]
    assert all("t" in r for r in led)                # stamped
    # a second store over the same dir sees the same ledger (the CAS is
    # the resume log — it survives the pushing process)
    assert len(ChunkStore(str(tmp_path / "cas")).round_state("mig")) == 2
    store.clear_rounds("mig")
    assert store.round_state("mig") == []


def test_push_round_ships_only_deltas_and_records(tmp_path):
    src, state = _chain(str(tmp_path / "src"))
    rep = DeltaReplicator(str(tmp_path / "peer"))
    closure = transfer_closure(src.store, 5)
    recs = [rep.push_round(str(tmp_path / "src"), s, "mig")
            for s in closure[:-1]]
    resid = rep.push_round(str(tmp_path / "src"), 5, "mig", residual=True)
    assert [r["round"] for r in recs + [resid]] == list(range(len(closure)))
    # every live round after the first ships strictly less than the full
    # image: the CAS dedups unchanged content across rounds
    assert all(r["bytes_sent"] < recs[0]["bytes_sent"] + 1
               for r in recs[1:])
    assert resid["residual"] and resid["bytes_sent"] < recs[0]["bytes_sent"]
    summary = summarize_rounds(rep.round_state("mig"))
    assert summary["rounds_completed"] == len(closure) - 1
    assert summary["residual_bytes"] == resid["bytes_sent"]
    _assert_state_equal(_restore_state(str(tmp_path / "peer")), state)


# ------------------------------------------------- chaos migration matrix
class _Injector:
    """Minimal chaos injector: fire `exc` on the Nth hit of `site`."""

    def __init__(self, site, nth, exc=None, delay_s=0.0):
        self.site, self.nth, self.exc, self.delay_s = site, nth, exc, delay_s
        self.hits = 0

    def on(self, site, **ctx):
        if site != self.site:
            return None
        self.hits += 1
        if self.delay_s:
            time.sleep(self.delay_s)
        if self.exc is not None and self.hits == self.nth:
            raise self.exc
        return None


@pytest.mark.parametrize("fault", ["none", "cas_partition", "degraded_io",
                                   "host_kill"])
def test_precopy_survives_midround_faults(tmp_path, fault):
    """The matrix: a fault mid-round must leave the destination untorn
    and the migration resumable from the CAS-side round ledger — landed
    chunks are never re-sent, and the final image is bit-exact."""
    src, state = _chain(str(tmp_path / "src"))
    peer = str(tmp_path / "peer")
    closure = transfer_closure(src.store, 5)
    tag = "mig"

    rep = DeltaReplicator(peer, workers=1)           # deterministic order
    aborted_round = None
    if fault == "cas_partition":
        inj = _Injector("cas.put", nth=3, exc=IOError("cas partition"))
        chaos_hooks.install(inj)
        try:
            with pytest.raises(IOError, match="cas partition"):
                for s in closure[:-1]:
                    rep.push_round(str(tmp_path / "src"), s, tag)
            aborted_round = len(rep.round_state(tag))
        finally:
            chaos_hooks.uninstall()
        # diagnosable abort: no image committed, no torn ledger entry
        assert SnapshotStore(peer).list_steps() == []
        assert aborted_round == 0                    # round never landed
    elif fault == "degraded_io":
        inj = _Injector("cas.put", nth=0, delay_s=0.002)
        chaos_hooks.install(inj)
        try:
            for s in closure[:-1]:
                rep.push_round(str(tmp_path / "src"), s, tag)
        finally:
            chaos_hooks.uninstall()
        assert inj.hits > 0                          # delay really applied
    elif fault == "host_kill":
        # the pushing host dies after two live rounds; its in-memory
        # replicator and controller state are gone
        for s in closure[:2]:
            rep.push_round(str(tmp_path / "src"), s, tag)
        del rep
    else:
        for s in closure[:-1]:
            rep.push_round(str(tmp_path / "src"), s, tag)

    # a fresh replicator (new process, same target) resumes: the CAS
    # ledger seeds the controller and landed chunks negotiate away
    rep2 = DeltaReplicator(peer, workers=1)
    ctrl = PrecopyController(TransferPolicy(mode="delta",
                                            precopy_rounds=16))
    ledger_before = rep2.round_state(tag)
    ctrl.seed(ledger_before)
    done = {r["step"] for r in ledger_before}
    reused = 0
    first_resumed_stats = None
    for s in closure[:-1]:
        if s in done:
            continue
        rec = rep2.push_round(str(tmp_path / "src"), s, tag)
        if first_resumed_stats is None:
            first_resumed_stats = dict(rep2.stats)
        reused += rec["chunks_reused"]
    resid = rep2.push_round(str(tmp_path / "src"), 5, tag, residual=True)
    if fault == "cas_partition":
        # the chunks that landed before the link dropped negotiate away
        assert reused > 0
    if fault == "host_kill":
        # whole steps committed by the dead host's rounds skip entirely
        assert first_resumed_stats["steps_skipped"] >= 2
        # round numbering continued from the persisted ledger
        assert resid["round"] == len(rep2.round_state(tag)) - 1
        assert len(ledger_before) == 2
    _assert_state_equal(_restore_state(peer), state)
    # destination committed the full chain — nothing torn
    assert SnapshotStore(peer).list_steps() == closure


# ------------------------------------------------------- orchestrated run
@pytest.mark.slow
def test_migrate_scenario_precopy_bounded_blackout(tmp_path):
    """Orchestrated pre-copy migration: live rounds while the job steps,
    bounded blackout, zero replay, bit-exact vs an unmigrated run, and
    per-round transfer records in the RecoveryLog + jobs --json."""
    from repro.cli import main
    from repro.orchestrator import JobSpec, run_scenario
    from repro.orchestrator.workloads import TrainWorkload
    total = 8
    run = str(tmp_path / "orch")
    opts = CheckpointOptions(mode="sync", incremental=True, pack_format=2)
    policy = TransferPolicy(mode="delta", precopy_rounds=4,
                            max_blackout_ms=2000.0)
    summary = run_scenario("migrate", run, options=opts,
                           total_steps=total, transfer_policy=policy)
    assert summary["all_done"]
    j = summary["jobs"]["mover"]
    assert j["step"] == total
    mig = j["migration"]
    assert mig["state"] == "transferred"
    assert mig["outcome"] in ("converged", "fallback")
    assert mig["rounds"], "per-round records missing from the plan"
    assert any(r["residual"] for r in mig["rounds"])
    live = [r for r in mig["rounds"] if not r["residual"]]
    assert mig["rounds_completed"] == len(live) >= 1
    # the blackout is the residual push only — bounded by the budget
    assert mig["blackout_s"] * 1000.0 <= policy.max_blackout_ms
    (inc,) = [i for i in j["recovery"] if i["cause"] == "migration"]
    assert inc["steps_replayed"] == 0                # zero replay
    assert inc["transfer_rounds"] == mig["rounds"]
    # bit-exact vs the same job never migrated
    ref = TrainWorkload(JobSpec("ref", total_steps=total),
                        str(tmp_path / "ref"), mesh=None, options=opts)
    ref.start()
    while not ref.done:
        ref.run_slice(2)
    ref.finish()
    assert j["digest"] == ref.digest()
    # offline exposure: repro jobs --json carries the round records
    import io
    from contextlib import redirect_stdout
    buf = io.StringIO()
    with redirect_stdout(buf):
        assert main(["jobs", run, "--json"]) == 0
    rows = json.loads(buf.getvalue())
    (row,) = [r for r in rows if r["job"] == "mover"]
    assert row["transfer_rounds"] == mig["rounds"]


# ------------------------------------------------------------------- CLI
def test_migrate_cli_precopy_mode(tmp_path, capsys):
    from repro.cli import main
    src, state = _chain(str(tmp_path / "src"))
    peer = str(tmp_path / "peer")
    assert main(["migrate", str(tmp_path / "src"), peer,
                 "--max-rounds", "8", "--max-blackout-ms", "60000",
                 "--json"]) == 0
    stats = json.loads(capsys.readouterr().out)
    assert stats["outcome"] in ("converged", "fallback")
    assert stats["rounds_completed"] >= 1
    assert stats["residual_bytes"] > 0
    assert stats["rounds"][-1]["residual"]
    _assert_state_equal(_restore_state(peer), state)
    # human-readable variant prints the round table + blackout line
    assert main(["migrate", str(tmp_path / "src"),
                 str(tmp_path / "peer2"), "--max-rounds", "8"]) == 0
    out = capsys.readouterr().out
    assert "blackout" in out and "CRC-clean" in out


def test_migrate_cli_precopy_flag_validation(tmp_path):
    from repro.cli import main
    _chain(str(tmp_path / "src"))
    with pytest.raises(SystemExit, match="--transfer delta"):
        main(["migrate", str(tmp_path / "src"), str(tmp_path / "p"),
              "--transfer", "copy", "--max-rounds", "4"])
    with pytest.raises(SystemExit, match="--max-rounds"):
        main(["migrate", str(tmp_path / "src"), str(tmp_path / "p"),
              "--max-blackout-ms", "100"])
