"""repro.obs — unified observability plane.

Covers the subsystem's acceptance criteria:

  * zero overhead when disabled: span()/counter_add()/emit() are no-ops
    (shared singletons, no per-call state), and a dump's ``last_stats``
    carries exactly the same keys with or without the plane installed;
  * spans nest deterministically, including across the pack writer's
    thread pool (detail mode) and async-writer / speculate / lazy
    worker threads (job context survives the handoff);
  * satellites: replicator counters route through the metrics registry
    with a one-time warning for a stats-less replicator;
    ``wait_pending`` stalls emit a span + histogram + journal event;
  * the run journal validates, exports to Chrome trace-event JSON, and
    filters by job / class; injected chaos faults land as journal
    events aligned with incident spans.
"""
import json
import threading
import time
import warnings

import numpy as np
import pytest

from repro.core import SnapshotEngine
from repro.core.engine import PendingWriteStalled
from repro.obs import export, journal, metrics, trace
from repro.obs.plane import ObservabilityPlane, observed


def make_state(n=4, kb=8):
    rng = np.random.default_rng(0)
    return {f"w{i}": rng.integers(0, 8, size=kb * 256).astype(np.float32)
            for i in range(n)}


# --------------------------------------------------------- disabled path
def test_disabled_plane_is_inert():
    """No plane installed: every module global is None, span() returns
    the one shared no-op singleton, and the other entry points return
    without touching any state."""
    assert trace.TRACER is None
    assert metrics.REGISTRY is None
    assert journal.JOURNAL is None
    spans = [trace.span("dump.pause", step=i) for i in range(32)]
    assert all(sp is trace.NOOP_SPAN for sp in spans)
    with trace.span("dump.capture") as sp:
        sp.set(anything=1)          # no-op, chainable
    assert trace.record("recovery.detect", 0.0, 1.0) is None
    assert trace.current_context() == {}
    metrics.counter_add("dump.count")
    metrics.gauge_set("pack.queue_depth", 3)
    metrics.observe("dump.frozen_s", 0.1)
    journal.emit("dump", "commit", step=1)
    with trace.context(job="j0"):
        assert trace.span("dump.pause") is trace.NOOP_SPAN


def test_last_stats_parity_disabled_vs_seed(tmp_path):
    """The instrumented dump path publishes bit-identical stats keys
    whether or not the plane was ever installed — no obs bookkeeping
    leaks into ``last_stats``."""
    state = make_state()

    def run(run_dir, plane):
        eng = SnapshotEngine(str(run_dir))
        eng.attach(lambda: {"train_state": state})
        if plane:
            with observed(str(run_dir / "obs_run")):
                eng.checkpoint(1)
        else:
            eng.checkpoint(1)
        return dict(eng.last_stats)

    st_off = run(tmp_path / "off", plane=False)
    st_on = run(tmp_path / "on", plane=True)
    assert sorted(st_off) == sorted(st_on)
    assert not any(k.startswith("obs") for k in st_off)
    # plane uninstalled cleanly
    assert trace.TRACER is None and metrics.REGISTRY is None


def test_install_is_exclusive(tmp_path):
    plane = ObservabilityPlane(str(tmp_path / "run"))
    plane.install()
    try:
        other = ObservabilityPlane(str(tmp_path / "run2"))
        with pytest.raises(RuntimeError, match="already installed"):
            other.install()
        other.journal.close()
    finally:
        plane.close()
    assert trace.TRACER is None and journal.JOURNAL is None


# ----------------------------------------------------- spans and nesting
def test_span_nesting_and_context():
    tr = trace.Tracer()
    trace.install(tr)
    try:
        with trace.context(job="j1"):
            with trace.span("dump.pause", step=3) as outer:
                with trace.span("dump.capture") as inner:
                    pass
        assert inner.parent_id == outer.span_id
        assert outer.parent_id is None
        assert outer.attrs["job"] == "j1"
        assert inner.attrs["job"] == "j1"
        # context restores on exit
        with trace.span("dump.write") as after:
            pass
        assert "job" not in after.attrs
        assert after.t_end >= after.t_start
    finally:
        trace.uninstall()


def test_span_error_attribution():
    tr = trace.Tracer()
    trace.install(tr)
    try:
        with pytest.raises(ValueError):
            with trace.span("dump.write"):
                raise ValueError("boom")
        assert tr.spans[-1].attrs["error"] == "ValueError"
    finally:
        trace.uninstall()


def test_schema_covers_every_emitted_span_name(tmp_path):
    """Every span name the codebase emits is a key in SPAN_SCHEMA (the
    docs table / class filter contract)."""
    import re
    import subprocess
    out = subprocess.run(
        ["grep", "-rhoE",
         r'(span|begin|record)\(\s*"[a-z_]+\.[a-z_]+"', "src/repro"],
        capture_output=True, text=True, cwd="/root/repo").stdout
    names = set(re.findall(r'"([a-z_]+\.[a-z_]+)"', out))
    assert names, "span-name grep found nothing (layout changed?)"
    missing = names - set(trace.SPAN_SCHEMA)
    assert not missing, f"spans missing from SPAN_SCHEMA: {missing}"


def test_pack_detail_spans_deterministic(tmp_path):
    """Detail mode under the pipelined pack writer: per-chunk spans are
    complete and deterministic across identical runs — same multiset of
    (name, chunk) whatever the thread interleaving, every span on a
    named worker thread, job context inherited from the constructor."""
    from repro.serialization.pack import PackWriterV2, open_pack

    state = make_state(n=3, kb=64)

    def one_run(base):
        base.parent.mkdir(parents=True, exist_ok=True)
        tr = trace.Tracer(detail=True)
        trace.install(tr)
        try:
            with trace.context(job="jpack"):
                w = PackWriterV2(str(base), stripes=2, workers=2,
                                 chunk_bytes=32 * 1024, compress=True)
                for k, v in state.items():
                    w.add(k, v)
                w.close()
        finally:
            trace.uninstall()
        return tr.spans

    spans_a = one_run(tmp_path / "a" / "p.pack")
    spans_b = one_run(tmp_path / "b" / "p.pack")

    def key(spans, name):
        return sorted((sp.name, sp.attrs.get("chunk"))
                      for sp in spans if sp.name == name)

    for name in ("pack.compress", "pack.append"):
        assert key(spans_a, name) == key(spans_b, name)
        assert key(spans_a, name), f"no {name} spans recorded"
    for sp in spans_a:
        if sp.name == "pack.compress":
            assert sp.thread.startswith("repro-pack-compress-")
            assert sp.attrs["job"] == "jpack"
        elif sp.name == "pack.append":
            assert sp.thread.startswith("repro-pack-stripe-")
    # the written pack is intact
    with open_pack(str(tmp_path / "a" / "p.pack")) as r:
        for name in r.names():
            r.verify_entry(name)


def test_pack_disabled_runs_no_detail_spans(tmp_path):
    """A non-detail tracer records phase spans but never per-chunk ones
    — the hot-loop guard keeps the pipeline out of the span stream."""
    from repro.serialization.pack import PackWriterV2
    tr = trace.Tracer(detail=False)
    trace.install(tr)
    try:
        w = PackWriterV2(str(tmp_path / "p.pack"), stripes=2, workers=2,
                         chunk_bytes=32 * 1024)
        for k, v in make_state(n=2, kb=32).items():
            w.add(k, v)
        w.flush()
        w.close()
    finally:
        trace.uninstall()
    names = {sp.name for sp in tr.spans}
    assert "pack.compress" not in names
    assert "pack.append" not in names
    assert "pack.flush" in names


# ------------------------------------------------------------ satellites
def test_replicator_stats_routed_and_warn_once(tmp_path):
    """Satellite: replica counters mirror into the registry; a
    replicator without ``last_stats`` warns exactly once instead of
    dropping its counters silently."""
    class NoStatsReplicator:
        def push(self, run_dir, step):
            return None

    state = make_state()
    eng = SnapshotEngine(str(tmp_path / "run"),
                         replicator=NoStatsReplicator())
    eng.attach(lambda: {"train_state": state})
    reg = metrics.MetricsRegistry()
    metrics.install(reg)
    try:
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            eng.checkpoint(1)
            eng.checkpoint(2)
        hits = [x for x in w if "no last_stats" in str(x.message)]
        assert len(hits) == 1            # once, not per dump
        snap = reg.snapshot()
        assert snap["counters"]["replica.missing_stats"] == 2
        assert snap["counters"]["replica.push_count"] == 2
        assert snap["counters"]["dump.count"] == 2
    finally:
        metrics.uninstall()


def test_replicator_with_stats_mirrors_counters(tmp_path):
    from repro.core.replication import DirReplicator
    state = make_state()
    eng = SnapshotEngine(str(tmp_path / "run"),
                         replicator=DirReplicator(str(tmp_path / "peer")))
    eng.attach(lambda: {"train_state": state})
    reg = metrics.MetricsRegistry()
    metrics.install(reg)
    try:
        eng.checkpoint(1)
    finally:
        metrics.uninstall()
    snap = reg.snapshot()
    assert snap["counters"]["replica.push_count"] == 1
    assert "replica.missing_stats" not in snap["counters"]
    assert any(k.startswith("replica.") and k != "replica.push_count"
               for k in snap["counters"])
    # the historical stats keys are still published alongside
    assert any(k.startswith("replica_") for k in eng.last_stats)


def test_wait_pending_stall_is_observable(tmp_path, monkeypatch):
    """Satellite: a stalled async writer emits a span (stalled=True), a
    ``dump.pending_stall_s`` histogram sample, and a journal event —
    the raise is no longer the only trace it leaves."""
    from repro.api import CheckpointOptions
    state = make_state()
    eng = SnapshotEngine(str(tmp_path / "run"),
                         options=CheckpointOptions(mode="async"))
    eng.attach(lambda: {"train_state": state})
    release = threading.Event()
    orig_write = eng._write

    def slow_write(ctx):
        release.wait(5.0)
        return orig_write(ctx)

    monkeypatch.setattr(eng, "_write", slow_write)
    with observed(str(tmp_path / "obsrun")) as plane:
        eng.checkpoint(1)
        with pytest.raises(PendingWriteStalled):
            eng.wait_pending(timeout_s=0.05)
        release.set()
        eng.wait_pending()               # reap cleanly
        stalled = [sp for sp in plane.tracer.spans
                   if sp.name == "dump.wait_pending"
                   and sp.attrs.get("stalled")]
        assert len(stalled) == 1
        assert stalled[0].attrs["waited_s"] > 0
        hist = plane.registry.snapshot()["histograms"]
        assert hist["dump.pending_stall_s"]["count"] == 1
    events = export.load_journal(str(tmp_path / "obsrun"))
    stalls = [e for e in events if e.get("kind") == "pending_stall"]
    assert len(stalls) == 1 and stalls[0]["cls"] == "dump"


# ------------------------------------------------- journal and exporters
def _tiny_run(run_dir):
    """One synthetic observed run touching every event class."""
    with observed(str(run_dir)) as plane:
        with trace.context(job="j0"):
            with trace.span("dump.pause", step=1):
                time.sleep(0.001)
        t_mark = plane.tracer.clock()
        trace.record("recovery.detect", t_mark, t_mark + 0.01,
                     job="j0", cause="preemption")
        metrics.counter_add("dump.count")
        metrics.observe("dump.frozen_s", 0.25)
        metrics.gauge_set("pack.queue_depth", 2)
        journal.emit("fault", "host_kill", job="j0", at_step=3, t=0.1)
        journal.emit("job", "transition", job="j0", frm="running",
                     to="freezing", step=1)
        journal.emit("job", "transition", job="other", frm="pending",
                     to="running", step=0)
    return export.load_journal(str(run_dir))


def test_journal_validates_and_filters(tmp_path):
    events = _tiny_run(tmp_path / "run")
    assert export.validate_journal(events) == []
    assert events[0]["kind"] == "journal_open"
    # class filter
    faults = export.filter_events(events, cls="fault")
    assert [e["kind"] for e in faults] == ["host_kill"]
    # job filter crosses spans and plain events
    j0 = export.filter_events(events, job="j0")
    kinds = {(e.get("cls"), e.get("kind")) for e in j0}
    assert ("dump", "span") in kinds
    assert ("recovery", "span") in kinds
    assert ("fault", "host_kill") in kinds
    assert all(export._event_job(e) == "j0" for e in j0)
    # sorted by time
    ts = [export._event_t(e) for e in j0]
    assert ts == sorted(ts)


def test_chrome_trace_export(tmp_path):
    events = _tiny_run(tmp_path / "run")
    chrome = export.to_chrome_trace(events)
    blob = json.dumps(chrome)            # must be JSON-serializable
    parsed = json.loads(blob)
    evs = parsed["traceEvents"]
    complete = [e for e in evs if e["ph"] == "X"]
    instants = [e for e in evs if e["ph"] == "i"]
    meta = [e for e in evs if e["ph"] == "M"]
    assert {e["name"] for e in complete} >= {"dump.pause",
                                             "recovery.detect"}
    assert any(e["name"] == "fault:host_kill" for e in instants)
    assert any("->" in e["name"] for e in instants)
    assert any(e["name"] == "process_name" for e in meta)
    for e in complete:
        assert e["ts"] >= 0 and e["dur"] >= 0 and e["tid"] >= 1


def test_metrics_snapshot_flattens(tmp_path):
    events = _tiny_run(tmp_path / "run")
    flat = export.metrics_from_journal(events)
    assert flat["obs.counter.dump.count"] == 1
    assert flat["obs.gauge.pack.queue_depth"] == 2
    assert flat["obs.hist.dump.frozen_s.count"] == 1
    assert flat["obs.hist.dump.frozen_s.sum"] == pytest.approx(0.25)


def test_journal_tolerates_torn_tail(tmp_path):
    run = tmp_path / "run"
    _tiny_run(run)
    path = journal.journal_path(str(run))
    with open(path, "a") as f:
        f.write('{"v": 1, "cls": "dump", "ki')   # crash mid-line
    events = export.load_journal(str(run))
    assert export.validate_journal(events) == []


def test_journal_inherits_job_from_trace_context(tmp_path):
    with observed(str(tmp_path / "run")):
        with trace.context(job="jx"):
            journal.emit("dump", "commit", step=5)
        journal.emit("dump", "commit", step=6)
    events = export.load_journal(str(tmp_path / "run"))
    commits = {e["step"]: e.get("job")
               for e in events if e.get("kind") == "commit"}
    assert commits == {5: "jx", 6: None}


# ------------------------------------------------------ recovery + chaos
def test_recovery_phases_become_spans(tmp_path):
    from repro.orchestrator.recovery import RecoveryLog
    with observed(str(tmp_path / "run")) as plane:
        clk = plane.tracer.clock
        log = RecoveryLog(job_id="j9")
        t = clk()
        log.open("failure", t_interrupt=t, t_detect=t + 0.01,
                 step_at_interrupt=7, last_ckpt_step=6)
        log.mark_transfer(t + 0.01, t + 0.02, bytes_sent=10)
        log.mark_scheduled(t + 0.03)
        log.mark_restored(t + 0.05, restored_step=6)
        log.mark_materialized(t + 0.06)
        log.mark_caught_up(t + 0.08)
        names = [sp.name for sp in plane.tracer.spans]
        assert names == ["recovery.detect", "recovery.transfer",
                         "recovery.schedule", "recovery.restore",
                         "recovery.restore_background",
                         "recovery.replay"]
        assert all(sp.attrs["job"] == "j9" for sp in plane.tracer.spans)
        assert all(sp.t_end >= sp.t_start for sp in plane.tracer.spans)
    events = export.load_journal(str(tmp_path / "run"))
    kinds = [e["kind"] for e in events if e["cls"] == "recovery"]
    assert "incident_open" in kinds and "incident_closed" in kinds
    # persisted incident dicts unchanged by the span side-channel
    assert log.breakdown()[0]["total_s"] == pytest.approx(0.08, abs=1e-6)


def test_chaos_injections_land_in_journal(tmp_path):
    from repro.chaos.injector import FaultInjector
    from repro.chaos.plan import ChaosConfig, FaultEvent
    ev = FaultEvent(kind="host_kill", job_id="j1", at_step=4, seq=0)
    inj = FaultInjector(ChaosConfig(seed=0, hosts=1,
                                    counts={"host_kill": 1}, events=[ev]))
    with observed(str(tmp_path / "run")) as plane:
        inj._record(ev, step=4, host="host00")
        assert plane.registry.snapshot()["counters"][
            "chaos.injections"] == 1
    events = export.load_journal(str(tmp_path / "run"))
    faults = export.filter_events(events, cls="fault")
    assert len(faults) == 1
    assert faults[0]["kind"] == "host_kill"
    assert faults[0]["job"] == "j1"
    # audit trail untouched in shape (campaign fingerprints unaffected)
    assert inj.injections[0]["kind"] == "host_kill"


# --------------------------------------------------------------- the CLI
def test_cli_trace_events_metrics(tmp_path, capsys):
    from repro.cli import main
    run = tmp_path / "run"
    _tiny_run(run)

    assert main(["trace", str(run), "--chrome"]) == 0
    out = capsys.readouterr().out
    assert "trace.json" in out
    with open(run / "obs" / "trace.json") as f:
        chrome = json.load(f)
    assert any(e["ph"] == "X" for e in chrome["traceEvents"])

    assert main(["events", str(run), "--job", "j0",
                 "--class", "fault"]) == 0
    out = capsys.readouterr().out
    assert "host_kill" in out

    assert main(["metrics", str(run), "--json"]) == 0
    flat = json.loads(capsys.readouterr().out)
    assert flat["obs.counter.dump.count"] == 1

    # no journal -> clean error, not a stack trace
    assert main(["events", str(tmp_path / "nowhere")]) == 1
