"""Trip-count-aware HLO analyzer tests — the §Roofline measurement tool
must itself be validated (cost_analysis undercounts while bodies; the
analyzer must not)."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_analysis import (analyze_hlo, parse_module,
                                       effective_counts, top_buffers)


def _compile_text(f, *avals):
    return jax.jit(f).lower(*avals).compile().as_text()


def test_scan_flops_counted_per_trip():
    """8-trip scan of 512x512 matmuls: analytic = 8 * 2 * 512^3."""
    W = jnp.zeros((512, 512), jnp.float32)

    def step(c, _):
        return jnp.tanh(c @ W), None

    def f(x):
        y, _ = jax.lax.scan(step, x, None, length=8)
        return y

    txt = _compile_text(f, jax.ShapeDtypeStruct((512, 512), jnp.float32))
    rec = analyze_hlo(txt, 1)
    analytic = 8 * 2 * 512 ** 3
    assert rec["flops_by_kind"]["dot"] == pytest.approx(analytic, rel=1e-6)

    # and cost_analysis really does undercount (the reason this exists)
    compiled = jax.jit(f).lower(
        jax.ShapeDtypeStruct((512, 512), jnp.float32)).compile()
    cost = compiled.cost_analysis()
    cost = cost[0] if isinstance(cost, (list, tuple)) else cost
    assert cost["flops"] < analytic / 2


def test_single_matmul_flops_exact():
    def f(a, b):
        return a @ b

    txt = _compile_text(f, jax.ShapeDtypeStruct((64, 128), jnp.float32),
                        jax.ShapeDtypeStruct((128, 32), jnp.float32))
    rec = analyze_hlo(txt, 1)
    assert rec["flops_by_kind"]["dot"] == pytest.approx(2 * 64 * 128 * 32)


def test_nested_scan_multiplies():
    W = jnp.zeros((128, 128), jnp.float32)

    def inner(c, _):
        return c @ W, None

    def outer(c, _):
        c, _ = jax.lax.scan(inner, c, None, length=3)
        return c, None

    def f(x):
        y, _ = jax.lax.scan(outer, x, None, length=5)
        return y

    txt = _compile_text(f, jax.ShapeDtypeStruct((128, 128), jnp.float32))
    rec = analyze_hlo(txt, 1)
    analytic = 5 * 3 * 2 * 128 ** 3
    assert rec["flops_by_kind"]["dot"] == pytest.approx(analytic, rel=1e-6)


def test_memory_traffic_scales_with_trips():
    W = jnp.zeros((256, 256), jnp.float32)

    def f_n(n):
        def step(c, _):
            return jnp.tanh(c @ W), None

        def f(x):
            y, _ = jax.lax.scan(step, x, None, length=n)
            return y
        return f

    a = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    b4 = analyze_hlo(_compile_text(f_n(4), a), 1)["bytes"]
    b16 = analyze_hlo(_compile_text(f_n(16), a), 1)["bytes"]
    assert 2.5 < b16 / b4 < 5.5          # ~4x, modulo fixed I/O


def test_dynamic_slice_counts_slice_not_operand():
    """A scan that slices one row per step out of a big table must not be
    charged the full table per step."""
    table = jnp.zeros((1024, 1024), jnp.float32)

    def step(c, i):
        row = jax.lax.dynamic_slice_in_dim(table, i, 1, axis=0)
        return c + row[0], None

    def f(x):
        y, _ = jax.lax.scan(step, x, jnp.arange(64, dtype=jnp.int32))
        return y

    txt = _compile_text(f, jax.ShapeDtypeStruct((1024,), jnp.float32))
    rec = analyze_hlo(txt, 1)
    full_table_per_step = 64 * 1024 * 1024 * 4
    assert rec["bytes"] < full_table_per_step          # would be 256 MB


def test_collectives_inside_loops_multiply():
    hlo = """
HloModule m

%body (p: (s32[], f32[256])) -> (s32[], f32[256]) {
  %p = (s32[], f32[256]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[256] get-tuple-element(%p), index=1
  %one = s32[] constant(1)
  %i2 = s32[] add(%i, %one)
  %ar = f32[256] all-reduce(%x), replica_groups={{0,1,2,3}}, to_apply=%add
  ROOT %t = (s32[], f32[256]) tuple(%i2, %ar)
}

%cond (p: (s32[], f32[256])) -> pred[] {
  %p = (s32[], f32[256]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(10)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (x: f32[256]) -> f32[256] {
  %x = f32[256] parameter(0)
  %zero = s32[] constant(0)
  %t = (s32[], f32[256]) tuple(%zero, %x)
  %w = (s32[], f32[256]) while(%t), condition=%cond, body=%body
  ROOT %out = f32[256] get-tuple-element(%w), index=1
}
"""
    rec = analyze_hlo(hlo, 4)
    assert rec["collectives"]["all-reduce"]["count"] == 10
    # ring all-reduce: 2 * nbytes * (g-1)/g per trip
    expect = 10 * 2 * 256 * 4 * 3 / 4
    assert rec["collective_wire_bytes"] == pytest.approx(expect)


def test_known_trip_count_backend_config_preferred():
    hlo = """
ENTRY %main () -> s32[] {
  %c = s32[] constant(0)
  %t = (s32[]) tuple(%c)
  %w = (s32[]) while(%t), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"7"}}
  ROOT %o = s32[] get-tuple-element(%w), index=0
}

%body (p: (s32[])) -> (s32[]) {
  %p = (s32[]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %one = s32[] constant(1)
  %j = s32[] add(%i, %one)
  ROOT %t = (s32[]) tuple(%j)
}

%cond (p: (s32[])) -> pred[] {
  %p = (s32[]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(99)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}
"""
    comps = parse_module(hlo)
    mult, _ = effective_counts(comps)
    assert mult["body"] == 7.0           # config wins over constant 99


def test_top_buffers_finds_big_tensors():
    def f(x):
        return jnp.einsum("ij,kj->ik", x, x)

    txt = _compile_text(f, jax.ShapeDtypeStruct((512, 256), jnp.float32))
    bufs = top_buffers(txt, 3)
    assert bufs and bufs[0][0] >= 1.0    # >= 1 MiB result
