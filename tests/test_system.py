"""Flagship end-to-end system test: the full CRIUgpu-adapted story in one
run — train with periodic async unified snapshots, crash mid-run, restore
on a replacement trainer bitwise-exactly, finish training, then serve the
trained model with a mid-generation serving snapshot."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.snapshot_io import SnapshotStore
from repro.runtime.server import DecodeServer
from repro.runtime.trainer import TrainConfig, Trainer, run_with_restarts
from repro.sharding import get_policy

POLICY = get_policy("baseline")


@pytest.mark.slow
def test_end_to_end_train_crash_restore_serve(tmp_path, mesh1):
    cfg = get_smoke_config("qwen1.5-0.5b")
    run = str(tmp_path / "run")
    tcfg = TrainConfig(batch_size=4, seq_len=32, total_steps=40,
                       lr=5e-3, warmup_steps=2,
                       ckpt_every=5, ckpt_mode="async", incremental=True,
                       compute_dtype=jnp.float32, remat=False)

    def mk():
        return Trainer(cfg, tcfg, mesh1, POLICY, run)

    out = run_with_restarts(mk, total_steps=30, failures={13: "crash",
                                                          22: "crash"})
    assert out["steps"] == 30
    assert out["restarts"] == 2
    losses = out["loss_history"]
    assert np.mean(losses[-5:]) < np.mean(losses[:5])     # it learned

    # snapshots exist, are incremental, and carry the inventory flag
    store = SnapshotStore(run)
    steps = store.list_steps()
    assert steps and steps[-1] == 30
    man = store.manifest(steps[-1])
    assert man["has_device_state"] and man["incremental"]

    # ---- serve from the trained parameters ----
    trainer = out["trainer"]
    srv = DecodeServer(cfg, POLICY, mesh1, str(tmp_path / "srv"),
                       max_seq=64)
    srv.load(trainer.params)
    prompt = {"tokens": np.arange(8, dtype=np.int32)[None, :] % cfg.vocab_size}
    srv.start(prompt)
    srv.decode(2)
    srv.checkpoint(0)
    expected = srv.decode(3).copy()

    srv2 = DecodeServer(cfg, POLICY, mesh1, str(tmp_path / "srv"),
                        max_seq=64)
    srv2.load(srv.params)
    srv2.start(prompt)
    srv2.restore()
    got = srv2.decode(3)
    np.testing.assert_array_equal(expected, got)
    assert int(got.max()) < cfg.vocab_size     # padded vocab never sampled
