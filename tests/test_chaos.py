"""repro.chaos — seeded fault injection + survivability campaigns.

Covers the subsystem's acceptance criteria:

  * zero steady-state overhead: with no ChaosConfig installed the hook
    plane is inert (INJECTOR is None) and a sim job runs bit-exact;
  * seeded plans are deterministic and respect the injectability rules
    (exhaust exclusivity, kill caps, eviction needs >= 2 hosts);
  * a small campaign holds the invariant: every job recovers bit-exact
    or lands in diagnosable quarantine, every planned fault fires;
  * the same seed reproduces the identical survivability fingerprint;
  * satellites: ``ChunkStore.fsck(repair=True)`` quarantines corrupt
    objects (healed by the next transfer), ``FailureDetector`` reports
    each death exactly once, ``repro jobs --state`` filters, and the
    ``chaos-campaign`` CLI emits gated BENCH metrics.
"""
import json
import os

import numpy as np
import pytest

from repro.chaos import hooks
from repro.chaos.campaign import run_campaign
from repro.chaos.plan import (FAULT_CLASSES, generate_plan,
                              parse_fault_spec)
from repro.chaos.sim import SimWorkload, reference_digest
from repro.orchestrator.job import JobSpec
from repro.runtime.fault import FailureDetector
from repro.transfer import ChunkStore, DeltaReplicator


# ----------------------------------------------------------- hook plane
def test_hooks_inert_without_injector(tmp_path):
    """Zero steady-state overhead: no ChaosConfig -> INJECTOR is None,
    fire() is never consulted, and a sim job runs to its bit-exact
    reference digest through the production dump/restore stack."""
    assert hooks.INJECTOR is None
    assert hooks.fire("pack.chunk", anything=1) is None
    spec = JobSpec("solo", kind="sim", total_steps=6, ckpt_every=2)
    wl = SimWorkload(spec, str(tmp_path / "job"))
    wl.start()
    while not wl.done:
        wl.run_slice(2)
    wl.checkpoint(wl.step)
    wl.finish()
    assert wl.digest() == reference_digest(spec)
    # dump stats carry no chaos bookkeeping of any kind
    assert not any("chaos" in k for k in wl.session.last_stats)
    # restore path, same property
    r = SimWorkload(spec, str(tmp_path / "job"))
    assert r.restore() == 6
    assert not any("chaos" in k for k in r.session.last_stats)


def test_install_is_exclusive():
    class _Stub:
        def on(self, site, **ctx):
            return None

    hooks.install(_Stub())
    try:
        with pytest.raises(RuntimeError, match="already installed"):
            hooks.install(_Stub())
    finally:
        hooks.uninstall()
    assert hooks.INJECTOR is None


# ------------------------------------------------------------ fault plan
def test_parse_fault_spec():
    assert parse_fault_spec("all=2") == {c: 2 for c in FAULT_CLASSES}
    assert parse_fault_spec("host_kill=3,torn_write=1") == {
        "host_kill": 3, "torn_write": 1}
    # all=N seeds, later entries refine
    got = parse_fault_spec("all=1,exhaust=0")
    assert "exhaust" not in got and got["host_kill"] == 1
    with pytest.raises(ValueError, match="unknown fault class"):
        parse_fault_spec("rowhammer=1")


def _specs(n, max_restarts=6):
    return [JobSpec(f"j{i:03d}", kind="sim", total_steps=12, ckpt_every=3,
                    max_restarts=max_restarts) for i in range(n)]


def test_generate_plan_deterministic():
    a = generate_plan(11, _specs(20), 4, parse_fault_spec("all=1"))
    b = generate_plan(11, _specs(20), 4, parse_fault_spec("all=1"))
    assert [(e.kind, e.job_id, e.at_step, e.seq) for e in a.events] == \
           [(e.kind, e.job_id, e.at_step, e.seq) for e in b.events]
    c = generate_plan(12, _specs(20), 4, parse_fault_spec("all=1"))
    assert [(e.kind, e.job_id) for e in a.events] != \
           [(e.kind, e.job_id) for e in c.events]


def test_generate_plan_constraints():
    plan = generate_plan(3, _specs(30), 4, parse_fault_spec("all=2"))
    # exhaust targets are exclusive: nothing else may hit them
    exhaust = set(plan.targets("exhaust"))
    for ev in plan.events:
        if ev.kind != "exhaust":
            assert ev.job_id not in exhaust
    # every planned class got its events
    for cls in FAULT_CLASSES:
        assert len(plan.events_for(cls)) == 2, cls
    # eviction walls are dropped on a single-host fleet
    single = generate_plan(3, _specs(10), 1,
                           parse_fault_spec("eviction_wall=2,host_kill=1"))
    assert single.events_for("eviction_wall") == []
    assert len(single.events_for("host_kill")) == 1


def test_kill_load_capped_below_restart_budget():
    # 2 jobs, budget 2 each: at most 1 killing event lands per job, so
    # of 6 requested host_kills only 2 are schedulable
    plan = generate_plan(5, _specs(2, max_restarts=2), 2,
                         parse_fault_spec("host_kill=6"))
    per_job = {}
    for ev in plan.events_for("host_kill"):
        per_job[ev.job_id] = per_job.get(ev.job_id, 0) + 1
    assert all(n <= 1 for n in per_job.values())
    assert len(plan.events_for("host_kill")) == 2


# -------------------------------------------------------------- campaign
@pytest.mark.slow
def test_small_campaign_invariant_holds(tmp_path):
    report = run_campaign(str(tmp_path / "fleet"), jobs=8, hosts=3,
                          seed=7, faults="all=1")
    assert report.ok, report.violations
    # every planned fault fired
    for cls, row in report.rows.items():
        assert row["injected"] == row["planned"], cls
    # exhaust targets quarantine, everything else recovers bit-exact
    assert report.rows["exhaust"]["quarantined"] == 1
    assert report.rows["exhaust"]["recovered"] == 0
    for cls, row in report.rows.items():
        if cls != "exhaust":
            assert row["recovered"] == row["targets"], cls
    # replica-side corruption healed without a restart
    assert report.rows["cas_corrupt"]["healed"] >= 1
    done = [j for j, o in report.outcomes.items() if o == "recovered"]
    assert len(done) == 8 - 1                     # all but the exhaust job


@pytest.mark.slow
def test_same_seed_reproduces_identical_fingerprint(tmp_path):
    kw = dict(jobs=5, hosts=2, seed=21,
              faults="commit_kill=1,signal_dup=1,host_kill=1")
    a = run_campaign(str(tmp_path / "a"), **kw)
    b = run_campaign(str(tmp_path / "b"), **kw)
    assert a.ok and b.ok
    assert a.fingerprint() == b.fingerprint()
    assert a.outcomes == b.outcomes and a.digests == b.digests
    # a different seed yields a different schedule identity
    c = run_campaign(str(tmp_path / "c"), **dict(kw, seed=22))
    assert c.fingerprint() != a.fingerprint()


@pytest.mark.slow
def test_campaign_cli_emits_gated_bench_metrics(tmp_path, capsys):
    from repro.cli import main
    bench = str(tmp_path / "BENCH_chaos.json")
    rc = main(["chaos-campaign", str(tmp_path / "fleet"),
               "--jobs", "4", "--hosts", "2", "--seed", "5",
               "--faults", "torn_write=1,exhaust=1",
               "--json", bench])
    out = capsys.readouterr().out
    assert rc == 0
    assert "invariant held" in out and "fingerprint:" in out
    m = json.load(open(bench))
    assert m["chaos.invariant.violation_ratio"] == 0.0
    assert m["chaos.torn_write.missed_injection_ratio"] == 0.0
    assert m["chaos.torn_write.unsurvived_ratio"] == 0.0
    assert m["chaos.exhaust.quarantined_ratio"] == 1.0


@pytest.mark.slow
def test_jobs_state_filter_cli(tmp_path, capsys):
    """Satellite: `repro jobs --state failed --json` surfaces exactly the
    quarantined fleet, with host and exhausted fields for scripting."""
    from repro.cli import main
    fleet = str(tmp_path / "fleet")
    report = run_campaign(fleet, jobs=4, hosts=2, seed=5,
                          faults="torn_write=1,exhaust=1")
    assert report.ok
    assert main(["jobs", fleet, "--state", "failed", "--json"]) == 0
    rows = json.loads(capsys.readouterr().out)
    quarantined = {j for j, o in report.outcomes.items()
                   if o == "quarantined"}
    assert {r["job"] for r in rows} == quarantined
    assert all(r["exhausted"] for r in rows)
    assert all("host" in r for r in rows)
    # done-filter is the complement
    assert main(["jobs", fleet, "--state", "done", "--json"]) == 0
    done = {r["job"] for r in json.loads(capsys.readouterr().out)}
    assert done == {j for j, o in report.outcomes.items()
                    if o == "recovered"}
    with pytest.raises(SystemExit, match="unknown state"):
        main(["jobs", fleet, "--state", "zombie"])


# ------------------------------------------------------ fsck --repair
def _land_chain_in_cas(tmp_path):
    """A real pushed chain: returns (cas, peer_dir, src_dir, state)."""
    from repro.api import CheckpointOptions, CheckpointSession
    rng = np.random.default_rng(0)
    state = {f"t{i}": rng.integers(0, 8, 2048).astype(np.float32)
             for i in range(4)}
    src = str(tmp_path / "src")
    s = CheckpointSession(src, CheckpointOptions(mode="sync"),
                          backend="host")
    s.attach(lambda: {"train_state": state})
    s.checkpoint(1)
    peer = str(tmp_path / "peer")
    rep = DeltaReplicator(peer, workers=1)
    rep.push(src, 1)
    return ChunkStore(os.path.join(peer, ".cas")), peer, src, state


def test_fsck_repair_quarantines_corrupt_objects(tmp_path):
    cas, peer, src, _state = _land_chain_in_cas(tmp_path)
    objs = []
    for dirpath, _d, files in os.walk(cas.objects):
        objs += [os.path.join(dirpath, f) for f in files]
    victim = sorted(objs)[0]
    key = os.path.basename(victim)
    open(victim, "ab").write(b"x")
    before = cas.stats()["objects"]
    assert cas.fsck() == [key]                    # detect, leave in place
    assert cas.fsck(repair=True) == [key]         # quarantine
    assert cas.fsck() == []                       # store is clean now
    assert cas.stats()["objects"] == before - 1
    assert not cas.has(key)
    assert os.path.exists(os.path.join(cas.root, "quarantine", key))
    with pytest.raises(KeyError):                 # not CASCorruption
        cas.get(key)
    # quarantined objects count as missing: the next transfer re-lands
    # the chunk from source and the store is whole again
    DeltaReplicator(str(tmp_path / "peer_b"),
                    cas_dir=cas.root, workers=1).push(src, 1)
    assert cas.has(key) and cas.fsck() == []


def test_transfer_stats_repair_cli(tmp_path, capsys):
    from repro.cli import main
    cas, peer, _src, _state = _land_chain_in_cas(tmp_path)
    objs = []
    for dirpath, _d, files in os.walk(cas.objects):
        objs += [os.path.join(dirpath, f) for f in files]
    open(sorted(objs)[0], "ab").write(b"x")
    # detection alone exits 1 (corruption left in place)
    assert main(["transfer-stats", peer, "--fsck"]) == 1
    capsys.readouterr()
    # --repair quarantines and exits 0 (store is clean afterwards)
    assert main(["transfer-stats", peer, "--repair", "--json"]) == 0
    payload = json.loads(capsys.readouterr().out)
    assert payload["cas"]["corrupt_objects"] == 1
    assert payload["cas"]["quarantined_objects"] == 1
    assert main(["transfer-stats", peer, "--fsck"]) == 0


# ------------------------------------------------------ failure detector
def test_failure_detector_reports_each_death_once():
    t = {"now": 0.0}
    det = FailureDetector(deadline_s=1.0, clock=lambda: t["now"])
    det.register("w1")
    det.register("w2")
    t["now"] = 2.0
    det.heartbeat("w2")
    assert det.dead_workers() == ["w1"]           # first report
    assert det.dead_workers() == []               # suppressed, not spammed
    assert not det.healthy()                      # liveness still false
    det.heartbeat("w1")                           # proof of life re-arms
    assert det.healthy()
    t["now"] = 4.0
    assert det.dead_workers() == ["w1", "w2"]
    assert det.dead_workers() == []


def test_failure_detector_unregister_forgets_worker():
    t = {"now": 0.0}
    det = FailureDetector(deadline_s=1.0, clock=lambda: t["now"])
    det.register("w1")
    t["now"] = 5.0
    assert det.dead_workers() == ["w1"]
    det.unregister("w1")
    assert det.dead_workers() == []
    assert det.healthy()                          # not tracked at all
    det.register("w1")                            # re-registration re-arms
    t["now"] = 10.0
    assert det.dead_workers() == ["w1"]
