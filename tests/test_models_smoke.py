"""Per-architecture smoke tests (assignment requirement).

For each of the 10 assigned architectures: instantiate the REDUCED
same-family config, run one forward and one train step on CPU, assert
output shapes and no NaNs.  Decode/prefill consistency is covered for one
representative of each mixer family.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke_config
from repro.data import TokenPipeline
from repro.models.encdec import build_model
from repro.optim import AdamW
from repro.optim.schedule import constant
from repro.sharding import get_policy

POLICY = get_policy("baseline")


def _batch(cfg, B=2, S=32, seed=0):
    return {k: jnp.asarray(v)
            for k, v in TokenPipeline(cfg, B, S, seed=seed).next().items()}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg, POLICY, None, compute_dtype=jnp.float32,
                        remat=False)
    params = model.init(jax.random.key(0))
    batch = _batch(cfg)
    logits = model.forward(params, batch)
    B, S = batch["tokens"].shape
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits[..., :cfg.vocab_size])))
    # padded vocab columns are masked to -inf-like values
    if cfg.padded_vocab > cfg.vocab_size:
        assert float(jnp.max(logits[..., cfg.vocab_size:])) < -1e29


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_train_step(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg, POLICY, None, compute_dtype=jnp.float32,
                        remat=False)
    opt = AdamW(lr=constant(1e-3))
    params = model.init(jax.random.key(0))
    opt_state = opt.init(params)
    batch = _batch(cfg)

    @jax.jit
    def step(p, s, b):
        (loss, metrics), grads = jax.value_and_grad(
            model.loss, has_aux=True)(p, b)
        p, s, om = opt.update(grads, s, p)
        return p, s, loss, om["grad_norm"]

    p1, s1, loss, gnorm = step(params, opt_state, batch)
    assert np.isfinite(float(loss)) and np.isfinite(float(gnorm))
    assert float(gnorm) > 0.0
    # params actually moved
    moved = any(
        float(jnp.max(jnp.abs(a - b))) > 0
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p1)))
    assert moved
    # a few more steps on the same batch lower the loss (sanity descent)
    p, s = p1, s1
    last = None
    for _ in range(3):
        p, s, last, _ = step(p, s, batch)
    assert float(last) < float(loss)


@pytest.mark.parametrize("arch", ["qwen1.5-0.5b",      # dense GQA
                                  "h2o-danube-1.8b",   # SWA
                                  "mamba2-2.7b",       # SSM
                                  "jamba-v0.1-52b",    # hybrid + MoE
                                  "qwen3-moe-30b-a3b",  # MoE
                                  "whisper-tiny"])     # enc-dec
def test_prefill_decode_matches_forward(arch):
    """prefill(prompt) + decode_step(tok) must agree with a full forward
    over prompt+tok — the KV/SSM cache semantics are exact."""
    cfg = get_smoke_config(arch)
    model = build_model(cfg, POLICY, None, compute_dtype=jnp.float32,
                        remat=False)
    params = model.init(jax.random.key(0))
    B, S = 2, 24
    batch = _batch(cfg, B=B, S=S + 1, seed=3)
    full_logits = model.forward(params, batch)          # (B, S+1, V)

    prompt = {k: (v[:, :S] if k in ("tokens", "loss_mask") else v)
              for k, v in batch.items()}
    logits_p, cache = model.prefill(params, prompt)
    np.testing.assert_allclose(np.asarray(logits_p),
                               np.asarray(full_logits[:, S - 1]),
                               rtol=2e-4, atol=2e-4)

    # pad the cache seq dim (axis 2 of (L,B,S,KV,hd)) so pos S fits
    def pad(leaf):
        if hasattr(leaf, "ndim") and leaf.ndim == 5 and leaf.shape[2] == S:
            w = [(0, 0)] * 5
            w[2] = (0, 8)
            return jnp.pad(leaf, w)
        return leaf
    cache = jax.tree.map(pad, cache)
    logits_d, _ = model.decode_step(params, cache, batch["tokens"][:, S],
                                    jnp.int32(S))
    np.testing.assert_allclose(np.asarray(logits_d),
                               np.asarray(full_logits[:, S]),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "mamba2-2.7b"])
def test_decode_chain_matches_forward(arch):
    """N successive decode steps stay exact (cache update correctness)."""
    cfg = get_smoke_config(arch)
    model = build_model(cfg, POLICY, None, compute_dtype=jnp.float32,
                        remat=False)
    params = model.init(jax.random.key(1))
    B, S, N = 1, 16, 4
    batch = _batch(cfg, B=B, S=S + N, seed=5)
    full_logits = model.forward(params, batch)

    prompt = {"tokens": batch["tokens"][:, :S]}
    _, cache = model.prefill(params, prompt)

    def pad(leaf):
        if hasattr(leaf, "ndim") and leaf.ndim == 5 and leaf.shape[2] == S:
            w = [(0, 0)] * 5
            w[2] = (0, N)
            return jnp.pad(leaf, w)
        return leaf
    cache = jax.tree.map(pad, cache)
    for i in range(N):
        logits_d, cache = model.decode_step(
            params, cache, batch["tokens"][:, S + i], jnp.int32(S + i))
        np.testing.assert_allclose(np.asarray(logits_d),
                                   np.asarray(full_logits[:, S + i]),
                                   rtol=5e-4, atol=5e-4)


def test_vlm_vision_embeds_override():
    """Qwen2-VL stub frontend: vision embeddings replace the first P
    token embeddings and change the logits."""
    cfg = get_smoke_config("qwen2-vl-7b")
    model = build_model(cfg, POLICY, None, compute_dtype=jnp.float32,
                        remat=False)
    params = model.init(jax.random.key(0))
    batch = _batch(cfg)
    l1 = model.forward(params, batch)
    b2 = dict(batch)
    b2["vision_embeds"] = batch["vision_embeds"] + 1.0
    l2 = model.forward(params, b2)
    assert float(jnp.max(jnp.abs(l1 - l2))) > 1e-6


def test_mrope_positions_affect_logits():
    cfg = get_smoke_config("qwen2-vl-7b")
    assert cfg.mrope
    model = build_model(cfg, POLICY, None, compute_dtype=jnp.float32,
                        remat=False)
    params = model.init(jax.random.key(0))
    batch = _batch(cfg)
    B, S = batch["tokens"].shape
    base = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (3, B, S))
    l1 = model.forward(params, {**batch, "positions": base})
    shifted = base.at[1].add(7)          # move the "height" component
    l2 = model.forward(params, {**batch, "positions": shifted})
    assert float(jnp.max(jnp.abs(l1 - l2))) > 1e-6


def test_swa_vs_full_attention_differs():
    """h2o-danube SWA: tokens beyond the window are invisible."""
    import dataclasses
    cfg = get_smoke_config("h2o-danube-1.8b", sliding_window=8)
    model = build_model(cfg, POLICY, None, compute_dtype=jnp.float32,
                        remat=False)
    full_cfg = dataclasses.replace(cfg, layer_pattern=("attn",),
                                   sliding_window=0)
    model_full = build_model(full_cfg, POLICY, None,
                             compute_dtype=jnp.float32, remat=False)
    params = model.init(jax.random.key(0))
    batch = _batch(cfg)
    l_swa = model.forward(params, batch)
    l_full = model_full.forward(params, batch)
    # identical for early positions (inside the window), different later
    assert float(jnp.max(jnp.abs(l_swa[:, :8] - l_full[:, :8]))) < 1e-4
    assert float(jnp.max(jnp.abs(l_swa[:, -1] - l_full[:, -1]))) > 1e-6


def test_whisper_frames_affect_decoder():
    cfg = get_smoke_config("whisper-tiny")
    model = build_model(cfg, POLICY, None, compute_dtype=jnp.float32,
                        remat=False)
    params = model.init(jax.random.key(0))
    batch = _batch(cfg)
    l1 = model.forward(params, batch)
    b2 = dict(batch)
    b2["frames"] = batch["frames"] * 2.0 + 0.5
    l2 = model.forward(params, b2)
    assert float(jnp.max(jnp.abs(l1 - l2))) > 1e-6


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_use_kernels_path_matches_reference_path(arch):
    """Pallas-kernel path == pure-jnp path end-to-end per architecture."""
    cfg = get_smoke_config(arch)
    m0 = build_model(cfg, POLICY, None, compute_dtype=jnp.float32,
                     remat=False)
    m1 = build_model(cfg, POLICY, None, compute_dtype=jnp.float32,
                     remat=False, use_kernels=True)
    params = m0.init(jax.random.key(0))
    batch = _batch(cfg)
    l0 = m0.forward(params, batch)
    l1 = m1.forward(params, batch)
    np.testing.assert_allclose(
        np.asarray(l0[..., :cfg.vocab_size]),
        np.asarray(l1[..., :cfg.vocab_size]), rtol=1e-3, atol=1e-3)
