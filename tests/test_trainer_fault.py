"""Fault-tolerance runtime tests: failure detection, stragglers, JIT
checkpoint policy, periodic checkpoints, restart-to-completion."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.snapshot_io import SnapshotStore
from repro.runtime.fault import (FailureDetector, JITCheckpointPolicy,
                                 StragglerMonitor)
from repro.runtime.trainer import (TrainConfig, Trainer, run_with_restarts)
from repro.sharding import get_policy

POLICY = get_policy("baseline")


def make_trainer(run_dir, mesh, **kw):
    cfg = get_smoke_config("qwen1.5-0.5b")
    tcfg = TrainConfig(batch_size=2, seq_len=32, total_steps=64,
                       lr=5e-3, warmup_steps=2,
                       compute_dtype=jnp.float32, remat=False, **kw)
    return Trainer(cfg, tcfg, mesh, POLICY, run_dir)


# ------------------------------------------------------------- detector
def test_failure_detector_deadline():
    t = [0.0]
    fd = FailureDetector(deadline_s=5.0, clock=lambda: t[0])
    fd.register("w0")
    fd.register("w1")
    assert fd.healthy()
    t[0] = 4.0
    fd.heartbeat("w0")
    t[0] = 6.0
    assert fd.dead_workers() == ["w1"]
    fd.heartbeat("w1")
    assert fd.healthy()


def test_straggler_monitor_flags_outlier():
    m = StragglerMonitor(min_samples=8, threshold=3.0)
    flagged = [m.record(0.10 + 0.001 * (i % 3)) for i in range(20)]
    assert not any(flagged)
    assert m.record(0.50) is True
    assert m.record(0.10) is False


def test_jit_policy_cooldown(run_dir):
    class FakeEngine:
        def __init__(self):
            self.steps = []

        def checkpoint(self, step):
            self.steps.append(step)

    eng = FakeEngine()
    pol = JITCheckpointPolicy(eng, cooldown_steps=10)
    assert pol.on_signal(5) is True
    assert pol.on_signal(8) is False       # within cooldown
    assert pol.on_signal(16) is True
    assert eng.steps == [5, 16]


# ------------------------------------------------------------- trainer
def test_periodic_checkpoints_created(tmp_path, mesh1):
    t = make_trainer(str(tmp_path / "r"), mesh1, ckpt_every=3)
    t.run(7)
    assert SnapshotStore(str(tmp_path / "r")).list_steps() == [3, 6]


def test_loss_decreases_over_training(tmp_path, mesh1):
    t = make_trainer(str(tmp_path / "r"), mesh1)
    out = t.run(40)
    losses = t.metrics_history["loss"]
    assert np.mean(losses[-8:]) < np.mean(losses[:8]) - 0.02
    assert out["steps"] == 40


def test_multiple_failures_to_completion(tmp_path, mesh1):
    out = run_with_restarts(
        lambda: make_trainer(str(tmp_path / "r"), mesh1, ckpt_every=2),
        total_steps=12, failures={5: "crash", 9: "crash"})
    assert out["steps"] == 12
    assert out["restarts"] == 2


def test_failure_before_any_checkpoint(tmp_path, mesh1):
    """Crash before the first snapshot: restart falls back to step 0
    (fresh init) rather than dying."""
    def mk():
        return make_trainer(str(tmp_path / "r"), mesh1, ckpt_every=50)
    from repro.runtime.trainer import SimulatedFailure
    t = mk()
    t.initialize()
    with pytest.raises(SimulatedFailure):
        t.run(10, fail_at=3)
    t2 = mk()
    with pytest.raises(FileNotFoundError):
        t2.restore()                       # no snapshot exists: caller re-inits
    t2.initialize()
    t2.run(4)
    assert t2.step == 4


def test_straggler_triggers_jit_checkpoint(tmp_path, mesh1):
    t = make_trainer(str(tmp_path / "r"), mesh1)
    t.straggler = StragglerMonitor(min_samples=4, threshold=3.0)
    t.run(8)
    t.run(1, straggle_at=8)               # injected 0.25 s stall
    # the JIT policy snapshot fired for the straggler step
    assert t.jit_ckpt.triggered, "straggler did not trigger JIT checkpoint"
    steps = SnapshotStore(str(tmp_path / "r")).list_steps()
    assert steps, "no snapshot written by JIT policy"


def test_keep_gc_bounds_disk(tmp_path, mesh1):
    cfg = get_smoke_config("qwen1.5-0.5b")
    tcfg = TrainConfig(batch_size=2, seq_len=32, total_steps=32,
                       ckpt_every=1, compute_dtype=jnp.float32, remat=False)
    t = Trainer(cfg, tcfg, mesh1, POLICY, str(tmp_path / "r"))
    t.engine.keep = 2
    t.run(6)
    steps = SnapshotStore(str(tmp_path / "r")).list_steps()
    assert steps == [5, 6]
