"""Pack format v2: chunked entries, per-chunk CRC, striped files, the
pipelined writer, the parallel chunk reader, and v1 interop through
``open_pack``."""
import os
import threading
from concurrent.futures import ThreadPoolExecutor

import numpy as np
import pytest

from repro.serialization.integrity import crc32
from repro.serialization.pack import (MAGIC2, PackReader, PackReaderV2,
                                      PackWriter, PackWriterV2, open_pack,
                                      pack_files, stripe_path)


def _base(tmp_path):
    d = tmp_path / "snapshots" / "step_00000001"
    d.mkdir(parents=True, exist_ok=True)
    return str(d / "host0000.pack")


SIZES = [0, 1, 3, 999, 1000, 1001, 2000, 5003]      # straddle chunk edges


@pytest.mark.parametrize("compress", [False, True])
def test_v2_roundtrip_chunk_boundaries(tmp_path, compress):
    base = _base(tmp_path)
    rng = np.random.default_rng(0)
    arrays = {f"a{n}": rng.integers(0, 50, size=n).astype(np.int8)
              for n in SIZES}
    with PackWriterV2(base, compress=compress, chunk_bytes=1000,
                      stripes=3, workers=2) as w:
        for name, a in arrays.items():
            w.add(name, a)
        w.add_bytes("blob", b"\x00\x01\x02" * 700)
    # striped layout on disk: base.0..2, no single-file pack
    assert not os.path.exists(base)
    assert pack_files(base) == [stripe_path(base, k) for k in range(3)]
    with open(stripe_path(base, 0), "rb") as f:
        assert f.read(8) == MAGIC2
    r = open_pack(base)
    assert isinstance(r, PackReaderV2)
    with r:
        for name, a in arrays.items():
            got = r.read_array(name)
            assert got.dtype == a.dtype and got.shape == a.shape
            np.testing.assert_array_equal(got, a)
            nchunks = (a.nbytes + 999) // 1000
            assert len(r.entry(name)["chunks"]) == nchunks
        assert r.read_bytes("blob") == b"\x00\x01\x02" * 700


def test_v2_entry_crc_matches_full_raw_crc(tmp_path):
    base = _base(tmp_path)
    a = np.arange(4096, dtype=np.float32)
    with PackWriterV2(base, chunk_bytes=1024, stripes=2) as w:
        w.add("a", a)
        assert w.entry_crc("a") == crc32(a.tobytes())
    with open_pack(base) as r:
        assert r.entry("a")["crc32"] == crc32(a.tobytes())


def test_v2_parallel_reader_matches_serial(tmp_path):
    base = _base(tmp_path)
    rng = np.random.default_rng(1)
    a = rng.standard_normal(100_000).astype(np.float32)
    with PackWriterV2(base, compress=True, chunk_bytes=4096, stripes=4) as w:
        w.add("a", a)
    with ThreadPoolExecutor(max_workers=4) as ex:
        with PackReaderV2(base, executor=ex) as r:
            np.testing.assert_array_equal(r.read_array("a"), a)
            st = r.io_stats()
            assert st["read_bytes"] > 0 and st["read_s"] >= 0
    with PackReaderV2(base) as r:                    # serial fallback
        np.testing.assert_array_equal(r.read_array("a"), a)


def test_v2_mid_chunk_corruption_detected(tmp_path):
    base = _base(tmp_path)
    a = np.arange(8192, dtype=np.float32)
    with PackWriterV2(base, chunk_bytes=4096, stripes=2) as w:
        w.add("a", a)
    # flip bytes in the middle of a chunk of stripe 1
    with open(stripe_path(base, 1), "r+b") as f:
        f.seek(16 + 100)
        f.write(b"\xff\xfe\xfd")
    with open_pack(base) as r:
        with pytest.raises(IOError, match="chunk CRC mismatch"):
            r.read_array("a")
    # verify=False bypasses the CRC (benchmarks, image surgery)
    with open_pack(base, verify=False) as r:
        r.read_array("a")


def test_v2_truncated_stripe_detected(tmp_path):
    base = _base(tmp_path)
    a = np.arange(8192, dtype=np.float32)
    with PackWriterV2(base, chunk_bytes=4096, stripes=2) as w:
        w.add("a", a)
    p = stripe_path(base, 1)
    os.truncate(p, os.path.getsize(p) - 4000)
    with open_pack(base) as r:
        with pytest.raises(IOError, match="truncated"):
            r.read_array("a")


def test_v2_failed_write_leaves_no_files(tmp_path):
    base = _base(tmp_path)
    try:
        with PackWriterV2(base, chunk_bytes=256, stripes=2) as w:
            w.add("a", np.zeros(1000))
            raise RuntimeError("boom")
    except RuntimeError:
        pass
    for k in range(2):
        assert not os.path.exists(stripe_path(base, k))
        assert not os.path.exists(stripe_path(base, k) + ".tmp")


def test_v2_abort_survives_dead_stripe_writer(tmp_path):
    """A worker that dies mid-pipeline (ENOSPC-style) leaves its bounded
    queue full; abort()/close() must still drain instead of deadlocking
    on the sentinel put."""
    base = _base(tmp_path)
    w = PackWriterV2(base, chunk_bytes=64, stripes=1, workers=1)
    w._files[0].close()                  # every stripe append now raises
    try:
        for i in range(100):
            w.add(f"a{i}", np.arange(64, dtype=np.uint8))
    except Exception:
        pass                             # producer sees the worker error
    done = threading.Event()
    t = threading.Thread(target=lambda: (w.abort(), done.set()),
                         daemon=True)
    t.start()
    t.join(15)
    assert done.is_set(), "abort() deadlocked on a dead pipeline thread"
    assert not os.path.exists(stripe_path(base, 0) + ".tmp")


def test_open_pack_reads_v1_byte_identically(tmp_path):
    """Images written by the legacy single-file writer read back through
    the same factory the restore path uses."""
    base = _base(tmp_path)
    rng = np.random.default_rng(2)
    a = rng.standard_normal((64, 32)).astype(np.float32)
    with PackWriter(base, compress=True) as w:
        w.add("a", a)
        w.add_bytes("raw", b"xyz")
    r = open_pack(base)
    assert isinstance(r, PackReader)
    with r:
        got = r.read_array("a")
        assert got.tobytes() == a.tobytes()          # byte-identical
        assert r.read_bytes("raw") == b"xyz"


def test_open_pack_missing(tmp_path):
    with pytest.raises(FileNotFoundError):
        open_pack(str(tmp_path / "nope.pack"))


def test_v2_chunk_dedup_against_parent(tmp_path):
    """Unchanged chunks of a changed entry become refs into the parent's
    stripes (raw chunk CRC = content hash)."""
    base1 = _base(tmp_path)
    d2 = tmp_path / "snapshots" / "step_00000002"
    d2.mkdir(parents=True)
    base2 = str(d2 / "host0000.pack")
    a = np.arange(10_000, dtype=np.int32)            # 40000 B -> 10 chunks
    with PackWriterV2(base1, chunk_bytes=4000, stripes=2) as w:
        w.add("a", a)
    with open_pack(base1) as r1:
        parent = (r1.entry("a"), "step_00000001/host0000.pack")
        b = a.copy()
        b[0] = -1                                    # dirty chunk 0 only
        with PackWriterV2(base2, chunk_bytes=4000, stripes=2) as w:
            w.add("a", b, parent=parent)
            assert w.reused_chunk_bytes == 36_000
            assert w.ref_locs == {"step_00000001/host0000.pack"}
    with open_pack(base2) as r2:
        chunks = r2.entry("a")["chunks"]
        assert "ref" not in chunks[0] or not chunks[0].get("ref")
        assert all(c["ref"] == "step_00000001/host0000.pack"
                   for c in chunks[1:])
        np.testing.assert_array_equal(r2.read_array("a"), b)


def test_v2_deleted_ref_pack_is_clear_error(tmp_path):
    base1 = _base(tmp_path)
    d2 = tmp_path / "snapshots" / "step_00000002"
    d2.mkdir(parents=True)
    base2 = str(d2 / "host0000.pack")
    a = np.arange(10_000, dtype=np.int32)
    with PackWriterV2(base1, chunk_bytes=4000, stripes=2) as w:
        w.add("a", a)
    with open_pack(base1) as r1:
        with PackWriterV2(base2, chunk_bytes=4000, stripes=2) as w:
            b = a.copy()
            b[0] = -1
            w.add("a", b, parent=(r1.entry("a"),
                                  "step_00000001/host0000.pack"))
    for p in pack_files(base1):
        os.remove(p)                                 # break the chain
    with open_pack(base2) as r2:
        with pytest.raises(IOError, match="chunk file missing"):
            r2.read_array("a")
