"""Published-config fidelity: every assigned architecture matches the
numbers in the assignment table, and analytic parameter counts land in the
advertised size class."""
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config

# (arch, layers, d_model, heads, kv, d_ff, vocab)
TABLE = {
    "phi3-medium-14b": (40, 5120, 40, 10, 17920, 100352),
    "deepseek-coder-33b": (62, 7168, 56, 8, 19200, 32256),
    "h2o-danube-1.8b": (24, 2560, 32, 8, 6912, 32000),
    "qwen1.5-0.5b": (24, 1024, 16, 16, 2816, 151936),
    "jamba-v0.1-52b": (32, 4096, 32, 8, 14336, 65536),
    "whisper-tiny": (4, 384, 6, 6, 1536, 51865),
    "mamba2-2.7b": (64, 2560, 0, 0, 0, 50280),
    "qwen3-moe-30b-a3b": (48, 2048, 32, 4, 768, 151936),
    "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 1536, 151936),
    "qwen2-vl-7b": (28, 3584, 28, 4, 18944, 152064),
}

# advertised total parameter counts (approximate class), ±25%
SIZES = {
    "phi3-medium-14b": 14e9,
    "deepseek-coder-33b": 33e9,
    "h2o-danube-1.8b": 1.8e9,
    "qwen1.5-0.5b": 0.5e9,
    "jamba-v0.1-52b": 52e9,
    "whisper-tiny": 39e6,
    "mamba2-2.7b": 2.7e9,
    "qwen3-moe-30b-a3b": 30e9,
    "qwen3-moe-235b-a22b": 235e9,
    "qwen2-vl-7b": 7e9,
}

ACTIVE = {"qwen3-moe-30b-a3b": 3e9, "qwen3-moe-235b-a22b": 22e9,
          "jamba-v0.1-52b": 12e9}


def test_registry_covers_all_ten():
    assert sorted(ARCH_IDS) == sorted(TABLE)


@pytest.mark.parametrize("arch", sorted(TABLE))
def test_published_numbers(arch):
    cfg = get_config(arch)
    L, d, H, KV, ff, V = TABLE[arch]
    assert cfg.num_layers == L
    assert cfg.d_model == d
    assert cfg.num_heads == H
    assert cfg.num_kv_heads == KV
    if cfg.family == "moe":
        assert cfg.moe_d_ff == ff
    elif ff:
        assert cfg.d_ff == ff
    assert cfg.vocab_size == V


@pytest.mark.parametrize("arch", sorted(SIZES))
def test_param_count_in_size_class(arch):
    cfg = get_config(arch)
    n = cfg.param_count()
    lo, hi = 0.7 * SIZES[arch], 1.35 * SIZES[arch]
    assert lo < n < hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9:.1f}, {hi/1e9:.1f}]B"


@pytest.mark.parametrize("arch", sorted(ACTIVE))
def test_active_param_count(arch):
    cfg = get_config(arch)
    n = cfg.param_count(active_only=True)
    tgt = ACTIVE[arch]
    assert 0.6 * tgt < n < 1.6 * tgt, f"{arch}: active {n/1e9:.2f}B vs {tgt/1e9:.1f}B"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_vocab_padding_is_tp_divisible(arch):
    cfg = get_config(arch)
    assert cfg.padded_vocab % 16 == 0          # model axis of the prod mesh
    assert cfg.padded_vocab % 128 == 0         # MXU lane alignment
    assert 0 <= cfg.padded_vocab - cfg.vocab_size < cfg.vocab_pad_multiple


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_config_is_small(arch):
    cfg = get_smoke_config(arch)
    assert cfg.param_count() < 5e6
    assert cfg.num_layers <= 8
    # family preserved
    assert cfg.family == get_config(arch).family
    assert cfg.layer_pattern == get_config(arch).layer_pattern


def test_pattern_consistency():
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        assert cfg.num_layers % len(cfg.layer_pattern) == 0
        if cfg.family == "ssm":
            assert cfg.attention_free
        if cfg.moe_num_experts:
            assert cfg.moe_top_k > 0
