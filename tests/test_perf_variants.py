"""§Perf hillclimb knobs must be semantics-preserving: every variant is a
layout/traffic change, never a numerics change (beyond dtype rounding)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.data import TokenPipeline
from repro.models import layers as L
from repro.models.encdec import build_model
from repro.sharding import get_policy

POLICY = get_policy("baseline")


@pytest.fixture(autouse=True)
def reset_knobs():
    yield
    L.SCORE_DTYPE = jnp.float32
    L.XENT_SEQ_CHUNK = 0
    L.GQA_EXPAND = False
    L.CAST_PARAMS_ONCE = False


def _model_and_batch(arch="qwen1.5-0.5b", B=2, S=32):
    cfg = get_smoke_config(arch)
    model = build_model(cfg, POLICY, None, compute_dtype=jnp.float32,
                        remat=False)
    params = model.init(jax.random.key(0))
    batch = {k: jnp.asarray(v)
             for k, v in TokenPipeline(cfg, B, S, seed=1).next().items()}
    return cfg, model, params, batch


def test_gqa_expand_is_exact():
    """MHA expansion (repeat K/V over the group dim) == grouped attention."""
    cfg, model, params, batch = _model_and_batch("phi3-medium-14b")
    assert cfg.num_kv_heads < cfg.num_heads       # GQA smoke (kv=2, H=4)
    l0 = model.forward(params, batch)
    L.GQA_EXPAND = True
    l1 = model.forward(params, batch)
    np.testing.assert_allclose(np.asarray(l0), np.asarray(l1),
                               rtol=1e-5, atol=1e-5)


def test_xent_chunking_is_exact():
    cfg, model, params, batch = _model_and_batch(B=2, S=32)
    loss0 = float(model.loss(params, batch)[1]["loss"])
    L.XENT_SEQ_CHUNK = 8
    loss1 = float(model.loss(params, batch)[1]["loss"])
    assert loss0 == loss1                         # bitwise on CPU


def test_rolled_loss_equals_sliced_loss():
    """The full-length rolled-target loss == the classic [:-1]/[1:] loss."""
    cfg, model, params, batch = _model_and_batch()
    logits = model.forward(params, batch)
    tok = batch["tokens"]
    lg = logits[:, :-1].astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(lg, -1)
    iota = jax.lax.broadcasted_iota(jnp.int32, lg.shape, 2)
    tgt = jnp.sum(jnp.where(iota == tok[:, 1:, None], lg, 0.0), -1)
    sliced = float(jnp.mean(lse - tgt))
    rolled = float(model.loss(params, batch)[1]["loss"])
    assert abs(sliced - rolled) < 1e-6


def test_bf16_scores_close_to_f32():
    cfg, model, params, batch = _model_and_batch()
    l0 = model.forward(params, batch)
    L.SCORE_DTYPE = jnp.bfloat16
    l1 = model.forward(params, batch)
    np.testing.assert_allclose(np.asarray(l0[..., :cfg.vocab_size]),
                               np.asarray(l1[..., :cfg.vocab_size]),
                               rtol=0.1, atol=0.2)


def test_cast_params_once_close_to_master():
    cfg, model, params, batch = _model_and_batch()
    L.CAST_PARAMS_ONCE = True
    # compute_dtype is f32 in smokes -> cast is identity there; force bf16
    model_bf16 = build_model(cfg, POLICY, None,
                             compute_dtype=jnp.bfloat16, remat=False)
    l_ref = model_bf16.forward(params, batch)
    L.CAST_PARAMS_ONCE = False
    l_base = model_bf16.forward(params, batch)
    np.testing.assert_allclose(
        np.asarray(l_ref, np.float32)[..., :cfg.vocab_size],
        np.asarray(l_base, np.float32)[..., :cfg.vocab_size],
        rtol=0.1, atol=0.3)


def test_apply_variant_sets_and_composes():
    from repro.launch.dryrun import apply_variant, variant_parts
    assert variant_parts("gqaexpand_bf16cast") == {"gqaexpand", "bf16cast"}
    remat = apply_variant("gqaexpand_bf16score")
    assert remat is True
    assert L.GQA_EXPAND and L.SCORE_DTYPE == jnp.bfloat16
    remat = apply_variant("noremat")
    assert remat is False and not L.GQA_EXPAND
    apply_variant("base")
    assert L.SCORE_DTYPE == jnp.float32 and L.XENT_SEQ_CHUNK == 0


def test_seq_par_policy_spec():
    p = get_policy("seq_par")
    assert p.spec("batch", "seq", "act_d")[1] == "model"
    # logits keep vocab on the TP axis (logit_seq never claims it)
    assert p.spec("batch", "logit_seq", "vocab")[2] == "model"


def test_fsdp_all_policy_spec():
    p = get_policy("fsdp_all")
    assert p.spec("heads") == jax.sharding.PartitionSpec(None)   # no TP
    assert p.spec("experts")[0] == "model"                       # EP kept
    # expert weights: d_model drops the contested "model" axis
    s = p.spec("experts", "d_model", "moe_ff")
    assert s[0] == "model" and s[1] == "data"
