"""Pipelined data plane, end to end: v1/v2 interop through the engine,
chunk-level incremental dedup, the corruption matrix (mid-chunk flip,
truncated stripe, deleted parent pack), async write-failure surfacing,
and gc racing a concurrent restore."""
import os
import threading

import numpy as np
import pytest

from repro.api import CheckpointOptions, CheckpointSession
from repro.core import SnapshotEngine
from repro.core.snapshot_io import SnapshotStore, snapshot_dir
from repro.serialization.pack import pack_files, stripe_path


def _np_state(n=8, kb=16, seed=0):
    rng = np.random.default_rng(seed)
    return {f"t{i}": rng.integers(0, 9, size=kb * 256).astype(np.float32)
            for i in range(n)}


def _session(run_dir, holder, **opts):
    s = CheckpointSession(run_dir, CheckpointOptions(**opts), backend="host")
    s.attach(lambda: {"train_state": holder["state"]})
    return s


def _assert_state_equal(restored, state):
    for k, v in state.items():
        np.testing.assert_array_equal(np.asarray(restored["train_state"][k]),
                                      np.asarray(v))


# ------------------------------------------------------------ v1 interop
def test_v1_image_restores_through_new_reader(run_dir):
    """Serial-compat images (pack_format=1, the layout older code wrote)
    restore byte-identically through the v2-aware reader."""
    state = _np_state()
    s = _session(run_dir, {"state": state}, pack_format=1, compress=True)
    s.checkpoint(1)
    files = os.listdir(snapshot_dir(run_dir, 1))
    assert "host0000.pack" in files                  # single-file layout
    assert not any(f.startswith("host0000.pack.") for f in files)

    s2 = _session(run_dir, {"state": None})          # default (v2) options
    restored = s2.restore()
    _assert_state_equal(restored, state)
    for k in state:
        assert restored["train_state"][k].tobytes() == state[k].tobytes()


def test_incremental_chain_mixes_v1_parent_v2_child(run_dir):
    state = _np_state()
    s = _session(run_dir, {"state": state}, pack_format=1, incremental=True)
    s.checkpoint(1)
    state2 = dict(state, t0=state["t0"] + 1.0)
    s2 = _session(run_dir, {"state": state2}, pack_format=2,
                  incremental=True)
    s2.checkpoint(2)
    man = s2.store.manifest(2)
    assert man["format"] == 2 and man["parent"] == 1
    # unchanged entries resolve into the v1 parent's single-file pack
    assert any(loc.startswith("step_00000001") and loc.endswith(".pack")
               for loc in man["locations"].values())
    s3 = _session(run_dir, {"state": None})
    _assert_state_equal(s3.restore(), state2)


def test_v2_chunk_dedup_through_engine(run_dir):
    big = np.arange(1 << 20, dtype=np.float32)       # 4 MiB -> 4 x 1 MiB
    holder = {"state": {"big": big}}
    s = _session(run_dir, holder, incremental=True, chunk_mb=1)
    s.checkpoint(1)
    big2 = big.copy()
    big2[:4] = -1.0                                  # dirties chunk 0 only
    holder["state"] = {"big": big2}
    s.checkpoint(2)
    man = s.store.manifest(2)
    assert man["written_bytes"] == 1 << 20           # one chunk rewritten
    assert man["reused_bytes"] == 3 << 20
    assert 1 in man["ref_steps"]
    s2 = _session(run_dir, {"state": None})
    np.testing.assert_array_equal(
        np.asarray(s2.restore()["train_state"]["big"]), big2)
    # gc must keep step 1: step 2's chunks live in its stripes
    s.store.gc(keep=1)
    assert s.store.list_steps() == [1, 2]


# ------------------------------------------------------ corruption matrix
def _two_snapshots(run_dir, incremental=True):
    state = _np_state()
    holder = {"state": state}
    s = _session(run_dir, holder, incremental=incremental, chunk_mb=1)
    s.checkpoint(1)
    holder["state"] = dict(state, t0=state["t0"] + 1.0)
    s.checkpoint(2)
    return state, holder["state"]


def test_mid_chunk_flip_fails_verify_and_falls_back(run_dir):
    state1, _ = _two_snapshots(run_dir)
    pack = pack_files(os.path.join(snapshot_dir(run_dir, 2),
                                   "host0000.pack"))[0]
    with open(pack, "r+b") as f:
        f.seek(200)                                  # mid-chunk payload
        f.write(b"\xde\xad\xbe\xef")
    s = _session(run_dir, {"state": None})
    with pytest.raises(Exception, match="CRC"):
        s.restore(step=2)
    _assert_state_equal(s.restore(), state1)         # newest-valid fallback


def test_truncated_stripe_fails_verify_and_falls_back(run_dir):
    state1, _ = _two_snapshots(run_dir)
    stripe = stripe_path(os.path.join(snapshot_dir(run_dir, 2),
                                      "host0000.pack"), 1)
    os.truncate(stripe, 16)          # keep the header, drop every chunk
    s = _session(run_dir, {"state": None})
    with pytest.raises(IOError):
        s.restore(step=2)
    _assert_state_equal(s.restore(), state1)


def test_deleted_parent_pack_breaks_children_with_clear_error(run_dir):
    state1, state2 = _two_snapshots(run_dir)
    holder = {"state": dict(state2, t1=state2["t1"] + 2.0)}
    # step 3: full image, independent of the chain
    s_full = _session(run_dir, holder, incremental=False)
    s_full.checkpoint(3)
    # delete step 1's pack: steps 1 AND 2 (delta child) are now broken
    for p in pack_files(os.path.join(snapshot_dir(run_dir, 1),
                                     "host0000.pack")):
        os.remove(p)
    s = _session(run_dir, {"state": None})
    with pytest.raises(Exception,
                       match="(chunk file missing|No such file|no pack)"):
        s.restore(step=2)
    _assert_state_equal(s.restore(), holder["state"])  # falls back to 3
    # the CLI verifier reports the broken steps and the intact one
    from repro.cli import main
    assert main(["verify", run_dir]) == 1


# ------------------------------------------------------ async bug fixes
def test_async_write_failure_is_surfaced_not_swallowed(run_dir, monkeypatch):
    state = _np_state(n=2, kb=1)
    s = _session(run_dir, {"state": state}, mode="async")

    def boom(ctx):
        raise IOError("disk on fire")

    monkeypatch.setattr(s.engine, "_write", boom)
    s.checkpoint(1)
    with pytest.raises(IOError, match="disk on fire"):
        s.wait_pending()
    # the failure stays visible after being raised once
    assert "disk on fire" in s.write_error
    assert "disk on fire" in s.last_stats["write_error"]
    assert s.store.list_steps() == []                # nothing committed
    # drained: a second wait does not re-raise the same error
    s.wait_pending()


def test_write_error_resets_after_clean_dump(run_dir, monkeypatch):
    state = _np_state(n=2, kb=1)
    s = _session(run_dir, {"state": state}, mode="async")
    real_write = s.engine._write
    monkeypatch.setattr(s.engine, "_write",
                        lambda ctx: (_ for _ in ()).throw(IOError("boom")))
    s.checkpoint(1)
    with pytest.raises(IOError):
        s.wait_pending()
    assert s.write_error is not None
    monkeypatch.setattr(s.engine, "_write", real_write)
    s.checkpoint(2)
    s.wait_pending()
    assert s.write_error is None            # last dump committed cleanly
    assert s.store.list_steps() == [2]


def test_async_dump_publishes_write_stats_after_wait(run_dir):
    state = _np_state(n=4, kb=4)
    s = _session(run_dir, {"state": state}, mode="async", compress=True)
    s.checkpoint(1)
    s.wait_pending()
    for key in ("write_s", "written_bytes", "compress_s", "io_s"):
        assert key in s.last_stats, key


def test_same_step_format_switch_leaves_no_stale_layout(run_dir):
    """Re-dumping a step in the other pack format must not leave the old
    layout behind for the reader sniff to find (stale-data hazard)."""
    state = _np_state(n=3, kb=2)
    s1 = _session(run_dir, {"state": state}, pack_format=1)
    s1.checkpoint(7)
    state2 = {k: v + 1.0 for k, v in state.items()}
    s2 = _session(run_dir, {"state": state2}, pack_format=2)
    s2.checkpoint(7)                         # same step, new format
    files = sorted(os.listdir(snapshot_dir(run_dir, 7)))
    assert "host0000.pack" not in files      # stale v1 file removed
    r = _session(run_dir, {"state": None})
    _assert_state_equal(r.restore(step=7), state2)
    # and back: v1 re-dump removes the stripe set
    state3 = {k: v + 2.0 for k, v in state.items()}
    s3 = _session(run_dir, {"state": state3}, pack_format=1)
    s3.checkpoint(7)
    files = sorted(os.listdir(snapshot_dir(run_dir, 7)))
    assert not any(f.startswith("host0000.pack.") for f in files)
    _assert_state_equal(r.restore(step=7), state3)


def test_wait_pending_drains_every_queued_error(run_dir):
    eng = SnapshotEngine(run_dir)
    eng._pending_err.extend([IOError("first"), IOError("second")])
    with pytest.raises(RuntimeError, match="2 async snapshot writes"):
        eng.wait_pending()
    assert eng._pending_err == []
    assert "first" in eng.write_error and "second" in eng.write_error


# ------------------------------------------------------ gc vs restore
def test_gc_never_torn_under_concurrent_restore(run_dir):
    """store.gc in a writer thread vs restore() scans on the same store:
    the store lock means restore never observes a half-deleted image."""
    state = _np_state(n=4, kb=4)
    holder = {"state": state}
    eng = SnapshotEngine(run_dir, backend="host",
                         options=CheckpointOptions(keep=1))
    eng.attach(lambda: {"train_state": holder["state"]})
    eng.checkpoint(0)
    errors = []
    stop = threading.Event()

    def restorer():
        try:
            while not stop.is_set():
                restored = eng.restore()
                assert "train_state" in restored
        except BaseException as e:
            errors.append(e)

    t = threading.Thread(target=restorer)
    t.start()
    try:
        for step in range(1, 25):
            eng.checkpoint(step)                     # keep=1 -> gc each time
    finally:
        stop.set()
        t.join()
    assert not errors, errors[0]
    assert eng.store.list_steps() == [24]


def test_store_scan_tolerates_vanishing_root(run_dir):
    store = SnapshotStore(run_dir)
    assert store.list_steps() == []
    # a step dir without a manifest (mid-gc or torn) is invisible
    d = snapshot_dir(run_dir, 5)
    os.makedirs(d)
    assert store.list_steps() == []


# ------------------------------------------------- lazy restore (trainer)
def _tiny_trainer(run_dir, mesh, restore_mode="eager", total=16):
    import jax.numpy as jnp
    from repro.configs import get_smoke_config
    from repro.runtime.trainer import TrainConfig, Trainer
    from repro.sharding import get_policy
    cfg = get_smoke_config("qwen1.5-0.5b")
    tcfg = TrainConfig(batch_size=2, seq_len=16, total_steps=total,
                       warmup_steps=2, seed=0, compute_dtype=jnp.float32,
                       remat=False, ckpt_every=4,
                       ckpt=CheckpointOptions(restore_mode=restore_mode))
    return Trainer(cfg, tcfg, mesh, get_policy("baseline"), run_dir)


def _trainer_digest(tr):
    import jax
    flat = []
    for leaf in jax.tree.leaves({"params": tr.params, "opt": tr.opt_state}):
        flat.append(np.asarray(leaf))
    return [a.tobytes() for a in flat]


def test_lazy_restored_training_run_bit_exact(tmp_path, mesh1):
    """A lazy-restored (resume-before-read) training run matches the
    eager-restored run step for step: same losses, same params/opt."""
    run_a = str(tmp_path / "eager")
    run_b = str(tmp_path / "lazy")
    tr = _tiny_trainer(run_a, mesh1)
    tr.run_until(6)                          # periodic image at step 4
    import shutil
    shutil.copytree(os.path.join(run_a, "snapshots"),
                    os.path.join(run_b, "snapshots"))

    eager = _tiny_trainer(run_a, mesh1, "eager")
    assert eager.restore() == 4
    eager.run_until(8)

    lazy = _tiny_trainer(run_b, mesh1, "lazy")
    assert lazy.session.options.critical_states == ("train_state/params",)
    assert lazy.restore() == 4
    # resumed on the critical set: optimizer slots still streaming
    assert lazy._pending_opt_template is not None or \
        not lazy.session.lazy_pending
    lazy.run_until(8)                        # first step joins the stream
    assert lazy._pending_opt_template is None
    assert not lazy.session.lazy_pending

    assert eager.metrics_history["loss"] == lazy.metrics_history["loss"]
    for a, b in zip(_trainer_digest(eager), _trainer_digest(lazy)):
        assert a == b


def test_lazy_restore_elastic_resharded_bit_exact(tmp_path, mesh1):
    """Lazy restore through the elastic (resharded-mesh) path: restoring
    onto a mesh with different axis names forces topology mode
    'resharded', and the lazily-restored run still matches eager."""
    import shutil
    from repro.launch.mesh import make_mesh
    run = str(tmp_path / "run")
    run_b = str(tmp_path / "run_lazy")
    tr = _tiny_trainer(run, mesh1)
    tr.run_until(6)
    shutil.copytree(os.path.join(run, "snapshots"),
                    os.path.join(run_b, "snapshots"))

    mesh_x = make_mesh((1,), ("elastic",))   # same devices, new topology
    eager = _tiny_trainer(run, mesh_x, "eager")
    assert eager.restore() == 4
    assert eager.session.last_stats["topology_mode"] == "resharded"
    eager.run_until(8)

    lazy = _tiny_trainer(run_b, mesh_x, "lazy")
    assert lazy.restore() == 4
    assert lazy.session.last_stats["topology_mode"] == "resharded"
    lazy.run_until(8)
    assert not lazy.session.lazy_pending

    assert eager.metrics_history["loss"] == lazy.metrics_history["loss"]
    for a, b in zip(_trainer_digest(eager), _trainer_digest(lazy)):
        assert a == b


def test_lazy_trainer_preempt_before_first_step_joins_stream(tmp_path,
                                                             mesh1):
    """Checkpoint-on-signal immediately after a lazy restore must not
    dump a half-restored job: the freeze path joins the stream first."""
    run = str(tmp_path / "run")
    tr = _tiny_trainer(run, mesh1)
    tr.run_until(6)
    lazy = _tiny_trainer(run, mesh1, "lazy")
    lazy.restore()
    out = lazy.run_until(12, preempt=lambda: True)   # signal before step 1
    assert out["preempted"] and out["steps"] == 0
    assert lazy._pending_opt_template is None        # stream joined
    # the dumped image matches the step-4 state it restored from
    r = CheckpointSession(str(run), CheckpointOptions(), backend="host")
    r.attach(lambda: {"train_state": None})
    restored = r.restore(step=4)
    flat_eager = restored["train_state"]
    assert "params" in flat_eager and "opt" in flat_eager


# ------------------------------------------------------ options plumbing
def test_dataplane_options_env_roundtrip():
    o = CheckpointOptions(pack_format=1, io_threads=3, chunk_mb=2, stripes=4)
    assert CheckpointOptions.from_env(o.to_env()) == o
    assert o.effective_io_threads() == 3
    assert CheckpointOptions().effective_io_threads() >= 2


def test_dataplane_options_validate():
    from repro.api.options import OptionsError
    with pytest.raises(OptionsError):
        CheckpointOptions(pack_format=3)
    with pytest.raises(OptionsError):
        CheckpointOptions(chunk_mb=0)
    with pytest.raises(OptionsError):
        CheckpointOptions(stripes=0)
    with pytest.raises(OptionsError):
        CheckpointOptions(io_threads=-1)


def test_pipeline_stats_reported(run_dir):
    state = _np_state(n=6, kb=64)
    s = _session(run_dir, {"state": state}, compress=True)
    s.checkpoint(1)
    st = s.last_stats
    for key in ("capture_s", "compress_s", "io_s", "serialize_s",
                "stripe_utilization", "write_s", "frozen_s"):
        assert key in st, key
    assert 0.0 <= st["stripe_utilization"] <= 1.0
    s2 = _session(run_dir, {"state": None})
    s2.restore()
    for key in ("read_s", "decompress_s", "read_bytes", "place_s",
                "host_to_device_s"):
        assert key in s2.last_stats, key
