"""Deterministic restore (paper §6 "Deterministic Restore").

CRIUgpu's locking mechanism guarantees consistent snapshots and
deterministic, replay-free restore.  The testable JAX-side claim: a run
interrupted at step k and restored from the unified snapshot produces
BITWISE-identical losses/parameters to the uninterrupted run — same
hardware, same software, zero divergence (the Megatron-LM bitwise
reproducibility bar cited by the paper)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.runtime.trainer import (TrainConfig, Trainer,
                                   run_with_restarts)
from repro.sharding import get_policy

POLICY = get_policy("baseline")
TCFG = TrainConfig(batch_size=2, seq_len=32, total_steps=16, ckpt_every=4,
                   compute_dtype=jnp.float32, remat=False)


def make_trainer(run_dir, mesh):
    cfg = get_smoke_config("qwen1.5-0.5b")
    return Trainer(cfg, TCFG, mesh, POLICY, run_dir)


def test_bitwise_deterministic_restart(tmp_path, mesh1):
    # uninterrupted reference run
    t_ref = make_trainer(str(tmp_path / "ref"), mesh1)
    t_ref.run(12)
    ref_losses = list(t_ref.metrics_history["loss"])

    # interrupted run: crash at step 7, restore from the step-4 snapshot
    out = run_with_restarts(
        lambda: make_trainer(str(tmp_path / "crash"), mesh1),
        total_steps=12, failures={7: "crash"})
    assert out["restarts"] == 1
    assert out["steps"] == 12
    got = out["loss_history"]

    # the last 8 losses (steps 5..12) must match bitwise
    np.testing.assert_array_equal(np.float64(ref_losses[-8:]),
                                  np.float64(got[-8:]))

    # final parameters bitwise identical too
    ref_p = jax.tree.leaves(t_ref.params)
    got_p = jax.tree.leaves(out["trainer"].params)
    for a, b in zip(ref_p, got_p):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_double_restore_is_idempotent(tmp_path, mesh1):
    t = make_trainer(str(tmp_path / "a"), mesh1)
    t.run(5)
    t.engine.checkpoint(t.step)

    r1 = make_trainer(str(tmp_path / "a"), mesh1)
    r1.restore()
    r2 = make_trainer(str(tmp_path / "a"), mesh1)
    r2.restore()
    assert r1.step == r2.step == 5
    for a, b in zip(jax.tree.leaves(r1.params), jax.tree.leaves(r2.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_data_pipeline_cursor_restores_exactly(tmp_path, mesh1):
    """The unified snapshot carries the data cursor: the restored run sees
    exactly the batches the crashed run would have seen."""
    t = make_trainer(str(tmp_path / "c"), mesh1)
    t.run(6)
    t.engine.checkpoint(t.step)
    expected_next = t.pipeline.peek()

    r = make_trainer(str(tmp_path / "c"), mesh1)
    r.restore()
    got_next = r.pipeline.peek()
    np.testing.assert_array_equal(expected_next["tokens"],
                                  got_next["tokens"])


def test_async_mode_same_result_as_sync(tmp_path, mesh1):
    """Beyond-paper async (CheckFreq-style) snapshots must not change the
    captured state: restore from an async image == restore from sync."""
    import dataclasses
    cfg = get_smoke_config("qwen1.5-0.5b")
    t_s = Trainer(cfg, dataclasses.replace(TCFG, ckpt_mode="sync"),
                  mesh1, POLICY, str(tmp_path / "sync"))
    t_a = Trainer(cfg, dataclasses.replace(TCFG, ckpt_mode="async"),
                  mesh1, POLICY, str(tmp_path / "async"))
    t_s.run(4)
    t_a.run(4)
    t_s.engine.wait_pending()
    t_a.engine.wait_pending()

    r_s = Trainer(cfg, TCFG, mesh1, POLICY, str(tmp_path / "sync"))
    r_a = Trainer(cfg, TCFG, mesh1, POLICY, str(tmp_path / "async"))
    r_s.restore()
    r_a.restore()
    for a, b in zip(jax.tree.leaves(r_s.params), jax.tree.leaves(r_a.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
