"""Per-kernel allclose validation against the pure-jnp oracles in
kernels/ref.py, swept over shapes and dtypes (interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.rmsnorm import rmsnorm as rmsnorm_kernel
from repro.kernels.ssd_scan import ssd_scan


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------- flash
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,Sq,Sk,H,KV,hd,causal,window",
    [
        (1, 128, 128, 4, 4, 64, True, 0),      # MHA causal
        (2, 256, 256, 8, 2, 64, True, 0),      # GQA causal
        (1, 192, 192, 4, 2, 32, True, 64),     # sliding window (+pad)
        (2, 64, 160, 4, 4, 64, False, 0),      # cross attention, Sq != Sk
        (1, 100, 100, 2, 1, 16, True, 0),      # ragged (padding path)
    ])
def test_flash_attention_matches_ref(B, Sq, Sk, H, KV, hd, causal, window,
                                     dtype):
    k1, k2, k3 = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(k1, (B, Sq, H, hd), dtype)
    k = jax.random.normal(k2, (B, Sk, KV, hd), dtype)
    v = jax.random.normal(k3, (B, Sk, KV, hd), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=64, block_k=64, interpret=True)
    expect = ref.attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), **_tol(dtype))


def test_flash_attention_block_shape_invariance():
    """Result must not depend on the BlockSpec tiling."""
    k1, k2, k3 = jax.random.split(jax.random.key(1), 3)
    q = jax.random.normal(k1, (1, 256, 4, 64), jnp.float32)
    k = jax.random.normal(k2, (1, 256, 2, 64), jnp.float32)
    v = jax.random.normal(k3, (1, 256, 2, 64), jnp.float32)
    outs = [flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk,
                            interpret=True)
            for bq, bk in [(32, 32), (64, 128), (256, 64)]]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   rtol=1e-5, atol=1e-5)


def test_ops_attention_jit_dispatch():
    k1, k2, k3 = jax.random.split(jax.random.key(2), 3)
    q = jax.random.normal(k1, (2, 64, 4, 32), jnp.float32)
    k = jax.random.normal(k2, (2, 64, 2, 32), jnp.float32)
    v = jax.random.normal(k3, (2, 64, 2, 32), jnp.float32)
    out = ops.attention(q, k, v, causal=True, block_q=32, block_k=32)
    expect = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------- ssd
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,nh,P,N,chunk", [
    (1, 64, 2, 16, 32, 16),
    (2, 100, 3, 32, 64, 32),     # ragged: S % chunk != 0
    (1, 128, 1, 64, 128, 128),   # single chunk, MXU-shaped
])
def test_ssd_scan_matches_ref(B, S, nh, P, N, chunk, dtype):
    ks = jax.random.split(jax.random.key(3), 5)
    x = jax.random.normal(ks[0], (B, S, nh, P), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, nh), jnp.float32))
    A = -jnp.exp(jax.random.normal(ks[2], (nh,), jnp.float32) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, N), dtype)
    Cm = jax.random.normal(ks[4], (B, S, N), dtype)
    y, h = ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, interpret=True)
    y_r, h_r = ref.ssd_ref(x, dt, A, Bm, Cm)
    tol = dict(rtol=5e-2, atol=5e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_r, np.float32), **tol)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_r),
                               rtol=1e-4, atol=1e-4)


def test_ssd_chunk_invariance():
    """The chunked recurrence must be independent of the chunk size."""
    ks = jax.random.split(jax.random.key(4), 5)
    B, S, nh, P, N = 1, 96, 2, 16, 32
    x = jax.random.normal(ks[0], (B, S, nh, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, nh), jnp.float32))
    A = -jnp.exp(jax.random.normal(ks[2], (nh,), jnp.float32) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, N), jnp.float32)
    Cm = jax.random.normal(ks[4], (B, S, N), jnp.float32)
    y0, h0 = ssd_scan(x, dt, A, Bm, Cm, chunk=96, interpret=True)
    for c in (16, 32, 48):
        y, h = ssd_scan(x, dt, A, Bm, Cm, chunk=c, interpret=True)
        np.testing.assert_allclose(np.asarray(y0), np.asarray(y),
                                   rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(h0), np.asarray(h),
                                   rtol=2e-4, atol=2e-4)


def test_ssd_matches_production_chunked_path():
    """Kernel == the pure-JAX chunked path used by the models."""
    from repro.models.mamba import ssd_chunked
    ks = jax.random.split(jax.random.key(5), 5)
    B, S, nh, P, N = 2, 64, 2, 16, 32
    x = jax.random.normal(ks[0], (B, S, nh, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, nh), jnp.float32))
    A = -jnp.exp(jax.random.normal(ks[2], (nh,), jnp.float32) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, N), jnp.float32)
    Cm = jax.random.normal(ks[4], (B, S, N), jnp.float32)
    y_k, h_k = ops.ssd(x, dt, A, Bm, Cm, chunk=32)
    y_j, h_j = ssd_chunked(x, dt, A, Bm, Cm, chunk=32)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_j),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_j),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------- rmsnorm
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(8, 64), (3, 7, 96), (1, 384), (130, 256)])
def test_rmsnorm_matches_ref(shape, dtype):
    x = jax.random.normal(jax.random.key(6), shape, dtype)
    s = jax.random.normal(jax.random.key(7), (shape[-1],), jnp.float32)
    out = rmsnorm_kernel(x, s, block_rows=32, interpret=True)
    expect = ref.rmsnorm_ref(x, s)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), **_tol(dtype))
    assert out.dtype == x.dtype and out.shape == x.shape


def test_rmsnorm_matches_model_layer():
    from repro.models.layers import rmsnorm as layer_rmsnorm
    x = jax.random.normal(jax.random.key(8), (4, 16, 128), jnp.float32)
    s = jnp.ones((128,), jnp.float32) * 1.5
    out = ops.rmsnorm(x, s)
    expect = layer_rmsnorm({"scale": s}, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------- grads
def test_attention_grads_match_reference():
    """custom_vjp (kernel fwd / ref bwd): grads == pure-ref grads."""
    ks = jax.random.split(jax.random.key(9), 3)
    q = jax.random.normal(ks[0], (1, 64, 4, 32), jnp.float32)
    k = jax.random.normal(ks[1], (1, 64, 2, 32), jnp.float32)
    v = jax.random.normal(ks[2], (1, 64, 2, 32), jnp.float32)

    def f_kernel(q, k, v):
        return jnp.sum(ops.attention(q, k, v, causal=True,
                                     block_q=32, block_k=32) ** 2)

    def f_ref(q, k, v):
        return jnp.sum(ref.attention_ref(q, k, v, causal=True) ** 2)

    gk = jax.grad(f_kernel, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-4)


def test_ssd_grads_match_reference():
    ks = jax.random.split(jax.random.key(10), 5)
    B, S, nh, P, N = 1, 32, 2, 8, 16
    x = jax.random.normal(ks[0], (B, S, nh, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, nh), jnp.float32))
    A = -jnp.exp(jax.random.normal(ks[2], (nh,), jnp.float32) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, N), jnp.float32)
    Cm = jax.random.normal(ks[4], (B, S, N), jnp.float32)

    def f_kernel(x, dt, Bm, Cm):
        y, h = ops.ssd(x, dt, A, Bm, Cm, chunk=16)
        return jnp.sum(y ** 2) + jnp.sum(h ** 2)

    def f_ref(x, dt, Bm, Cm):
        y, h = ref.ssd_ref(x, dt, A, Bm, Cm)
        return jnp.sum(y ** 2) + jnp.sum(h ** 2)

    gk = jax.grad(f_kernel, argnums=(0, 1, 2, 3))(x, dt, Bm, Cm)
    gr = jax.grad(f_ref, argnums=(0, 1, 2, 3))(x, dt, Bm, Cm)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-3, atol=1e-3)


def test_rmsnorm_grads_match_reference():
    x = jax.random.normal(jax.random.key(11), (4, 8, 64), jnp.float32)
    s = jax.random.normal(jax.random.key(12), (64,), jnp.float32)

    gk = jax.grad(lambda x, s: jnp.sum(ops.rmsnorm(x, s) ** 2),
                  argnums=(0, 1))(x, s)
    gr = jax.grad(lambda x, s: jnp.sum(ref.rmsnorm_ref(x, s) ** 2),
                  argnums=(0, 1))(x, s)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)


def test_train_step_through_kernels():
    """A full train step with use_kernels=True descends and stays finite."""
    from repro.configs import get_smoke_config
    from repro.models.encdec import build_model
    from repro.optim import AdamW
    from repro.optim.schedule import constant
    from repro.sharding import get_policy
    from repro.data import TokenPipeline
    cfg = get_smoke_config("qwen1.5-0.5b")
    model = build_model(cfg, get_policy("baseline"), None,
                        compute_dtype=jnp.float32, remat=False,
                        use_kernels=True)
    opt = AdamW(lr=constant(5e-3))
    params = model.init(jax.random.key(0))
    state = opt.init(params)
    batch = {k: jnp.asarray(v)
             for k, v in TokenPipeline(cfg, 2, 32).next().items()}

    @jax.jit
    def step(p, s, b):
        (loss, m), g = jax.value_and_grad(model.loss, has_aux=True)(p, b)
        p, s, _ = opt.update(g, s, p)
        return p, s, loss

    p, s, l0 = step(params, state, batch)
    for _ in range(3):
        p, s, l1 = step(p, s, batch)
    assert np.isfinite(float(l1)) and float(l1) < float(l0)
