import os

# Tests run single-device (the dry-run sets its own 512-device flag in a
# subprocess); keep CPU math deterministic.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import pytest


@pytest.fixture
def run_dir(tmp_path):
    return str(tmp_path / "run")


@pytest.fixture
def mesh1():
    """Trivial 1-device mesh with the production axis names."""
    from repro.launch.mesh import make_mesh
    return make_mesh((1,), ("data",))


@pytest.fixture
def key():
    return jax.random.key(0)
