"""repro.api surface: CheckpointOptions validation + env round-trip,
capabilities()/check() report shape, the versioned backend/plugin registry,
the frozen() phase context manager, and session-driven round trips."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (CheckpointOptions, CheckpointSession, OptionsError,
                       capabilities, check)
from repro.core import (PLUGIN_API_VERSION, Plugin, PluginVersionError,
                        SnapshotEngine, available_backends, create_backend)
from repro.core.backends import BackendError, register_backend


def make_state(n=3):
    ks = jax.random.split(jax.random.key(0), n)
    return {f"w{i}": jax.random.normal(ks[i], (4, 8), jnp.float32)
            for i in range(n)}


# ---------------------------------------------------------------- options
def test_options_defaults_valid():
    CheckpointOptions().validate()          # must not raise


@pytest.mark.parametrize("bad", [
    dict(mode="turbo"),
    dict(keep=-1),
    dict(lock_timeout_s=0),
    dict(restore_threads=-2),
    dict(replicate_to=""),
])
def test_options_validation_rejects(bad):
    with pytest.raises(OptionsError):
        CheckpointOptions(**bad)


def test_options_frozen():
    o = CheckpointOptions()
    with pytest.raises(dataclasses.FrozenInstanceError):
        o.mode = "async"


def test_options_env_round_trip():
    o = CheckpointOptions(mode="async", incremental=True, compress=True,
                          keep=5, lock_timeout_s=2.5, restore_threads=4,
                          replicate_to="/tmp/peer", verify_restore=False)
    assert CheckpointOptions.from_env(o.to_env()) == o


def test_options_from_env_defaults_and_parsing():
    assert CheckpointOptions.from_env({}) == CheckpointOptions()
    o = CheckpointOptions.from_env({"REPRO_CKPT_MODE": "async",
                                    "REPRO_CKPT_INCREMENTAL": "true",
                                    "REPRO_CKPT_KEEP": "3"})
    assert o.mode == "async" and o.incremental and o.keep == 3


def test_options_replace():
    o = CheckpointOptions().replace(mode="async")
    assert o.mode == "async" and CheckpointOptions().mode == "sync"


# ----------------------------------------------------------- capabilities
def test_capabilities_report_shape():
    caps = capabilities()
    assert caps["plugin_api_version"] == PLUGIN_API_VERSION
    assert caps["jax"]["version"] == jax.__version__
    assert caps["jax"]["device_count"] >= 1
    assert isinstance(caps["mesh"]["axis_types"], bool)
    assert set(caps["backends"]) >= {"jax", "host"}
    for spec in caps["backends"].values():
        assert spec["api_version"] == PLUGIN_API_VERSION
        assert isinstance(spec["features"], list)
    assert caps["modes"] == ["sync", "async"]


def test_check_passes_here(tmp_path):
    report = check(run_dir=str(tmp_path / "imgs"),
                   options=CheckpointOptions())
    assert report.ok, report.problems
    assert report.capabilities["jax"]["device_count"] >= 1
    assert "repro check" in report.summary()


def test_session_check_and_capabilities(run_dir):
    s = CheckpointSession(run_dir, CheckpointOptions(mode="async"))
    assert s.check().ok
    caps = s.capabilities()
    assert caps["session"]["backend"] == "jax"
    assert caps["session"]["options"]["mode"] == "async"
    assert "device" in caps["session"]["plugins"]


# --------------------------------------------------------------- registry
def test_backend_registry_lists_jax_and_host():
    av = available_backends()
    assert "jax" in av and "host" in av
    assert "device_arrays" in av["jax"]["features"]


def test_backend_registry_rejects_wrong_api_version():
    with pytest.raises(PluginVersionError):
        register_backend("future", lambda **kw: Plugin(),
                         api_version=PLUGIN_API_VERSION + 1)
    assert "future" not in available_backends()


def test_backend_registry_rejects_duplicate_and_unknown():
    with pytest.raises(BackendError):
        register_backend("jax", lambda **kw: Plugin(),
                         api_version=PLUGIN_API_VERSION)
    with pytest.raises(BackendError):
        create_backend("no-such-backend")


def test_engine_rejects_mismatched_plugin(run_dir):
    class OldPlugin(Plugin):
        name = "old"
        api_version = PLUGIN_API_VERSION - 1

    with pytest.raises(PluginVersionError):
        SnapshotEngine(run_dir, plugins=[OldPlugin()])


def test_legacy_engine_kwargs_deprecated(run_dir):
    with pytest.warns(DeprecationWarning):
        eng = SnapshotEngine(run_dir, mode="async", keep=2)
    assert eng.mode == "async" and eng.keep == 2
    # no-kwarg construction stays silent
    import warnings
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        SnapshotEngine(run_dir)


# ---------------------------------------------------------------- session
def test_session_round_trip(run_dir, mesh1):
    state = make_state()
    host = {"v": {"step": 3}}
    s = CheckpointSession(run_dir, mesh=mesh1)
    s.attach(lambda: {"train_state": state})
    s.register_host_state("host", lambda: host["v"],
                          lambda v: host.__setitem__("v", v))
    s.checkpoint(3)
    assert s.store.list_steps() == [3]

    host2 = {"v": None}
    s2 = CheckpointSession(run_dir, mesh=mesh1)
    s2.attach(lambda: {"train_state": None})
    s2.register_host_state("host", lambda: None,
                           lambda v: host2.__setitem__("v", v))
    restored = s2.restore()
    assert host2["v"] == {"step": 3}
    np.testing.assert_array_equal(
        np.asarray(restored["train_state"]["w0"]), np.asarray(state["w0"]))


def test_session_frozen_phases(run_dir):
    state = make_state()
    s = CheckpointSession(run_dir)
    s.attach(lambda: {"train_state": state})
    with s.frozen(1) as snap:
        # ①–③ already ran: capture is in host memory, job quiesced
        assert snap.step == 1
        assert "frozen_s" in snap.stats
        assert s.engine.device_plugin.lock.locked
        assert s.store.list_steps() == []      # nothing committed yet
    # ④ ran on exit: image committed, lock released
    assert s.store.list_steps() == [1]
    assert not s.engine.device_plugin.lock.locked
    assert snap.path is not None


def test_session_frozen_abort_on_exception(run_dir):
    s = CheckpointSession(run_dir)
    s.attach(lambda: {"train_state": make_state()})
    with pytest.raises(RuntimeError, match="boom"):
        with s.frozen(1):
            raise RuntimeError("boom")
    assert s.store.list_steps() == []          # no image written
    assert not s.engine.device_plugin.lock.locked


def test_session_frozen_explicit_abort(run_dir):
    s = CheckpointSession(run_dir)
    s.attach(lambda: {"train_state": make_state()})
    with s.frozen(2) as snap:
        snap.abort()                           # e.g. preflight said no
    assert s.store.list_steps() == []
    assert not s.engine.device_plugin.lock.locked


def test_session_host_backend_round_trip(run_dir):
    state = make_state()
    s = CheckpointSession(run_dir, backend="host")
    s.attach(lambda: {"train_state": state})
    s.checkpoint(1)
    s2 = CheckpointSession(run_dir, backend="host")
    s2.attach(lambda: {"train_state": None})
    restored = s2.restore()
    got = restored["train_state"]["w1"]
    assert isinstance(got, np.ndarray)         # never device-placed
    np.testing.assert_array_equal(got, np.asarray(state["w1"]))


def test_session_from_env(run_dir, monkeypatch):
    monkeypatch.setenv("REPRO_CKPT_MODE", "async")
    monkeypatch.setenv("REPRO_CKPT_KEEP", "7")
    s = CheckpointSession.from_env(run_dir)
    assert s.options.mode == "async" and s.options.keep == 7


def test_session_context_manager_waits_async(run_dir):
    state = make_state()
    with CheckpointSession(run_dir, CheckpointOptions(mode="async")) as s:
        s.attach(lambda: {"train_state": state})
        s.checkpoint(1)
    # exiting the with-block drained the background writer
    assert s.store.manifest(1)["step"] == 1


def test_trainconfig_resolves_options():
    from repro.runtime.trainer import TrainConfig
    legacy = TrainConfig(ckpt_mode="async", incremental=True)
    assert legacy.checkpoint_options() == CheckpointOptions(
        mode="async", incremental=True)
    explicit = TrainConfig(ckpt=CheckpointOptions(keep=4))
    assert explicit.checkpoint_options().keep == 4
