"""Content-addressed checkpoint transfer + live cross-host migration.

Covers the tentpole acceptance criteria and the edge cases from the
issue's satellite list:

  * delta push moves only chunks the target CAS is missing (warm pushes
    re-send nothing);
  * an interrupted transfer resumes without re-sending received chunks
    (the CAS is the resume log);
  * target CAS corruption is detected (CRC) before any restore and
    healed from the source while it still exists;
  * v1-format images fall back to whole-file copy;
  * ``repro orchestrate --scenario migrate`` recovers the migrated job
    bit-exact vs an unmigrated run.
"""
import json
import os

import numpy as np
import pytest

from repro.api import CheckpointOptions, CheckpointSession
from repro.core.engine import SnapshotEngine
from repro.core.snapshot_io import MANIFEST, SnapshotStore, snapshot_dir
from repro.transfer import (CASCorruption, ChunkStore, DeltaReplicator,
                            chunk_key, transfer_closure)


def _chain(run_dir, steps=4, entries=6, entry_kb=64, pack_format=2,
           seed=0):
    """Incremental chain: full image + deltas, 2 entries mutate/step."""
    rng = np.random.default_rng(seed)
    state = {f"t{i}": rng.integers(0, 8, size=entry_kb * 256)
             .astype(np.float32) for i in range(entries)}
    opts = CheckpointOptions(mode="sync", incremental=True,
                             pack_format=pack_format)
    s = CheckpointSession(run_dir, opts, backend="host")
    s.attach(lambda: {"train_state": state})
    names = sorted(state)
    for step in range(1, steps + 1):
        if step > 1:
            for i in range(2):
                k = names[(step * 2 + i) % entries]
                state[k] = rng.integers(0, 8, size=entry_kb * 256) \
                    .astype(np.float32)
        s.checkpoint(step)
    return s, state


def _restore_state(run_dir):
    eng = SnapshotEngine(run_dir, backend="host")
    eng.attach(lambda: {"train_state": None})
    return eng.restore()["train_state"]


def _assert_state_equal(got, want):
    assert sorted(got) == sorted(want)
    for k in want:
        np.testing.assert_array_equal(np.asarray(got[k]),
                                      np.asarray(want[k]))


# ----------------------------------------------------------------- delta
def test_delta_push_roundtrip_and_warm_dedup(tmp_path):
    src, state = _chain(str(tmp_path / "src"))
    rep = DeltaReplicator(str(tmp_path / "peer"))
    st = rep.push(str(tmp_path / "src"), 4)
    assert st["bytes_sent"] > 0 and st["steps_transferred"] >= 2
    _assert_state_equal(_restore_state(str(tmp_path / "peer")), state)
    # an identical re-push is pure negotiation: nothing moves
    st2 = DeltaReplicator(str(tmp_path / "peer")).push(
        str(tmp_path / "src"), 4)
    assert st2["bytes_sent"] == 0 and st2["steps_transferred"] == 0
    assert st2["steps_skipped"] == st["steps_transferred"]


def test_warm_cas_ships_only_the_new_delta(tmp_path):
    src, state = _chain(str(tmp_path / "src"), steps=5)
    rep = DeltaReplicator(str(tmp_path / "peer"))
    closure = transfer_closure(src.store, 5)
    rep.push(str(tmp_path / "src"), closure[-2])     # pre-stage the chain
    st = rep.push(str(tmp_path / "src"), 5)          # only step 5 moves
    full = sum(os.path.getsize(os.path.join(r, f))
               for s in closure
               for r in [snapshot_dir(str(tmp_path / "src"), s)]
               for f in os.listdir(r))
    assert st["bytes_sent"] < full / 2               # acceptance bound
    _assert_state_equal(_restore_state(str(tmp_path / "peer")), state)


def test_interrupted_transfer_resumes_without_resending(tmp_path):
    """Kill the ship mid-flight; the retry must re-negotiate and skip
    every chunk that already landed in the target CAS."""
    src, state = _chain(str(tmp_path / "src"))
    peer = str(tmp_path / "peer")

    real_put = ChunkStore.put
    calls = {"n": 0}

    def flaky_put(self, key, data):
        calls["n"] += 1
        if calls["n"] > 3:
            raise IOError("link dropped")
        return real_put(self, key, data)

    rep = DeltaReplicator(peer, workers=1)           # deterministic order
    ChunkStore.put = flaky_put
    try:
        with pytest.raises(IOError, match="link dropped"):
            rep.push(str(tmp_path / "src"), 4)
    finally:
        ChunkStore.put = real_put
    landed = ChunkStore(os.path.join(peer, ".cas")).stats()["objects"]
    assert landed == 3                               # partial transfer
    # no image committed at the target: manifests only land after payload
    assert SnapshotStore(peer).list_steps() == []

    retry = DeltaReplicator(peer, workers=1)
    st = retry.push(str(tmp_path / "src"), 4)
    assert st["chunks_reused"] >= landed             # received: not re-sent
    _assert_state_equal(_restore_state(peer), state)


def test_target_cas_corruption_detected_and_healed(tmp_path):
    """A bit-rotted CAS object must be caught by its CRC *during
    materialization* — before any restore can read the bad bytes — and
    healed from the source while one still exists.  The reuse scenario
    is a host-shared CAS: a second store on the same host dedups against
    objects an earlier transfer landed."""
    src, state = _chain(str(tmp_path / "src"))
    cas_dir = str(tmp_path / "host_cas")
    rep = DeltaReplicator(str(tmp_path / "peer_a"), cas_dir=cas_dir)
    rep.push(str(tmp_path / "src"), 4)
    cas = ChunkStore(cas_dir)
    # bit-rot one object
    objs = []
    for dirpath, _d, files in os.walk(cas.objects):
        objs += [os.path.join(dirpath, f) for f in files]
    victim = sorted(objs)[0]
    raw = open(victim, "rb").read()
    open(victim, "wb").write(b"\x00" * len(raw))
    key = os.path.basename(victim)
    # detection is CRC-based and independent of any transfer
    with pytest.raises(CASCorruption):
        cas.get(key)
    assert cas.fsck() == [key]
    # a second store on this host reuses the CAS: the corrupt object is
    # caught at materialization time and re-fetched from the source
    rep_b = DeltaReplicator(str(tmp_path / "peer_b"), cas_dir=cas_dir)
    st = rep_b.push(str(tmp_path / "src"), 4)
    assert st["corrupt_objects_healed"] >= 1
    assert cas.fsck() == []
    _assert_state_equal(_restore_state(str(tmp_path / "peer_b")), state)


def test_v1_images_fall_back_to_full_copy(tmp_path):
    src, state = _chain(str(tmp_path / "src"), pack_format=1)
    rep = DeltaReplicator(str(tmp_path / "peer"))
    st = rep.push(str(tmp_path / "src"), 4)
    assert st["files_copied"] > 0 and st["bytes_copied"] > 0
    assert st["chunks_sent"] == 0                    # no chunk index in v1
    _assert_state_equal(_restore_state(str(tmp_path / "peer")), state)


def test_transfer_closure_spans_referenced_parents(tmp_path):
    src, _ = _chain(str(tmp_path / "src"), steps=4)
    closure = transfer_closure(src.store, 4)
    assert closure[-1] == 4 and 1 in closure         # full image included
    assert closure == sorted(closure)


def test_chunk_key_qualifies_size_and_stored_crc():
    a = {"raw_crc32": 1, "raw_nbytes": 10, "crc32": 2}
    assert chunk_key(a) != chunk_key(dict(a, raw_nbytes=11))
    assert chunk_key(a) != chunk_key(dict(a, crc32=3))
    assert chunk_key(a) == chunk_key(dict(a))


def test_cas_put_rejects_corrupt_payload(tmp_path):
    cas = ChunkStore(str(tmp_path / "cas"))
    key = chunk_key({"raw_crc32": 1, "raw_nbytes": 4, "crc32": 0})
    with pytest.raises(CASCorruption):
        cas.put(key, b"data")                        # crc32(b"data") != 0


def test_cas_put_same_key_concurrently(tmp_path):
    """Duplicate-content chunks land from parallel stripe lanes: racing
    puts of the same key must both succeed (identical bytes, atomic
    replace), never crash on a tmp-file collision."""
    import threading
    from repro.serialization.integrity import crc32
    cas = ChunkStore(str(tmp_path / "cas"))
    data = b"\x00" * 4096
    key = chunk_key({"raw_crc32": crc32(data), "raw_nbytes": len(data),
                     "crc32": crc32(data)})
    barrier = threading.Barrier(4)
    errors = []

    def racer():
        try:
            barrier.wait()
            cas.put(key, data)
        except BaseException as e:                   # pragma: no cover
            errors.append(e)

    threads = [threading.Thread(target=racer) for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert errors == []
    assert cas.get(key) == data
    assert cas.stats()["objects"] == 1


def test_cas_ingest_pack_warms_store_from_local_snapshots(tmp_path):
    """A host can pre-warm its CAS from snapshots it already holds, so
    the first delta push to it ships only genuinely new chunks."""
    src, state = _chain(str(tmp_path / "src"))
    cas_dir = str(tmp_path / "cas")
    cas = ChunkStore(cas_dir)
    from repro.serialization.pack import pack_files
    n = 0
    for step in src.store.list_steps():
        base = pack_files(os.path.join(
            snapshot_dir(str(tmp_path / "src"), step),
            "host0000.pack"))[0].rsplit(".", 1)[0]
        n += cas.ingest_pack(base)
    assert n > 0 and cas.fsck() == []
    # a push against the warmed CAS moves no chunk bytes at all
    rep = DeltaReplicator(str(tmp_path / "peer"), cas_dir=cas_dir)
    st = rep.push(str(tmp_path / "src"), 4)
    assert st["bytes_sent"] == 0 and st["chunks_reused"] > 0
    _assert_state_equal(_restore_state(str(tmp_path / "peer")), state)


# ------------------------------------------------------------ engine glue
def test_options_transfer_knob_builds_delta_replicator(tmp_path):
    opts = CheckpointOptions(replicate_to=str(tmp_path / "peer"),
                             transfer="delta")
    eng = SnapshotEngine(str(tmp_path / "run"), options=opts,
                         backend="host")
    assert isinstance(eng.replicator, DeltaReplicator)
    with pytest.raises(Exception):
        CheckpointOptions(transfer="rsync")
    # env round-trip carries the new knobs
    env = opts.to_env()
    assert CheckpointOptions.from_env(env) == opts


def test_engine_replication_stats_and_delta_path(tmp_path):
    state = {"w": np.arange(4096, dtype=np.float32)}
    opts = CheckpointOptions(replicate_to=str(tmp_path / "peer"),
                             transfer="delta", incremental=True)
    s = CheckpointSession(str(tmp_path / "run"), opts, backend="host")
    s.attach(lambda: {"train_state": state})
    s.checkpoint(1)
    assert s.last_stats["replica_bytes_sent"] > 0
    assert "replicate_s" in s.last_stats
    state["w"] = state["w"] + 1
    s.checkpoint(2)
    _assert_state_equal(_restore_state(str(tmp_path / "peer")), state)


def test_dir_replicator_skips_unchanged_files(tmp_path):
    """Satellite fix: replication is O(delta), not O(image) — unchanged
    files (same size+mtime) are skipped on re-push, and the counters
    surface through the engine's dump stats."""
    from repro.core.replication import DirReplicator
    state = {"w": np.arange(8192, dtype=np.float32)}
    opts = CheckpointOptions(replicate_to=str(tmp_path / "peer"))
    s = CheckpointSession(str(tmp_path / "run"), opts, backend="host")
    s.attach(lambda: {"train_state": state})
    s.checkpoint(1)
    assert isinstance(s.engine.replicator, DirReplicator)
    assert s.last_stats["replica_files_copied"] > 0
    assert s.last_stats["replica_files_skipped"] == 0
    # identical re-push of the same committed step: all files skipped
    st = s.engine.replicator.push(str(tmp_path / "run"), 1)
    assert st["files_copied"] == 0
    assert st["files_skipped"] > 0 and st["bytes_copied"] == 0
    _assert_state_equal(_restore_state(str(tmp_path / "peer")), state)


def test_dir_replicator_repush_of_changed_step_recommits(tmp_path):
    """Re-pushing a step whose content changed (re-dump after restore)
    must re-commit the peer image: manifest dropped before payload is
    replaced, re-landed last — never a committed manifest over a
    half-replaced pack."""
    from repro.core.replication import DirReplicator
    state = {"w": np.arange(4096, dtype=np.float32)}
    run = str(tmp_path / "run")
    s = CheckpointSession(run, CheckpointOptions(mode="sync"),
                          backend="host")
    s.attach(lambda: {"train_state": state})
    s.checkpoint(1)
    rep = DirReplicator(str(tmp_path / "peer"))
    rep.push(run, 1)
    state["w"] = state["w"] * 2
    s.checkpoint(1)                                  # re-dump, new content
    st = rep.push(run, 1)
    assert st["files_copied"] > 0
    _assert_state_equal(_restore_state(str(tmp_path / "peer")), state)


def test_incremental_redump_of_same_step_stays_restorable(tmp_path):
    """Regression: a re-dump of an existing step must not use the image
    it overwrites as its own incremental parent (self-referential torn
    image) — the parent is the newest *older* step."""
    run = str(tmp_path / "run")
    state = {"w": np.arange(4096, dtype=np.float32)}
    s = CheckpointSession(run, CheckpointOptions(mode="sync",
                                                 incremental=True),
                          backend="host")
    s.attach(lambda: {"train_state": state})
    s.checkpoint(1)
    state["w"] = state["w"] + 1
    s.checkpoint(2)
    s.checkpoint(2)                                  # re-dump same step
    m = s.store.manifest(2)
    assert m["parent"] == 1                          # not itself
    reader = s.store.reader(2)
    try:
        reader.verify_all()                          # restorable image
    finally:
        reader.close()
    _assert_state_equal(_restore_state(run), state)


# -------------------------------------------------------------- migration
@pytest.mark.slow
def test_migrate_scenario_recovers_bit_exact(tmp_path):
    """Acceptance: the migrated job's final train state is bit-exact vs
    an unmigrated run, with the transfer phase measured in its incident
    and the job restored on a different simulated host."""
    from repro.orchestrator import JobSpec, run_scenario
    from repro.orchestrator.workloads import TrainWorkload
    total = 8
    summary = run_scenario("migrate", str(tmp_path / "orch"),
                           total_steps=total)
    assert summary["all_done"]
    j = summary["jobs"]["mover"]
    assert j["step"] == total and j["restarts"] == 1
    assert j["migration"]["state"] == "transferred"
    assert j["migration"]["from"] != j["migration"]["to"]
    assert j["host"] == j["migration"]["to"]
    (inc,) = [i for i in j["recovery"] if i["cause"] == "migration"]
    assert inc["transfer_s"] is not None and inc["transfer_s"] > 0
    assert inc["restore_s"] is not None
    # checkpoint-on-signal means migration replays nothing
    assert inc["steps_replayed"] == 0
    # the same job, never migrated, reaches the identical state
    ref = TrainWorkload(JobSpec("ref", total_steps=total),
                        str(tmp_path / "ref"), mesh=None)
    ref.start()
    while not ref.done:
        ref.run_slice(2)
    ref.finish()
    assert j["digest"] == ref.digest()
    # job record persists the placement for offline inspection
    raw = json.load(open(os.path.join(str(tmp_path / "orch"), "jobs",
                                      "mover.json")))
    assert raw["host"] == j["migration"]["to"]


def test_migration_requires_multiple_hosts(tmp_path):
    from repro.orchestrator import (JobSpec, Orchestrator,
                                    OrchestratorConfig)
    with pytest.raises(ValueError, match="multi-host"):
        Orchestrator(str(tmp_path / "orch"),
                     [JobSpec("j", migrate_at_step=2)],
                     config=OrchestratorConfig(capacity=1, hosts=1))


# ------------------------------------------------------------------- CLI
def test_migrate_and_transfer_stats_cli(tmp_path, capsys):
    from repro.cli import main
    src, state = _chain(str(tmp_path / "src"))
    peer = str(tmp_path / "peer")
    assert main(["migrate", str(tmp_path / "src"), peer]) == 0
    out = capsys.readouterr().out
    assert "CRC-clean at destination" in out
    # idempotent re-run: everything already present
    assert main(["migrate", str(tmp_path / "src"), peer, "--json"]) == 0
    stats = json.loads(capsys.readouterr().out)
    assert stats["bytes_sent"] == 0 and stats["steps_skipped"] >= 2
    assert main(["transfer-stats", peer, "--fsck"]) == 0
    out = capsys.readouterr().out
    assert "CAS object(s)" in out and "CRC-clean" in out
    # --transfer copy exercises the DirReplicator closure path
    assert main(["migrate", str(tmp_path / "src"),
                 str(tmp_path / "peer2"), "--transfer", "copy"]) == 0
    _assert_state_equal(_restore_state(str(tmp_path / "peer2")), state)


def test_transfer_stats_detects_corruption(tmp_path, capsys):
    from repro.cli import main
    _chain(str(tmp_path / "src"))
    peer = str(tmp_path / "peer")
    assert main(["migrate", str(tmp_path / "src"), peer]) == 0
    capsys.readouterr()
    cas = ChunkStore(os.path.join(peer, ".cas"))
    objs = []
    for dirpath, _d, files in os.walk(cas.objects):
        objs += [os.path.join(dirpath, f) for f in files]
    open(sorted(objs)[0], "ab").write(b"x")
    assert main(["transfer-stats", peer, "--fsck"]) == 1
    assert "corrupt" in capsys.readouterr().out


# ----------------------------------------------------------- double fault
def _corrupt_entry_chunk(root, step, entry):
    """Flip bytes inside `entry`'s first chunk of `root`'s step image."""
    from repro.serialization.pack import open_pack, stripe_path
    base = os.path.join(snapshot_dir(root, step), "host0000.pack")
    with open_pack(base, verify=False) as r:
        c = r.index[entry]["chunks"][0]
    with open(stripe_path(base, c["stripe"]), "r+b") as f:
        f.seek(c["offset"] + 8)
        f.write(b"\xde\xad\xbe\xef")


def test_double_fault_quarantines_with_diagnosable_error(tmp_path):
    """Local chunk torn AND the replica's copy of the same entry torn:
    the heal pulls equally-bad bytes, the retried entry fails again, and
    the lazy materializer quarantines the step with a diagnosable error
    naming the entry — it never crashes the loop and never serves bad
    bytes.  The retried restore falls back to the previous commit."""
    from repro.core.lazy import LazyRestoreError
    run, peer = str(tmp_path / "run"), str(tmp_path / "peer")
    rng = np.random.default_rng(0)
    state1 = {"hot": rng.standard_normal(512).astype(np.float32),
              "cold": {f"c{i}": rng.standard_normal(8 * 256)
                       .astype(np.float32) for i in range(3)}}
    holder = {"state": state1}
    s = CheckpointSession(run,
                          CheckpointOptions(mode="sync", replicate_to=peer),
                          backend="host")
    s.attach(lambda: {"train_state": holder["state"]})
    s.checkpoint(1)
    state2 = {"hot": state1["hot"] + 1.0,
              "cold": {k: v + 1.0 for k, v in state1["cold"].items()}}
    holder["state"] = state2
    s.checkpoint(2)

    entry = "train_state::cold/c0::np"
    _corrupt_entry_chunk(run, 2, entry)      # fault 1: local image
    _corrupt_entry_chunk(peer, 2, entry)     # fault 2: replica, same entry

    r = CheckpointSession(
        run, CheckpointOptions(replicate_to=peer, restore_mode="lazy",
                               critical_states=("train_state/hot",)),
        backend="host")
    r.attach(lambda: {"train_state": None})
    restored = r.restore()                   # criticals verify clean
    np.testing.assert_array_equal(
        np.asarray(restored["train_state"]["hot"]), state2["hot"])
    # the heal pulls the replica's equally-corrupt bytes, the retried
    # entry fails again, and the barrier names the entry it gave up on
    with pytest.raises(LazyRestoreError, match="cold/c0"):
        r.restore_barrier()
    # step 2 is quarantined: the retry falls back to step 1, bit-exact
    again = r.restore(wait="all")
    np.testing.assert_array_equal(
        np.asarray(again["train_state"]["hot"]), state1["hot"])
    for k, v in state1["cold"].items():
        np.testing.assert_array_equal(
            np.asarray(again["train_state"]["cold"][k]), v)
