"""Concurrent (soft-freeze) capture: copy-on-write speculation with
validated commit.

The contract under test: with ``CheckpointOptions(capture="concurrent")``
a dump is pinned in a brief pause, speculated in the background while the
job keeps mutating state, then validated in a second short pause — and
the committed image is *always* bit-exact with the live state at the
validate pause, no matter which interleaving of async prefetch, donation
rebinds, in-place mutations, and cross-host collectives happened in
between.  When an op cannot be quiesced at a capture boundary the dump
fails fast with "unsafe op in flight" and no manifest — never torn state.
"""
import threading
import time

import numpy as np
import pytest

from repro.api import (CheckpointOptions, CheckpointSession,
                       OptionsError, PendingWriteStalled)
from repro.core.engine import CheckpointAborted
from repro.core.streams import StreamOp, StreamSet

pytestmark = pytest.mark.filterwarnings("ignore::DeprecationWarning")


def _state(n=6, kb=32, seed=0):
    rng = np.random.default_rng(seed)
    return {f"w{i}": rng.standard_normal(kb * 128).astype(np.float32)
            for i in range(n)}


def _opts(**kw):
    base = dict(pack_format=2, incremental=True, capture="concurrent")
    base.update(kw)
    return CheckpointOptions(**base)


def _session(run_dir, state, **kw):
    sess = CheckpointSession(run_dir, _opts(**kw), backend="host")
    sess.attach(lambda: {"state": state})
    return sess


def _restore(run_dir, step=None):
    r = CheckpointSession(run_dir, CheckpointOptions(pack_format=2),
                          backend="host")
    r.attach(lambda: {"state": None})
    return r.restore(step=step)["state"]


# ---------------------------------------------------------------- options
def test_capture_option_validated_up_front():
    with pytest.raises(OptionsError, match="capture"):
        CheckpointOptions(capture="turbo")
    with pytest.raises(OptionsError, match="pack_format=2"):
        CheckpointOptions(capture="concurrent", pack_format=1,
                          incremental=True)
    with pytest.raises(OptionsError, match="incremental"):
        CheckpointOptions(capture="concurrent", pack_format=2,
                          incremental=False)
    with pytest.raises(OptionsError, match="async"):
        CheckpointOptions(capture="concurrent", pack_format=2,
                          incremental=True, mode="async")


def test_capture_option_env_roundtrip(monkeypatch):
    for k, v in _opts().to_env().items():
        monkeypatch.setenv(k, v)
    assert CheckpointOptions.from_env().capture == "concurrent"


def test_concurrent_requires_dirty_tracking_backend(run_dir, monkeypatch):
    from repro.core.backends import HostNumpyBackend
    monkeypatch.setattr(HostNumpyBackend, "features",
                        frozenset({"device_state"}), raising=False)
    with pytest.raises(OptionsError, match="dirty_tracking"):
        CheckpointSession(run_dir, _opts(), backend="host")


# ------------------------------------------------------------- bit-exact
def test_concurrent_image_bit_exact_vs_sync_dump(tmp_path):
    state = _state()
    sync_dir, conc_dir = str(tmp_path / "sync"), str(tmp_path / "conc")

    s = CheckpointSession(sync_dir, CheckpointOptions(
        pack_format=2, incremental=True), backend="host")
    s.attach(lambda: {"state": state})
    s.checkpoint(1)

    c = _session(conc_dir, state)
    path = c.checkpoint(1)              # begin + speculate + finalize
    assert path

    ms, mc = s.store.manifest(1), c.store.manifest(1)
    assert ms["entry_crcs"] == mc["entry_crcs"]
    assert ms.get("capture") == "sync"
    assert mc.get("capture") == "concurrent"
    cs = mc["capture_stats"]
    assert cs["speculated_entries"] == len(state)
    assert cs["recaptured_entries"] == 0
    assert cs["frozen_s"] == pytest.approx(
        cs["pin_pause_s"] + cs["validate_pause_s"])
    restored = _restore(conc_dir)
    for k, v in state.items():
        np.testing.assert_array_equal(np.asarray(restored[k]), v)


def test_frozen_window_is_locked_pause_not_speculation(tmp_path):
    """frozen_window_s must report pin+validate, not the whole dump."""
    from repro.runtime.interval import frozen_window_s
    state = _state(n=8, kb=256)
    c = _session(str(tmp_path / "c"), state)
    handle = c.checkpoint_begin(1)
    handle.wait_speculated()
    c.checkpoint_finalize()
    st = c.last_stats
    assert frozen_window_s(st) == st["locked_total_s"]
    assert st["locked_total_s"] <= st["total_s"]
    assert st["speculate_s"] > 0


# -------------------------------------------------- interleaving matrix
def test_prefetch_retired_at_pin_lands_in_image(tmp_path):
    """A quiescable prefetch in flight when the dump begins is applied
    (like block_until_ready) before the pin — its write is captured."""
    state = _state()
    c = _session(str(tmp_path / "c"), state)
    streams = StreamSet()
    c.engine.device_plugin.attach_streams(streams)

    def land_prefetch():
        state["w0"][:8] = 123.0

    streams.enqueue("h2d", StreamOp("prefetch", targets=("state::w0",),
                                    apply=land_prefetch))
    c.checkpoint(1)
    restored = _restore(str(tmp_path / "c"))
    assert np.all(np.asarray(restored["w0"])[:8] == 123.0)


def test_mutation_during_speculation_is_recaptured(tmp_path):
    """An op that retires between pin and validate mutates a pinned
    buffer; the dirty protocol must invalidate the stale speculated
    shard and the commit must carry the post-mutation bytes."""
    state = _state()
    c = _session(str(tmp_path / "c"), state)
    streams = StreamSet()
    c.engine.device_plugin.attach_streams(streams)

    handle = c.checkpoint_begin(1)
    assert c.concurrent_capture is handle
    handle.wait_speculated()
    # the step loop races the snapshot: an async dispatch completes and
    # overwrites w1 after it was (probably) already speculated
    def dispatch_lands():
        state["w1"][:] = -7.0

    streams.enqueue("compute", StreamOp("dispatch",
                                        targets=("state::w1",),
                                        apply=dispatch_lands))
    c.checkpoint_finalize()
    st = c.last_stats
    assert st["dirty_entries"] >= 1
    assert st["recaptured_entries"] >= 1
    restored = _restore(str(tmp_path / "c"))
    np.testing.assert_array_equal(np.asarray(restored["w1"]),
                                  np.full_like(state["w1"], -7.0))
    for k in state:
        np.testing.assert_array_equal(np.asarray(restored[k]), state[k])


def test_donation_rebind_detected_by_identity_drift(tmp_path):
    """Donated-buffer semantics: the step fn returns a *new* array for
    the same key (the old one is gone).  No note() fires — identity
    drift alone must flag the entry."""
    state = _state()
    c = _session(str(tmp_path / "c"), state)
    handle = c.checkpoint_begin(1)
    handle.wait_speculated()
    state["w2"] = np.full_like(state["w2"], 42.0)     # rebind, no note
    c.checkpoint_finalize()
    assert c.last_stats["dirty_entries"] >= 1
    restored = _restore(str(tmp_path / "c"))
    np.testing.assert_array_equal(np.asarray(restored["w2"]), state["w2"])


def test_structural_drift_add_and_drop_entries(tmp_path):
    state = _state()
    c = _session(str(tmp_path / "c"), state)
    handle = c.checkpoint_begin(1)
    handle.wait_speculated()
    state["fresh"] = np.ones(16, np.float32)          # appears mid-capture
    dropped = state.pop("w3")                         # vanishes mid-capture
    c.checkpoint_finalize()
    restored = _restore(str(tmp_path / "c"))
    assert "w3" not in restored
    np.testing.assert_array_equal(np.asarray(restored["fresh"]),
                                  state["fresh"])
    assert dropped is not None


def test_unsafe_collective_at_finalize_aborts_cleanly(tmp_path):
    """A non-quiescable collective in flight at the validate boundary:
    fail fast, commit nothing, recover on the next dump."""
    state = _state()
    c = _session(str(tmp_path / "c"), state)
    streams = StreamSet()
    c.engine.device_plugin.attach_streams(streams)
    handle = c.checkpoint_begin(1)
    handle.wait_speculated()
    streams.enqueue("collective", StreamOp("allreduce",
                                           quiescable=False))
    with pytest.raises(CheckpointAborted, match="unsafe op in flight"):
        c.checkpoint_finalize()
    assert c.engine.concurrent_capture is None
    assert c.store.latest_step() is None              # no torn manifest
    assert streams.clear_stuck() == 1
    path = c.checkpoint(2)                            # job fully recovered
    assert path and c.store.latest_step() == 2
    restored = _restore(str(tmp_path / "c"))
    for k, v in state.items():
        np.testing.assert_array_equal(np.asarray(restored[k]), v)


def test_unsafe_op_at_pin_aborts_before_any_speculation(tmp_path):
    state = _state()
    c = _session(str(tmp_path / "c"), state)
    streams = StreamSet()
    c.engine.device_plugin.attach_streams(streams)
    streams.enqueue("collective", StreamOp("allreduce",
                                           quiescable=False))
    with pytest.raises(CheckpointAborted, match="unsafe op in flight"):
        c.checkpoint_begin(1)
    assert c.engine.concurrent_capture is None
    assert c.store.latest_step() is None
    streams.clear_stuck()
    assert c.checkpoint(1)


def test_mutation_storm_commit_never_torn(tmp_path):
    """Every entry mutated (in place + rebinds) while the capture is
    open; the image must equal the live tree at finalize, entry for
    entry — a mix of stale and fresh shards would be torn state."""
    state = _state(n=10)
    c = _session(str(tmp_path / "c"), state)
    streams = StreamSet()
    c.engine.device_plugin.attach_streams(streams)
    handle = c.checkpoint_begin(1)
    handle.wait_speculated()
    for i, k in enumerate(list(state)):
        if i % 2:
            state[k] = state[k] * np.float32(-1.0)    # donation rebind
        else:
            arr = state[k]
            streams.enqueue("compute", StreamOp(
                "dispatch", targets=(f"state::{k}",),
                apply=lambda a=arr, s=i: a.__setitem__(
                    slice(None), np.float32(s))))
    c.checkpoint_finalize()
    assert c.last_stats["recaptured_entries"] == len(state)
    restored = _restore(str(tmp_path / "c"))
    for k, v in state.items():
        np.testing.assert_array_equal(np.asarray(restored[k]), v)


def test_second_dump_settles_open_capture_first(tmp_path):
    state = _state()
    c = _session(str(tmp_path / "c"), state)
    c.checkpoint_begin(1)
    # a second dump while a soft-freeze is open must settle it first,
    # not interleave two writers over the same store
    path = c.checkpoint(2)
    assert c.store.latest_step() == 2
    assert c.store.manifest(1).get("capture") == "concurrent"
    assert path


# ----------------------------------------------------------- wait_pending
def test_wait_pending_timeout_raises_diagnosable(tmp_path):
    state = _state(n=2, kb=4)
    c = CheckpointSession(str(tmp_path / "c"),
                          CheckpointOptions(mode="async"), backend="host")
    c.attach(lambda: {"state": state})
    c.checkpoint(1)
    c.wait_pending()                                  # drains normally
    # wedge: a writer thread that outlives the deadline
    release = threading.Event()
    wedged = threading.Thread(target=release.wait, daemon=True)
    wedged.start()
    c.engine._pending = wedged
    c.engine._pending_ctx = None
    with pytest.raises(PendingWriteStalled, match="still running"):
        c.wait_pending(timeout_s=0.05)
    release.set()                                     # I/O recovers
    wedged.join()
    c.wait_pending(timeout_s=5.0)                     # reaps cleanly
    assert c.engine._pending is None


# ------------------------------------------------------------------ CLI
def test_inspect_reports_capture_mode_and_stats(tmp_path, capsys):
    from repro.cli import main
    state = _state()
    run = str(tmp_path / "c")
    c = _session(run, state)
    handle = c.checkpoint_begin(1)
    handle.wait_speculated()
    state["w0"][:] = 5.0
    handle._tracker.note("state::w0")
    c.checkpoint_finalize()
    assert main(["inspect", run, "--step", "1"]) == 0
    out = capsys.readouterr().out
    assert "capture:     concurrent" in out
    assert "frozen window:" in out
    assert "re-captured:" in out


# ------------------------------------------------------------- chaos plan
def test_dirty_burst_planned_only_on_compatible_jobs():
    from repro.chaos.plan import generate_plan, parse_fault_spec
    from repro.orchestrator.job import JobSpec
    specs = [JobSpec(f"j{i:03d}", kind="sim", total_steps=12,
                     ckpt_every=3, max_restarts=6) for i in range(20)]
    counts = parse_fault_spec("all=2")
    assert counts["dirty_burst"] == 2
    plan = generate_plan(9, specs, 4, counts)
    non_inc = set(plan.targets("torn_write")) | set(
        plan.targets("fsync_drop"))
    assert len(plan.events_for("dirty_burst")) == 2
    for ev in plan.events_for("dirty_burst"):
        assert ev.job_id not in non_inc


# ---------------------------------------------------------------- trainer
@pytest.mark.slow
def test_trainer_loop_with_concurrent_capture(tmp_path, mesh1):
    import jax.numpy as jnp
    from repro.configs import get_smoke_config
    from repro.runtime.trainer import TrainConfig, Trainer
    from repro.sharding import get_policy
    cfg = get_smoke_config("qwen1.5-0.5b")
    tcfg = TrainConfig(batch_size=2, seq_len=16, total_steps=8,
                       warmup_steps=2, ckpt_every=2,
                       compute_dtype=jnp.float32, remat=False,
                       ckpt=_opts(mode="sync"))
    policy = get_policy("baseline")
    tr = Trainer(cfg, tcfg, mesh1, policy, str(tmp_path / "r"))
    out = tr.run(6)
    assert out["steps"] == 6
    assert tr.session.concurrent_capture is None      # all settled
    steps = tr.session.store.list_steps()
    assert steps, "periodic concurrent dumps must have committed"
    m = tr.session.store.manifest(steps[-1])
    assert m.get("capture") == "concurrent"
    # restore-into-fresh-trainer round-trips (bit-exact unified restore)
    tr2 = Trainer(cfg, tcfg, mesh1, policy, str(tmp_path / "r"))
    assert tr2.restore() == steps[-1]
