"""Deterministic, checkpointable data pipeline tests."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs import get_smoke_config
from repro.data import TokenPipeline

CFG = get_smoke_config("qwen1.5-0.5b")


def test_deterministic_given_cursor():
    p1 = TokenPipeline(CFG, 4, 16, seed=1)
    p2 = TokenPipeline(CFG, 4, 16, seed=1)
    for _ in range(5):
        np.testing.assert_array_equal(p1.next()["tokens"],
                                      p2.next()["tokens"])


def test_state_restore_resumes_stream():
    p = TokenPipeline(CFG, 4, 16, seed=2)
    for _ in range(3):
        p.next()
    st_ = p.state()
    expected = [p.next()["tokens"] for _ in range(3)]

    q = TokenPipeline(CFG, 1, 1, seed=0)       # wrong ctor params on purpose
    q.restore_state(st_)
    got = [q.next()["tokens"] for _ in range(3)]
    for e, g in zip(expected, got):
        np.testing.assert_array_equal(e, g)


def test_hosts_get_disjoint_streams():
    a = TokenPipeline(CFG, 4, 16, seed=3, host_id=0, num_hosts=2)
    b = TokenPipeline(CFG, 4, 16, seed=3, host_id=1, num_hosts=2)
    assert not np.array_equal(a.next()["tokens"], b.next()["tokens"])


def test_peek_does_not_advance():
    p = TokenPipeline(CFG, 2, 8, seed=4)
    t1 = p.peek()["tokens"]
    t2 = p.peek()["tokens"]
    np.testing.assert_array_equal(t1, t2)
    t3 = p.next()["tokens"]
    np.testing.assert_array_equal(t1, t3)
    assert p.step == 1


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**16), step=st.integers(0, 100))
def test_tokens_in_vocab_property(seed, step):
    p = TokenPipeline(CFG, 2, 16, seed=seed, step=step)
    toks = p.next()["tokens"]
    assert toks.min() >= 0 and toks.max() < CFG.vocab_size
    assert toks.dtype == np.int32


def test_learnable_structure():
    """The successor-stream structure: most transitions are +1 mod V."""
    p = TokenPipeline(CFG, 8, 64, seed=5)
    t = p.next()["tokens"]
    succ = (t[:, 1:] == (t[:, :-1] + 1) % CFG.vocab_size).mean()
    assert succ > 0.75


def test_multimodal_stub_keys():
    vl = get_smoke_config("qwen2-vl-7b")
    b = TokenPipeline(vl, 2, 32).next()
    assert b["vision_embeds"].shape == (2, vl.num_patches, vl.d_model)
    assert b["loss_mask"].shape == (2, 32)
    au = get_smoke_config("whisper-tiny")
    b = TokenPipeline(au, 2, 32).next()
    assert b["frames"].shape == (2, au.num_audio_frames, au.d_model)
