"""Unified architecture configuration.

One dataclass covers every assigned family: dense/GQA transformers, SWA,
MoE, SSM (Mamba2/SSD), hybrid (Jamba), encoder-decoder (Whisper) and VLM
backbones (Qwen2-VL).  A layer *pattern* (cycled over ``num_layers``)
selects the mixer per layer ("attn" | "swa" | "mamba"), and a MoE period
selects which layers use expert FFNs.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | hybrid | audio | ssm | moe | vlm
    num_layers: int
    d_model: int
    num_heads: int                   # query heads (0 for attention-free)
    num_kv_heads: int
    head_dim: int
    d_ff: int                        # dense-MLP width (0 = no dense MLP)
    vocab_size: int

    # --- attention ---
    layer_pattern: Tuple[str, ...] = ("attn",)   # cycled; "attn"|"swa"|"mamba"
    sliding_window: int = 0          # window size for "swa" layers
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    mrope: bool = False              # multimodal 3-component RoPE (Qwen2-VL)
    qk_norm: bool = False            # Qwen3-style per-head q/k RMSNorm

    # --- MoE ---
    moe_num_experts: int = 0         # 0 = dense everywhere
    moe_top_k: int = 0
    moe_d_ff: int = 0                # expert FFN width
    moe_layer_period: int = 1        # layer i is MoE iff i % period == period-1
    moe_capacity_factor: float = 1.25

    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0               # N (state size per head)
    ssm_headdim: int = 64            # P
    ssm_expand: int = 2              # d_inner = expand * d_model
    ssm_conv_width: int = 4
    ssm_chunk: int = 128             # SSD chunk length

    # --- encoder-decoder (Whisper) ---
    encoder_layers: int = 0          # >0 => enc-dec; num_layers = decoder layers
    num_audio_frames: int = 1500     # post-conv frames the stub frontend emits

    # --- VLM stub ---
    vision_stub: bool = False
    num_patches: int = 1024          # patch embeddings the stub frontend emits

    # --- numerics ---
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    vocab_pad_multiple: int = 256    # pad vocab for TP divisibility + MXU tiles

    # ----------------------------------------------------------------- utils
    def layer_kind(self, i: int) -> str:
        return self.layer_pattern[i % len(self.layer_pattern)]

    def is_moe_layer(self, i: int) -> bool:
        if self.moe_num_experts == 0:
            return False
        p = self.moe_layer_period
        return i % p == p - 1

    @property
    def attention_free(self) -> bool:
        return all(k == "mamba" for k in self.layer_pattern)

    @property
    def sub_quadratic(self) -> bool:
        """True if every mixer layer is sub-quadratic in sequence length."""
        return all(k in ("mamba", "swa") for k in self.layer_pattern)

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up so the TP axis divides it and lm-head matmul
        dims stay 128-aligned (e.g. mamba2 50280 → 50432).  Padded logit
        columns are masked to -inf in the head."""
        m = self.vocab_pad_multiple
        return ((self.vocab_size + m - 1) // m) * m

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_headdim

    # ------------------------------------------------------------- counting
    def layer_kinds(self):
        return [self.layer_kind(i) for i in range(self.num_layers)]

    def param_count(self, active_only: bool = False) -> int:
        """Analytic parameter count (used for MODEL_FLOPS = 6*N*D)."""
        d, v = self.d_model, self.vocab_size
        total = v * d                      # embedding
        if not self.tie_embeddings:
            total += v * d                 # lm head
        # encoder stack (whisper): attn + dense mlp per layer
        for _ in range(self.encoder_layers):
            total += self._attn_params(cross=False) + self._mlp_params(self.d_ff)
            total += 2 * d                 # norms
        for i in range(self.num_layers):
            kind = self.layer_kind(i)
            if kind in ("attn", "swa"):
                total += self._attn_params(cross=False)
            else:
                total += self._mamba_params()
            if self.encoder_layers and kind in ("attn", "swa"):
                total += self._attn_params(cross=True) + d
            if self.is_moe_layer(i):
                n_e = self.moe_top_k if active_only else self.moe_num_experts
                total += n_e * self._mlp_params(self.moe_d_ff)
                total += d * self.moe_num_experts   # router
            elif self.d_ff > 0:
                total += self._mlp_params(self.d_ff)
            total += 2 * d                 # pre-norms
        total += d                         # final norm
        return total

    def _attn_params(self, cross: bool) -> int:
        d, h, kv, hd = self.d_model, self.num_heads, self.num_kv_heads, self.head_dim
        q = d * h * hd
        k = d * kv * hd
        vproj = d * kv * hd
        o = h * hd * d
        bias = (h * hd + 2 * kv * hd) if self.qkv_bias else 0
        return q + k + vproj + o + bias

    def _mlp_params(self, width: int) -> int:
        # SwiGLU: gate + up + down
        return 3 * self.d_model * width

    def _mamba_params(self) -> int:
        d, di, n, p = self.d_model, self.d_inner, self.ssm_state, self.ssm_headdim
        nh = self.ssm_nheads
        in_proj = d * (2 * di + 2 * n + nh)   # x, z, B, C, dt
        conv = self.ssm_conv_width * (di + 2 * n)
        out_proj = di * d
        extra = nh * 2 + di                    # A_log, D, norm
        return in_proj + conv + out_proj + extra

    def flops_per_token(self, seq_len: int, active_only: bool = True) -> float:
        """~6 * N_active per token for training fwd+bwd, plus attention term."""
        n = self.param_count(active_only=active_only)
        flops = 6.0 * n
        # attention score/value FLOPs: 12 * h * hd * window per token (fwd+bwd)
        for i in range(self.num_layers):
            kind = self.layer_kind(i)
            if kind == "attn":
                w = seq_len
            elif kind == "swa":
                w = min(seq_len, self.sliding_window)
            else:
                continue
            flops += 12.0 * self.num_heads * self.head_dim * w / 2.0
        return flops


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A tiny same-family variant for CPU smoke tests."""
    pat = cfg.layer_pattern
    small = dict(
        num_layers=max(2, len(pat)) if len(pat) > 1 else 2,
        d_model=64,
        num_heads=4 if cfg.num_heads else 0,
        num_kv_heads=min(2, cfg.num_kv_heads) if cfg.num_kv_heads else 0,
        head_dim=16 if cfg.num_heads else cfg.head_dim,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=512,
        sliding_window=min(cfg.sliding_window, 16) if cfg.sliding_window else 0,
        moe_num_experts=min(4, cfg.moe_num_experts),
        moe_top_k=min(2, cfg.moe_top_k),
        moe_d_ff=64 if cfg.moe_d_ff else 0,
        moe_capacity_factor=8.0,   # no-drop capacity => decode == forward

        ssm_state=16 if cfg.ssm_state else 0,
        ssm_headdim=16 if cfg.ssm_state else cfg.ssm_headdim,
        ssm_chunk=8 if cfg.ssm_state else cfg.ssm_chunk,
        encoder_layers=2 if cfg.encoder_layers else 0,
        num_audio_frames=32,
        num_patches=16,
        name=cfg.name + "-smoke",
    )
    small.update(overrides)
    return dataclasses.replace(cfg, **small)
