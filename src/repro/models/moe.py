"""Expert-parallel Mixture-of-Experts FFN with explicit collectives.

Layout (production posture):
  * tokens are sharded over the DP axes ("pod","data"); activations are
    replicated over the TP/EP axis ("model");
  * expert weights are sharded over "model" on the expert dim (EP) and over
    "data" on the d_model dim (FSDP/ZeRO-3);
  * each model shard computes its local experts for all local tokens and the
    top-k mixture is completed by a single psum over "model" — the same
    collective volume as a Megatron row-parallel FFN, with no all-to-all.

The block is written with ``jax.shard_map`` so the collective schedule is
explicit and stable for the roofline analysis (GSPMD propagation through the
scatter/gather dispatch is otherwise unpredictable).

Dispatch is sort-free and matmul-free (no O(T·E·C·d) one-hot einsums that
would pollute HLO_FLOPs): an (E_local, C) index table is built by a cumsum
over the top-k assignment one-hot (T·k × E_local ints) and tokens are
gathered/scattered through it.  Tokens over per-expert capacity
C = ceil(T·k/E · capacity_factor) are dropped (standard GShard semantics).
"""
from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import ParamSpec
from repro.sharding.policy import ShardingPolicy

CAPACITY_FACTOR = 1.25


def moe_specs(cfg) -> Dict[str, ParamSpec]:
    E, d, f = cfg.moe_num_experts, cfg.d_model, cfg.moe_d_ff
    return {
        "router": ParamSpec((d, E), (None, None)),   # replicated (tiny)
        "w_gate": ParamSpec((E, d, f), ("experts", "d_model", "moe_ff")),
        "w_up": ParamSpec((E, d, f), ("experts", "d_model", "moe_ff")),
        "w_down": ParamSpec((E, f, d), ("experts", "moe_ff", "d_model")),
    }


def capacity(tokens: int, k: int, num_experts: int,
             factor: float = CAPACITY_FACTOR) -> int:
    # an expert can receive at most `tokens` assignments, so C is capped there
    return min(tokens, max(k, int(np.ceil(tokens * k / num_experts * factor))))


def _local_moe(x, router, w_gate, w_up, w_down, *, cfg, ep_axes, fsdp_axes,
               dp_axes, dropless):
    """Per-shard body.  x (T_loc, d) f32/bf16, expert weights local slices."""
    E, k = cfg.moe_num_experts, cfg.moe_top_k
    T = x.shape[0]

    # FSDP: un-shard the d_model dim of the local expert weights
    for ax in fsdp_axes:
        w_gate = jax.lax.all_gather(w_gate, ax, axis=1, tiled=True)
        w_up = jax.lax.all_gather(w_up, ax, axis=1, tiled=True)
        w_down = jax.lax.all_gather(w_down, ax, axis=2, tiled=True)
    E_loc = w_gate.shape[0]
    first_e = (jax.lax.axis_index(ep_axes[0]) * E_loc) if ep_axes else 0

    # ---- routing (computed redundantly on every model shard) ----
    logits = (x.astype(jnp.float32) @ router.astype(jnp.float32))     # (T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, k)                            # (T,k)
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)            # renorm

    # aux load-balance loss (Switch): E * sum_e f_e * P_e
    assign = jax.nn.one_hot(top_e[:, 0], E, dtype=jnp.float32)
    f_e = jnp.mean(assign, axis=0)
    P_e = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(f_e * P_e)

    # ---- dispatch table ----
    C = T if dropless else capacity(T, k, E, cfg.moe_capacity_factor)
    flat_e = top_e.reshape(-1)                                        # (T*k,)
    flat_t = jnp.repeat(jnp.arange(T), k)
    flat_w = top_w.reshape(-1)
    local_e = flat_e - first_e
    is_local = (local_e >= 0) & (local_e < E_loc)
    onehot = (local_e[:, None] == jnp.arange(E_loc)[None, :]) & is_local[:, None]
    slot_per_e = jnp.cumsum(onehot.astype(jnp.int32), axis=0) - 1     # (T*k,E_loc)
    slot = jnp.sum(jnp.where(onehot, slot_per_e, 0), axis=1)          # (T*k,)
    keep = is_local & (slot < C)
    le_c = jnp.where(keep, local_e, 0)
    slot_c = jnp.where(keep, slot, C)          # overflow slot C = garbage

    table = jnp.zeros((E_loc, C + 1), jnp.int32).at[le_c, slot_c].set(flat_t)
    wtab = jnp.zeros((E_loc, C + 1), jnp.float32).at[le_c, slot_c].set(flat_w)
    vtab = jnp.zeros((E_loc, C + 1), jnp.bool_).at[le_c, slot_c].set(keep)
    table, wtab, vtab = table[:, :C], wtab[:, :C], vtab[:, :C]

    # ---- expert compute ----
    dt = x.dtype
    xin = x[table.reshape(-1)].reshape(E_loc, C, -1)                  # (E,C,d)
    xin = jnp.where(vtab[..., None], xin, 0).astype(dt)
    g = jnp.einsum("ecd,edf->ecf", xin, w_gate.astype(dt))
    u = jnp.einsum("ecd,edf->ecf", xin, w_up.astype(dt))
    h = jax.nn.silu(g) * u
    out = jnp.einsum("ecf,efd->ecd", h, w_down.astype(dt))
    out = out * (wtab * vtab)[..., None].astype(dt)

    # ---- combine: scatter-add back (f32), then sum expert shards ----
    y = jnp.zeros((T, x.shape[-1]), jnp.float32).at[table.reshape(-1)].add(
        out.reshape(-1, x.shape[-1]).astype(jnp.float32))
    if ep_axes:
        y = jax.lax.psum(y, ep_axes)
    y = y.astype(x.dtype)
    if dp_axes:
        aux = jax.lax.pmean(aux, dp_axes)
    return y, aux


def moe_block(params, cfg, x: jax.Array, policy: ShardingPolicy,
              mesh, dropless: bool = False) -> Tuple[jax.Array, jax.Array]:
    """x (B, S, d) -> (y (B, S, d), aux_loss scalar)."""
    from jax.sharding import PartitionSpec as P

    B, S, d = x.shape
    xt = x.reshape(B * S, d)
    if mesh is None:
        # single-device fallback (smoke tests): same math, no collectives
        y, aux = _local_moe(xt, params["router"], params["w_gate"],
                            params["w_up"], params["w_down"], cfg=cfg,
                            ep_axes=(), fsdp_axes=(), dp_axes=(),
                            dropless=dropless)
        return y.reshape(B, S, d), aux
    dp = tuple(a for a in policy.dp if a in mesh.axis_names)
    ep = tuple(a for a in policy.ep if a in mesh.axis_names)
    fsdp = tuple(a for a in policy.fsdp if a in mesh.axis_names
                 and a not in ep        # expert dim owns its axes
                 and policy.zero_stage >= 3)
    if len(ep) != 1:
        raise ValueError(f"MoE block requires single-axis EP, got {ep}")
    dp_size = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
    dp_sharded = (B * S) % max(dp_size, 1) == 0 and dp_size > 1
    tok_spec = P(dp if dp_sharded else None, None)

    body = functools.partial(
        _local_moe, cfg=cfg, ep_axes=ep, fsdp_axes=fsdp,
        dp_axes=dp if dp_sharded else (), dropless=dropless)
    y, aux = _shard_map(
        body, mesh=mesh,
        in_specs=(tok_spec, P(None, None),
                  P(ep[0], fsdp if fsdp else None, None),
                  P(ep[0], fsdp if fsdp else None, None),
                  P(ep[0], None, fsdp if fsdp else None)),
        out_specs=(tok_spec, P()),
    )(xt, params["router"], params["w_gate"], params["w_up"],
      params["w_down"])
    return y.reshape(B, S, d), aux


def _shard_map(body, *, mesh, in_specs, out_specs):
    """``jax.shard_map`` with replication checking off, on any JAX: the
    top-level API (check_vma) where present, the experimental one
    (check_rep) otherwise."""
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=False)
    from jax.experimental.shard_map import shard_map as esm
    return esm(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)
