"""Encoder-decoder model (Whisper-family backbone).

The audio conv frontend is a STUB per the assignment: the batch provides
post-conv *frame embeddings* (B, F, d_model).  The encoder is non-causal
self-attention; the decoder is a causal LM with cross-attention into the
encoder output.  Adaptations vs. the original Whisper (recorded in
DESIGN.md): RoPE instead of learned absolute positions, SwiGLU MLPs shared
with the rest of the zoo.

Batch keys: frames (B, F, d) f32/bf16, tokens (B, S) int32,
            loss_mask optional.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.sharding.policy import ShardingPolicy, constrain

PyTree = Any


def _enc_layer_specs(cfg) -> Dict[str, Any]:
    return {
        "pre_attn_norm": L.rmsnorm_spec(cfg.d_model),
        "pre_mlp_norm": L.rmsnorm_spec(cfg.d_model),
        "attn": L.attention_specs(cfg),
        "mlp": L.mlp_specs(cfg.d_model, cfg.d_ff),
    }


def _dec_layer_specs(cfg) -> Dict[str, Any]:
    return {
        "pre_self_norm": L.rmsnorm_spec(cfg.d_model),
        "pre_cross_norm": L.rmsnorm_spec(cfg.d_model),
        "pre_mlp_norm": L.rmsnorm_spec(cfg.d_model),
        "self_attn": L.attention_specs(cfg),
        "cross_attn": L.attention_specs(cfg),
        "mlp": L.mlp_specs(cfg.d_model, cfg.d_ff),
    }


def encdec_param_specs(cfg: ModelConfig) -> Dict[str, Any]:
    specs: Dict[str, Any] = {
        "embed": {"tok": L.ParamSpec((cfg.padded_vocab, cfg.d_model),
                                     ("vocab", "d_model"), scale=0.02)},
        "enc_blocks": L.stack_specs(_enc_layer_specs(cfg), cfg.encoder_layers),
        "dec_blocks": L.stack_specs(_dec_layer_specs(cfg), cfg.num_layers),
        "enc_final_norm": L.rmsnorm_spec(cfg.d_model),
        "final_norm": L.rmsnorm_spec(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = L.ParamSpec((cfg.d_model, cfg.padded_vocab),
                                       ("d_model", "vocab"))
    return specs


class EncDecLM:
    """Same external interface as ``repro.models.lm.LM``."""

    def __init__(self, cfg: ModelConfig, policy: ShardingPolicy, mesh,
                 compute_dtype=jnp.bfloat16, param_dtype=jnp.float32,
                 remat: bool = True, use_kernels: bool = False):
        assert cfg.encoder_layers > 0
        self.cfg = cfg
        self.policy = policy.for_mesh(mesh) if mesh is not None else policy
        self.mesh = mesh
        self.compute_dtype = compute_dtype
        self.param_dtype = param_dtype
        self.remat = remat
        self._specs = encdec_param_specs(cfg)

    # ---------------- params ----------------
    def init(self, key) -> PyTree:
        return L.init_params(self._specs, key, self.param_dtype)

    def init_abstract(self) -> PyTree:
        return L.abstract_params(self._specs, self.param_dtype)

    def param_axes(self) -> PyTree:
        return L.axes_tree(self._specs)

    def param_shardings(self):
        ax = self.param_axes()
        return jax.tree.map(
            lambda a: self.policy.sharding(self.mesh, *a), ax,
            is_leaf=lambda x: isinstance(x, tuple))

    # ---------------- encoder ----------------
    def encode(self, params, frames) -> jax.Array:
        cfg = self.cfg
        x = frames.astype(self.compute_dtype)
        x = constrain(x, self.policy, "batch", "frames", "act_d")
        B, F, _ = x.shape
        pos = jnp.broadcast_to(jnp.arange(F, dtype=jnp.int32), (B, F))

        def block(x, lp):
            h = L.rmsnorm(lp["pre_attn_norm"], x, cfg.norm_eps)
            q, k, v = L._qkv(lp["attn"], cfg, h, pos, self.policy)
            o = L.self_attention(q, k, v, causal=False)
            o = o.reshape(B, F, cfg.num_heads * cfg.head_dim)
            x = x + o @ lp["attn"]["wo"].astype(x.dtype)
            h = L.rmsnorm(lp["pre_mlp_norm"], x, cfg.norm_eps)
            x = x + L.mlp(lp["mlp"], h, self.policy)
            return x, None

        body = jax.checkpoint(block, prevent_cse=False) if self.remat else block
        x, _ = jax.lax.scan(body, x, params["enc_blocks"])
        return L.rmsnorm(params["enc_final_norm"], x, cfg.norm_eps)

    # ---------------- decoder ----------------
    def _dec_block(self, lp, x, enc_kv, pos, causal=True):
        """x (B,S,d); enc_kv = (k, v) (B,F,KV,hd)."""
        cfg = self.cfg
        B, S, _ = x.shape
        h = L.rmsnorm(lp["pre_self_norm"], x, cfg.norm_eps)
        q, k, v = L._qkv(lp["self_attn"], cfg, h, pos, self.policy)
        o = L.self_attention(q, k, v, causal=causal)
        x = x + o.reshape(B, S, -1) @ lp["self_attn"]["wo"].astype(x.dtype)

        h = L.rmsnorm(lp["pre_cross_norm"], x, cfg.norm_eps)
        q = (h @ lp["cross_attn"]["wq"].astype(x.dtype)
             ).reshape(B, S, cfg.num_heads, cfg.head_dim)
        ek, ev = enc_kv
        o = L.cross_attention(q, ek, ev)
        x = x + o.reshape(B, S, -1) @ lp["cross_attn"]["wo"].astype(x.dtype)

        h = L.rmsnorm(lp["pre_mlp_norm"], x, cfg.norm_eps)
        return x + L.mlp(lp["mlp"], h, self.policy), (k, v)

    def _cross_kv(self, lp, enc_out):
        B, F, _ = enc_out.shape
        cfg = self.cfg
        dt = enc_out.dtype
        ek = (enc_out @ lp["cross_attn"]["wk"].astype(dt)
              ).reshape(B, F, cfg.num_kv_heads, cfg.head_dim)
        ev = (enc_out @ lp["cross_attn"]["wv"].astype(dt)
              ).reshape(B, F, cfg.num_kv_heads, cfg.head_dim)
        return ek, ev

    def forward(self, params, batch) -> jax.Array:
        cfg = self.cfg
        enc_out = self.encode(params, batch["frames"])
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = jnp.take(params["embed"]["tok"].astype(self.compute_dtype),
                     tokens, axis=0)
        x = constrain(x, self.policy, "batch", "seq", "act_d")
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

        def block(x, lp):
            x, _ = self._dec_block(lp, x, self._cross_kv(lp, enc_out), pos)
            return x, None

        body = jax.checkpoint(block, prevent_cse=False) if self.remat else block
        x, _ = jax.lax.scan(body, x, params["dec_blocks"])
        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        return self._head(params, x)

    def _head(self, params, x):
        if self.cfg.tie_embeddings:
            w = params["embed"]["tok"].astype(x.dtype).T
        else:
            w = params["lm_head"].astype(x.dtype)
        logits = L.mask_padded_vocab(x @ w, self.cfg)
        return constrain(logits, self.policy, "batch", "logit_seq", "vocab")

    def loss(self, params, batch) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        logits = self.forward(params, batch)
        tokens = batch["tokens"]
        targets = jnp.roll(tokens, -1, axis=1)
        mask = batch.get("loss_mask")
        if mask is None:
            mask = jnp.ones(tokens.shape, jnp.float32)
        mask = mask.astype(jnp.float32).at[:, -1].set(0.0)
        loss, ntok = L.softmax_xent_sharded(logits, targets, mask)
        return loss, {"loss": loss, "aux_loss": jnp.zeros((), jnp.float32),
                      "ntokens": ntok}

    # ---------------- serving ----------------
    def _cache_struct(self, batch: int, max_seq: int, abstract: bool):
        cfg = self.cfg
        Ld = cfg.num_layers
        KV, hd, F = cfg.num_kv_heads, cfg.head_dim, cfg.num_audio_frames
        mk = (lambda s: jax.ShapeDtypeStruct(s, self.compute_dtype)) \
            if abstract else (lambda s: jnp.zeros(s, self.compute_dtype))
        return {
            "self_k": mk((Ld, batch, max_seq, KV, hd)),
            "self_v": mk((Ld, batch, max_seq, KV, hd)),
            "cross_k": mk((Ld, batch, F, KV, hd)),
            "cross_v": mk((Ld, batch, F, KV, hd)),
        }

    def init_cache(self, batch: int, max_seq: int):
        return self._cache_struct(batch, max_seq, abstract=False)

    def cache_abstract(self, batch: int, max_seq: int):
        return self._cache_struct(batch, max_seq, abstract=True)

    def cache_axes(self) -> PyTree:
        ax = ("layers", "batch", "cache_seq", "kv_heads", None)
        fx = ("layers", "batch", "frames", "kv_heads", None)
        return {"self_k": ax, "self_v": ax, "cross_k": fx, "cross_v": fx}

    def cache_shardings(self, batch=None, max_seq=None):
        from repro.models.lm import _cache_policy
        from repro.sharding.policy import fit_shardings_tree
        policy = _cache_policy(self.policy, self.mesh, batch)
        sh = jax.tree.map(
            lambda a: policy.sharding(self.mesh, *a), self.cache_axes(),
            is_leaf=lambda x: isinstance(x, tuple))
        if batch is not None and max_seq is not None:
            sh = fit_shardings_tree(sh, self.cache_abstract(batch, max_seq),
                                    self.mesh)
        return sh

    def prefill(self, params, batch) -> Tuple[jax.Array, PyTree]:
        """Encode frames + run the decoder prompt, returning last-token
        logits and a populated cache (self cache length == prompt length)."""
        cfg = self.cfg
        enc_out = self.encode(params, batch["frames"])
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = jnp.take(params["embed"]["tok"].astype(self.compute_dtype),
                     tokens, axis=0)
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

        def block(x, lp):
            ck, cv = self._cross_kv(lp, enc_out)
            x, (sk, sv) = self._dec_block(lp, x, (ck, cv), pos)
            return x, {"self_k": sk, "self_v": sv,
                       "cross_k": ck, "cross_v": cv}

        x, cache = jax.lax.scan(block, x, params["dec_blocks"])
        x = L.rmsnorm(params["final_norm"], x[:, -1:, :], cfg.norm_eps)
        return self._head(params, x)[:, 0, :], cache

    def decode_step(self, params, cache, tokens, pos
                    ) -> Tuple[jax.Array, PyTree]:
        cfg = self.cfg
        B = tokens.shape[0]
        H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
        S_c = cache["self_k"].shape[2]
        x = jnp.take(params["embed"]["tok"].astype(self.compute_dtype),
                     tokens, axis=0)
        posv = jnp.full((B, 1), pos, jnp.int32)

        def block(x, xs):
            lp, lc = xs
            h = L.rmsnorm(lp["pre_self_norm"], x, cfg.norm_eps)
            q, k_new, v_new = L._qkv(lp["self_attn"], cfg, h[:, None, :],
                                     posv, self.policy)
            k = jax.lax.dynamic_update_slice_in_dim(lc["self_k"], k_new, pos, 1)
            v = jax.lax.dynamic_update_slice_in_dim(lc["self_v"], v_new, pos, 1)
            qg = q.reshape(B, 1, KV, H // KV, hd)
            sc = jnp.einsum("bqgrd,bkgd->bgrqk", qg, k) / np.sqrt(hd)
            sc = jnp.where((jnp.arange(S_c) <= pos)[None, None, None, None, :],
                           sc.astype(jnp.float32), -1e30)
            pr = jax.nn.softmax(sc, axis=-1).astype(x.dtype)
            o = jnp.einsum("bgrqk,bkgd->bqgrd", pr, v).reshape(B, H * hd)
            x = x + o @ lp["self_attn"]["wo"].astype(x.dtype)

            h = L.rmsnorm(lp["pre_cross_norm"], x, cfg.norm_eps)
            q = (h @ lp["cross_attn"]["wq"].astype(x.dtype)
                 ).reshape(B, 1, H, hd)
            o = L.cross_attention(q, lc["cross_k"], lc["cross_v"])
            x = x + o.reshape(B, H * hd) @ lp["cross_attn"]["wo"].astype(x.dtype)

            h = L.rmsnorm(lp["pre_mlp_norm"], x, cfg.norm_eps)
            x = x + L.mlp(lp["mlp"], h, self.policy)
            return x, {"self_k": k, "self_v": v,
                       "cross_k": lc["cross_k"], "cross_v": lc["cross_v"]}

        x, new_cache = jax.lax.scan(block, x, (params["dec_blocks"], cache))
        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        if self.cfg.tie_embeddings:
            w = params["embed"]["tok"].astype(x.dtype).T
        else:
            w = params["lm_head"].astype(x.dtype)
        return L.mask_padded_vocab(x @ w, self.cfg), new_cache


def build_model(cfg: ModelConfig, policy: ShardingPolicy, mesh, **kw):
    """Factory: pick LM or EncDecLM from the config."""
    from repro.models.lm import LM
    if cfg.encoder_layers > 0:
        return EncDecLM(cfg, policy, mesh, **kw)
    return LM(cfg, policy, mesh, **kw)
