from repro.models.config import ModelConfig, reduced  # noqa: F401
