"""Unified language model covering the dense / MoE / SSM / hybrid / VLM
architectures in the zoo.

Layers are stacked per *pattern position* and iterated with
``jax.lax.scan`` over super-blocks (one super-block = one cycle of
``cfg.layer_pattern``), with full activation rematerialisation per block —
this keeps the HLO compact enough to compile 94-layer models on a
512-device mesh and is the standard memory/recompute trade at scale.

Batch dict keys (all optional except "tokens"):
  tokens         (B, S) int32
  loss_mask      (B, S) f32/bool — 1 where the next-token loss applies
  positions      (B, S) or (3, B, S) int32 (M-RoPE)
  vision_embeds  (B, P, d) — VLM stub frontend output, overrides the first
                 P token embeddings
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L
from repro.models import mamba as M
from repro.models import moe as MOE
from repro.models.config import ModelConfig
from repro.sharding.policy import ShardingPolicy, constrain

PyTree = Any


# ======================================================================
# per-layer specs
# ======================================================================
def _layer_specs(cfg: ModelConfig, pos: int) -> Dict[str, Any]:
    kind = cfg.layer_pattern[pos % len(cfg.layer_pattern)]
    s: Dict[str, Any] = {
        "pre_mixer_norm": L.rmsnorm_spec(cfg.d_model),
        "pre_mlp_norm": L.rmsnorm_spec(cfg.d_model),
    }
    if kind in ("attn", "swa"):
        s["attn"] = L.attention_specs(cfg)
    else:
        s["mamba"] = M.mamba_specs(cfg)
    if cfg.is_moe_layer(pos):
        s["moe"] = MOE.moe_specs(cfg)
    elif cfg.d_ff > 0:
        s["mlp"] = L.mlp_specs(cfg.d_model, cfg.d_ff)
    return s


def lm_param_specs(cfg: ModelConfig) -> Dict[str, Any]:
    P = len(cfg.layer_pattern)
    assert cfg.num_layers % P == 0, (cfg.num_layers, P)
    n_sb = cfg.num_layers // P
    blocks = {f"pos{j}": L.stack_specs(_layer_specs(cfg, j), n_sb)
              for j in range(P)}
    specs: Dict[str, Any] = {
        "embed": {"tok": L.ParamSpec((cfg.padded_vocab, cfg.d_model),
                                     ("vocab", "d_model"), scale=0.02)},
        "blocks": blocks,
        "final_norm": L.rmsnorm_spec(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = L.ParamSpec((cfg.d_model, cfg.padded_vocab),
                                       ("d_model", "vocab"))
    return specs


# ======================================================================
# blocks
# ======================================================================
def _mixer(lp, cfg, kind, x, positions, policy, use_kernels=False):
    if kind in ("attn", "swa"):
        q, k, v = L._qkv(lp["attn"], cfg, x, positions, policy)
        k, v = L.maybe_expand_gqa(q, k, v, policy)
        window = cfg.sliding_window if kind == "swa" else 0
        if use_kernels:
            from repro.kernels import ops
            o = ops.attention(q, k, v, causal=True, window=window)
        else:
            o = L.self_attention(q, k, v, causal=True, window=window)
        B, S = x.shape[:2]
        o = o.reshape(B, S, cfg.num_heads * cfg.head_dim)
        return o @ lp["attn"]["wo"].astype(x.dtype)
    return M.mamba_block(lp["mamba"], cfg, x, policy, use_kernels=use_kernels)


def _ffn(lp, cfg, pos, x, policy, mesh):
    if "moe" in lp:
        return MOE.moe_block(lp["moe"], cfg, x, policy, mesh)
    if "mlp" in lp:
        return L.mlp(lp["mlp"], x, policy), jnp.zeros((), jnp.float32)
    return None, jnp.zeros((), jnp.float32)   # pure-SSM archs: no FFN


def _block(lp, cfg, pos, x, positions, policy, mesh, use_kernels=False):
    kind = cfg.layer_kind(pos)
    h = L.rmsnorm(lp["pre_mixer_norm"], x, cfg.norm_eps)
    x = x + _mixer(lp, cfg, kind, h, positions, policy, use_kernels)
    x = constrain(x, policy, "batch", "seq", "act_d")
    f, aux = _ffn(lp, cfg, pos,
                  L.rmsnorm(lp["pre_mlp_norm"], x, cfg.norm_eps),
                  policy, mesh)
    if f is not None:
        x = x + f
        x = constrain(x, policy, "batch", "seq", "act_d")
    return x, aux


# ======================================================================
# model
# ======================================================================
class LM:
    def __init__(self, cfg: ModelConfig, policy: ShardingPolicy, mesh,
                 compute_dtype=jnp.bfloat16, param_dtype=jnp.float32,
                 remat: bool = True, use_kernels: bool = False):
        self.cfg = cfg
        self.policy = policy.for_mesh(mesh) if mesh is not None else policy
        self.mesh = mesh
        self.compute_dtype = compute_dtype
        self.param_dtype = param_dtype
        self.remat = remat
        self.use_kernels = use_kernels
        self._specs = lm_param_specs(cfg)

    # ---------------- params ----------------
    def init(self, key) -> PyTree:
        return L.init_params(self._specs, key, self.param_dtype)

    def init_abstract(self) -> PyTree:
        return L.abstract_params(self._specs, self.param_dtype)

    def param_axes(self) -> PyTree:
        return L.axes_tree(self._specs)

    def param_shardings(self):
        ax = self.param_axes()
        return jax.tree.map(
            lambda a: self.policy.sharding(self.mesh, *a), ax,
            is_leaf=lambda x: isinstance(x, tuple))

    # ---------------- embedding / head ----------------
    def _embed(self, params, batch):
        tokens = batch["tokens"]
        emb = jnp.take(params["embed"]["tok"].astype(self.compute_dtype),
                       tokens, axis=0)
        ve = batch.get("vision_embeds")
        if ve is not None:
            emb = jax.lax.dynamic_update_slice_in_dim(
                emb, ve.astype(self.compute_dtype), 0, axis=1)
        return constrain(emb, self.policy, "batch", "seq", "act_d")

    def _head(self, params, x):
        if self.cfg.tie_embeddings:
            w = params["embed"]["tok"].astype(x.dtype).T
        else:
            w = params["lm_head"].astype(x.dtype)
        logits = x @ w
        logits = L.mask_padded_vocab(logits, self.cfg)
        return constrain(logits, self.policy, "batch", "logit_seq", "vocab")

    def _positions(self, batch):
        tokens = batch["tokens"]
        pos = batch.get("positions")
        if pos is not None:
            return pos
        B, S = tokens.shape
        base = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        if self.cfg.mrope:
            return jnp.broadcast_to(base, (3, B, S))
        return base

    # ---------------- forward (train / prefill) ----------------
    def forward(self, params, batch) -> jax.Array:
        cfg = self.cfg
        params = L.maybe_cast_params(params, self.compute_dtype)
        x = self._embed(params, batch)
        positions = self._positions(batch)
        P = len(cfg.layer_pattern)

        def superblock(carry, block_params):
            x, aux = carry
            for j in range(P):
                x, a = _block(block_params[f"pos{j}"], cfg, j, x, positions,
                              self.policy, self.mesh, self.use_kernels)
                aux = aux + a
            return (x, aux), None

        body = superblock
        if self.remat:
            body = jax.checkpoint(superblock, prevent_cse=False)
        (x, aux), _ = jax.lax.scan(body,
                                   (x, jnp.zeros((), jnp.float32)),
                                   params["blocks"])
        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = self._head(params, x)
        self._last_aux = aux   # stashed for loss (retrieved within same trace)
        return logits

    def loss(self, params, batch) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        logits = self.forward(params, batch)
        aux = self._last_aux
        tokens = batch["tokens"]
        # full-length next-token loss: targets = roll(tokens), final
        # position masked — keeps S (and its sharding/chunking) intact
        # instead of slicing to S-1.
        targets = jnp.roll(tokens, -1, axis=1)
        mask = batch.get("loss_mask")
        if mask is None:
            mask = jnp.ones(tokens.shape, jnp.float32)
        mask = mask.astype(jnp.float32).at[:, -1].set(0.0)
        loss, ntok = L.softmax_xent_sharded(logits, targets, mask)
        total = loss + 0.01 * aux
        return total, {"loss": loss, "aux_loss": aux, "ntokens": ntok}

    # ---------------- KV / SSM cache ----------------
    def _layer_cache_struct(self, pos: int, batch: int, max_seq: int,
                            abstract: bool):
        cfg = self.cfg
        kind = cfg.layer_kind(pos)
        mk = (lambda s, d: jax.ShapeDtypeStruct(s, d)) if abstract else \
             (lambda s, d: jnp.zeros(s, d))
        if kind in ("attn", "swa"):
            S = min(max_seq, cfg.sliding_window) if kind == "swa" else max_seq
            shp = (batch, S, cfg.num_kv_heads, cfg.head_dim)
            return {"k": mk(shp, self.compute_dtype),
                    "v": mk(shp, self.compute_dtype)}
        if abstract:
            return M.mamba_cache_abstract(cfg, batch, self.compute_dtype)
        return M.mamba_cache_init(cfg, batch, self.compute_dtype)

    def _cache(self, batch: int, max_seq: int, abstract: bool):
        cfg = self.cfg
        P = len(cfg.layer_pattern)
        n_sb = cfg.num_layers // P
        out = {}
        for j in range(P):
            leaf = self._layer_cache_struct(j, batch, max_seq, abstract)
            if abstract:
                out[f"pos{j}"] = jax.tree.map(
                    lambda s: jax.ShapeDtypeStruct((n_sb,) + s.shape, s.dtype),
                    leaf)
            else:
                out[f"pos{j}"] = jax.tree.map(
                    lambda a: jnp.broadcast_to(a, (n_sb,) + a.shape).copy(),
                    leaf)
        return out

    def init_cache(self, batch: int, max_seq: int):
        return self._cache(batch, max_seq, abstract=False)

    def cache_abstract(self, batch: int, max_seq: int):
        return self._cache(batch, max_seq, abstract=True)

    def cache_axes(self) -> PyTree:
        cfg = self.cfg
        out = {}
        for j in range(len(cfg.layer_pattern)):
            kind = cfg.layer_kind(j)
            if kind in ("attn", "swa"):
                ax = {"k": ("layers", "batch", "cache_seq", "kv_heads", None),
                      "v": ("layers", "batch", "cache_seq", "kv_heads", None)}
            else:
                ax = {k: ("layers",) + v
                      for k, v in M.MAMBA_CACHE_AXES.items()}
            out[f"pos{j}"] = ax
        return out

    def cache_shardings(self, batch: Optional[int] = None,
                        max_seq: Optional[int] = None):
        """Decode-cache shardings.  Batch-aware: when the global batch does
        not divide the DP extent (e.g. long_500k, batch=1) the cache cannot
        shard its batch dim — shard the cache *sequence* dim over the DP
        axes instead (the long-context decode posture).  When ``max_seq``
        is also given, every spec is divisibility-fitted to the concrete
        cache shapes (e.g. 8 kv-heads on a 16-way TP axis replicate)."""
        from repro.sharding.policy import fit_shardings_tree
        ax = self.cache_axes()
        policy = _cache_policy(self.policy, self.mesh, batch)
        sh = jax.tree.map(
            lambda a: policy.sharding(self.mesh, *a), ax,
            is_leaf=lambda x: isinstance(x, tuple))
        if batch is not None and max_seq is not None:
            sh = fit_shardings_tree(sh, self.cache_abstract(batch, max_seq),
                                    self.mesh)
        return sh

    # ---------------- decode ----------------
    def _decode_attn(self, lp, kind, x, cache, pos):
        """x (B, d); cache {"k","v"} (B, S_c, KV, hd); pos scalar."""
        cfg = self.cfg
        B = x.shape[0]
        H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
        S_c = cache["k"].shape[1]
        window = cfg.sliding_window if kind == "swa" else 0

        posv = jnp.full((B, 1), pos, jnp.int32)
        if cfg.mrope:
            posv = jnp.broadcast_to(posv, (3, B, 1))
        q, k_new, v_new = L._qkv(lp["attn"], cfg, x[:, None, :], posv,
                                 self.policy)
        slot = jnp.mod(pos, S_c) if window else pos
        k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new, slot, 1)
        v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new, slot, 1)

        qg = q.reshape(B, 1, KV, H // KV, hd)
        scores = jnp.einsum("bqgrd,bkgd->bgrqk", qg, k) / np.sqrt(hd)
        scores = scores.astype(jnp.float32)
        idx = jnp.arange(S_c)
        if window:
            valid = idx < jnp.minimum(pos + 1, S_c)       # ring buffer
        else:
            valid = idx <= pos
        scores = jnp.where(valid[None, None, None, None, :], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
        o = jnp.einsum("bgrqk,bkgd->bqgrd", probs, v).reshape(B, H * hd)
        out = o @ lp["attn"]["wo"].astype(x.dtype)
        return out, {"k": k, "v": v}

    def decode_step(self, params, cache, tokens, pos
                    ) -> Tuple[jax.Array, PyTree]:
        """One serving step: tokens (B,) int32, pos scalar int32."""
        cfg = self.cfg
        P = len(cfg.layer_pattern)
        x = jnp.take(params["embed"]["tok"].astype(self.compute_dtype),
                     tokens, axis=0)                       # (B, d)
        x = constrain(x, self.policy, "batch", "act_d")

        def superblock(x, xs):
            block_params, block_cache = xs
            new_cache = {}
            for j in range(P):
                lp = block_params[f"pos{j}"]
                lc = block_cache[f"pos{j}"]
                kind = cfg.layer_kind(j)
                h = L.rmsnorm(lp["pre_mixer_norm"], x, cfg.norm_eps)
                if kind in ("attn", "swa"):
                    o, nc = self._decode_attn(lp, kind, h, lc, pos)
                else:
                    o, nc = M.mamba_decode(lp["mamba"], cfg, h, lc,
                                           self.policy)
                x = x + o
                h2 = L.rmsnorm(lp["pre_mlp_norm"], x, cfg.norm_eps)
                if "moe" in lp:
                    f, _ = MOE.moe_block(lp["moe"], cfg, h2[:, None, :],
                                         self.policy, self.mesh,
                                         dropless=True)
                    x = x + f[:, 0, :]
                elif "mlp" in lp:
                    x = x + L.mlp(lp["mlp"], h2, self.policy)
                new_cache[f"pos{j}"] = nc
            return x, new_cache

        x, new_cache = jax.lax.scan(superblock, x,
                                    (params["blocks"], cache))
        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = self._head(params, x)
        return logits, new_cache

    # ---------------- prefill (build cache + logits) ----------------
    def prefill(self, params, batch) -> Tuple[jax.Array, PyTree]:
        """Forward over a prompt, returning last-position logits and the
        populated KV/SSM cache (cache length == prompt length)."""
        cfg = self.cfg
        x = self._embed(params, batch)
        positions = self._positions(batch)
        P = len(cfg.layer_pattern)

        def superblock(carry, block_params):
            x = carry
            new_cache = {}
            for j in range(P):
                lp = block_params[f"pos{j}"]
                kind = cfg.layer_kind(j)
                h = L.rmsnorm(lp["pre_mixer_norm"], x, cfg.norm_eps)
                if kind in ("attn", "swa"):
                    q, k, v = L._qkv(lp["attn"], cfg, h, positions,
                                     self.policy)
                    window = cfg.sliding_window if kind == "swa" else 0
                    o = L.self_attention(q, k, v, causal=True, window=window)
                    B, S = x.shape[:2]
                    o = o.reshape(B, S, cfg.num_heads * cfg.head_dim)
                    o = o @ lp["attn"]["wo"].astype(x.dtype)
                    if window and window < k.shape[1]:
                        # ring-buffer alignment: abs position p lives at
                        # slot p % window
                        s = k.shape[1] % window
                        nc = {"k": jnp.roll(k[:, -window:], s, axis=1),
                              "v": jnp.roll(v[:, -window:], s, axis=1)}
                    else:
                        nc = {"k": k, "v": v}
                else:
                    o, hfin, tails = _mamba_prefill(lp["mamba"], cfg, h,
                                                    self.policy)
                    nc = {"h": hfin, **tails}
                x = x + o
                h2 = L.rmsnorm(lp["pre_mlp_norm"], x, cfg.norm_eps)
                f, _ = _ffn(lp, cfg, j, h2, self.policy, self.mesh)
                if f is not None:
                    x = x + f
                new_cache[f"pos{j}"] = nc
            return x, new_cache

        body = superblock
        if self.remat:
            body = jax.checkpoint(superblock, prevent_cse=False)
        x, cache = jax.lax.scan(body, x, params["blocks"])
        x = L.rmsnorm(params["final_norm"], x[:, -1:, :], cfg.norm_eps)
        logits = self._head(params, x)[:, 0, :]
        return logits, cache


def _cache_policy(policy: ShardingPolicy, mesh, batch: Optional[int]
                  ) -> ShardingPolicy:
    """Pick batch- vs. sequence-sharding for the decode cache."""
    import dataclasses as _dc
    if batch is None or mesh is None:
        return policy
    dp = tuple(a for a in policy.dp if a in mesh.axis_names)
    dp_size = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
    if dp_size > 1 and batch % dp_size == 0:
        # batch shards cleanly: keep it, drop seq sharding (axis conflict)
        return _dc.replace(policy, shard_seq_decode=False)
    # batch unshardable: give the DP axes to the cache sequence dim
    return _dc.replace(policy, dp=(), seq=dp, shard_seq_decode=True)


def _mamba_prefill(params, cfg, x, policy):
    """Mamba forward that also returns the final SSM state (for prefill)."""
    B, S, _ = x.shape
    di, N, nh, Pdim = cfg.d_inner, cfg.ssm_state, cfg.ssm_nheads, cfg.ssm_headdim
    dt_ = x.dtype
    xz = x @ params["w_z"].astype(dt_)
    xi = x @ params["w_x"].astype(dt_)
    Bm = x @ params["w_B"].astype(dt_)
    Cm = x @ params["w_C"].astype(dt_)
    dt = x @ params["w_dt"].astype(dt_)
    w = cfg.ssm_conv_width
    tails = {"conv_x": xi[:, S - (w - 1):, :],
             "conv_B": Bm[:, S - (w - 1):, :],
             "conv_C": Cm[:, S - (w - 1):, :]}
    xi = jax.nn.silu(M.causal_conv(xi, params["conv_x"]))
    Bm = jax.nn.silu(M.causal_conv(Bm, params["conv_B"]))
    Cm = jax.nn.silu(M.causal_conv(Cm, params["conv_C"]))
    dt = jax.nn.softplus(dt.astype(jnp.float32) +
                         params["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    xh = xi.reshape(B, S, nh, Pdim)
    y, h_final = M.ssd_chunked(xh, dt, A, Bm, Cm, chunk=cfg.ssm_chunk)
    y = (y + params["D"].astype(jnp.float32)[None, None, :, None] * xh
         ).astype(dt_)
    y = y.reshape(B, S, di)
    y = L.rmsnorm({"scale": params["norm"]}, y * jax.nn.silu(xz),
                  cfg.norm_eps)
    return y @ params["w_out"].astype(dt_), h_final, tails
