"""Core layers shared by every architecture in the zoo.

Design rules:
  * params are plain nested dicts of ``jnp.ndarray`` (f32 masters);
  * every param is declared through a ``ParamSpec`` carrying *logical* axis
    names, so sharding policies can map them to mesh axes without the layer
    knowing anything about meshes;
  * compute runs in ``compute_dtype`` (bf16 by default), masters stay f32;
  * attention is query-chunked above ``CHUNK_THRESHOLD`` so 32k-sequence
    prefill never materialises an (S × S) score tensor — the pure-JAX
    analogue of the flash-attention kernel in ``repro.kernels``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.sharding.policy import ShardingPolicy, constrain

PyTree = Any

CHUNK_THRESHOLD = 8192     # chunk queries when S >= this
QUERY_CHUNK = 1024

# ---- §Perf hillclimb knobs (set by launch/dryrun --variant) ----------
# dtype the attention score/prob matrices materialise in.  f32 is the
# paper-faithful baseline; bf16 halves the dominant HBM term of the
# unfused attention path (the Pallas flash kernel keeps them in VMEM
# entirely — see EXPERIMENTS.md §Perf).
SCORE_DTYPE = jnp.float32
# sequence-chunked cross-entropy: when > 0 the (B, S, V) logit loss is
# computed in S/chunk pieces via lax.map, bounding live logits memory.
XENT_SEQ_CHUNK = 0
# GQA→MHA expansion: when KV heads do not divide the TP degree (deepseek
# kv=8, qwen2-vl kv=4 on a 16-way model axis), the 5-D grouped attention
# einsum defeats GSPMD propagation and the full (B,KV,rep,S,S) score
# tensor replicates per device with TiB-scale all-gathers.  Expanding K/V
# to the query-head count gives a 4-D head-sharded einsum GSPMD handles
# (pads 56→64 heads internally) — the standard Megatron/vLLM posture for
# KV < TP.
GQA_EXPAND = False
# cast-before-gather: convert the f32 master params to compute dtype ONCE,
# sharded, at step entry — so FSDP's per-layer all-gathers move bf16, not
# f32 (XLA does not reorder convert past all-gather on its own; halves the
# dominant collective term of the fsdp_all policy).
CAST_PARAMS_ONCE = False


def maybe_cast_params(params, dtype):
    if not CAST_PARAMS_ONCE:
        return params
    return jax.tree.map(
        lambda p: p.astype(dtype) if (hasattr(p, "dtype")
                                      and p.dtype == jnp.float32) else p,
        params)


# ======================================================================
# Param declaration
# ======================================================================
@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]          # logical axis names
    init: str = "normal"                     # normal | zeros | ones
    scale: Optional[float] = None            # stddev for "normal"

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _leaf_paths(tree: PyTree, prefix=()):
    if isinstance(tree, dict):
        for k in sorted(tree):
            yield from _leaf_paths(tree[k], prefix + (k,))
    else:
        yield prefix, tree


def _stable_hash(s: str) -> int:
    """Process-independent string hash (Python's hash() is randomised by
    PYTHONHASHSEED — multi-host init must agree bitwise across processes)."""
    import zlib
    return zlib.crc32(s.encode()) & 0x7FFFFFFF


def init_params(specs: PyTree, key: jax.Array, dtype=jnp.float32) -> PyTree:
    """Materialise a param pytree from ParamSpecs (deterministic per path)."""
    def make(path, spec: ParamSpec):
        if spec.init == "zeros":
            return jnp.zeros(spec.shape, dtype)
        if spec.init == "ones":
            return jnp.ones(spec.shape, dtype)
        k = key
        for p in path:
            k = jax.random.fold_in(k, _stable_hash(p))
        scale = spec.scale
        if scale is None:
            fan_in = spec.shape[0] if len(spec.shape) >= 1 else 1
            scale = 1.0 / np.sqrt(max(1, fan_in))
        return (jax.random.normal(k, spec.shape, jnp.float32) * scale).astype(dtype)

    out = {}
    for path, spec in _leaf_paths(specs):
        node = out
        for p in path[:-1]:
            node = node.setdefault(p, {})
        node[path[-1]] = make(path, spec)
    return out


def abstract_params(specs: PyTree, dtype=jnp.float32) -> PyTree:
    """ShapeDtypeStruct pytree (no allocation) — used by the dry-run."""
    out = {}
    for path, spec in _leaf_paths(specs):
        node = out
        for p in path[:-1]:
            node = node.setdefault(p, {})
        node[path[-1]] = jax.ShapeDtypeStruct(spec.shape, dtype)
    return out


def axes_tree(specs: PyTree) -> PyTree:
    out = {}
    for path, spec in _leaf_paths(specs):
        node = out
        for p in path[:-1]:
            node = node.setdefault(p, {})
        node[path[-1]] = spec.axes
    return out


def stack_specs(specs: PyTree, n: int) -> PyTree:
    """Add a leading scan ("layers") dim of size n to every ParamSpec."""
    def f(s: ParamSpec) -> ParamSpec:
        return ParamSpec((n,) + s.shape, ("layers",) + s.axes, s.init, s.scale)
    return jax.tree.map(f, specs,
                        is_leaf=lambda x: isinstance(x, ParamSpec))


# ======================================================================
# Normalisation
# ======================================================================
def rmsnorm_spec(d: int) -> Dict[str, ParamSpec]:
    return {"scale": ParamSpec((d,), ("d_model",), init="ones")}


def rmsnorm(params, x, eps: float = 1e-5):
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dtype)


def head_rmsnorm(scale, x, eps: float = 1e-5):
    """Per-head q/k norm (Qwen3): x (..., hd), scale (hd,)."""
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(dtype)


# ======================================================================
# Rotary embeddings (incl. multimodal M-RoPE)
# ======================================================================
def mrope_sections(head_dim: int) -> Tuple[int, int, int]:
    half = head_dim // 2
    s = 3 * half // 8
    return (half - 2 * s, s, s)          # e.g. hd=128 -> (16, 24, 24)


def rope_freqs(head_dim: int, theta: float) -> np.ndarray:
    half = head_dim // 2
    return 1.0 / (theta ** (np.arange(half, dtype=np.float64) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float,
               mrope: bool = False) -> jax.Array:
    """x: (B, S, H, hd); positions: (B, S) or (3, B, S) for M-RoPE."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.asarray(rope_freqs(hd, theta), jnp.float32)      # (half,)
    if mrope:
        # positions (3, B, S): temporal/height/width per frequency section
        sec = mrope_sections(hd)
        idx = np.concatenate([np.full(s, i) for i, s in enumerate(sec)])
        pos = positions.astype(jnp.float32)[idx]                 # (half, B, S)
        angles = jnp.einsum("hbs,h->bsh", pos, freqs)            # (B, S, half)
    else:
        angles = positions.astype(jnp.float32)[..., None] * freqs  # (B,S,half)
    cos = jnp.cos(angles)[:, :, None, :]                          # (B,S,1,half)
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ======================================================================
# Attention
# ======================================================================
def attention_specs(cfg) -> Dict[str, ParamSpec]:
    d, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    s: Dict[str, ParamSpec] = {
        "wq": ParamSpec((d, H * hd), ("d_model", "heads")),
        "wk": ParamSpec((d, KV * hd), ("d_model", "kv_heads")),
        "wv": ParamSpec((d, KV * hd), ("d_model", "kv_heads")),
        "wo": ParamSpec((H * hd, d), ("heads", "d_model")),
    }
    if cfg.qkv_bias:
        s["bq"] = ParamSpec((H * hd,), ("heads",), init="zeros")
        s["bk"] = ParamSpec((KV * hd,), ("kv_heads",), init="zeros")
        s["bv"] = ParamSpec((KV * hd,), ("kv_heads",), init="zeros")
    if cfg.qk_norm:
        s["q_norm"] = ParamSpec((hd,), ("head_dim",), init="ones")
        s["k_norm"] = ParamSpec((hd,), ("head_dim",), init="ones")
    return s


def _qkv(params, cfg, x, positions, policy: ShardingPolicy,
         rope: bool = True):
    """Project to q (B,S,H,hd), k/v (B,S,KV,hd) with RoPE applied."""
    B, S, _ = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    dt = x.dtype
    q = x @ params["wq"].astype(dt)
    k = x @ params["wk"].astype(dt)
    v = x @ params["wv"].astype(dt)
    if cfg.qkv_bias:
        q = q + params["bq"].astype(dt)
        k = k + params["bk"].astype(dt)
        v = v + params["bv"].astype(dt)
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, KV, hd)
    v = v.reshape(B, S, KV, hd)
    if cfg.qk_norm:
        q = head_rmsnorm(params["q_norm"], q, cfg.norm_eps)
        k = head_rmsnorm(params["k_norm"], k, cfg.norm_eps)
    if rope and positions is not None:
        q = apply_rope(q, positions, cfg.rope_theta, cfg.mrope)
        k = apply_rope(k, positions, cfg.rope_theta, cfg.mrope)
    q = constrain(q, policy, "batch", "seq", "heads", None)
    k = constrain(k, policy, "batch", "seq", "kv_heads", None)
    v = constrain(v, policy, "batch", "seq", "kv_heads", None)
    return q, k, v


def _sdpa_block(q, k, v, mask, scale):
    """q (B,Q,KV,rep,hd), k/v (B,Sk,KV,hd), mask (Q,Sk) bool or None."""
    scores = jnp.einsum("bqgrd,bkgd->bgrqk", q, k) * scale
    scores = scores.astype(SCORE_DTYPE)
    neg = jnp.asarray(-1e30 if SCORE_DTYPE == jnp.float32 else -3e38,
                      SCORE_DTYPE)
    if mask is not None:
        scores = jnp.where(mask[None, None, None], scores, neg)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bgrqk,bkgd->bqgrd", probs, v)


def maybe_expand_gqa(q, k, v, policy: ShardingPolicy):
    """GQA_EXPAND knob: broadcast K/V to the query-head count so attention
    shards on the (padded) head dim instead of the non-divisible KV dim."""
    H, KV = q.shape[2], k.shape[2]
    if not GQA_EXPAND or H == KV:
        return k, v
    rep = H // KV
    k = jnp.repeat(k, rep, axis=2)
    v = jnp.repeat(v, rep, axis=2)
    k = constrain(k, policy, "batch", "seq", "heads", None)
    v = constrain(v, policy, "batch", "seq", "heads", None)
    return k, v


def self_attention(q, k, v, *, causal: bool, window: int = 0,
                   q_offset: int = 0):
    """Exact chunked attention.  q (B,Sq,H,hd), k/v (B,Sk,KV,hd).

    Query chunking keeps the live score block at (Cq × Sk) instead of
    (Sq × Sk); with SWA the key block is additionally sliced to
    (window + Cq), making compute sub-quadratic.
    """
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    Sk = k.shape[1]
    rep = H // KV
    scale = 1.0 / np.sqrt(hd)
    qg = q.reshape(B, Sq, KV, rep, hd)

    def mask_for(qpos, kpos):
        m = jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
        if causal:
            m &= kpos[None, :] <= qpos[:, None]
        if window:
            m &= kpos[None, :] > qpos[:, None] - window
        return m

    if Sq < CHUNK_THRESHOLD or Sq % QUERY_CHUNK != 0:
        qpos = jnp.arange(Sq) + q_offset
        kpos = jnp.arange(Sk)
        mask = mask_for(qpos, kpos) if (causal or window) else None
        out = _sdpa_block(qg, k, v, mask, scale)
        return out.reshape(B, Sq, H, hd)

    # ---- chunked path (S >= CHUNK_THRESHOLD) ----
    nC = Sq // QUERY_CHUNK
    qc = qg.reshape(B, nC, QUERY_CHUNK, KV, rep, hd)

    use_window = window and window + QUERY_CHUNK < Sk

    def one_chunk(c, q_chunk):
        qpos = c * QUERY_CHUNK + jnp.arange(QUERY_CHUNK) + q_offset
        if use_window:
            blk = window + QUERY_CHUNK
            start = jnp.clip(c * QUERY_CHUNK + q_offset - window, 0, Sk - blk)
            kb = jax.lax.dynamic_slice_in_dim(k, start, blk, axis=1)
            vb = jax.lax.dynamic_slice_in_dim(v, start, blk, axis=1)
            kpos = start + jnp.arange(blk)
        else:
            kb, vb = k, v
            kpos = jnp.arange(Sk)
        m = mask_for(qpos, kpos) if (causal or window) else None
        return _sdpa_block(q_chunk, kb, vb, m, scale)

    out = jax.lax.map(lambda args: one_chunk(args[0], args[1]),
                      (jnp.arange(nC), jnp.moveaxis(qc, 1, 0)))
    out = jnp.moveaxis(out, 0, 1).reshape(B, Sq, H, hd)
    return out


def cross_attention(q, k, v):
    """Non-causal attention against a fixed memory (whisper cross-attn)."""
    return self_attention(q, k, v, causal=False, window=0)


def mask_padded_vocab(logits: jax.Array, cfg) -> jax.Array:
    """-inf out the vocab-padding columns (see ModelConfig.padded_vocab)."""
    if cfg.padded_vocab == cfg.vocab_size:
        return logits
    iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    return jnp.where(iota < cfg.vocab_size, logits,
                     jnp.asarray(-1e30, logits.dtype))


# ======================================================================
# loss
# ======================================================================
def _xent_nll(logits: jax.Array, targets: jax.Array) -> jax.Array:
    """Per-token NLL over a vocab-sharded logits tensor.

    The label logit is extracted with an iota-compare masked reduction
    instead of take_along_axis: a gather on the sharded vocab dim makes
    GSPMD all-gather the full (B, S, V) logits per device (tens of GB at
    150k vocab); compare+select+reduce stays sharded and fuses — the
    all-reduce is only the (B, S) partials.
    """
    lg = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(lg, axis=-1)              # (B,S)
    iota = jax.lax.broadcasted_iota(jnp.int32, lg.shape, lg.ndim - 1)
    sel = jnp.where(iota == targets[..., None], lg, 0.0)
    tgt = jnp.sum(sel, axis=-1)                                  # (B,S)
    return lse - tgt


def softmax_xent_sharded(logits: jax.Array, targets: jax.Array,
                         mask: Optional[jax.Array] = None):
    """Masked mean cross-entropy; optionally sequence-chunked (the
    XENT_SEQ_CHUNK knob) so at most (B, chunk, V) logit-loss intermediates
    are live at once.  Callers keep S divisible by passing full-length
    logits with a shifted mask (see LM.loss) rather than slicing to S-1."""
    S = logits.shape[1]
    C = XENT_SEQ_CHUNK
    if C and S > C and S % C == 0:
        nC = S // C
        lg = jnp.moveaxis(
            logits.reshape(logits.shape[0], nC, C, -1), 1, 0)
        tg = jnp.moveaxis(targets.reshape(targets.shape[0], nC, C), 1, 0)
        nll = jax.lax.map(lambda ab: _xent_nll(ab[0], ab[1]), (lg, tg))
        nll = jnp.moveaxis(nll, 0, 1).reshape(targets.shape)
    else:
        nll = _xent_nll(logits, targets)
    if mask is None:
        mask = jnp.ones(targets.shape, jnp.float32)
    mask = mask.astype(jnp.float32)
    ntok = jnp.maximum(mask.sum(), 1.0)
    return (nll * mask).sum() / ntok, ntok


# ======================================================================
# MLP (SwiGLU)
# ======================================================================
def mlp_specs(d: int, ff: int) -> Dict[str, ParamSpec]:
    return {
        "w_gate": ParamSpec((d, ff), ("d_model", "d_ff")),
        "w_up": ParamSpec((d, ff), ("d_model", "d_ff")),
        "w_down": ParamSpec((ff, d), ("d_ff", "d_model")),
    }


def mlp(params, x, policy: ShardingPolicy):
    dt = x.dtype
    g = x @ params["w_gate"].astype(dt)
    u = x @ params["w_up"].astype(dt)
    h = jax.nn.silu(g) * u
    h = constrain(h, policy, "batch", "seq", "d_ff")
    return h @ params["w_down"].astype(dt)
