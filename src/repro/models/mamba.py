"""Mamba2 (SSD — state-space duality) block, pure-JAX reference path.

The chunked SSD algorithm here is the oracle for ``repro.kernels.ssd_scan``
(the Pallas TPU kernel) and the implementation used by the dry-run lowering.

Shapes:  x (B, S, d_model) -> y (B, S, d_model)
Internal: d_inner = expand*d_model, nh = d_inner/headdim heads, state N.
Training/prefill uses the chunked scan (O(S·Q) + O(S·N·P)); decode is the
O(1)-per-token recurrence on a (B, nh, P, N) state.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import ParamSpec, rmsnorm
from repro.sharding.policy import ShardingPolicy, constrain


def mamba_specs(cfg) -> Dict[str, ParamSpec]:
    d, di, N, nh = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_nheads
    w = cfg.ssm_conv_width
    return {
        "w_x": ParamSpec((d, di), ("d_model", "ssm_inner")),
        "w_z": ParamSpec((d, di), ("d_model", "ssm_inner")),
        "w_B": ParamSpec((d, N), ("d_model", "state")),
        "w_C": ParamSpec((d, N), ("d_model", "state")),
        "w_dt": ParamSpec((d, nh), ("d_model", "ssm_heads")),
        "dt_bias": ParamSpec((nh,), ("ssm_heads",), init="zeros"),
        "A_log": ParamSpec((nh,), ("ssm_heads",), init="zeros"),
        "D": ParamSpec((nh,), ("ssm_heads",), init="ones"),
        "conv_x": ParamSpec((w, di), ("conv", "ssm_inner")),
        "conv_B": ParamSpec((w, N), ("conv", "state")),
        "conv_C": ParamSpec((w, N), ("conv", "state")),
        "norm": ParamSpec((di,), ("ssm_inner",), init="ones"),
        "w_out": ParamSpec((di, d), ("ssm_inner", "d_model")),
    }


# ----------------------------------------------------------------------
# causal depthwise conv
# ----------------------------------------------------------------------
def causal_conv(x: jax.Array, w: jax.Array) -> jax.Array:
    """x (B, S, C), w (W, C) depthwise causal convolution."""
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        xp, w[:, None, :].astype(x.dtype),           # (W, 1, C)
        window_strides=(1,), padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=x.shape[-1])
    return out


def conv_step(x_new: jax.Array, conv_state: jax.Array, w: jax.Array
              ) -> Tuple[jax.Array, jax.Array]:
    """One decode step.  x_new (B, C), conv_state (B, W-1, C), w (W, C)."""
    full = jnp.concatenate([conv_state, x_new[:, None, :]], axis=1)   # (B,W,C)
    y = jnp.einsum("bwc,wc->bc", full.astype(jnp.float32),
                   w.astype(jnp.float32)).astype(x_new.dtype)
    return y, full[:, 1:, :]


# ----------------------------------------------------------------------
# chunked SSD scan (training / prefill)
# ----------------------------------------------------------------------
def ssd_chunked(x: jax.Array, dt: jax.Array, A: jax.Array,
                Bm: jax.Array, Cm: jax.Array, chunk: int,
                h0: jax.Array | None = None
                ) -> Tuple[jax.Array, jax.Array]:
    """SSD over one sequence.

    x  (B, S, nh, P)   inputs per head
    dt (B, S, nh)      positive step sizes (post-softplus)
    A  (nh,)           negative decay rates
    Bm (B, S, N), Cm (B, S, N)   input/output projections (single group)
    Returns y (B, S, nh, P) and final state (B, nh, P, N).
    """
    B, S, nh, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    if S % Q:
        # pad with dt=0 steps: decay exp(0)=1, contribution 0 — a no-op
        # for the recurrence, sliced off the output below.
        pad = Q - S % Q
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    S_pad = x.shape[1]
    nC = S_pad // Q

    xf = x.astype(jnp.float32).reshape(B, nC, Q, nh, P)
    dtf = dt.astype(jnp.float32).reshape(B, nC, Q, nh)
    Bf = Bm.astype(jnp.float32).reshape(B, nC, Q, N)
    Cf = Cm.astype(jnp.float32).reshape(B, nC, Q, N)
    Af = A.astype(jnp.float32)

    dA = dtf * Af                                    # (B,nC,Q,nh)
    cum = jnp.cumsum(dA, axis=2)                     # inclusive
    # decay from chunk entry to position i (state contribution)
    decay_in = jnp.exp(cum)                          # (B,nC,Q,nh)
    # decay from position j to chunk exit
    total = cum[:, :, -1:, :]                        # (B,nC,1,nh)
    decay_out = jnp.exp(total - cum)                 # (B,nC,Q,nh)
    chunk_decay = jnp.exp(total[:, :, 0, :])         # (B,nC,nh)

    # intra-chunk (quadratic within chunk):
    # L[i,j] = exp(cum_i - cum_j) for j<=i
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # (B,nC,Qi,Qj,nh)
    mask = (jnp.arange(Q)[:, None] >= jnp.arange(Q)[None, :])
    L = jnp.where(mask[None, None, :, :, None], jnp.exp(diff), 0.0)
    CB = jnp.einsum("bcin,bcjn->bcij", Cf, Bf)             # (B,nC,Q,Q)
    G = CB[..., None] * L                                  # (B,nC,Qi,Qj,nh)
    y_intra = jnp.einsum("bcijh,bcjh,bcjhp->bcihp", G, dtf, xf)

    # inter-chunk recurrence
    # state contribution of chunk c: sum_j decay_out[j] * dt[j] * B[j] ⊗ x[j]
    state_contrib = jnp.einsum("bcjh,bcjh,bcjn,bcjhp->bchpn",
                               decay_out, dtf, Bf, xf)      # (B,nC,nh,P,N)

    if h0 is None:
        h0 = jnp.zeros((B, nh, P, N), jnp.float32)

    def step(h, inputs):
        contrib, cdecay = inputs                            # (B,nh,P,N),(B,nh)
        h_new = h * cdecay[:, :, None, None] + contrib
        return h_new, h

    (h_final, h_prevs) = jax.lax.scan(
        step,
        h0.astype(jnp.float32),
        (jnp.moveaxis(state_contrib, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)                   # (B,nC,nh,P,N)

    # y_inter[i] = decay_in[i] * C[i] · h_prev
    y_inter = jnp.einsum("bcin,bchpn,bcih->bcihp", Cf, h_prevs, decay_in)

    y = (y_intra + y_inter).reshape(B, S_pad, nh, P)[:, :S]
    return y.astype(x.dtype), h_final


def ssd_decode_step(x: jax.Array, dt: jax.Array, A: jax.Array,
                    Bm: jax.Array, Cm: jax.Array, h: jax.Array
                    ) -> Tuple[jax.Array, jax.Array]:
    """One-token recurrence.  x (B,nh,P), dt (B,nh), Bm/Cm (B,N),
    h (B,nh,P,N) -> y (B,nh,P), h_new."""
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    dA = jnp.exp(dtf * A.astype(jnp.float32))                  # (B,nh)
    contrib = jnp.einsum("bh,bn,bhp->bhpn", dtf, Bm.astype(jnp.float32), xf)
    h_new = h * dA[:, :, None, None] + contrib
    y = jnp.einsum("bn,bhpn->bhp", Cm.astype(jnp.float32), h_new)
    return y.astype(x.dtype), h_new


# ----------------------------------------------------------------------
# full block
# ----------------------------------------------------------------------
def mamba_block(params, cfg, x: jax.Array, policy: ShardingPolicy,
                use_kernels: bool = False) -> jax.Array:
    """Training/prefill forward.  x (B, S, d_model)."""
    B, S, _ = x.shape
    di, N, nh, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_nheads, cfg.ssm_headdim
    dt_ = x.dtype
    xz = x @ params["w_z"].astype(dt_)                     # gate
    xi = x @ params["w_x"].astype(dt_)
    Bm = x @ params["w_B"].astype(dt_)
    Cm = x @ params["w_C"].astype(dt_)
    dt = x @ params["w_dt"].astype(dt_)
    xi = constrain(xi, policy, "batch", "seq", "ssm_inner")

    xi = jax.nn.silu(causal_conv(xi, params["conv_x"]))
    Bm = jax.nn.silu(causal_conv(Bm, params["conv_B"]))
    Cm = jax.nn.silu(causal_conv(Cm, params["conv_C"]))
    dt = jax.nn.softplus(dt.astype(jnp.float32) +
                         params["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(params["A_log"].astype(jnp.float32))

    xh = xi.reshape(B, S, nh, P)
    xh = constrain(xh, policy, "batch", "seq", "ssm_heads", None)
    if use_kernels:
        from repro.kernels import ops
        y, _ = ops.ssd(xh, dt, A, Bm, Cm, chunk=cfg.ssm_chunk)
    else:
        y, _ = ssd_chunked(xh, dt, A, Bm, Cm, chunk=cfg.ssm_chunk)
    y = (y + params["D"].astype(jnp.float32)[None, None, :, None] * xh
         ).astype(dt_)
    y = y.reshape(B, S, di)
    y = rmsnorm({"scale": params["norm"]}, y * jax.nn.silu(xz), cfg.norm_eps)
    return y @ params["w_out"].astype(dt_)


def mamba_cache_init(cfg, batch: int, dtype=jnp.float32):
    di, N, nh, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_nheads, cfg.ssm_headdim
    w = cfg.ssm_conv_width
    return {
        "h": jnp.zeros((batch, nh, P, N), jnp.float32),
        "conv_x": jnp.zeros((batch, w - 1, di), dtype),
        "conv_B": jnp.zeros((batch, w - 1, N), dtype),
        "conv_C": jnp.zeros((batch, w - 1, N), dtype),
    }


def mamba_cache_abstract(cfg, batch: int, dtype=jnp.float32):
    di, N, nh, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_nheads, cfg.ssm_headdim
    w = cfg.ssm_conv_width
    return {
        "h": jax.ShapeDtypeStruct((batch, nh, P, N), jnp.float32),
        "conv_x": jax.ShapeDtypeStruct((batch, w - 1, di), dtype),
        "conv_B": jax.ShapeDtypeStruct((batch, w - 1, N), dtype),
        "conv_C": jax.ShapeDtypeStruct((batch, w - 1, N), dtype),
    }


MAMBA_CACHE_AXES = {
    "h": ("batch", "ssm_heads", None, None),
    "conv_x": ("batch", None, "ssm_inner"),
    "conv_B": ("batch", None, "state"),
    "conv_C": ("batch", None, "state"),
}


def mamba_decode(params, cfg, x: jax.Array, cache: dict,
                 policy: ShardingPolicy) -> Tuple[jax.Array, dict]:
    """One-token decode.  x (B, d_model)."""
    B, _ = x.shape
    di, N, nh, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_nheads, cfg.ssm_headdim
    dt_ = x.dtype
    xz = x @ params["w_z"].astype(dt_)
    xi = x @ params["w_x"].astype(dt_)
    Bm = x @ params["w_B"].astype(dt_)
    Cm = x @ params["w_C"].astype(dt_)
    dt = x @ params["w_dt"].astype(dt_)

    xi, cx = conv_step(xi, cache["conv_x"], params["conv_x"])
    Bm, cB = conv_step(Bm, cache["conv_B"], params["conv_B"])
    Cm, cC = conv_step(Cm, cache["conv_C"], params["conv_C"])
    xi, Bm, Cm = jax.nn.silu(xi), jax.nn.silu(Bm), jax.nn.silu(Cm)
    dt = jax.nn.softplus(dt.astype(jnp.float32) +
                         params["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(params["A_log"].astype(jnp.float32))

    xh = xi.reshape(B, nh, P)
    y, h_new = ssd_decode_step(xh, dt, A, Bm, Cm, cache["h"])
    y = (y + params["D"].astype(jnp.float32)[None, :, None] * xh
         ).astype(dt_)
    y = y.reshape(B, di)
    y = rmsnorm({"scale": params["norm"]}, y * jax.nn.silu(xz), cfg.norm_eps)
    out = y @ params["w_out"].astype(dt_)
    return out, {"h": h_new, "conv_x": cx, "conv_B": cB, "conv_C": cC}
