"""Fused RMSNorm Pallas TPU kernel.

RMSNorm is memory-bound (one read + one write of the activation, O(d)
FLOPs/row); the fusion win on TPU is keeping the f32 square/mean/rsqrt
pipeline inside VMEM so the activation streams HBM→VMEM exactly once.
Rows are tiled (block_rows × d) with d kept whole per tile — model dims in
the zoo (384…7168) fit VMEM comfortably at 256 rows.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl



def _rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)                    # (rows, d)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps) * s_ref[...].astype(jnp.float32)
    o_ref[...] = y.astype(o_ref.dtype)


def rmsnorm(x: jax.Array, scale: jax.Array, *, eps: float = 1e-5,
            block_rows: int = 256, interpret: bool = False) -> jax.Array:
    """x (..., d), scale (d,) -> same shape/dtype as x."""
    orig_shape = x.shape
    d = x.shape[-1]
    rows = 1
    for s in x.shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)

    block_rows = min(block_rows, max(rows, 1))
    pad = (-rows) % block_rows
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    grid = (x2.shape[0] // block_rows,)

    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2.shape, x.dtype),
        interpret=interpret,
    )(x2, scale)

    if pad:
        out = out[:rows]
    return out.reshape(orig_shape)
