"""Mamba2 SSD (state-space duality) chunked-scan Pallas TPU kernel.

TPU adaptation (DESIGN.md §2): the Tri Dao SSD GPU kernel relies on warp-
level parallel prefix products; on TPU we exploit the *sequential* grid
instead — the recurrent state h (P×N, f32) lives in VMEM scratch carried
across the innermost (chunk) grid dimension, while the intra-chunk work is
three MXU matmuls per step:

    CB      = C · Bᵀ                 (Q×N)·(N×Q)  -> (Q,Q)
    y_intra = (CB ⊙ L(dt)) · (dt⊙x)  (Q,Q)·(Q,P)
    y_inter = decay_in ⊙ (C · hᵀ)    (Q,N)·(N,P)
    h_new   = exp(Σ dA) h + xᵀ·(decay_out⊙dt⊙B)   (P,Q)·(Q,N)

Grid: (batch, heads, nChunks) — chunks innermost/sequential.
Block shapes: Q (chunk len) and N (state) are 128-aligned; P (head dim,
64 for mamba2-2.7b) rides the MXU at half occupancy — recorded in
EXPERIMENTS.md §Roofline.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.flash_attention import pl_scratch


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref,
                y_ref, hout_ref, h_ref, *, chunk: int):
    ic = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ic == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0, :, 0].astype(jnp.float32)        # (Q, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)      # (Q,)
    A = a_ref[0]                                  # scalar
    Bm = b_ref[0].astype(jnp.float32)             # (Q, N)
    Cm = c_ref[0].astype(jnp.float32)             # (Q, N)

    dA = dt * A                                   # (Q,)
    cum = jnp.cumsum(dA)                          # inclusive
    total = cum[-1]
    decay_in = jnp.exp(cum)                       # chunk entry -> i
    decay_out = jnp.exp(total - cum)              # j -> chunk exit

    # intra-chunk: L[i,j] = exp(cum_i - cum_j) for j <= i
    diff = cum[:, None] - cum[None, :]
    tri = (jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
           >= jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1))
    L = jnp.where(tri, jnp.exp(diff), 0.0)        # (Q, Q)
    CB = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    G = CB * L                                    # (Q, Q)
    y_intra = jax.lax.dot_general(G * dt[None, :], x,
                                  (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)

    # inter-chunk: y_inter[i] = decay_in[i] * C[i] · h_prev
    h = h_ref[...]                                # (P, N)
    y_inter = decay_in[:, None] * jax.lax.dot_general(
        Cm, h, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)       # (Q, P)

    y_ref[0, :, 0] = (y_intra + y_inter).astype(y_ref.dtype)

    # state update
    w = (decay_out * dt)[:, None] * Bm            # (Q, N)
    contrib = jax.lax.dot_general(x, w, (((0,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    h_ref[...] = h * jnp.exp(total) + contrib

    @pl.when(ic == nc - 1)
    def _emit_state():
        hout_ref[0, 0] = h_ref[...]


def ssd_scan(x: jax.Array, dt: jax.Array, A: jax.Array,
             Bm: jax.Array, Cm: jax.Array, *, chunk: int = 128,
             interpret: bool = False) -> Tuple[jax.Array, jax.Array]:
    """Pallas SSD.  Same contract as ``kernels.ref.ssd_ref``:

    x (B, S, nh, P); dt (B, S, nh) post-softplus; A (nh,) negative;
    Bm/Cm (B, S, N) -> y (B, S, nh, P), h_final (B, nh, P, N).

    S is padded to a chunk multiple with dt=0 no-op steps (decay 1,
    contribution 0) — semantics-preserving for the recurrence.
    """
    B, S, nh, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    pad = (-S) % Q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    S_pad = x.shape[1]
    nC = S_pad // Q
    grid = (B, nh, nC)

    kernel = functools.partial(_ssd_kernel, chunk=Q)
    y, h_final = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Q, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, Q, 1), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, Q, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, Q, N), lambda b, h, c: (b, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, Q, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S_pad, nh, P), x.dtype),
            jax.ShapeDtypeStruct((B, nh, P, N), jnp.float32),
        ],
        scratch_shapes=[pl_scratch((P, N))],       # carried SSM state
        interpret=interpret,
    )(x, dt, A.astype(jnp.float32), Bm, Cm)

    return (y[:, :S] if pad else y), h_final
