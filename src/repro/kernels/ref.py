"""Pure-jnp oracles for every Pallas kernel in this package.

These are deliberately *naive* implementations (full score matrices,
token-by-token SSM recurrence) so they are independent of both the Pallas
kernels and the chunked pure-JAX production paths in ``repro.models`` —
all three are cross-checked in tests/test_kernels.py.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: int = 0) -> jax.Array:
    """Naive exact attention with GQA.

    q (B, Sq, H, hd); k/v (B, Sk, KV, hd); returns (B, Sq, H, hd).
    ``window`` > 0 restricts key j to (i - window, i] (sliding window).
    """
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    rep = H // KV
    qf = q.astype(jnp.float32).reshape(B, Sq, KV, rep, hd)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scores = jnp.einsum("bqgrd,bkgd->bgrqk", qf, kf) / np.sqrt(hd)
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    scores = jnp.where(mask[None, None, None], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bgrqk,bkgd->bqgrd", probs, vf)
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


def ssd_ref(x: jax.Array, dt: jax.Array, A: jax.Array,
            Bm: jax.Array, Cm: jax.Array,
            h0: Optional[jax.Array] = None
            ) -> Tuple[jax.Array, jax.Array]:
    """Token-by-token SSD recurrence (the ground-truth semantics).

    x (B, S, nh, P); dt (B, S, nh) post-softplus; A (nh,) negative;
    Bm/Cm (B, S, N).  Returns y (B, S, nh, P), final state (B, nh, P, N).

      h_t = exp(dt_t A) * h_{t-1} + dt_t * B_t ⊗ x_t
      y_t = C_t · h_t
    """
    B, S, nh, P = x.shape
    N = Bm.shape[-1]
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Af = A.astype(jnp.float32)
    Bf = Bm.astype(jnp.float32)
    Cf = Cm.astype(jnp.float32)
    if h0 is None:
        h0 = jnp.zeros((B, nh, P, N), jnp.float32)

    def step(h, tup):
        xt, dtt, Bt, Ct = tup                       # (B,nh,P),(B,nh),(B,N)x2
        decay = jnp.exp(dtt * Af[None])             # (B, nh)
        contrib = jnp.einsum("bh,bn,bhp->bhpn", dtt, Bt, xt)
        h = h * decay[:, :, None, None] + contrib
        y = jnp.einsum("bn,bhpn->bhp", Ct, h)
        return h, y

    h_final, ys = jax.lax.scan(
        step, h0.astype(jnp.float32),
        (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(dtf, 1, 0),
         jnp.moveaxis(Bf, 1, 0), jnp.moveaxis(Cf, 1, 0)))
    y = jnp.moveaxis(ys, 0, 1)                       # (B,S,nh,P)
    return y.astype(x.dtype), h_final


def rmsnorm_ref(x: jax.Array, scale: jax.Array,
                eps: float = 1e-5) -> jax.Array:
    """x (..., d), scale (d,)."""
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)
            * scale.astype(jnp.float32)).astype(dtype)
