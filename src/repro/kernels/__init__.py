"""Pallas TPU kernels for the workload hot-spots (DESIGN.md §3).

CRIUgpu itself has no kernel-level contribution — these serve the models
being checkpointed:

  flash_attention  — online-softmax attention (causal/SWA/cross), MXU-tiled
  ssd_scan         — Mamba2 SSD chunked scan, VMEM-carried recurrent state
  rmsnorm          — fused normalisation (single HBM pass)

``ops`` is the jit'd dispatch layer (interpret=True on CPU); ``ref`` holds
the deliberately-naive pure-jnp oracles used by tests/test_kernels.py.
"""
from repro.kernels import ops, ref  # noqa: F401
