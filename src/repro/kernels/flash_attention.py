"""Flash attention Pallas TPU kernel (forward).

TPU adaptation notes (DESIGN.md §2): the GPU flash-attention algorithm is
re-blocked for the TPU memory hierarchy — q/k/v tiles stream HBM→VMEM via
``BlockSpec`` index maps, the running softmax state (m, l, acc) lives in
VMEM scratch that persists across the *sequential* innermost grid dimension
(TPU grids execute in order, the Pallas analogue of a k-loop), and all
matmul tile dims are multiples of the 128-wide MXU systolic array.

Grid: (batch, q_heads, nQ, nK) — nK innermost/sequential.
GQA is folded into the k/v ``index_map`` (kv head = h * KV // H), so k/v
tiles are fetched once per kv-head and reused by the query-head group.

Causal + sliding-window masking is positional (absolute q/k positions via
``broadcasted_iota``); fully-masked k-blocks are skipped with ``pl.when``
(block-sparse early-out, halves causal work).
"""
from __future__ import annotations

import functools
import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref,
                  acc_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, window: int,
                  block_q: int, block_k: int, seq_k: int):
    """One (q-block, k-block) step of the online-softmax recurrence."""
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = iq * block_q
    k_start = ik * block_k

    # --- block-level early-out -----------------------------------------
    # causal: skip k-blocks entirely above the diagonal;
    # window:  skip k-blocks entirely below the window of the last query.
    run = jnp.bool_(True)
    if causal:
        run &= k_start <= q_start + block_q - 1
    if window:
        run &= k_start + block_k - 1 > q_start - window

    @pl.when(run)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)              # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)              # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)              # (bk, hd)

        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (bq, bk)

        qpos = q_start + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        mask = kpos < seq_k                               # key padding
        if causal:
            mask &= kpos <= qpos
        if window:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                               # (bq, 1)
        l_prev = l_ref[...]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)                   # rescale old acc
        p = jnp.exp(s - m_new)                            # (bq, bk)
        l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)

        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new
        l_ref[...] = l_new

    @pl.when(ik == nk - 1)
    def _finalize():
        l = l_ref[...]
        # fully-masked rows (can happen only in key padding) -> 0
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / safe).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    block_q: int = 512, block_k: int = 512,
                    interpret: bool = False) -> jax.Array:
    """q (B, Sq, H, hd); k/v (B, Sk, KV, hd) -> (B, Sq, H, hd).

    Sq/Sk are padded to the block sizes; padded keys are masked, padded
    queries sliced off.  hd must be 128-aligned for MXU efficiency on real
    TPUs (validated in interpret mode regardless).
    """
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    assert H % KV == 0, (H, KV)
    scale = 1.0 / np.sqrt(hd)

    block_q = min(block_q, max(Sq, 16))
    block_k = min(block_k, max(Sk, 16))
    pq = (-Sq) % block_q
    pk = (-Sk) % block_k
    qp = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0))) if pq else q
    kp = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0))) if pk else k
    vp = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0))) if pk else v

    # (B, S, H, hd) -> (B, H, S, hd) for contiguous per-head tiles
    qt = jnp.moveaxis(qp, 2, 1)
    kt = jnp.moveaxis(kp, 2, 1)
    vt = jnp.moveaxis(vp, 2, 1)

    nQ = qt.shape[2] // block_q
    nK = kt.shape[2] // block_k
    grid = (B, H, nQ, nK)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, seq_k=Sk)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd),
                         lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, iq, ik, KV=KV, H=H:
                         (b, h * KV // H, ik, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, iq, ik, KV=KV, H=H:
                         (b, h * KV // H, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, qt.shape[2], hd), q.dtype),
        scratch_shapes=[
            pl_scratch((block_q, hd)),      # acc
            pl_scratch((block_q, 1)),       # m (running max)
            pl_scratch((block_q, 1)),       # l (running denominator)
        ],
        interpret=interpret,
    )(qt, kt, vt)

    out = jnp.moveaxis(out, 1, 2)
    return out[:, :Sq] if pq else out


def pl_scratch(shape):
    """VMEM f32 scratch (TPU) that also works in interpret mode."""
    try:
        from jax.experimental.pallas import tpu as pltpu
        return pltpu.VMEM(shape, jnp.float32)
    except Exception:                                    # pragma: no cover
        return pl.MemorySpace.ANY(shape, jnp.float32)
