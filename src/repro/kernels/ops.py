"""Jit'd dispatch layer over the Pallas kernels.

On a real TPU backend the kernels lower through Mosaic; on CPU (this
container) they execute with ``interpret=True`` — the kernel body runs
op-by-op in Python with identical semantics, which is how the per-kernel
allclose tests validate them.  Set ``REPRO_FORCE_INTERPRET=0`` to force
compiled mode (TPU only).
"""
from __future__ import annotations

import functools
import os
from typing import Tuple

import jax

from repro.kernels import flash_attention as _fa
from repro.kernels import rmsnorm as _rn
from repro.kernels import ssd_scan as _ssd


def _interpret() -> bool:
    env = os.environ.get("REPRO_FORCE_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _attention_vjp(q, k, v, causal, window, block_q, block_k):
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               block_q=block_q, block_k=block_k,
                               interpret=_interpret())


def _attention_fwd(q, k, v, causal, window, block_q, block_k):
    return _attention_vjp(q, k, v, causal, window, block_q, block_k), \
        (q, k, v)


def _attention_bwd(causal, window, block_q, block_k, res, g):
    """Backward through the exact reference (the standard fast-forward
    pattern: Pallas fwd kernel + XLA-differentiated bwd — bitwise-matched
    to the oracle in tests)."""
    from repro.kernels.ref import attention_ref
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: attention_ref(q_, k_, v_, causal=causal,
                                         window=window), q, k, v)
    return vjp(g)


_attention_vjp.defvjp(_attention_fwd, _attention_bwd)


@functools.partial(jax.jit, static_argnames=("causal", "window",
                                             "block_q", "block_k"))
def attention(q, k, v, *, causal: bool = True, window: int = 0,
              block_q: int = 512, block_k: int = 512) -> jax.Array:
    """Flash attention.  q (B,Sq,H,hd), k/v (B,Sk,KV,hd).  Differentiable
    (custom VJP: kernel forward, reference backward)."""
    return _attention_vjp(q, k, v, causal, window, block_q, block_k)


@functools.partial(jax.custom_vjp, nondiff_argnums=(5,))
def _ssd_vjp(x, dt, A, Bm, Cm, chunk):
    return _ssd.ssd_scan(x, dt, A, Bm, Cm, chunk=chunk,
                         interpret=_interpret())


def _ssd_fwd(x, dt, A, Bm, Cm, chunk):
    return _ssd_vjp(x, dt, A, Bm, Cm, chunk), (x, dt, A, Bm, Cm)


def _ssd_bwd(chunk, res, g):
    from repro.models.mamba import ssd_chunked
    x, dt, A, Bm, Cm = res
    _, vjp = jax.vjp(lambda *a: ssd_chunked(*a, chunk=chunk),
                     x, dt, A, Bm, Cm)
    return vjp(g)


_ssd_vjp.defvjp(_ssd_fwd, _ssd_bwd)


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd(x, dt, A, Bm, Cm, *, chunk: int = 128
        ) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan (differentiable: kernel fwd, chunked-jnp bwd)."""
    return _ssd_vjp(x, dt, A, Bm, Cm, chunk)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def _rmsnorm_vjp(x, scale, eps, block_rows):
    return _rn.rmsnorm(x, scale, eps=eps, block_rows=block_rows,
                       interpret=_interpret())


def _rmsnorm_fwd(x, scale, eps, block_rows):
    return _rmsnorm_vjp(x, scale, eps, block_rows), (x, scale)


def _rmsnorm_bwd(eps, block_rows, res, g):
    from repro.kernels.ref import rmsnorm_ref
    x, scale = res
    _, vjp = jax.vjp(lambda x_, s_: rmsnorm_ref(x_, s_, eps), x, scale)
    return vjp(g)


_rmsnorm_vjp.defvjp(_rmsnorm_fwd, _rmsnorm_bwd)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows"))
def rmsnorm(x, scale, *, eps: float = 1e-5,
            block_rows: int = 256) -> jax.Array:
    return _rmsnorm_vjp(x, scale, eps, block_rows)
