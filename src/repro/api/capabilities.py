"""Preflight — the `criu check` analogue.

``criu check`` validates that the kernel supports everything a dump/restore
will need *before* anyone trusts it with a workload.  Our equivalents are
runtime-library probes: JAX version and device availability, mesh
axis-type support, serialization stack (msgpack / zlib / zstd), and the
device-backend registry.  ``capabilities()`` reports; ``check()`` judges.
"""
from __future__ import annotations

import dataclasses
import os
import tempfile
from typing import Any, Dict, List, Optional


def capabilities() -> Dict[str, Any]:
    """Structured report of what this environment supports."""
    import jax

    from repro.core.backends import available_backends
    from repro.core.plugins import PLUGIN_API_VERSION
    from repro.launch.mesh import HAS_AXIS_TYPES

    try:
        devices = jax.devices()
        platform = devices[0].platform if devices else None
        device_count = len(devices)
    except Exception:                                  # pragma: no cover
        platform, device_count = None, 0

    try:
        import msgpack
        msgpack_version = ".".join(map(str, msgpack.version))
    except Exception:                                  # pragma: no cover
        msgpack_version = None

    try:
        import zstandard
        zstd_available = True
    except Exception:
        zstd_available = False

    return {
        "plugin_api_version": PLUGIN_API_VERSION,
        "jax": {
            "version": jax.__version__,
            "platform": platform,
            "device_count": device_count,
            "process_count": jax.process_count(),
        },
        "mesh": {"axis_types": HAS_AXIS_TYPES},
        "serialization": {
            "msgpack": msgpack_version,
            "zlib": True,                     # stdlib, always present
            "zstd": zstd_available,
        },
        "backends": available_backends(),
        "modes": ["sync", "async"],
        "pack_formats": [1, 2],
        "features": {
            "incremental": True,
            "compression": True,
            "replication": True,
            "elastic_restore": True,
            "parallel_restore": True,
            "chunked_packs": True,        # pack v2: per-chunk CRC + codec
            "striped_io": True,           # N pack files/host, appender each
            "pipelined_writer": True,     # capture → compress → write stages
            "chunk_dedup": True,          # incremental reuse at chunk grain
            "delta_transfer": True,       # CAS have/want cross-host ship
            "content_addressed_store": True,   # repro.transfer.ChunkStore
            "migration": True,            # orchestrator migrate scenario
        },
        "transfer_modes": ["copy", "delta"],
    }


@dataclasses.dataclass
class CheckReport:
    ok: bool
    problems: List[str]
    warnings: List[str]
    capabilities: Dict[str, Any]

    def summary(self) -> str:
        lines = []
        status = "OK" if self.ok else "FAIL"
        lines.append(f"repro check: {status}")
        for p in self.problems:
            lines.append(f"  problem: {p}")
        for w in self.warnings:
            lines.append(f"  warning: {w}")
        return "\n".join(lines)


def check(run_dir: Optional[str] = None, options=None) -> CheckReport:
    """Validate that checkpoint/restore can work here (`criu check`).

    Probes the runtime (not just imports): builds a trivial mesh, round-
    trips a msgpack blob, and — when `run_dir` is given — proves the image
    directory is writable.  Returns a report instead of raising so
    schedulers can surface every problem at once.
    """
    problems: List[str] = []
    warns: List[str] = []

    caps = capabilities()
    if caps["jax"]["device_count"] == 0:
        problems.append("no JAX devices visible")
    if not caps["serialization"]["msgpack"]:
        problems.append("msgpack unavailable (host-state blobs need it)")
    if not caps["serialization"]["zstd"]:
        warns.append("zstandard not installed; compress=True falls "
                     "back to zlib")
    if not caps["mesh"]["axis_types"]:
        warns.append("this JAX has no mesh axis_types support; meshes are "
                     "built without explicit AxisType (compat shim)")
    if "jax" not in caps["backends"]:
        problems.append("no 'jax' device backend registered")

    # runtime probes, not just version strings
    try:
        from repro.launch.mesh import make_mesh
        make_mesh((1,), ("data",))
    except Exception as e:
        problems.append(f"mesh construction failed: {e}")
    try:
        from repro.core.snapshot_io import pack_host_blob, unpack_host_blob
        if unpack_host_blob(pack_host_blob({"probe": 1}))["probe"] != 1:
            problems.append("msgpack round-trip corrupted data")
    except Exception as e:
        problems.append(f"msgpack round-trip failed: {e}")

    if options is not None:
        try:
            options.validate()
        except Exception as e:
            problems.append(f"invalid options: {e}")

    if run_dir is not None:
        try:
            os.makedirs(run_dir, exist_ok=True)
            with tempfile.NamedTemporaryFile(dir=run_dir, prefix=".check"):
                pass
        except Exception as e:
            problems.append(f"run_dir {run_dir!r} not writable: {e}")

    return CheckReport(ok=not problems, problems=problems,
                       warnings=warns, capabilities=caps)
