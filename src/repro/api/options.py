"""CheckpointOptions — the declarative `criu_set_*` analogue.

CRIU's libcriu configures a dump/restore with ``criu_set_*`` calls before
the operation runs; everything about *how* a checkpoint is taken lives in
one options object, not scattered across call sites.  This is our
equivalent: a frozen dataclass carrying every knob the engine understands,
validated at construction, round-trippable through the environment (so
schedulers can configure checkpointing without touching code).

Deliberately dependency-free: importable from the CLI, tests, and config
tooling without pulling in jax.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Dict, Optional, Tuple

_MODES = ("sync", "async")
_TRANSFERS = ("copy", "delta")
_RESTORE_MODES = ("eager", "lazy")
_CAPTURES = ("sync", "concurrent")

# env-var names, one per field (the `criu_set_*` <-> CRIU_* convention)
_ENV_PREFIX = "REPRO_CKPT_"


class OptionsError(ValueError):
    """An invalid CheckpointOptions field combination."""


def auto_io_threads() -> int:
    """The io_threads=0 auto-sizing policy — the single source of truth
    for every data-plane consumer (engine, snapshot writer, CLI)."""
    return min(8, max(2, os.cpu_count() or 2))


@dataclasses.dataclass(frozen=True)
class CheckpointOptions:
    """Declarative checkpoint configuration.

    mode             "sync" (paper-faithful: frozen through dump+write) or
                     "async" (resume after device capture, write in
                     background — CheckFreq-style).
    incremental      delta images: unchanged entries point at the parent
                     snapshot's pack (Check-N-Run-style).
    compress         per-entry compression in the pack files.
    keep             GC: retain the newest N images (0 = keep all); parent
                     chains of kept images are never broken.
    lock_timeout_s   device-lock acquisition deadline; on timeout the dump
                     aborts and the job keeps running (paper §3.1.1).
    restore_threads  parallel pack-entry loads on restore (>1 enables the
                     on-demand-parallelism optimization).
    replicate_to     peer directory for snapshot replication (Gemini-style);
                     None disables.
    transfer         how bytes reach the replication peer: "copy" (whole
                     files, skipped when size+mtime match) or "delta"
                     (content-addressed: only chunks missing from the
                     peer's CAS ship — the cross-host migration path).
    transfer_workers parallel chunk-ship lanes for delta transfer;
                     0 = auto-size like io_threads.
    verify_restore   CRC-verify images before restoring from them (both the
                     newest-valid scan and explicitly requested steps).
    restore_mode     "eager" (default: the whole image is materialized
                     before restore() returns) or "lazy" (resume-before-
                     read: restore() returns once the critical set is
                     placed; a background LazyMaterializer streams the
                     remaining entries, joined via restore_barrier()).
    critical_states  which entries form the lazy critical set.  Each spec
                     is "state" (every entry of that state) or
                     "state/path-prefix" (a subtree, e.g.
                     "train_state/params").  None = the first state in
                     the image's recorded restore order.
    pack_format      2 (default): chunked/striped packs written by the
                     pipelined data plane; 1: serial-compat single-file
                     packs, byte-compatible with images from older code.
    io_threads       data-plane worker threads (compress/CRC on dump,
                     chunk read/decompress on restore); 0 = auto-size
                     from the host's CPU count.
    chunk_mb         pack-v2 chunk size in MiB (per-chunk CRC doubles as
                     the incremental content hash).
    stripes          pack files per host; each stripe gets its own
                     appender thread, so writes overlap compression.
    capture          "sync" (default: the job stays frozen for the whole
                     device capture) or "concurrent" (soft-freeze:
                     a brief pin pause, then shards are speculated to
                     disk while the step loop keeps running; a short
                     final validate pause re-hashes dirtied entries
                     against pack v2's per-chunk content hashes and
                     re-captures only the invalidated ones —
                     PhoenixOS-style validated speculation).  Requires
                     pack_format=2, incremental=True, and a backend
                     with the "dirty_tracking" feature; incompatible
                     with mode="async" (the validate pause already
                     overlaps the write).
    """

    mode: str = "sync"
    incremental: bool = False
    compress: bool = False
    keep: int = 0
    lock_timeout_s: float = 10.0
    restore_threads: int = 0
    replicate_to: Optional[str] = None
    transfer: str = "copy"
    transfer_workers: int = 0
    verify_restore: bool = True
    restore_mode: str = "eager"
    critical_states: Optional[Tuple[str, ...]] = None
    pack_format: int = 2
    io_threads: int = 0
    chunk_mb: int = 4
    stripes: int = 2
    capture: str = "sync"

    def __post_init__(self):
        if isinstance(self.critical_states, (list, set)):
            # frozen dataclass: normalize to a hashable tuple in place
            object.__setattr__(self, "critical_states",
                               tuple(self.critical_states))
        self.validate()

    # ------------------------------------------------------------ checks
    def validate(self) -> None:
        if self.mode not in _MODES:
            raise OptionsError(f"mode must be one of {_MODES}, "
                               f"got {self.mode!r}")
        if not isinstance(self.keep, int) or self.keep < 0:
            raise OptionsError(f"keep must be an int >= 0, got {self.keep!r}")
        if self.lock_timeout_s <= 0:
            raise OptionsError("lock_timeout_s must be > 0, "
                               f"got {self.lock_timeout_s!r}")
        if not isinstance(self.restore_threads, int) or \
                self.restore_threads < 0:
            raise OptionsError("restore_threads must be an int >= 0, "
                               f"got {self.restore_threads!r}")
        if self.replicate_to is not None and not self.replicate_to:
            raise OptionsError("replicate_to must be a path or None")
        if self.transfer not in _TRANSFERS:
            raise OptionsError(f"transfer must be one of {_TRANSFERS}, "
                               f"got {self.transfer!r}")
        if not isinstance(self.transfer_workers, int) or \
                self.transfer_workers < 0:
            raise OptionsError("transfer_workers must be an int >= 0, "
                               f"got {self.transfer_workers!r}")
        if self.restore_mode not in _RESTORE_MODES:
            raise OptionsError(f"restore_mode must be one of "
                               f"{_RESTORE_MODES}, got {self.restore_mode!r}")
        if self.critical_states is not None:
            if (not isinstance(self.critical_states, tuple)
                    or not all(isinstance(s, str) and s
                               for s in self.critical_states)):
                raise OptionsError(
                    "critical_states must be a tuple of non-empty "
                    "'state' or 'state/path-prefix' specs, "
                    f"got {self.critical_states!r}")
        if self.pack_format not in (1, 2):
            raise OptionsError(f"pack_format must be 1 or 2, "
                               f"got {self.pack_format!r}")
        if not isinstance(self.io_threads, int) or self.io_threads < 0:
            raise OptionsError("io_threads must be an int >= 0, "
                               f"got {self.io_threads!r}")
        if not isinstance(self.chunk_mb, int) or self.chunk_mb < 1:
            raise OptionsError("chunk_mb must be an int >= 1, "
                               f"got {self.chunk_mb!r}")
        if not isinstance(self.stripes, int) or not 1 <= self.stripes <= 64:
            raise OptionsError("stripes must be an int in [1, 64], "
                               f"got {self.stripes!r}")
        if self.capture not in _CAPTURES:
            raise OptionsError(f"capture must be one of {_CAPTURES}, "
                               f"got {self.capture!r}")
        # reject conflicting combinations up front, not mid-dump
        if self.capture == "concurrent":
            if self.pack_format != 2:
                raise OptionsError(
                    "capture='concurrent' requires pack_format=2: "
                    "speculation is validated against pack v2's "
                    "per-chunk raw_crc32 content hashes, which v1 "
                    "packs do not record")
            if not self.incremental:
                raise OptionsError(
                    "capture='concurrent' requires incremental=True: "
                    "re-capturing invalidated shards reuses the "
                    "incremental chunk-dedup path to patch the open "
                    "stripe set")
            if self.mode == "async":
                raise OptionsError(
                    "capture='concurrent' is incompatible with "
                    "mode='async': the speculative capture already "
                    "overlaps the step loop, and the final validate "
                    "pause must observe the committed bytes")

    def replace(self, **changes) -> "CheckpointOptions":
        return dataclasses.replace(self, **changes)

    def effective_io_threads(self) -> int:
        """io_threads with 0 resolved against this host's CPU count."""
        return self.io_threads or auto_io_threads()

    # ------------------------------------------------------------ env i/o
    @classmethod
    def from_env(cls, env: Optional[Dict[str, str]] = None
                 ) -> "CheckpointOptions":
        """Build options from REPRO_CKPT_* variables (missing = default)."""
        env = os.environ if env is None else env

        def get(name, conv, default):
            raw = env.get(_ENV_PREFIX + name)
            if raw is None:
                return default
            return conv(raw)

        def as_bool(raw: str) -> bool:
            return raw.strip().lower() in ("1", "true", "yes", "on")

        def as_specs(raw: str) -> Optional[Tuple[str, ...]]:
            specs = tuple(s.strip() for s in raw.split(",") if s.strip())
            return specs or None

        return cls(
            mode=get("MODE", str, cls.mode),
            incremental=get("INCREMENTAL", as_bool, cls.incremental),
            compress=get("COMPRESS", as_bool, cls.compress),
            keep=get("KEEP", int, cls.keep),
            lock_timeout_s=get("LOCK_TIMEOUT_S", float, cls.lock_timeout_s),
            restore_threads=get("RESTORE_THREADS", int, cls.restore_threads),
            replicate_to=get("REPLICATE_TO", str, cls.replicate_to),
            transfer=get("TRANSFER", str, cls.transfer),
            transfer_workers=get("TRANSFER_WORKERS", int,
                                 cls.transfer_workers),
            verify_restore=get("VERIFY_RESTORE", as_bool, cls.verify_restore),
            restore_mode=get("RESTORE_MODE", str, cls.restore_mode),
            critical_states=get("CRITICAL_STATES", as_specs,
                                cls.critical_states),
            pack_format=get("PACK_FORMAT", int, cls.pack_format),
            io_threads=get("IO_THREADS", int, cls.io_threads),
            chunk_mb=get("CHUNK_MB", int, cls.chunk_mb),
            stripes=get("STRIPES", int, cls.stripes),
            capture=get("CAPTURE", str, cls.capture),
        )

    def to_env(self) -> Dict[str, str]:
        """Inverse of from_env: CheckpointOptions.from_env(o.to_env()) == o."""
        out = {
            _ENV_PREFIX + "MODE": self.mode,
            _ENV_PREFIX + "INCREMENTAL": "1" if self.incremental else "0",
            _ENV_PREFIX + "COMPRESS": "1" if self.compress else "0",
            _ENV_PREFIX + "KEEP": str(self.keep),
            _ENV_PREFIX + "LOCK_TIMEOUT_S": repr(self.lock_timeout_s),
            _ENV_PREFIX + "RESTORE_THREADS": str(self.restore_threads),
            _ENV_PREFIX + "TRANSFER": self.transfer,
            _ENV_PREFIX + "TRANSFER_WORKERS": str(self.transfer_workers),
            _ENV_PREFIX + "VERIFY_RESTORE": "1" if self.verify_restore
            else "0",
            _ENV_PREFIX + "RESTORE_MODE": self.restore_mode,
            _ENV_PREFIX + "PACK_FORMAT": str(self.pack_format),
            _ENV_PREFIX + "IO_THREADS": str(self.io_threads),
            _ENV_PREFIX + "CHUNK_MB": str(self.chunk_mb),
            _ENV_PREFIX + "STRIPES": str(self.stripes),
            _ENV_PREFIX + "CAPTURE": self.capture,
        }
        if self.replicate_to is not None:
            out[_ENV_PREFIX + "REPLICATE_TO"] = self.replicate_to
        if self.critical_states is not None:
            out[_ENV_PREFIX + "CRITICAL_STATES"] = ",".join(
                self.critical_states)
        return out

    def to_dict(self) -> Dict[str, object]:
        return dataclasses.asdict(self)
