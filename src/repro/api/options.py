"""CheckpointOptions — the declarative `criu_set_*` analogue.

CRIU's libcriu configures a dump/restore with ``criu_set_*`` calls before
the operation runs; everything about *how* a checkpoint is taken lives in
one options object, not scattered across call sites.  This is our
equivalent: a frozen dataclass carrying every knob the engine understands,
validated at construction, round-trippable through the environment (so
schedulers can configure checkpointing without touching code).

Deliberately dependency-free: importable from the CLI, tests, and config
tooling without pulling in jax.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Dict, Optional

_MODES = ("sync", "async")

# env-var names, one per field (the `criu_set_*` <-> CRIU_* convention)
_ENV_PREFIX = "REPRO_CKPT_"


class OptionsError(ValueError):
    """An invalid CheckpointOptions field combination."""


@dataclasses.dataclass(frozen=True)
class CheckpointOptions:
    """Declarative checkpoint configuration.

    mode             "sync" (paper-faithful: frozen through dump+write) or
                     "async" (resume after device capture, write in
                     background — CheckFreq-style).
    incremental      delta images: unchanged entries point at the parent
                     snapshot's pack (Check-N-Run-style).
    compress         per-entry compression in the pack files.
    keep             GC: retain the newest N images (0 = keep all); parent
                     chains of kept images are never broken.
    lock_timeout_s   device-lock acquisition deadline; on timeout the dump
                     aborts and the job keeps running (paper §3.1.1).
    restore_threads  parallel pack-entry loads on restore (>1 enables the
                     on-demand-parallelism optimization).
    replicate_to     peer directory for snapshot replication (Gemini-style);
                     None disables.
    verify_restore   CRC-verify images before restoring from them (both the
                     newest-valid scan and explicitly requested steps).
    """

    mode: str = "sync"
    incremental: bool = False
    compress: bool = False
    keep: int = 0
    lock_timeout_s: float = 10.0
    restore_threads: int = 0
    replicate_to: Optional[str] = None
    verify_restore: bool = True

    def __post_init__(self):
        self.validate()

    # ------------------------------------------------------------ checks
    def validate(self) -> None:
        if self.mode not in _MODES:
            raise OptionsError(f"mode must be one of {_MODES}, "
                               f"got {self.mode!r}")
        if not isinstance(self.keep, int) or self.keep < 0:
            raise OptionsError(f"keep must be an int >= 0, got {self.keep!r}")
        if self.lock_timeout_s <= 0:
            raise OptionsError("lock_timeout_s must be > 0, "
                               f"got {self.lock_timeout_s!r}")
        if not isinstance(self.restore_threads, int) or \
                self.restore_threads < 0:
            raise OptionsError("restore_threads must be an int >= 0, "
                               f"got {self.restore_threads!r}")
        if self.replicate_to is not None and not self.replicate_to:
            raise OptionsError("replicate_to must be a path or None")

    def replace(self, **changes) -> "CheckpointOptions":
        return dataclasses.replace(self, **changes)

    # ------------------------------------------------------------ env i/o
    @classmethod
    def from_env(cls, env: Optional[Dict[str, str]] = None
                 ) -> "CheckpointOptions":
        """Build options from REPRO_CKPT_* variables (missing = default)."""
        env = os.environ if env is None else env

        def get(name, conv, default):
            raw = env.get(_ENV_PREFIX + name)
            if raw is None:
                return default
            return conv(raw)

        def as_bool(raw: str) -> bool:
            return raw.strip().lower() in ("1", "true", "yes", "on")

        return cls(
            mode=get("MODE", str, cls.mode),
            incremental=get("INCREMENTAL", as_bool, cls.incremental),
            compress=get("COMPRESS", as_bool, cls.compress),
            keep=get("KEEP", int, cls.keep),
            lock_timeout_s=get("LOCK_TIMEOUT_S", float, cls.lock_timeout_s),
            restore_threads=get("RESTORE_THREADS", int, cls.restore_threads),
            replicate_to=get("REPLICATE_TO", str, cls.replicate_to),
            verify_restore=get("VERIFY_RESTORE", as_bool, cls.verify_restore),
        )

    def to_env(self) -> Dict[str, str]:
        """Inverse of from_env: CheckpointOptions.from_env(o.to_env()) == o."""
        out = {
            _ENV_PREFIX + "MODE": self.mode,
            _ENV_PREFIX + "INCREMENTAL": "1" if self.incremental else "0",
            _ENV_PREFIX + "COMPRESS": "1" if self.compress else "0",
            _ENV_PREFIX + "KEEP": str(self.keep),
            _ENV_PREFIX + "LOCK_TIMEOUT_S": repr(self.lock_timeout_s),
            _ENV_PREFIX + "RESTORE_THREADS": str(self.restore_threads),
            _ENV_PREFIX + "VERIFY_RESTORE": "1" if self.verify_restore
            else "0",
        }
        if self.replicate_to is not None:
            out[_ENV_PREFIX + "REPLICATE_TO"] = self.replicate_to
        return out

    def to_dict(self) -> Dict[str, object]:
        return dataclasses.asdict(self)
