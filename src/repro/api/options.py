"""CheckpointOptions — the declarative `criu_set_*` analogue.

CRIU's libcriu configures a dump/restore with ``criu_set_*`` calls before
the operation runs; everything about *how* a checkpoint is taken lives in
one options object, not scattered across call sites.  This is our
equivalent: a frozen dataclass carrying every knob the engine understands,
validated at construction, round-trippable through the environment (so
schedulers can configure checkpointing without touching code).

Deliberately dependency-free: importable from the CLI, tests, and config
tooling without pulling in jax.
"""
from __future__ import annotations

import dataclasses
import os
import warnings
from typing import Dict, Optional, Tuple

_MODES = ("sync", "async")
_TRANSFERS = ("copy", "delta")
_RESTORE_MODES = ("eager", "lazy")
_CAPTURES = ("sync", "concurrent")

# env-var names, one per field (the `criu_set_*` <-> CRIU_* convention)
_ENV_PREFIX = "REPRO_CKPT_"

# deprecation warnings fire once per process, keyed by what was deprecated
_WARNED: set = set()


def _warn_once(key: str, message: str) -> None:
    if key in _WARNED:
        return
    _WARNED.add(key)
    warnings.warn(message, DeprecationWarning, stacklevel=3)


class OptionsError(ValueError):
    """An invalid CheckpointOptions field combination."""


def auto_io_threads() -> int:
    """The io_threads=0 auto-sizing policy — the single source of truth
    for every data-plane consumer (engine, snapshot writer, CLI)."""
    return min(8, max(2, os.cpu_count() or 2))


@dataclasses.dataclass(frozen=True)
class TransferPolicy:
    """How snapshot bytes reach a peer — the structured replacement for
    the stringly ``transfer=`` / ``transfer_workers=`` knobs.

    mode                "copy" (whole files, skipped when size+mtime
                        match) or "delta" (content-addressed: only chunks
                        missing from the peer's CAS ship — the cross-host
                        migration path).
    workers             parallel chunk-ship lanes for delta transfer;
                        0 = auto-size like io_threads.
    precopy_rounds      iterative pre-copy live migration: the maximum
                        number of delta rounds pushed while the job keeps
                        stepping before the residual freeze.  0 disables
                        pre-copy (stop-and-copy, the pre-PR-9 behavior);
                        > 0 requires mode="delta" (rounds are diffed via
                        pack v2's per-chunk raw-CRC content hashes in the
                        destination CAS).
    max_blackout_ms     blackout budget: the convergence controller
                        freezes for the residual round only once the
                        predicted residual-push wall fits this budget
                        (or a cap trips and it falls back to
                        stop-and-copy).  None = freeze as soon as a
                        round ships zero new bytes or stops shrinking.
    residual_bytes_cap  fallback trip-wire: when the cumulative pre-copy
                        bytes exceed this cap the controller gives up on
                        convergence and falls back to stop-and-copy.
                        None = no byte cap (round cap still applies).
    """

    mode: str = "copy"
    workers: int = 0
    precopy_rounds: int = 0
    max_blackout_ms: Optional[float] = None
    residual_bytes_cap: Optional[int] = None

    def __post_init__(self):
        self.validate()

    def validate(self) -> None:
        if self.mode not in _TRANSFERS:
            raise OptionsError(f"TransferPolicy.mode must be one of "
                               f"{_TRANSFERS}, got {self.mode!r}")
        if not isinstance(self.workers, int) or self.workers < 0:
            raise OptionsError("TransferPolicy.workers must be an int "
                               f">= 0, got {self.workers!r}")
        if not isinstance(self.precopy_rounds, int) or \
                self.precopy_rounds < 0:
            raise OptionsError("TransferPolicy.precopy_rounds must be an "
                               f"int >= 0, got {self.precopy_rounds!r}")
        if self.precopy_rounds > 0 and self.mode != "delta":
            raise OptionsError(
                "TransferPolicy.precopy_rounds > 0 requires mode='delta': "
                "pre-copy rounds diff against the destination CAS via "
                "pack v2 content hashes, which a raw copy does not have")
        if self.max_blackout_ms is not None:
            if not isinstance(self.max_blackout_ms, (int, float)) or \
                    self.max_blackout_ms <= 0:
                raise OptionsError(
                    "TransferPolicy.max_blackout_ms must be a number > 0 "
                    f"or None, got {self.max_blackout_ms!r}")
            if self.precopy_rounds == 0:
                raise OptionsError(
                    "TransferPolicy.max_blackout_ms needs pre-copy rounds "
                    "to converge within: set precopy_rounds > 0")
        if self.residual_bytes_cap is not None:
            if not isinstance(self.residual_bytes_cap, int) or \
                    self.residual_bytes_cap <= 0:
                raise OptionsError(
                    "TransferPolicy.residual_bytes_cap must be an int > 0 "
                    f"or None, got {self.residual_bytes_cap!r}")
            if self.precopy_rounds == 0:
                raise OptionsError(
                    "TransferPolicy.residual_bytes_cap only bounds "
                    "pre-copy rounds: set precopy_rounds > 0")

    @property
    def precopy_enabled(self) -> bool:
        return self.mode == "delta" and self.precopy_rounds > 0

    def replace(self, **changes) -> "TransferPolicy":
        return dataclasses.replace(self, **changes)

    # ---------------------------------------------------------- spec i/o
    # one compact "k=v,k=v" string so the whole policy rides in a single
    # REPRO_CKPT_TRANSFER_POLICY variable (None fields omitted)
    def to_spec(self) -> str:
        parts = [f"mode={self.mode}", f"workers={self.workers}",
                 f"precopy_rounds={self.precopy_rounds}"]
        if self.max_blackout_ms is not None:
            parts.append(f"max_blackout_ms={self.max_blackout_ms!r}")
        if self.residual_bytes_cap is not None:
            parts.append(f"residual_bytes_cap={self.residual_bytes_cap}")
        return ",".join(parts)

    @classmethod
    def from_spec(cls, spec: str) -> "TransferPolicy":
        convs = {"mode": str, "workers": int, "precopy_rounds": int,
                 "max_blackout_ms": float, "residual_bytes_cap": int}
        kwargs = {}
        for part in spec.split(","):
            part = part.strip()
            if not part:
                continue
            if "=" not in part:
                raise OptionsError(
                    f"TransferPolicy spec parts must be k=v, got {part!r} "
                    f"in {spec!r}")
            k, v = part.split("=", 1)
            k = k.strip()
            if k not in convs:
                raise OptionsError(
                    f"unknown TransferPolicy spec key {k!r} in {spec!r}")
            try:
                kwargs[k] = convs[k](v.strip())
            except ValueError as e:
                raise OptionsError(
                    f"bad TransferPolicy spec value for {k}: {e}") from e
        return cls(**kwargs)

    def to_dict(self) -> Dict[str, object]:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class CheckpointOptions:
    """Declarative checkpoint configuration.

    mode             "sync" (paper-faithful: frozen through dump+write) or
                     "async" (resume after device capture, write in
                     background — CheckFreq-style).
    incremental      delta images: unchanged entries point at the parent
                     snapshot's pack (Check-N-Run-style).
    compress         per-entry compression in the pack files.
    keep             GC: retain the newest N images (0 = keep all); parent
                     chains of kept images are never broken.
    lock_timeout_s   device-lock acquisition deadline; on timeout the dump
                     aborts and the job keeps running (paper §3.1.1).
    restore_threads  parallel pack-entry loads on restore (>1 enables the
                     on-demand-parallelism optimization).
    replicate_to     peer directory for snapshot replication (Gemini-style);
                     None disables.
    transfer         DEPRECATED legacy spelling of transfer_policy.mode;
                     accepted (with a one-time DeprecationWarning) and
                     mirrored from the resolved policy so old readers
                     keep working.  Pass transfer_policy instead.
    transfer_workers DEPRECATED legacy spelling of
                     transfer_policy.workers; same shim as transfer.
    transfer_policy  structured TransferPolicy (mode / workers /
                     precopy_rounds / max_blackout_ms /
                     residual_bytes_cap) governing how bytes reach the
                     replication peer and whether migration pre-copies
                     live rounds before the residual freeze.  None =
                     default policy (copy, stop-and-copy), or whatever
                     the legacy kwargs map to.
    verify_restore   CRC-verify images before restoring from them (both the
                     newest-valid scan and explicitly requested steps).
    restore_mode     "eager" (default: the whole image is materialized
                     before restore() returns) or "lazy" (resume-before-
                     read: restore() returns once the critical set is
                     placed; a background LazyMaterializer streams the
                     remaining entries, joined via restore_barrier()).
    critical_states  which entries form the lazy critical set.  Each spec
                     is "state" (every entry of that state) or
                     "state/path-prefix" (a subtree, e.g.
                     "train_state/params").  None = the first state in
                     the image's recorded restore order.
    pack_format      2 (default): chunked/striped packs written by the
                     pipelined data plane; 1: serial-compat single-file
                     packs, byte-compatible with images from older code.
    io_threads       data-plane worker threads (compress/CRC on dump,
                     chunk read/decompress on restore); 0 = auto-size
                     from the host's CPU count.
    chunk_mb         pack-v2 chunk size in MiB (per-chunk CRC doubles as
                     the incremental content hash).
    stripes          pack files per host; each stripe gets its own
                     appender thread, so writes overlap compression.
    capture          "sync" (default: the job stays frozen for the whole
                     device capture) or "concurrent" (soft-freeze:
                     a brief pin pause, then shards are speculated to
                     disk while the step loop keeps running; a short
                     final validate pause re-hashes dirtied entries
                     against pack v2's per-chunk content hashes and
                     re-captures only the invalidated ones —
                     PhoenixOS-style validated speculation).  Requires
                     pack_format=2, incremental=True, and a backend
                     with the "dirty_tracking" feature; incompatible
                     with mode="async" (the validate pause already
                     overlaps the write).
    """

    mode: str = "sync"
    incremental: bool = False
    compress: bool = False
    keep: int = 0
    lock_timeout_s: float = 10.0
    restore_threads: int = 0
    replicate_to: Optional[str] = None
    transfer: Optional[str] = None
    transfer_workers: Optional[int] = None
    transfer_policy: Optional[TransferPolicy] = None
    verify_restore: bool = True
    restore_mode: str = "eager"
    critical_states: Optional[Tuple[str, ...]] = None
    pack_format: int = 2
    io_threads: int = 0
    chunk_mb: int = 4
    stripes: int = 2
    capture: str = "sync"

    def __post_init__(self):
        if isinstance(self.critical_states, (list, set)):
            # frozen dataclass: normalize to a hashable tuple in place
            object.__setattr__(self, "critical_states",
                               tuple(self.critical_states))
        self._resolve_transfer_policy()
        self.validate()

    def _resolve_transfer_policy(self) -> None:
        """Fold the deprecated transfer/transfer_workers kwargs into
        transfer_policy, then mirror the policy back onto them so legacy
        readers (and dataclass equality across old/new spellings) keep
        working."""
        policy = self.transfer_policy
        if policy is None:
            legacy = {}
            if self.transfer is not None:
                legacy["mode"] = self.transfer
            if self.transfer_workers is not None:
                legacy["workers"] = self.transfer_workers
            if legacy:
                _warn_once(
                    "options.transfer-kwargs",
                    "CheckpointOptions(transfer=..., transfer_workers=...) "
                    "is deprecated; pass "
                    "transfer_policy=TransferPolicy(mode=..., workers=...) "
                    "instead")
            policy = TransferPolicy(**legacy)
        else:
            if not isinstance(policy, TransferPolicy):
                raise OptionsError(
                    "transfer_policy must be a TransferPolicy or None, "
                    f"got {policy!r}")
            if self.transfer is not None and self.transfer != policy.mode:
                raise OptionsError(
                    f"conflicting transfer settings: legacy "
                    f"transfer={self.transfer!r} vs "
                    f"transfer_policy.mode={policy.mode!r} — drop the "
                    f"legacy kwarg")
            if self.transfer_workers is not None and \
                    self.transfer_workers != policy.workers:
                raise OptionsError(
                    f"conflicting transfer settings: legacy "
                    f"transfer_workers={self.transfer_workers!r} vs "
                    f"transfer_policy.workers={policy.workers!r} — drop "
                    f"the legacy kwarg")
        object.__setattr__(self, "transfer_policy", policy)
        object.__setattr__(self, "transfer", policy.mode)
        object.__setattr__(self, "transfer_workers", policy.workers)

    # ------------------------------------------------------------ checks
    def validate(self) -> None:
        if self.mode not in _MODES:
            raise OptionsError(f"mode must be one of {_MODES}, "
                               f"got {self.mode!r}")
        if not isinstance(self.keep, int) or self.keep < 0:
            raise OptionsError(f"keep must be an int >= 0, got {self.keep!r}")
        if self.lock_timeout_s <= 0:
            raise OptionsError("lock_timeout_s must be > 0, "
                               f"got {self.lock_timeout_s!r}")
        if not isinstance(self.restore_threads, int) or \
                self.restore_threads < 0:
            raise OptionsError("restore_threads must be an int >= 0, "
                               f"got {self.restore_threads!r}")
        if self.replicate_to is not None and not self.replicate_to:
            raise OptionsError("replicate_to must be a path or None")
        # transfer/transfer_workers are mirrors of transfer_policy by the
        # time validate() runs; the policy validates itself
        if self.transfer_policy is not None:
            self.transfer_policy.validate()
        if self.restore_mode not in _RESTORE_MODES:
            raise OptionsError(f"restore_mode must be one of "
                               f"{_RESTORE_MODES}, got {self.restore_mode!r}")
        if self.critical_states is not None:
            if (not isinstance(self.critical_states, tuple)
                    or not all(isinstance(s, str) and s
                               for s in self.critical_states)):
                raise OptionsError(
                    "critical_states must be a tuple of non-empty "
                    "'state' or 'state/path-prefix' specs, "
                    f"got {self.critical_states!r}")
        if self.pack_format not in (1, 2):
            raise OptionsError(f"pack_format must be 1 or 2, "
                               f"got {self.pack_format!r}")
        if not isinstance(self.io_threads, int) or self.io_threads < 0:
            raise OptionsError("io_threads must be an int >= 0, "
                               f"got {self.io_threads!r}")
        if not isinstance(self.chunk_mb, int) or self.chunk_mb < 1:
            raise OptionsError("chunk_mb must be an int >= 1, "
                               f"got {self.chunk_mb!r}")
        if not isinstance(self.stripes, int) or not 1 <= self.stripes <= 64:
            raise OptionsError("stripes must be an int in [1, 64], "
                               f"got {self.stripes!r}")
        if self.capture not in _CAPTURES:
            raise OptionsError(f"capture must be one of {_CAPTURES}, "
                               f"got {self.capture!r}")
        # reject conflicting combinations up front, not mid-dump
        if self.capture == "concurrent":
            if self.pack_format != 2:
                raise OptionsError(
                    "capture='concurrent' requires pack_format=2: "
                    "speculation is validated against pack v2's "
                    "per-chunk raw_crc32 content hashes, which v1 "
                    "packs do not record")
            if not self.incremental:
                raise OptionsError(
                    "capture='concurrent' requires incremental=True: "
                    "re-capturing invalidated shards reuses the "
                    "incremental chunk-dedup path to patch the open "
                    "stripe set")
            if self.mode == "async":
                raise OptionsError(
                    "capture='concurrent' is incompatible with "
                    "mode='async': the speculative capture already "
                    "overlaps the step loop, and the final validate "
                    "pause must observe the committed bytes")

    def replace(self, **changes) -> "CheckpointOptions":
        if "transfer_policy" in changes:
            # a new policy wins outright; drop the mirrored legacy fields
            # so _resolve_transfer_policy doesn't see a stale conflict
            changes.setdefault("transfer", None)
            changes.setdefault("transfer_workers", None)
        elif "transfer" in changes or "transfer_workers" in changes:
            # legacy-field replace: fold into the current policy so the
            # other policy knobs (precopy_rounds, budgets) survive
            pol_changes = {}
            if "transfer" in changes:
                pol_changes["mode"] = changes["transfer"]
            if "transfer_workers" in changes:
                pol_changes["workers"] = changes["transfer_workers"]
            changes["transfer_policy"] = \
                self.transfer_policy.replace(**pol_changes)
        return dataclasses.replace(self, **changes)

    def effective_io_threads(self) -> int:
        """io_threads with 0 resolved against this host's CPU count."""
        return self.io_threads or auto_io_threads()

    # ------------------------------------------------------------ env i/o
    @classmethod
    def from_env(cls, env: Optional[Dict[str, str]] = None
                 ) -> "CheckpointOptions":
        """Build options from REPRO_CKPT_* variables (missing = default)."""
        env = os.environ if env is None else env

        def get(name, conv, default):
            raw = env.get(_ENV_PREFIX + name)
            if raw is None:
                return default
            return conv(raw)

        def as_bool(raw: str) -> bool:
            return raw.strip().lower() in ("1", "true", "yes", "on")

        def as_specs(raw: str) -> Optional[Tuple[str, ...]]:
            specs = tuple(s.strip() for s in raw.split(",") if s.strip())
            return specs or None

        # the structured policy var wins; the legacy vars still map (with
        # a one-time DeprecationWarning) so old scheduler configs work
        policy = get("TRANSFER_POLICY", TransferPolicy.from_spec, None)
        legacy_mode = get("TRANSFER", str, None)
        legacy_workers = get("TRANSFER_WORKERS", int, None)
        if policy is not None:
            legacy_mode = legacy_workers = None
        elif legacy_mode is not None or legacy_workers is not None:
            _warn_once(
                "options.transfer-env",
                f"{_ENV_PREFIX}TRANSFER / {_ENV_PREFIX}TRANSFER_WORKERS "
                f"are deprecated; set {_ENV_PREFIX}TRANSFER_POLICY "
                f"(e.g. 'mode=delta,workers=2') instead")
            # fold into a policy here so the constructor's kwargs shim
            # doesn't fire a *second* deprecation for the same env vars
            legacy = {}
            if legacy_mode is not None:
                legacy["mode"] = legacy_mode
            if legacy_workers is not None:
                legacy["workers"] = legacy_workers
            policy = TransferPolicy(**legacy)
            legacy_mode = legacy_workers = None

        return cls(
            mode=get("MODE", str, cls.mode),
            incremental=get("INCREMENTAL", as_bool, cls.incremental),
            compress=get("COMPRESS", as_bool, cls.compress),
            keep=get("KEEP", int, cls.keep),
            lock_timeout_s=get("LOCK_TIMEOUT_S", float, cls.lock_timeout_s),
            restore_threads=get("RESTORE_THREADS", int, cls.restore_threads),
            replicate_to=get("REPLICATE_TO", str, cls.replicate_to),
            transfer=legacy_mode,
            transfer_workers=legacy_workers,
            transfer_policy=policy,
            verify_restore=get("VERIFY_RESTORE", as_bool, cls.verify_restore),
            restore_mode=get("RESTORE_MODE", str, cls.restore_mode),
            critical_states=get("CRITICAL_STATES", as_specs,
                                cls.critical_states),
            pack_format=get("PACK_FORMAT", int, cls.pack_format),
            io_threads=get("IO_THREADS", int, cls.io_threads),
            chunk_mb=get("CHUNK_MB", int, cls.chunk_mb),
            stripes=get("STRIPES", int, cls.stripes),
            capture=get("CAPTURE", str, cls.capture),
        )

    def to_env(self) -> Dict[str, str]:
        """Inverse of from_env: CheckpointOptions.from_env(o.to_env()) == o."""
        out = {
            _ENV_PREFIX + "MODE": self.mode,
            _ENV_PREFIX + "INCREMENTAL": "1" if self.incremental else "0",
            _ENV_PREFIX + "COMPRESS": "1" if self.compress else "0",
            _ENV_PREFIX + "KEEP": str(self.keep),
            _ENV_PREFIX + "LOCK_TIMEOUT_S": repr(self.lock_timeout_s),
            _ENV_PREFIX + "RESTORE_THREADS": str(self.restore_threads),
            _ENV_PREFIX + "TRANSFER_POLICY": self.transfer_policy.to_spec(),
            _ENV_PREFIX + "VERIFY_RESTORE": "1" if self.verify_restore
            else "0",
            _ENV_PREFIX + "RESTORE_MODE": self.restore_mode,
            _ENV_PREFIX + "PACK_FORMAT": str(self.pack_format),
            _ENV_PREFIX + "IO_THREADS": str(self.io_threads),
            _ENV_PREFIX + "CHUNK_MB": str(self.chunk_mb),
            _ENV_PREFIX + "STRIPES": str(self.stripes),
            _ENV_PREFIX + "CAPTURE": self.capture,
        }
        if self.replicate_to is not None:
            out[_ENV_PREFIX + "REPLICATE_TO"] = self.replicate_to
        if self.critical_states is not None:
            out[_ENV_PREFIX + "CRITICAL_STATES"] = ",".join(
                self.critical_states)
        return out

    def to_dict(self) -> Dict[str, object]:
        return dataclasses.asdict(self)
