"""CheckpointSession — the libcriu-style façade over the snapshot engine.

One object owns the whole checkpoint lifecycle the way a ``criu_*`` session
does: configured by a :class:`CheckpointOptions` (the ``criu_set_*``
analogue), preflighted with :meth:`check` (``criu check``), driven with
:meth:`checkpoint` / :meth:`restore` (``criu dump`` / ``criu restore``),
and inspectable via :meth:`capabilities`.  The engine, backend plugin, and
replicator wiring that callers used to hand-assemble from nine keyword
arguments live here.

The :meth:`frozen` context manager exposes the dump phases that
``SnapshotEngine.checkpoint`` runs privately::

    with session.frozen(step) as snap:      # ①–③ quiesce + capture done
        ...                                 # job is frozen; inspect snap
    # ④ on exit: write + commit + resume (abort on exception)
"""
from __future__ import annotations

import contextlib
from typing import Any, Callable, Dict, List, Optional

from repro.api.capabilities import CheckReport, capabilities, check
from repro.api.options import CheckpointOptions

PyTree = Any


class SnapshotWriteFailed(RuntimeError):
    """A background snapshot write failed.

    Raised by step loops that poll :attr:`CheckpointSession.write_error`
    (``Trainer.run_until`` / ``DecodeServer.decode_until``): the job must
    abort promptly instead of running on while believing its recent
    checkpoints committed."""


class FrozenCheckpoint:
    """Handle to a dump frozen between capture (①–③) and commit (④)."""

    def __init__(self, engine, ctx):
        self._engine = engine
        self._ctx = ctx
        self._done = False
        self.path: Optional[str] = None

    @property
    def step(self) -> int:
        return self._ctx.step

    @property
    def stats(self) -> Dict[str, float]:
        return self._ctx.stats

    @property
    def warnings(self) -> List[str]:
        return self._ctx.warnings

    def commit(self) -> str:
        """Phase ④: write + manifest-commit the capture, resume the job."""
        if self._done:
            raise RuntimeError("frozen checkpoint already finished")
        self._done = True
        self.path = self._engine.commit_dump(self._ctx)
        return self.path

    def abort(self) -> None:
        """Resume the job without writing an image."""
        if self._done:
            return
        self._done = True
        self._engine.abort_dump(self._ctx)


class CheckpointSession:
    """Owns engine construction + lifecycle for one run directory."""

    def __init__(self, run_dir: str,
                 options: Optional[CheckpointOptions] = None, *,
                 mesh=None,
                 plugins: Optional[List[Any]] = None,
                 replicator=None,
                 backend: str = "jax",
                 planner=None):
        from repro.core.engine import SnapshotEngine
        self.run_dir = run_dir
        self.options = options if options is not None else CheckpointOptions()
        self.backend_name = backend
        self.engine = SnapshotEngine(run_dir, plugins=plugins,
                                     options=self.options, mesh=mesh,
                                     replicator=replicator, backend=backend)
        self._planner = planner

    # ------------------------------------------------------- constructors
    @classmethod
    def from_env(cls, run_dir: str, **kwargs) -> "CheckpointSession":
        """Session configured from REPRO_CKPT_* environment variables."""
        return cls(run_dir, CheckpointOptions.from_env(), **kwargs)

    @classmethod
    def from_engine(cls, engine) -> "CheckpointSession":
        """Wrap an already-built SnapshotEngine (migration aid)."""
        self = cls.__new__(cls)
        self.run_dir = engine.run_dir
        self.options = engine.options
        # registry name stamped by create_backend ("jax"/"host"), not the
        # plugin's own .name ("device")
        self.backend_name = getattr(engine.device_plugin, "backend_name",
                                    "jax")
        self.engine = engine
        self._planner = None
        return self

    # ------------------------------------------------------- preflight
    def capabilities(self) -> Dict[str, Any]:
        caps = capabilities()
        caps["session"] = {
            "run_dir": self.run_dir,
            "backend": self.backend_name,
            "options": self.options.to_dict(),
            "plugins": [p.name for p in self.engine.registry.plugins],
            "plugin_features": sorted(self.engine.registry.features()),
        }
        return caps

    def check(self) -> CheckReport:
        """`criu check` for this session's run_dir + options + backend."""
        return check(run_dir=self.run_dir, options=self.options)

    # ------------------------------------------------------- wiring
    def attach(self, provider: Callable[[], Dict[str, PyTree]]) -> None:
        self.engine.attach(provider)

    def register_host_state(self, name: str, getter: Callable[[], Any],
                            setter: Callable[[Any], None]) -> None:
        self.engine.register_host_state(name, getter, setter)

    def add_plugin(self, plugin) -> None:
        self.engine.add_plugin(plugin)

    def set_planner(self, planner) -> None:
        """Attach an :class:`repro.runtime.interval.IntervalPlanner`: every
        dump's measured frozen-window cost (``engine.last_stats``) is fed
        into ``planner.observe(...)`` automatically, so τ* adapts to the
        engine actually in use without callers hand-wiring stats."""
        self._planner = planner

    def _feed_planner(self) -> None:
        if self._planner is not None and self.engine.last_stats:
            self._planner.observe(self.engine.last_stats)

    # ------------------------------------------------------- lifecycle
    def checkpoint(self, step: int) -> str:
        path = self.engine.checkpoint(step)
        self._feed_planner()
        return path

    def checkpoint_running(self, step: int) -> str:
        """Commit a snapshot while minimizing the pause the job observes
        — the capture each pre-copy migration round rides on.  Under
        ``capture="concurrent"`` the job is only paused for the pin +
        validate windows; otherwise this is an ordinary checkpoint."""
        path = self.engine.snapshot_while_running(step)
        self._feed_planner()
        return path

    def checkpoint_begin(self, step: int):
        """Start a soft-freeze capture (requires
        ``CheckpointOptions(capture="concurrent")``) and return its
        :class:`repro.core.engine.ConcurrentCapture` handle.  The job
        keeps stepping while speculation runs; poll
        ``handle.speculation_done`` and call :meth:`checkpoint_finalize`
        (or ``handle.finalize()``) for the short validate pause."""
        return self.engine.begin_concurrent(step)

    def checkpoint_finalize(self) -> Optional[str]:
        """Finalize the in-flight soft-freeze capture, if any.  Returns
        the snapshot path, or None when nothing was in flight."""
        handle = self.engine.concurrent_capture
        if handle is None:
            return None
        path = handle.finalize()
        self._feed_planner()
        return path

    @property
    def concurrent_capture(self):
        return self.engine.concurrent_capture

    @contextlib.contextmanager
    def frozen(self, step: int):
        """Freeze, yield the in-memory capture, commit (or abort) on exit.

        The body runs with the job quiesced and the image captured in host
        memory: inspect ``snap.stats``/``snap.warnings``, decide to
        ``snap.abort()``, or call ``snap.commit()`` early to time the
        write yourself.  An exception in the body aborts the dump (the
        job resumes; no image is written) and propagates.  In async mode
        the commit follows ``checkpoint()``'s contract: the write lands
        in the background and is drained by ``wait_pending()`` / session
        exit.
        """
        snap = FrozenCheckpoint(self.engine, self.engine.freeze(step))
        try:
            yield snap
        except BaseException:
            snap.abort()
            raise
        else:
            if not snap._done:
                snap.commit()
            if snap.path is not None:          # committed (not aborted)
                self._feed_planner()

    def restore(self, step: Optional[int] = None, mesh=None,
                shardings: Optional[Dict[str, Any]] = None,
                verify: Optional[bool] = None,
                wait: Optional[str] = None) -> Dict[str, Any]:
        """`criu restore`.  ``wait="critical"`` (the default when
        ``options.restore_mode == "lazy"``) returns as soon as the
        critical set is placed — the job resumes while the rest of the
        image streams in the background; join it with
        :meth:`restore_barrier`.  ``wait="all"`` blocks until the whole
        image is materialized."""
        return self.engine.restore(step=step, mesh=mesh,
                                   shardings=shardings, verify=verify,
                                   wait=wait)

    def restore_into(self, template: PyTree, state: str = "train_state",
                     step: Optional[int] = None, mesh=None,
                     shardings: Optional[PyTree] = None,
                     wait: Optional[str] = None) -> PyTree:
        return self.engine.restore_into(template, state=state, step=step,
                                        mesh=mesh, shardings=shardings,
                                        wait=wait)

    def restore_barrier(self) -> Optional[Dict[str, Any]]:
        """Join the background restore stream (no-op after eager
        restores): blocks until every lazily-scheduled entry has landed
        and returns the complete restored tree.  Raises
        :class:`repro.core.lazy.LazyRestoreError` if the stream died; the
        step is quarantined and a retried :meth:`restore` falls back to
        an eager restore of the previous committed image."""
        return self.engine.restore_barrier()

    @property
    def lazy_pending(self) -> bool:
        """True while a background restore stream is still outstanding."""
        return self.engine.lazy_pending

    # ------------------------------------------------------- queries
    @property
    def store(self):
        return self.engine.store

    @property
    def last_stats(self) -> Dict[str, Any]:
        return self.engine.last_stats

    @property
    def write_error(self) -> Optional[str]:
        """repr of the most recent async write failure, or None.  A
        silently-failed background dump is visible here (and in
        ``last_stats['write_error']``) even before ``wait_pending()``
        re-raises it."""
        return self.engine.write_error

    @property
    def last_commit_step(self) -> Optional[int]:
        """Step of the newest image committed *by this session* (None
        until the first dump lands).  Unlike :meth:`latest_step`, a
        leftover on-disk image from a previous incarnation does not
        count — use this to decide whether re-dumping the current step
        would be redundant."""
        return self.engine.last_commit_step

    @property
    def frozen_window_s(self) -> Optional[float]:
        """Blocked-window cost of the last dump in seconds: how long the
        job was actually frozen (async: device→host copy only; sync: the
        full dump+write).  This is the δ that drives τ*."""
        from repro.runtime.interval import frozen_window_s
        return frozen_window_s(self.engine.last_stats)

    def latest_step(self) -> Optional[int]:
        return self.engine.latest_step()

    def wait_pending(self, timeout_s: Optional[float] = None) -> None:
        """Drain the async background writer.  With ``timeout_s`` a
        wedged writer raises
        :class:`repro.core.engine.PendingWriteStalled` instead of
        hanging forever."""
        self.engine.wait_pending(timeout_s)

    # session is a context manager: exiting drains async writers
    def __enter__(self) -> "CheckpointSession":
        return self

    def __exit__(self, *exc) -> None:
        self.wait_pending()
