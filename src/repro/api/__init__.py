"""repro.api — the stable checkpointing surface (paper §3.1).

    from repro.api import CheckpointOptions, CheckpointSession

    opts = CheckpointOptions(mode="async", incremental=True, keep=3)
    session = CheckpointSession(run_dir, opts, mesh=mesh)
    assert session.check().ok                 # `criu check`
    session.attach(lambda: {"train_state": state})
    session.checkpoint(step)                  # `criu dump`
    session.restore()                         # `criu restore`

Everything else (SnapshotEngine, plugins, backends) is mechanism; this
package is policy + lifecycle.  The image directories it produces are
operable offline via ``python -m repro`` (the CRIT analogue).
"""
from repro.api.options import (CheckpointOptions,  # noqa: F401
                               OptionsError, TransferPolicy)
from repro.api.capabilities import (CheckReport, capabilities,  # noqa: F401
                                    check)
from repro.api.session import (CheckpointSession,  # noqa: F401
                               FrozenCheckpoint, SnapshotWriteFailed)
from repro.core.engine import (ConcurrentCapture,  # noqa: F401
                               PendingWriteStalled)
