from repro.baselines.interception import InterceptionCheckpointer  # noqa: F401
