"""Cricket-style API-interception checkpointing baseline (paper §2).

State-of-the-art *semi-transparent* GPU checkpointing interposes a device
proxy between the application and the device API (LD_PRELOAD), then

  intercept → log → (at restore) replay

every device call.  JAX has no dynamically-linked device API to preload;
the faithful interposition point is the jitted-callable boundary — every
device-touching computation passes through it, exactly as every CUDA call
passes through Cricket's proxy.  Per intercepted call this layer does what
the proxy does:

  * flatten the argument pytree and record avals (the proxy records
    argument values/handles for replay);
  * copy host-resident inputs (the proxy's cudaMemcpyAsync→cudaMemcpy
    forwarding — synchronous H2D logging);
  * tag device-resident arguments by object identity (GPU pointers in the
    proxy's handle table);
  * append the record to the replay log.

The costs reproduce the paper's findings: per-call overhead on the critical
path that grows with iteration count (Fig. 2), a replay log whose length is
proportional to run time, and restore = re-execution of the whole log from
the last state snapshot (prolonged, non-deterministic-prone recovery).
"""
from __future__ import annotations

import os
import pickle
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

PyTree = Any


class InterceptionCheckpointer:
    def __init__(self, run_dir: Optional[str] = None,
                 snapshot_every: int = 0):
        self.run_dir = run_dir
        if run_dir:
            os.makedirs(run_dir, exist_ok=True)
        self.log: List[Dict[str, Any]] = []
        self._fns: Dict[str, Callable] = {}
        self._handles: Dict[int, str] = {}       # id(device arg) -> handle
        self._next_handle = 0
        self._results: Dict[str, Any] = {}       # handle -> live object
        self.initial_state: Optional[Dict[str, Any]] = None
        self.stats = {"intercepted_calls": 0, "logged_bytes": 0,
                      "intercept_s": 0.0}
        self.snapshot_every = snapshot_every

    # ------------------------------------------------------------ wiring
    def _handle_for(self, obj) -> str:
        key = id(obj)
        if key not in self._handles:
            h = f"h{self._next_handle}"
            self._next_handle += 1
            self._handles[key] = h
            self._results[h] = obj
        return self._handles[key]

    def register_initial_state(self, name: str, tree: PyTree) -> None:
        """The proxy snapshots device memory once; replay starts from it."""
        if self.initial_state is None:
            self.initial_state = {}
        self.initial_state[name] = tree
        for leaf in jax.tree.leaves(tree):
            if isinstance(leaf, jax.Array):
                self._handle_for(leaf)

    def wrap(self, fn: Callable, name: str) -> Callable:
        """Interpose on a device-touching callable."""
        self._fns[name] = fn

        def intercepted(*args, **kwargs):
            t0 = time.perf_counter()
            flat, treedef = jax.tree_util.tree_flatten((args, kwargs))
            rec_args = []
            logged = 0
            for leaf in flat:
                if isinstance(leaf, jax.Array):
                    rec_args.append(("dev", self._handle_for(leaf)))
                elif isinstance(leaf, np.ndarray):
                    # H2D transfer: the proxy logs the payload synchronously
                    buf = leaf.copy()
                    rec_args.append(("host", buf))
                    logged += buf.nbytes
                else:
                    rec_args.append(("py", leaf))
            rec = {"fn": name, "treedef": treedef, "args": rec_args}
            self.stats["intercept_s"] += time.perf_counter() - t0

            out = fn(*args, **kwargs)

            t1 = time.perf_counter()
            out_handles = []
            for leaf in jax.tree.leaves(out):
                if isinstance(leaf, jax.Array):
                    out_handles.append(self._handle_for(leaf))
            rec["out_handles"] = out_handles
            self.log.append(rec)
            self.stats["intercepted_calls"] += 1
            self.stats["logged_bytes"] += logged
            self.stats["intercept_s"] += time.perf_counter() - t1
            return out

        return intercepted

    # ------------------------------------------------------------ ckpt
    def checkpoint(self, step: int) -> str:
        """Persist initial state + replay log (the proxy's image)."""
        assert self.run_dir, "run_dir required for checkpoint()"
        t0 = time.perf_counter()
        path = os.path.join(self.run_dir, f"intercept_{step:08d}.pkl")
        init_np = jax.tree.map(
            lambda l: np.asarray(l) if isinstance(l, jax.Array) else l,
            self.initial_state)
        payload = {
            "initial_state": init_np,
            "log": [self._strip(rec) for rec in self.log],
            "step": step,
        }
        with open(path + ".tmp", "wb") as f:
            pickle.dump(payload, f, protocol=4)
        os.rename(path + ".tmp", path)
        self.stats["checkpoint_s"] = time.perf_counter() - t0
        return path

    @staticmethod
    def _strip(rec):
        return {"fn": rec["fn"], "treedef": rec["treedef"],
                "args": rec["args"], "out_handles": rec["out_handles"]}

    # ------------------------------------------------------------ restore
    def restore(self, path: str, fns: Dict[str, Callable],
                state_handle_map: Callable[[Dict[str, Any]], Dict[str, Any]]
                = None) -> Tuple[Dict[str, Any], Dict[str, Any]]:
        """Replay the log from the initial snapshot (the slow path the
        paper measures).  Returns (final handle table, stats)."""
        t0 = time.perf_counter()
        with open(path, "rb") as f:
            payload = pickle.load(f)
        state = jax.tree.map(jax.numpy.asarray, payload["initial_state"])

        # rebuild the handle table exactly as register+wrap would have
        results: Dict[str, Any] = {}
        next_h = 0
        for name, tree in state.items():
            for leaf in jax.tree.leaves(tree):
                if isinstance(leaf, jax.Array):
                    results[f"h{next_h}"] = leaf
                    next_h += 1

        replayed = 0
        for rec in payload["log"]:
            flat = []
            for kind, val in rec["args"]:
                if kind == "dev":
                    flat.append(results[val])
                elif kind == "host":
                    flat.append(val)
                else:
                    flat.append(val)
            args, kwargs = jax.tree_util.tree_unflatten(rec["treedef"], flat)
            out = fns[rec["fn"]](*args, **kwargs)
            out_flat = [l for l in jax.tree.leaves(out)
                        if isinstance(l, jax.Array)]
            for h, leaf in zip(rec["out_handles"], out_flat):
                results[h] = leaf
            replayed += 1
        jax.block_until_ready([v for v in results.values()
                               if isinstance(v, jax.Array)])
        stats = {"replayed_calls": replayed,
                 "restore_s": time.perf_counter() - t0,
                 "log_entries": len(payload["log"])}
        return results, stats
