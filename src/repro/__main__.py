"""Entry point: ``python -m repro <check|inspect|verify|gc|restore>``."""
import sys

from repro.cli import main

if __name__ == "__main__":
    sys.exit(main())
