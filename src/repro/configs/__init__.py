"""Architecture registry: one module per assigned architecture.

``get_config("phi3-medium-14b")`` returns the full published config;
``get_smoke_config(...)`` returns a reduced same-family variant for CPU tests.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.config import ModelConfig, reduced

# arch-id -> module name
_MODULES: Dict[str, str] = {
    "phi3-medium-14b": "phi3_medium_14b",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "h2o-danube-1.8b": "h2o_danube_1_8b",
    "qwen1.5-0.5b": "qwen1_5_0_5b",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
    "whisper-tiny": "whisper_tiny",
    "mamba2-2.7b": "mamba2_2_7b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "qwen2-vl-7b": "qwen2_vl_7b",
}

ARCH_IDS: List[str] = list(_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.CONFIG


def get_smoke_config(arch: str, **overrides) -> ModelConfig:
    return reduced(get_config(arch), **overrides)


def list_archs() -> List[str]:
    return list(ARCH_IDS)
