"""mamba2-2.7b [ssm] — arXiv:2405.21060 (SSD, state-space duality).

64L d_model=2560, attention-free, vocab=50280, ssm_state=128.
d_inner = 2*2560 = 5120, headdim=64 => 80 SSD heads.  long_500k runs.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    num_layers=64,
    d_model=2560,
    num_heads=0,
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,                    # pure SSM blocks, no MLP
    vocab_size=50280,
    layer_pattern=("mamba",),
    ssm_state=128,
    ssm_headdim=64,
    ssm_expand=2,
    ssm_chunk=128,
    tie_embeddings=True,
)
