"""qwen3-moe-30b-a3b [moe] — hf:Qwen/Qwen3-30B-A3B.

48L d_model=2048 32H (GQA kv=4, head_dim=128, q/k-norm) moe_d_ff=768
vocab=151936, MoE 128 experts top-8 on every layer.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=0,                    # every layer uses expert FFNs
    vocab_size=151936,
    layer_pattern=("attn",),
    qk_norm=True,
    moe_num_experts=128,
    moe_top_k=8,
    moe_d_ff=768,
    moe_layer_period=1,
    rope_theta=1000000.0,
)
