"""qwen2-vl-7b [vlm] — arXiv:2409.12191.

28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064; M-RoPE (3-component
temporal/height/width rotary positions), dynamic resolution.  The vision
frontend (ViT) is a STUB: input_specs() provides precomputed patch embeddings
of shape (batch, num_patches, d_model) plus 3-component position ids.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b",
    family="vlm",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    head_dim=128,
    d_ff=18944,
    vocab_size=152064,
    layer_pattern=("attn",),
    qkv_bias=True,
    mrope=True,
    vision_stub=True,
    num_patches=1024,
    rope_theta=1000000.0,
)
