"""jamba-v0.1-52b [hybrid] — arXiv:2403.19887.

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536; Mamba+attention with a
1:7 attn:mamba interleave (attention at position 4 of each 8-layer block) and
MoE (16 experts, top-2) every other layer.

Adaptation note (recorded in DESIGN.md): the Mamba layers use our Mamba2/SSD
block (state=16 as in Jamba v0.1) so the SSD Pallas kernel is shared between
jamba and mamba2 configs.  Sub-quadratic mixers dominate => long_500k runs.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=65536,
    # 8-layer Jamba block: attention at index 4, Mamba elsewhere (1:7)
    layer_pattern=("mamba", "mamba", "mamba", "mamba",
                   "attn", "mamba", "mamba", "mamba"),
    moe_num_experts=16,
    moe_top_k=2,
    moe_d_ff=14336,
    moe_layer_period=2,
    ssm_state=16,
    ssm_headdim=64,
    ssm_expand=2,
    ssm_chunk=128,
    rope_theta=10000.0,
)
