"""whisper-tiny [audio] — arXiv:2212.04356.

4L enc + 4L dec, d_model=384 6H (MHA) d_ff=1536 vocab=51865.  Encoder-decoder;
the conv audio frontend is a STUB: input_specs() provides post-conv frame
embeddings of shape (batch, 1500, d_model).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="audio",
    num_layers=4,              # decoder layers
    encoder_layers=4,
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    head_dim=64,
    d_ff=1536,
    vocab_size=51865,
    layer_pattern=("attn",),
    num_audio_frames=1500,
    tie_embeddings=True,
    rope_theta=10000.0,        # (whisper uses learned pos-emb; we use RoPE-free
                               # sinusoidal for enc, learned for dec — see model)
)
