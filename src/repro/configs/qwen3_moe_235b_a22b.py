"""qwen3-moe-235b-a22b [moe] — Qwen3 family (same recipe as Qwen3-30B-A3B).

94L d_model=4096 64H (GQA kv=4, head_dim=128, q/k-norm) moe_d_ff=1536
vocab=151936, MoE 128 experts top-8 on every layer.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    num_layers=94,
    d_model=4096,
    num_heads=64,
    num_kv_heads=4,
    head_dim=128,
    d_ff=0,
    vocab_size=151936,
    layer_pattern=("attn",),
    qk_norm=True,
    moe_num_experts=128,
    moe_top_k=8,
    moe_d_ff=1536,
    moe_layer_period=1,
    rope_theta=1000000.0,
)
