"""qwen1.5-0.5b [dense] — hf:Qwen/Qwen1.5-0.5B.

24L d_model=1024 16H (GQA kv=16, i.e. MHA) d_ff=2816 vocab=151936, QKV bias.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-0.5b",
    family="dense",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=2816,
    vocab_size=151936,
    layer_pattern=("attn",),
    qkv_bias=True,
    rope_theta=1000000.0,
    tie_embeddings=True,
)
