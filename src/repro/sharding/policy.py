"""Logical-axis → mesh-axis sharding policies.

Every parameter / activation in the model zoo is annotated with *logical*
axis names ("batch", "heads", "d_ff", "experts", ...).  A ``ShardingPolicy``
maps those names onto physical mesh axes; swapping policies is how the §Perf
hillclimb explores different distribution schemes without touching model code.

Baseline policy (production posture):
  - DP over ("pod", "data")        — batch dim of activations
  - FSDP (ZeRO-3) over ("data",)   — "d_model"-like param dims
  - TP over ("model",)             — heads / d_ff / vocab param dims
  - EP over ("model",)             — MoE expert dim
  - sequence-sharding over ("data",) for long-context decode caches
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axes = Optional[Tuple[str, ...]]


@dataclasses.dataclass(frozen=True)
class ShardingPolicy:
    name: str
    # physical mesh axes per role
    dp: Tuple[str, ...] = ("pod", "data")     # batch data-parallel
    fsdp: Tuple[str, ...] = ("data",)         # param sharding (ZeRO-3)
    tp: Tuple[str, ...] = ("model",)          # tensor parallel
    ep: Tuple[str, ...] = ("model",)          # expert parallel
    seq: Tuple[str, ...] = ("data",)          # sequence/cache sharding (decode)
    sp: Tuple[str, ...] = ()                  # Megatron-style sequence parallel
    shard_seq_decode: bool = True             # shard KV cache seq dim in decode
    zero_stage: int = 3                       # 3: shard params; 1: only opt state

    # ---- logical -> physical table ------------------------------------
    def table(self) -> Dict[str, Axes]:
        fsdp = self.fsdp if self.zero_stage >= 3 else ()
        return {
            # activations
            "batch": self.dp,
            "seq": self.sp or None,   # SP shards activations between blocks
            "logit_seq": None,        # logits seq dim: never SP (vocab wins)
            "act_d": None,
            "frames": None,
            "patches": None,
            "cache_seq": self.seq if self.shard_seq_decode else None,
            # params
            "d_model": fsdp,
            "heads": self.tp,
            "kv_heads": self.tp,
            "head_dim": None,
            "d_ff": self.tp,
            "vocab": self.tp,
            "experts": self.ep,
            "moe_ff": None,
            "ssm_inner": self.tp,
            "ssm_heads": self.tp,
            "state": None,
            "conv": None,
            "layers": None,           # scan-stacked leading dim
            "replicated": None,
        }

    def spec(self, *logical: Optional[str]) -> P:
        """PartitionSpec for a tuple of logical axis names (None = replicated).

        A physical mesh axis can shard at most one positional dim; when two
        logical axes of the same tensor resolve to the same physical axis
        (e.g. "batch"→data and "cache_seq"→data on a decode cache), the
        first dim wins and the later dim drops the contested axis."""
        t = self.table()
        used: set = set()
        out = []
        for name in logical:
            if name is None:
                out.append(None)
                continue
            if name not in t:
                raise KeyError(f"unknown logical axis {name!r}")
            ax = t[name]
            ax = tuple(a for a in (ax or ()) if a not in used)
            used.update(ax)
            if len(ax) == 0:
                out.append(None)
            elif len(ax) == 1:
                out.append(ax[0])
            else:
                out.append(tuple(ax))
        return P(*out)

    def sharding(self, mesh: Mesh, *logical: Optional[str]) -> NamedSharding:
        return NamedSharding(mesh, self.spec(*logical))

    def for_mesh(self, mesh: Mesh) -> "ShardingPolicy":
        """Drop mesh axes this mesh does not have (e.g. 'pod' on 1-pod)."""
        names = set(mesh.axis_names)
        f = lambda axes: tuple(a for a in axes if a in names)
        return dataclasses.replace(
            self, dp=f(self.dp), fsdp=f(self.fsdp), tp=f(self.tp),
            ep=f(self.ep), seq=f(self.seq))


def logical_spec(policy: ShardingPolicy, axes: Tuple[Optional[str], ...]) -> P:
    return policy.spec(*axes)


def fit_spec(spec: P, shape: Tuple[int, ...],
             axis_sizes: Dict[str, int]) -> P:
    """Pure core of fit_sharding: drop mesh axes from dims they do not
    divide, keeping the largest dividing prefix (partial sharding)."""
    new = []
    for i, axes in enumerate(tuple(spec) + (None,) * (len(shape)
                                                      - len(spec))):
        if axes is None:
            new.append(None)
            continue
        ax_t = (axes,) if isinstance(axes, str) else tuple(axes)
        keep, prod = [], 1
        for a in ax_t:
            n = axis_sizes[a]
            if shape[i] % (prod * n) == 0:
                keep.append(a)
                prod *= n
        if not keep:
            new.append(None)
        elif len(keep) == 1:
            new.append(keep[0])
        else:
            new.append(tuple(keep))
    return P(*new)


def fit_sharding(sh: NamedSharding, shape: Tuple[int, ...],
                 mesh: Mesh) -> NamedSharding:
    """Drop mesh axes from dims they do not divide.

    E.g. a KV cache with 8 kv-heads on a 16-way model axis: the heads dim
    cannot shard 16 ways, so it replicates across TP (the standard serving
    posture when KV heads < TP degree)."""
    return NamedSharding(mesh, fit_spec(sh.spec, shape, dict(mesh.shape)))


def fit_shardings_tree(sh_tree, abstract_tree, mesh):
    """Tree-map fit_sharding over (shardings, ShapeDtypeStruct) trees."""
    return jax.tree.map(
        lambda sh, ab: fit_sharding(sh, ab.shape, mesh),
        sh_tree, abstract_tree)


def constrain(x, policy: ShardingPolicy, *logical: Optional[str]):
    """with_sharding_constraint by logical axes (no-op outside jit/mesh)."""
    try:
        return jax.lax.with_sharding_constraint(x, policy.spec(*logical))
    except (ValueError, RuntimeError):
        return x


# ----------------------------------------------------------------------
# Named policies.  The non-baseline entries are the §Perf hillclimb levers.
# ----------------------------------------------------------------------
POLICIES: Dict[str, ShardingPolicy] = {
    # paper-faithful production baseline: DP×FSDP×TP
    "baseline": ShardingPolicy(name="baseline"),
    # pure tensor-parallel (params replicated over data) — ZeRO-1 posture
    "tp_only": ShardingPolicy(name="tp_only", fsdp=(), zero_stage=1),
    # FSDP also across pods (ZeRO-3 over DCN; higher comm, lowest memory)
    "fsdp_pod": ShardingPolicy(name="fsdp_pod", fsdp=("pod", "data")),
    # two-axis tensor parallel: TP over both data+model (long-context decode)
    "tp_wide": ShardingPolicy(
        name="tp_wide", dp=("pod",), fsdp=(), tp=("data", "model"),
        ep=("data", "model"), seq=(), shard_seq_decode=False, zero_stage=1),
    # keep KV cache unsharded along seq (decode alternative)
    "noseq": ShardingPolicy(name="noseq", shard_seq_decode=False),
    # Megatron-style sequence parallelism: activations shard their seq dim
    # over the TP axis between attention/MLP blocks (memory + norm compute)
    "seq_par": ShardingPolicy(name="seq_par", sp=("model",)),
    # pure ZeRO-3 over BOTH mesh axes, no tensor parallelism: at 256 chips
    # with global batch 256 the per-layer param all-gathers (0.5 GB/layer
    # bf16 for a 33B model) cost ~50x less wire than Megatron TP's
    # per-layer activation all-reduces — the §Perf hillclimb winner for
    # dense archs.  MoE keeps EP over "model" (dedup keeps expert weights'
    # d_model on "data" only).
    "fsdp_all": ShardingPolicy(
        name="fsdp_all", dp=("pod", "data", "model"),
        fsdp=("data", "model"), tp=(), ep=("model",), seq=("data",)),
}


def get_policy(name: str) -> ShardingPolicy:
    if name not in POLICIES:
        raise KeyError(f"unknown policy {name!r}; known: {list(POLICIES)}")
    return POLICIES[name]
