from repro.sharding.policy import (  # noqa: F401
    ShardingPolicy,
    POLICIES,
    get_policy,
    logical_spec,
)
