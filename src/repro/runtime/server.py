"""Batched decode server with transparent serving-state snapshots.

Serving state (KV/SSM caches + generated tokens + positions) is device
state like any other — the engine checkpoints a half-finished generation
and a fresh server resumes it token-exact.  This is the inference-side
story of the paper (Modal/MemVerge deployments snapshot serving processes
for fast cold-start).
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import CheckpointOptions, CheckpointSession
from repro.models.config import ModelConfig
from repro.models.encdec import build_model
from repro.sharding.policy import ShardingPolicy


class DecodeServer:
    def __init__(self, cfg: ModelConfig, policy: ShardingPolicy, mesh,
                 run_dir: str, max_seq: int = 256,
                 compute_dtype=jnp.float32,
                 options: Optional[CheckpointOptions] = None,
                 session: Optional[CheckpointSession] = None,
                 model=None):
        self.cfg = cfg
        # `model=` lets a fleet of replicas share one model (and one jit
        # cache) instead of recompiling per server
        self.model = model if model is not None else build_model(
            cfg, policy, mesh, compute_dtype=compute_dtype, remat=False)
        self.max_seq = max_seq
        self.params = None
        self.cache = None
        self.tokens: Optional[np.ndarray] = None       # generated so far
        self.pos = 0
        if session is None:
            if (options is not None and options.restore_mode == "lazy"
                    and options.critical_states is None):
                # resume-before-read default: the decode loop touches
                # params immediately; the (large) KV cache streams in
                # behind the resumed server
                options = options.replace(
                    critical_states=("serve_state/params",))
            session = CheckpointSession(run_dir, options, mesh=mesh)
        self.session = session
        self._pending_cache_template = None   # lazy: cache still streaming
        self.engine = self.session.engine              # back-compat alias
        self.session.attach(lambda: {"serve_state": {
            "params": self.params, "cache": self.cache}})
        self.session.register_host_state(
            "decode_cursor",
            lambda: {"pos": self.pos,
                     "tokens": self.tokens},
            self._restore_cursor)
        jits = getattr(self.model, "_decode_server_jit", None)
        if jits is None:
            jits = (jax.jit(self.model.prefill),
                    jax.jit(self.model.decode_step))
            self.model._decode_server_jit = jits
        self._prefill, self._decode = jits

    def _restore_cursor(self, st):
        self.pos = st["pos"]
        self.tokens = st["tokens"]

    def load(self, params) -> None:
        self.params = params

    # ------------------------------------------------------------- serving
    def start(self, batch: Dict[str, Any]) -> None:
        """Prefill a batch of prompts; cache is padded to max_seq."""
        prompt = batch["tokens"]
        B, S = prompt.shape
        logits, cache = self._prefill(self.params,
                                      {k: jnp.asarray(v)
                                       for k, v in batch.items()})
        self.cache = self._pad_cache(cache, self.max_seq)
        nxt = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        self.tokens = np.concatenate([np.asarray(prompt, np.int32),
                                      nxt[:, None]], axis=1)
        self.pos = S

    def _pad_cache(self, cache, max_seq):
        """Pad the *attention* KV seq dim (axis 2 of (L,B,S,KV,hd)) to
        max_seq.  Keyed by leaf name — SSM states are 5-D too and must not
        be touched."""
        def pad(leaf):
            if leaf.ndim == 5 and leaf.shape[2] < max_seq:
                w = [(0, 0)] * 5
                w[2] = (0, max_seq - leaf.shape[2])
                return jnp.pad(leaf, w)
            return leaf

        def walk(node):
            if isinstance(node, dict):
                return {k: (pad(v) if k in ("k", "v", "self_k", "self_v")
                            and hasattr(v, "ndim") else walk(v))
                        for k, v in node.items()}
            return node

        return walk(cache)

    def decode_until(self, target_pos: int,
                     preempt: Optional[Callable[[], bool]] = None,
                     fail_at: Optional[int] = None,
                     straggle_at: Optional[int] = None) -> Dict[str, Any]:
        """Decode to `target_pos`; resumable and preemptible.

        Mirrors ``Trainer.run_until``: `preempt` is polled between tokens
        and triggers a checkpoint-on-signal (``session.frozen`` at the
        current position) before yielding; a failed async snapshot write
        aborts the generation promptly with :class:`SnapshotWriteFailed`.
        """
        from repro.api.session import SnapshotWriteFailed
        t0 = time.perf_counter()
        executed = 0
        preempted = False
        ckpt_path = None
        while self.pos < target_pos:
            if self.session.write_error is not None:
                raise SnapshotWriteFailed(
                    f"async snapshot write failed at pos {self.pos}: "
                    f"{self.session.write_error}")
            if preempt is not None and preempt():
                # a dump captures the live roots: the streaming cache
                # must have landed before the freeze
                self._finish_lazy_restore()
                if (self.session.last_commit_step == self.pos
                        and self.session.latest_step() == self.pos):
                    # THIS incarnation committed an image at this exact
                    # position: yield it instead of re-dumping
                    from repro.core.snapshot_io import snapshot_dir
                    ckpt_path = snapshot_dir(self.session.run_dir,
                                             self.pos)
                else:
                    with self.session.frozen(self.pos) as snap:
                        pass                           # dump-and-yield
                    ckpt_path = snap.path
                preempted = True
                break
            if fail_at is not None and self.pos == fail_at:
                from repro.runtime.trainer import SimulatedFailure
                raise SimulatedFailure(f"injected failure at pos {self.pos}")
            if straggle_at is not None and self.pos == straggle_at:
                time.sleep(0.25)                   # injected straggler
            # first-touch join of the lazily-streaming cache
            self._finish_lazy_restore()
            last = jnp.asarray(self.tokens[:, -1])
            logits, self.cache = self._decode(self.params, self.cache,
                                              last, jnp.int32(self.pos))
            nxt = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
            self.tokens = np.concatenate([self.tokens, nxt[:, None]], axis=1)
            self.pos += 1
            executed += 1
        return {"steps": executed, "pos": self.pos, "preempted": preempted,
                "ckpt_path": ckpt_path,
                "wall_s": time.perf_counter() - t0}

    def decode(self, n_tokens: int) -> np.ndarray:
        self.decode_until(self.pos + n_tokens)
        return self.tokens

    # ------------------------------------------------------------- ckpt
    def checkpoint(self, tag: int = 0) -> str:
        # a dump captures self.cache through the provider: the lazily
        # streaming cache must be adopted first, or the image would pair
        # restored params with the pre-restore cache
        self._finish_lazy_restore()
        return self.session.checkpoint(tag)

    def _boot_template(self, template):
        """Fill missing template subtrees with abstract skeletons.

        Cold boot: by the time this runs, ``session.restore`` has already
        replayed the ``decode_cursor`` host state, so the live batch size
        comes from the restored tokens; the model supplies abstract
        params/cache trees and ``retree`` only needs their structure.
        """
        if template["params"] is None:
            template = dict(template, params=self.model.init_abstract())
        if template["cache"] is None:
            if self.tokens is None:
                raise RuntimeError(
                    "cold restore needs the decode_cursor host state in "
                    "the image to size the cache skeleton")
            B = int(np.asarray(self.tokens).shape[0])
            template = dict(template,
                            cache=self.model.cache_abstract(B, self.max_seq))
        return template

    def restore(self, params_template=None, step: Optional[int] = None):
        """Resume a generation from its image — warm or cold.

        A warm server (started, or loaded with params) restores into its
        live trees; a cold one (fresh object, nothing loaded) derives
        abstract skeletons from the model once the snapshot's host state
        has replayed the decode cursor — no prefill re-execution, no
        hand-crafted cache skeleton.
        """
        template = {"params": self.params if self.params is not None
                    else params_template,
                    "cache": self.cache}
        engine = self.session.engine
        if self.session.options.restore_mode == "lazy":
            # resume-before-read: params place now, the KV cache streams
            # behind the server and is joined before the first decode step
            restored = self.session.restore(step=step, wait="critical")
            template = self._boot_template(template)
            raw = restored.get("serve_state", {})
            try:
                self.params = engine.retree(template["params"],
                                            raw.get("params", {}))
            except (KeyError, RuntimeError):
                # critical spec did not cover the whole params subtree:
                # join the stream and retree from the complete tree
                raw = self.session.restore_barrier()["serve_state"]
                self.params = engine.retree(template["params"],
                                            raw["params"])
            if self.session.lazy_pending:
                self._pending_cache_template = template["cache"]
            else:
                self.cache = engine.retree(template["cache"], raw["cache"])
            return self.pos
        if template["params"] is None or template["cache"] is None:
            raw = self.session.restore(step=step, wait="all")
            template = self._boot_template(template)
            serve = raw["serve_state"]
            self.params = engine.retree(template["params"], serve["params"])
            self.cache = engine.retree(template["cache"], serve["cache"])
            return self.pos
        restored = self.session.restore_into(template, state="serve_state",
                                             step=step)
        self.params = restored["params"]
        self.cache = restored["cache"]
        return self.pos

    def _finish_lazy_restore(self) -> None:
        """Join the background stream and adopt the cold KV cache."""
        if self._pending_cache_template is None:
            return
        template, self._pending_cache_template = \
            self._pending_cache_template, None
        full = self.session.restore_barrier()
        self.cache = self.session.engine.retree(
            template, full["serve_state"]["cache"])
