"""Optimal checkpoint-interval policy (paper §7 "Deciding when to
Checkpoint").

Periodic checkpointing trades runtime overhead (checkpoint cost δ every τ
seconds) against expected rework after a failure (τ/2 on average).  The
Young/Daly first-order optimum is

    τ* = sqrt(2 · δ · MTBF)

With CRIUgpu-class numbers the point of the paper becomes quantitative:
the *frozen* window δ is what matters for overhead, and the async engine
shrinks δ from full-write cost to device→host copy cost — so τ* drops and
expected lost work falls with it.  ``IntervalPlanner`` feeds live
measurements (engine.last_stats + a failure estimate from the
FailureDetector/cluster telemetry) back into τ*.

LLaMA-3.1 anchor from the paper's §1: 419 interruptions / 54 days / 16k
GPUs → per-job MTBF ≈ 11.1 h; with a 77 s frozen window (paper Table 2,
H100) τ* ≈ 41 min; with our async engine's ~1 s blocked window τ* ≈ 4.7
min and expected lost work per failure drops ~9×.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, List, Mapping, Optional

# preference order for the measured blocked window in an engine stats
# dict: async dumps block only for the device→host copy (locked_total_s),
# sync dumps for the whole dump+write (total_s); frozen_s (capture phase
# only) is the floor either way
_WINDOW_KEYS = ("locked_total_s", "total_s", "frozen_s")


def frozen_window_s(stats: Mapping[str, Any]) -> Optional[float]:
    """Extract the job-blocked window δ from ``engine.last_stats``."""
    for k in _WINDOW_KEYS:
        v = stats.get(k)
        if v is not None:
            return float(v)
    return None


def young_daly(ckpt_cost_s: float, mtbf_s: float) -> float:
    """τ* = sqrt(2 δ M) (guarded for degenerate inputs)."""
    if ckpt_cost_s <= 0:
        return float("inf") if mtbf_s <= 0 else max(mtbf_s * 1e-3, 1e-3)
    if mtbf_s <= 0:
        return float("inf")
    return math.sqrt(2.0 * ckpt_cost_s * mtbf_s)


def expected_overhead_fraction(interval_s: float, ckpt_cost_s: float,
                               mtbf_s: float) -> float:
    """First-order expected overhead (checkpointing + rework) as a fraction
    of runtime: δ/τ + τ/(2M)."""
    if interval_s <= 0 or mtbf_s <= 0:
        return float("inf")
    return ckpt_cost_s / interval_s + interval_s / (2.0 * mtbf_s)


@dataclasses.dataclass
class IntervalPlanner:
    """Adaptive τ*: tracks measured checkpoint cost and failure spacing."""

    mtbf_guess_s: float = 6 * 3600.0
    min_interval_s: float = 30.0
    max_interval_s: float = 24 * 3600.0
    _costs: List[float] = dataclasses.field(default_factory=list)
    _failure_times: List[float] = dataclasses.field(default_factory=list)

    def record_checkpoint_cost(self, blocked_s: float) -> None:
        self._costs.append(float(blocked_s))

    def observe(self, stats: Mapping[str, Any]) -> Optional[float]:
        """Feed one dump's measured stats (``engine.last_stats``) — the
        blocked window is extracted with the async/sync preference above.
        ``CheckpointSession.set_planner`` calls this after every dump."""
        w = frozen_window_s(stats)
        if w is not None:
            self.record_checkpoint_cost(w)
        return w

    def record_failure(self, t_s: float) -> None:
        self._failure_times.append(float(t_s))

    @property
    def ckpt_cost_s(self) -> float:
        if not self._costs:
            return 60.0                     # pessimistic default
        tail = self._costs[-8:]
        return sum(tail) / len(tail)

    @property
    def mtbf_s(self) -> float:
        if len(self._failure_times) < 2:
            return self.mtbf_guess_s
        ts = sorted(self._failure_times)
        gaps = [b - a for a, b in zip(ts, ts[1:]) if b > a]
        return sum(gaps) / len(gaps) if gaps else self.mtbf_guess_s

    def interval_s(self) -> float:
        tau = young_daly(self.ckpt_cost_s, self.mtbf_s)
        return min(max(tau, self.min_interval_s), self.max_interval_s)

    def steps_between_checkpoints(self, step_time_s: float) -> int:
        if step_time_s <= 0:
            return 1
        return max(1, int(round(self.interval_s() / step_time_s)))
