"""Training runtime with transparent unified checkpointing.

The loop contains no checkpoint logic for its *state* — the SnapshotEngine
is attached to a state provider and captures params/optimizer/RNG (device)
plus data-cursor/metrics (host) through plugins.  Periodic and just-in-time
policies both drive the same engine.  ``run_with_restarts`` demonstrates
the full failure story: crash (SimulatedFailure or real exception) →
re-construct a fresh Trainer → engine.restore → continue — including onto a
*different mesh* (elastic restart).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import CheckpointOptions, CheckpointSession
from repro.core import SnapshotEngine
from repro.data import TokenPipeline
from repro.launch.mesh import use_mesh
from repro.models.config import ModelConfig
from repro.models.encdec import build_model
from repro.optim import AdamW
from repro.optim.schedule import warmup_cosine
from repro.runtime.fault import JITCheckpointPolicy, StragglerMonitor
from repro.sharding.policy import ShardingPolicy

PyTree = Any


class SimulatedFailure(RuntimeError):
    pass


@dataclasses.dataclass
class TrainConfig:
    batch_size: int = 4
    seq_len: int = 64
    lr: float = 3e-4
    warmup_steps: int = 20
    total_steps: int = 200
    ckpt_every: int = 0             # 0 = no periodic checkpoints
    ckpt: Optional[CheckpointOptions] = None   # how snapshots are taken
    ckpt_mode: str = "sync"         # deprecated: use ckpt=CheckpointOptions
    incremental: bool = False       # deprecated: use ckpt=CheckpointOptions
    seed: int = 0
    compute_dtype: Any = jnp.bfloat16
    remat: bool = True

    def checkpoint_options(self) -> CheckpointOptions:
        """Resolve the effective options (explicit `ckpt` wins over the
        deprecated per-field knobs)."""
        if self.ckpt is not None:
            return self.ckpt
        return CheckpointOptions(mode=self.ckpt_mode,
                                 incremental=self.incremental)


class Trainer:
    def __init__(self, cfg: ModelConfig, tcfg: TrainConfig, mesh,
                 policy: ShardingPolicy, run_dir: str,
                 engine: Optional[SnapshotEngine] = None,
                 replicator=None,
                 session: Optional[CheckpointSession] = None):
        self.cfg = cfg
        self.tcfg = tcfg
        self.mesh = mesh
        self.model = build_model(cfg, policy, mesh,
                                 compute_dtype=tcfg.compute_dtype,
                                 remat=tcfg.remat)
        self.opt = AdamW(lr=warmup_cosine(tcfg.lr, tcfg.warmup_steps,
                                          tcfg.total_steps))
        self.pipeline = TokenPipeline(cfg, tcfg.batch_size, tcfg.seq_len,
                                      seed=tcfg.seed)
        self.params = None
        self.opt_state = None
        self.step = 0
        self.metrics_history: Dict[str, list] = {"loss": []}
        self.straggler = StragglerMonitor()

        if session is None:
            if engine is not None:       # migration aid: wrap a bare engine
                session = CheckpointSession.from_engine(engine)
            else:
                opts = tcfg.checkpoint_options()
                if (opts.restore_mode == "lazy"
                        and opts.critical_states is None):
                    # resume-before-read default: the first step's forward
                    # pass touches params; optimizer slots are cold and
                    # stream in behind the resumed job
                    opts = opts.replace(
                        critical_states=("train_state/params",))
                session = CheckpointSession(
                    run_dir, opts, mesh=mesh,
                    replicator=replicator)
        self.session = session
        # lazy restore: the optimizer template whose leaves are still
        # streaming; joined right before the first step runs
        self._pending_opt_template = None
        self.engine = session.engine     # back-compat alias
        # transparent wiring: live state via provider, host bits via plugins
        self.session.attach(lambda: {"train_state": {
            "params": self.params, "opt": self.opt_state}})
        self.session.register_host_state(
            "data_cursor", lambda: self.pipeline.state(),
            lambda st: self.pipeline.restore_state(st))
        self.session.register_host_state(
            "trainer", lambda: {"step": self.step,
                                "loss_hist": self.metrics_history["loss"][-50:]},
            self._restore_trainer_state)
        self.jit_ckpt = JITCheckpointPolicy(self.session)

        self._step_fn = jax.jit(
            self._train_step,
            donate_argnums=(0, 1),
            in_shardings=(self.model.param_shardings(),
                          self._opt_shardings(), None),
        ) if mesh is not None and np.prod(mesh.devices.shape) > 1 else \
            jax.jit(self._train_step, donate_argnums=(0, 1))

    def _restore_trainer_state(self, st):
        self.step = st["step"]
        self.metrics_history["loss"] = list(st["loss_hist"])

    def _opt_shardings(self):
        from repro.optim.adamw import OptState
        ps = self.model.param_shardings()
        from jax.sharding import NamedSharding, PartitionSpec
        scalar = NamedSharding(self.mesh, PartitionSpec())
        return OptState(step=scalar, m=ps, v=ps)

    # ------------------------------------------------------------- steps
    def _train_step(self, params, opt_state, batch):
        def loss_fn(p):
            return self.model.loss(p, batch)
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        params, opt_state, om = self.opt.update(grads, opt_state, params)
        return params, opt_state, {**metrics, **om}

    def initialize(self) -> None:
        self.params = self.model.init(jax.random.key(self.tcfg.seed))
        self.opt_state = self.opt.init(self.params)
        self.step = 0

    def restore(self, step: Optional[int] = None, mesh=None) -> int:
        """Unified restore (engine pushes host state back via plugins).

        In lazy mode (``CheckpointOptions(restore_mode="lazy")``) this
        returns as soon as the critical set — by default the parameters —
        is placed; the optimizer slots keep streaming in the background
        and are joined right before the first step executes
        (resume-before-read)."""
        if self.params is None:
            # template for typed restore
            self.params = self.model.init(jax.random.key(self.tcfg.seed))
            self.opt_state = self.opt.init(self.params)
        template = {"params": self.params, "opt": self.opt_state}
        shardings = None
        if self.mesh is not None:
            shardings = {"params": self.model.param_shardings(),
                         "opt": self._opt_shardings()}
        if self.session.options.restore_mode == "lazy":
            restored = self.session.restore(
                step=step, mesh=mesh or self.mesh,
                shardings={"train_state": shardings}
                if shardings is not None else None,
                wait="critical")
            engine = self.session.engine
            raw = restored.get("train_state", {})
            try:
                self.params = engine.retree(template["params"],
                                            raw.get("params", {}))
            except (KeyError, RuntimeError):
                # a custom critical_states spec that does not cover the
                # whole params subtree: the leaves are still streaming
                # (or partially landed) — join and retree from the
                # complete tree instead of crashing
                raw = self.session.restore_barrier()["train_state"]
                self.params = engine.retree(template["params"],
                                            raw["params"])
            if self.session.lazy_pending:
                self._pending_opt_template = template["opt"]
            else:                       # stream finished (or joined above)
                self.opt_state = engine.retree(template["opt"], raw["opt"])
            return self.step
        restored = self.session.restore_into(
            template, state="train_state", step=step,
            mesh=mesh or self.mesh, shardings=shardings)
        self.params = restored["params"]
        self.opt_state = restored["opt"]
        return self.step

    def _finish_lazy_restore(self) -> None:
        """Join the background stream and adopt the cold optimizer slots
        — called on first touch (right before the first step, or before a
        checkpoint-on-signal captures the live roots)."""
        if self._pending_opt_template is None:
            return
        template, self._pending_opt_template = \
            self._pending_opt_template, None
        full = self.session.restore_barrier()
        self.opt_state = self.session.engine.retree(
            template, full["train_state"]["opt"])

    # ------------------------------------------------------------- loop
    def run_until(self, target_step: int,
                  preempt: Optional[Callable[[], bool]] = None,
                  fail_at: Optional[int] = None,
                  straggle_at: Optional[int] = None) -> Dict[str, Any]:
        """Run to `target_step`; resumable and preemptible.

        `preempt` is polled between steps (the SIGTERM-trap analogue): when
        it fires the trainer checkpoints-on-signal — ``session.frozen``
        dump at the current step — and returns with ``preempted=True``
        instead of raising, so an orchestrator can release the devices and
        reschedule the job.  A failed *async* snapshot write aborts the run
        promptly with :class:`SnapshotWriteFailed` rather than surfacing at
        the next explicit dump — the job must not keep running on the
        assumption that its recent checkpoints exist.
        """
        from repro.api.session import SnapshotWriteFailed
        if self.params is None:
            self.initialize()
        t_loop = time.perf_counter()
        executed = 0
        preempted = False
        ckpt_path = None
        while self.step < target_step:
            if self.session.write_error is not None:
                raise SnapshotWriteFailed(
                    f"async snapshot write failed at step {self.step}: "
                    f"{self.session.write_error}")
            handle = self.session.concurrent_capture
            if handle is not None and handle.speculation_done:
                # soft-freeze capture finished speculating in the
                # background: take the short validate pause now, between
                # steps, instead of letting it collide with a later dump
                self.session.checkpoint_finalize()
            if preempt is not None and preempt():
                # a dump captures the live roots: the cold optimizer
                # slots must have landed before the freeze
                self._finish_lazy_restore()
                # an in-flight soft-freeze capture must settle before the
                # signal dump (its validate pause re-reads the live roots)
                self.session.checkpoint_finalize()
                if (self.session.last_commit_step == self.step
                        and self.session.latest_step() == self.step):
                    # THIS incarnation committed an image of this exact
                    # step (periodic dump landed right before the
                    # signal): yield it instead of re-dumping the same
                    # state.  A same-numbered leftover from an earlier
                    # incarnation never matches last_commit_step.
                    from repro.core.snapshot_io import snapshot_dir
                    ckpt_path = snapshot_dir(self.session.run_dir,
                                             self.step)
                else:
                    with self.session.frozen(self.step) as snap:
                        pass                           # dump-and-yield
                    ckpt_path = snap.path
                preempted = True
                break
            if fail_at is not None and self.step == fail_at:
                raise SimulatedFailure(f"injected failure at {self.step}")
            batch_np = self.pipeline.next()
            batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
            # first-touch join: batch prep (and everything since restore
            # returned) overlapped the background optimizer-slot stream
            self._finish_lazy_restore()
            t0 = time.perf_counter()
            if straggle_at is not None and self.step == straggle_at:
                time.sleep(0.25)                       # injected straggler
            with use_mesh(self.mesh):
                self.params, self.opt_state, metrics = self._step_fn(
                    self.params, self.opt_state, batch)
            loss = float(metrics["loss"])
            self.metrics_history["loss"].append(loss)
            dt = time.perf_counter() - t0
            self.step += 1
            executed += 1
            if self.straggler.record(dt):
                self.jit_ckpt.on_signal(self.step)     # just-in-time ckpt
            if (self.tcfg.ckpt_every
                    and self.step % self.tcfg.ckpt_every == 0):
                if self.session.options.capture == "concurrent":
                    # soft-freeze: brief pin pause, then the loop keeps
                    # stepping while shards are speculated in background;
                    # the handle is finalized by the poll above (or the
                    # settle below if the run ends first)
                    self.session.checkpoint_begin(self.step)
                else:
                    self.session.checkpoint(self.step)
        # never leave a capture half-done across run_until boundaries
        self.session.checkpoint_finalize()
        return {"steps": executed, "step": self.step,
                "preempted": preempted, "ckpt_path": ckpt_path,
                "loss": (self.metrics_history["loss"][-1]
                         if self.metrics_history["loss"] else None),
                "wall_s": time.perf_counter() - t_loop}

    def run(self, num_steps: int, fail_at: Optional[int] = None,
            straggle_at: Optional[int] = None) -> Dict[str, Any]:
        if self.params is None:
            self.initialize()
        t_loop = time.perf_counter()
        self.run_until(self.step + num_steps, fail_at=fail_at,
                       straggle_at=straggle_at)
        self.session.wait_pending()
        return {"steps": self.step,
                "loss": self.metrics_history["loss"][-1],
                "wall_s": time.perf_counter() - t_loop}


def run_with_restarts(make_trainer, total_steps: int,
                      failures: Dict[int, str]) -> Dict[str, Any]:
    """Drive training to `total_steps`, surviving injected failures.

    failures: {step: kind} — trainer is rebuilt from scratch and restored
    from the newest valid snapshot after each crash (node-replacement
    semantics).
    """
    restarts = 0
    trainer = make_trainer()
    trainer.initialize()
    pending = dict(failures)
    while trainer.step < total_steps:
        fail_at = min((s for s in pending if s >= trainer.step),
                      default=None)
        try:
            trainer.run(total_steps - trainer.step, fail_at=fail_at)
        except SimulatedFailure:
            pending.pop(fail_at, None)
            restarts += 1
            trainer = make_trainer()                   # replacement node
            trainer.restore()                          # newest valid image
    return {"steps": trainer.step, "restarts": restarts,
            "loss_history": trainer.metrics_history["loss"],
            "trainer": trainer}
