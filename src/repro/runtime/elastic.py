"""Elastic restart: restore a unified snapshot onto a *different* mesh.

The paper's CUDA path requires identical GPU type/count/order on restore
(§4.4); the AMD path supports GPUID translation onto a compatible subset
(§3.1.2).  Our adaptation goes further: saved shard layouts are reassembled
and re-laid-out for whatever mesh the replacement job brings up (scale-down
after losing a pod, scale-up after repair) — the engine's "resharded"
topology mode.  This module packages the recipe the runtime uses.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

from repro.api import CheckpointOptions, CheckpointSession


def elastic_restore(run_dir: str, new_mesh, model, opt,
                    step: Optional[int] = None,
                    options: Optional[CheckpointOptions] = None
                    ) -> Dict[str, Any]:
    """Restore ``train_state`` from `run_dir` onto `new_mesh`.

    The model/optimizer must be constructed against the new mesh (their
    sharding policies define the target layout); shapes are topology-
    independent so any saved image can be re-laid-out.
    Returns {"params", "opt", "step"}.
    """
    session = CheckpointSession(run_dir, options, mesh=new_mesh)
    meta: Dict[str, Any] = {}
    session.register_host_state("trainer",
                                lambda: {},
                                lambda st: meta.update(st))
    session.register_host_state("data_cursor",
                                lambda: {},
                                lambda st: meta.setdefault("cursor", st))
    params_t = model.init_abstract()
    opt_t = opt.init_abstract(params_t)
    shardings = {"params": model.param_shardings(),
                 "opt": _opt_shardings(model, opt, new_mesh)}
    restored = session.restore_into(
        {"params": params_t, "opt": opt_t}, state="train_state",
        step=step, mesh=new_mesh, shardings=shardings)
    return {"params": restored["params"], "opt": restored["opt"],
            "step": meta.get("step"), "meta": meta,
            "topology_mode": session.last_stats.get("topology_mode")}


def _opt_shardings(model, opt, mesh):
    from jax.sharding import NamedSharding, PartitionSpec
    from repro.optim.adamw import OptState
    ps = model.param_shardings()
    return OptState(step=NamedSharding(mesh, PartitionSpec()), m=ps, v=ps)
