"""Failure detection + straggler mitigation (cluster-runtime substrate).

At 1000+-node scale the checkpoint engine is driven by signals from a
failure detector (heartbeats) and a straggler monitor (step-time outliers).
Both are implemented host-side and deterministic enough to unit-test:

  * ``FailureDetector`` — heartbeat registry with deadlines; a worker that
    stops beating is reported dead and the runtime restarts from the newest
    valid unified snapshot (paper §7 "Deciding when to Checkpoint").
  * ``StragglerMonitor`` — robust (median + MAD) step-time outlier
    detection; on detection it can trigger a *just-in-time* checkpoint
    (Gupta et al., EuroSys'24 — the paper positions CRIUgpu as the
    mechanism under exactly this policy).
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional


class FailureDetector:
    """Each death is reported exactly once: :meth:`dead_workers` returns a
    worker the first time it ages past the deadline, then suppresses it
    until a fresh heartbeat (or re-registration) proves it alive again —
    an evicted-but-not-unregistered worker cannot re-trigger a detection
    storm every tick.  Callers that evict a worker for good should
    :meth:`unregister` it."""

    def __init__(self, deadline_s: float = 5.0,
                 clock: Callable[[], float] = time.monotonic):
        self.deadline_s = deadline_s
        self.clock = clock
        self.last_beat: Dict[str, float] = {}
        self._reported: set = set()

    def register(self, worker: str) -> None:
        self.last_beat[worker] = self.clock()
        self._reported.discard(worker)

    def heartbeat(self, worker: str) -> None:
        self.last_beat[worker] = self.clock()
        self._reported.discard(worker)

    def unregister(self, worker: str) -> None:
        """Forget the worker entirely (evicted / quarantined): it is
        neither tracked nor ever re-reported until re-registered."""
        self.last_beat.pop(worker, None)
        self._reported.discard(worker)

    def _past_deadline(self) -> List[str]:
        now = self.clock()
        return [w for w, t in self.last_beat.items()
                if now - t > self.deadline_s]

    def dead_workers(self) -> List[str]:
        fresh = [w for w in self._past_deadline()
                 if w not in self._reported]
        self._reported.update(fresh)
        return fresh

    def healthy(self) -> bool:
        """Liveness view (non-mutating): no tracked worker is currently
        past its deadline, reported or not."""
        return not self._past_deadline()


class StragglerMonitor:
    def __init__(self, window: int = 32, threshold: float = 3.0,
                 min_samples: int = 8):
        self.window = window
        self.threshold = threshold
        self.min_samples = min_samples
        self.times: List[float] = []
        self.flagged_steps: List[int] = []
        self._step = 0

    def record(self, step_time_s: float) -> bool:
        """Returns True if this step is a straggler."""
        self._step += 1
        history = self.times[-self.window:]
        self.times.append(step_time_s)
        if len(history) < self.min_samples:
            return False
        srt = sorted(history)
        med = srt[len(srt) // 2]
        mad = sorted(abs(t - med) for t in history)[len(history) // 2]
        is_straggler = step_time_s > med + self.threshold * max(mad, 0.05 * med)
        if is_straggler:
            self.flagged_steps.append(self._step)
        return is_straggler

    @property
    def median(self) -> Optional[float]:
        if not self.times:
            return None
        srt = sorted(self.times)
        return srt[len(srt) // 2]


class JITCheckpointPolicy:
    """Just-in-time checkpointing: snapshot when an anomaly signal fires
    (straggler flagged / peer failure reported) instead of on a period."""

    def __init__(self, engine, cooldown_steps: int = 16):
        self.engine = engine
        self.cooldown = cooldown_steps
        self._last = -10**9
        self.triggered: List[int] = []

    def on_signal(self, step: int) -> bool:
        if step - self._last < self.cooldown:
            return False
        self.engine.checkpoint(step)
        self._last = step
        self.triggered.append(step)
        return True
