from repro.runtime.trainer import Trainer, TrainConfig, SimulatedFailure  # noqa: F401
from repro.runtime.fault import StragglerMonitor, FailureDetector  # noqa: F401
from repro.runtime.server import DecodeServer  # noqa: F401
