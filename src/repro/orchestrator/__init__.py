"""repro.orchestrator — multi-tenant preemption orchestrator.

The subsystem that makes the repo's checkpoint mechanism *scheduler-
driven*: N concurrent checkpointable jobs under a priority scheduler with
preemption, heartbeat failure detection, straggler-triggered JIT dumps,
and τ*-adaptive checkpoint cadence — with every lifecycle transition
timestamped into a per-job recovery log so recovery time and goodput are
measurable per scenario (the paper's multi-tenant framing, reproduced).

    from repro.orchestrator import Orchestrator, JobSpec, run_scenario

    summary = run_scenario("preemption", run_dir)
    assert summary["all_done"]
"""
from repro.orchestrator.fleet import (FleetConfig, Replica,  # noqa: F401
                                      ServingFleet, run_fleet)
from repro.orchestrator.job import (InvalidTransition, JobRecord,  # noqa: F401
                                    JobSpec, JobState, list_job_records)
from repro.orchestrator.orchestrator import (MigrationPlan,  # noqa: F401
                                             Orchestrator,
                                             OrchestratorConfig)
from repro.orchestrator.recovery import GoodputMeter, RecoveryLog  # noqa: F401
from repro.orchestrator.scheduler import Decision, Scheduler  # noqa: F401
from repro.orchestrator.signals import Signal, SignalChannel  # noqa: F401
from repro.orchestrator.scenarios import (SCENARIOS, run_scenario,  # noqa: F401
                                          scenario_specs)
from repro.orchestrator.workloads import (InterceptionWorkload,  # noqa: F401
                                          ServeWorkload, TrainWorkload,
                                          make_workload_factory)
