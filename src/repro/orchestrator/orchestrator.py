"""Orchestrator — the event loop that drives preemption and recovery.

One process plays the cluster: a priority :class:`Scheduler` over
simulated device capacity, a :class:`SignalChannel` for SIGTERM-style
preemption, ``FailureDetector`` heartbeats for crash detection,
per-job ``StragglerMonitor`` JIT-checkpoint triggers, and per-job
``IntervalPlanner`` τ* cadence (auto-fed from measured frozen windows via
``CheckpointSession.set_planner``).  Jobs run cooperatively in slices —
each tick gives every running job up to ``slice_steps`` steps, with the
preemption predicate checked between steps so a signal lands mid-run.

The lifecycle per interruption (the paper's recovery story, measured):

    signal/crash -> detect -> [RecoveryLog] -> reschedule -> restore
    (image read) -> replay to the interrupted step -> caught up

Every transition persists the job's JSON record, so ``python -m repro
jobs RUN_DIR`` inspects a (possibly dead) cluster offline.
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Any, Callable, Dict, List, Optional

from repro.chaos import hooks as chaos_hooks
from repro.obs import trace as obs_trace
from repro.orchestrator.job import JobRecord, JobSpec, JobState
from repro.orchestrator.scheduler import Scheduler
from repro.orchestrator.signals import Signal, SignalChannel
from repro.orchestrator.workloads import make_workload_factory
from repro.runtime.fault import FailureDetector, StragglerMonitor
from repro.runtime.interval import IntervalPlanner


@dataclasses.dataclass
class OrchestratorConfig:
    capacity: int = 2               # simulated device slots
    slice_steps: int = 2            # steps per job per tick
    heartbeat_deadline_s: float = 0.05
    max_ticks: int = 10_000
    mtbf_guess_s: float = 3600.0    # planner prior per job
    planner_min_interval_s: float = 0.5
    jit_cooldown_steps: int = 8
    idle_sleep_s: float = 0.005     # when a tick ran nothing (await detect)
    hosts: int = 1                  # simulated hosts (job dirs per host)
    transfer: str = "delta"         # DEPRECATED: transfer_policy.mode
    transfer_workers: int = 0       # DEPRECATED: transfer_policy.workers
    transfer_policy: Optional[Any] = None   # api.TransferPolicy

    def resolved_transfer_policy(self):
        """The structured migration policy; legacy string knobs map into
        a stop-and-copy TransferPolicy when no policy was given."""
        if self.transfer_policy is not None:
            return self.transfer_policy
        from repro.api.options import TransferPolicy
        return TransferPolicy(mode=self.transfer,
                              workers=self.transfer_workers)


@dataclasses.dataclass
class MigrationPlan:
    """One planned live migration: checkpoint the job on its current
    host, delta-transfer the image to another host's store, restore it
    there.  Driven by ``JobSpec.migrate_at_step``.

    Stop-and-copy state walk: pending → signalled → transferred (or
    failed).  With a pre-copy policy (``TransferPolicy.precopy_rounds``)
    an extra live phase slots in — pending → **precopy** (budget-driven
    delta rounds while the job keeps stepping, each appended to
    ``rounds``) → signalled (the convergence controller called freeze or
    fallback; ``outcome`` records which) → transferred/failed."""
    job_id: str
    at_step: int
    src_host: Optional[str] = None
    dst_host: Optional[str] = None
    state: str = "pending"
    stats: Dict[str, Any] = dataclasses.field(default_factory=dict)
    rounds: List[Dict[str, Any]] = dataclasses.field(default_factory=list)
    outcome: Optional[str] = None   # "converged" | "fallback" | None


class Orchestrator:
    def __init__(self, run_dir: str, specs: List[JobSpec],
                 workload_factory: Optional[Callable] = None,
                 config: Optional[OrchestratorConfig] = None,
                 options=None, mesh=None,
                 clock: Callable[[], float] = time.perf_counter):
        self.run_dir = run_dir
        self.cfg = config or OrchestratorConfig()
        self.clock = clock
        self.factory = workload_factory or make_workload_factory(
            run_dir, options=options, mesh=mesh)
        self.channel = SignalChannel()
        self.scheduler = Scheduler(self.cfg.capacity, self.channel)
        self.detector = FailureDetector(self.cfg.heartbeat_deadline_s)
        for s in specs:
            if s.devices > self.cfg.capacity:
                raise ValueError(
                    f"job {s.job_id!r} demands {s.devices} device(s) but "
                    f"the cluster has {self.cfg.capacity}: it could never "
                    f"be scheduled")
        self.hosts: List[str] = (
            [f"host{i:02d}" for i in range(self.cfg.hosts)]
            if self.cfg.hosts > 1 else [])
        self.migrations: Dict[str, MigrationPlan] = {
            s.job_id: MigrationPlan(s.job_id, s.migrate_at_step)
            for s in specs if s.migrate_at_step is not None}
        if self.migrations and len(self.hosts) < 2:
            raise ValueError(
                "jobs with migrate_at_step need a multi-host cluster "
                f"(OrchestratorConfig(hosts=2+), got {self.cfg.hosts})")
        self.records: Dict[str, JobRecord] = {
            s.job_id: JobRecord(s, run_dir) for s in specs}
        for rec in self.records.values():
            rec.save()
        self.workloads: Dict[str, Any] = {}
        self.planners: Dict[str, IntervalPlanner] = {
            s.job_id: IntervalPlanner(
                mtbf_guess_s=self.cfg.mtbf_guess_s,
                min_interval_s=self.cfg.planner_min_interval_s)
            for s in specs}
        self.stragglers: Dict[str, StragglerMonitor] = {
            s.job_id: StragglerMonitor(min_samples=4) for s in specs}
        self._last_jit: Dict[str, int] = {}
        self._crash_t: Dict[str, float] = {}
        # live pre-copy state per migrating job: replicator + convergence
        # controller + CAS ledger tag (the durable half lives in the
        # destination CAS, so a killed source resumes from there)
        self._precopy: Dict[str, Dict[str, Any]] = {}
        self.final: Dict[str, Dict[str, Any]] = {}
        self.ticks = 0
        self.t0: Optional[float] = None

    # ---------------------------------------------------------- lifecycle
    def _all_settled(self) -> bool:
        return all(r.terminal or r.exhausted for r in self.records.values())

    def run(self) -> Dict[str, Any]:
        self.t0 = self.clock()
        while self.ticks < self.cfg.max_ticks and not self._all_settled():
            self._tick(self.ticks)
            self.ticks += 1
        for job_id, wl in list(self.workloads.items()):
            try:
                wl.finish()
            except Exception as e:          # drain failure on exit: the
                self.records[job_id].events.append(  # record says why
                    {"t": self.clock(), "drain_error": repr(e)})
                self.records[job_id].save()
        return self.summary()

    # --------------------------------------------------------------- tick
    def _tick(self, tick: int) -> None:
        if chaos_hooks.INJECTOR is not None:
            # chaos: the campaign driver — delivers deferred signals and
            # fires progress-anchored events (kills, eviction walls)
            chaos_hooks.fire("orch.tick", orch=self, tick=tick)
        # every live workload beats at tick start: a crashed "process"
        # (its workload object is gone) cannot, so only real deaths age
        # past the deadline — another job's long slice or a checkpoint
        # write in *this* process must never read as a missed beat
        for job_id in self._running_jobs():
            self.detector.heartbeat(job_id)
        self._detect_failures()
        self._schedule(tick)
        ran = self._run_slices()
        if not ran:
            # nothing runnable this tick (e.g. waiting out the heartbeat
            # deadline of a crashed job) — don't hot-spin the loop
            time.sleep(self.cfg.idle_sleep_s)

    # ------------------------------------------------- failure detection
    def _detect_failures(self) -> None:
        now = self.clock()
        for job_id in self.detector.dead_workers():
            rec = self.records.get(job_id)
            self.detector.unregister(job_id)
            if rec is None or rec.state != JobState.RUNNING:
                continue
            rec.recovery.open(
                "failure",
                t_interrupt=self._crash_t.pop(job_id, now),
                t_detect=now, step_at_interrupt=rec.step,
                last_ckpt_step=rec.last_ckpt_step)
            rec.transition(JobState.FAILED, detected="heartbeat")
            self._evict(job_id)

    def _evict(self, job_id: str) -> None:
        self.scheduler.release(job_id)
        self.channel.unregister(job_id)
        self.detector.unregister(job_id)
        self.workloads.pop(job_id, None)

    # --------------------------------------------------------- scheduling
    def _schedule(self, tick: int) -> None:
        decision = self.scheduler.plan(self.records, tick)
        for job_id in decision.admit:
            rec = self.records[job_id]
            self.scheduler.allocate(job_id, rec.spec.devices)
            if rec.state == JobState.PENDING:
                self._start_fresh(rec)
            else:
                self._restore_job(rec)

    def _host_load(self) -> Dict[str, int]:
        load: Dict[str, int] = {}
        for rec in self.records.values():
            if rec.host is not None and not rec.terminal:
                load[rec.host] = load.get(rec.host, 0) + 1
        return load

    def _make_workload(self, rec: JobRecord):
        """Instantiate the job's workload on its assigned host.  The
        host kwarg is only passed when placement is active so custom
        two-argument factories (tests, embedders) keep working."""
        if rec.host is not None:
            return self.factory(rec.spec, rec.attempt, host=rec.host)
        return self.factory(rec.spec, rec.attempt)

    def _start_fresh(self, rec: JobRecord) -> None:
        if self.hosts and rec.host is None:
            rec.host = Scheduler.place(self.hosts, self._host_load())
        wl = self._make_workload(rec)
        wl.start()
        self._register(rec, wl)
        rec.transition(JobState.RUNNING)

    def _restore_job(self, rec: JobRecord) -> None:
        now = self.clock()
        rec.recovery.mark_scheduled(now)
        rec.transition(JobState.RESTORING)
        rec.attempt += 1
        wl = self._make_workload(rec)
        t0 = self.clock()
        # job attribution: every span the restore emits (restore.critical,
        # restore.background, pack reads) inherits this job id
        with obs_trace.context(job=rec.spec.job_id):
            try:
                restored_step = wl.restore()
            except FileNotFoundError:
                # interrupted before any image existed: cold restart
                wl.start()
                restored_step = 0
        restore_s = self.clock() - t0
        rec.step = restored_step
        meta = {"restore_wall_s": restore_s}
        if getattr(wl, "session", None) is not None:
            stats = wl.session.last_stats
            meta.update({k: stats[k] for k in
                         ("read_s", "decompress_s", "place_s",
                          "topology_mode", "restore_mode",
                          "restore_critical_s", "critical_bytes",
                          "critical_entries", "restored_from_replica")
                         if k in stats})
        # under a lazy restore wl.restore() returned on the critical set:
        # t_restored is the RESUME point, and the background stream is
        # closed out by _update_materialized once the workload joins it
        rec.recovery.mark_restored(self.clock(),
                                   restored_step=restored_step, **meta)
        self._register(rec, wl)
        rec.transition(JobState.RUNNING)
        inc = rec.recovery.current
        if inc is not None and restored_step >= inc["step_at_interrupt"]:
            # dump landed exactly at the interrupt step: nothing to replay
            rec.recovery.mark_caught_up(self.clock())
        rec.save()

    def _register(self, rec: JobRecord, wl) -> None:
        job_id = rec.spec.job_id
        self.workloads[job_id] = wl
        self.detector.register(job_id)
        # signal-handler tier: delivery is timestamped into the job
        # record the moment the scheduler sends it, so `repro jobs`
        # shows who was asked to yield even before the poll-side ack
        self.channel.register(
            job_id, lambda sig, rec=rec: rec.events.append(
                {"t": self.clock(), "signal": sig.value,
                 "step": rec.step}))
        if getattr(wl, "session", None) is not None:
            # glue: measured frozen windows feed τ* with no hand-wiring
            wl.session.set_planner(self.planners[job_id])

    # ------------------------------------------------------------- slices
    def _running_jobs(self) -> List[str]:
        return [j for j, r in self.records.items()
                if r.state == JobState.RUNNING and j in self.workloads]

    def _run_slices(self) -> int:
        from repro.api.session import SnapshotWriteFailed
        from repro.core.lazy import LazyRestoreError
        from repro.runtime.trainer import SimulatedFailure
        ran = 0
        for job_id in self._running_jobs():
            rec = self.records[job_id]
            wl = self.workloads[job_id]
            now = self.clock()
            if self.channel.pending(job_id) == Signal.KILL:
                # no grace window: the job just disappears; the detector
                # notices via the missed heartbeats
                self.channel.consume(job_id)
                self._crash_t[job_id] = now
                self.workloads.pop(job_id, None)
                continue
            prev_step = rec.step
            try:
                # dump/pack spans emitted inside the slice (planner-driven
                # checkpoints) carry the owning job id
                with obs_trace.context(job=job_id):
                    out = wl.run_slice(self.cfg.slice_steps,
                                       preempt=self.channel.checker(job_id))
            except SnapshotWriteFailed as e:
                # in-band abort: a background dump failed; the job stops
                # promptly instead of trusting phantom checkpoints
                self._fail_write_error(rec, now, e)
                continue
            except LazyRestoreError as e:
                # the lazy background stream died (torn cold chunk, no
                # replica): this job is half-restored and must stop —
                # never the whole loop; its retry falls back eagerly
                self._fail_write_error(rec, now, e, cause="restore_error")
                continue
            except SimulatedFailure:
                # crash: the "process" dies silently — heartbeats stop,
                # detection happens at the deadline like a real dead node.
                # Record the true progress at death so the incident's
                # replay accounting covers the partially-executed slice.
                rec.step = wl.step
                rec.save()
                self._crash_t[job_id] = self.clock()
                self.workloads.pop(job_id, None)
                continue
            ran += 1
            rec.step = wl.step
            rec.goodput.record_slice(prev_step, rec.step, out["wall_s"])
            self.detector.heartbeat(job_id)
            self._update_materialized(rec, wl)
            self._update_catch_up(rec)
            if out.get("preempted"):
                self._freeze_and_yield(rec, wl, out)
                continue
            self._maybe_signal_migration(rec)
            if getattr(wl, "session", None) is not None:
                latest = wl.session.latest_step()
                if latest is not None:
                    rec.last_ckpt_step = max(rec.last_ckpt_step or 0, latest)
            if wl.done:
                try:
                    wl.finish()            # drain pending async writes
                except Exception as e:
                    # the job's last dump never committed: this is a
                    # write_error fault, not a completed job
                    self._fail_write_error(rec, now, e)
                    continue
                self.final[job_id] = {"digest": wl.digest(),
                                      "step": rec.step,
                                      "jit_triggers": getattr(
                                          wl, "jit_triggers", 0)}
                rec.transition(JobState.DONE)
                self._evict(job_id)
                continue
            try:
                self._maybe_checkpoint(rec, wl, out)
            except Exception as e:
                # a dump that fails at freeze/commit time (e.g. a pending
                # async failure re-raised by wait_pending) is the same
                # fault as an in-slice write_error: stop the job promptly
                self._fail_write_error(rec, now, e)
                continue
            rec.save()
        return ran

    def _fail_write_error(self, rec: JobRecord, t_interrupt: float,
                          exc: BaseException,
                          cause: str = "write_error") -> None:
        """A snapshot write (or lazy restore stream) failed for this job:
        open an incident, mark it FAILED, and release its resources —
        never the whole loop."""
        rec.recovery.open(cause, t_interrupt=t_interrupt,
                          t_detect=self.clock(),
                          step_at_interrupt=rec.step,
                          last_ckpt_step=rec.last_ckpt_step)
        rec.transition(JobState.FAILED, write_error=repr(exc))
        self._evict(rec.spec.job_id)

    def _update_materialized(self, rec: JobRecord, wl) -> None:
        """Close the restore-background phase once the workload's
        first-touch join has drained the lazy stream (the session records
        ``restore_background_s`` at the barrier)."""
        session = getattr(wl, "session", None)
        if session is None:
            return
        bg = session.last_stats.get("restore_background_s")
        if bg is None or session.lazy_pending:
            return
        incs = rec.recovery.incidents
        if incs and incs[-1].get("t_restored") is not None \
                and incs[-1].get("t_materialized") is None:
            # anchor at t_restored + the measured stream wall rather than
            # "now": the barrier happened inside the workload's slice, and
            # this stays correct under injected test clocks
            rec.recovery.mark_materialized(
                incs[-1]["t_restored"] + bg, restore_background_s=bg,
                background_bytes=session.last_stats.get(
                    "background_bytes"))

    def _update_catch_up(self, rec: JobRecord) -> None:
        inc = rec.recovery.current
        if (inc is not None and inc["t_restored"] is not None
                and rec.step >= inc["step_at_interrupt"]):
            rec.recovery.mark_caught_up(self.clock())

    def _maybe_signal_migration(self, rec: JobRecord) -> None:
        """Drive a due migration.  Stop-and-copy: deliver a PREEMPT — the
        job checkpoints-on-signal and yields through the normal freeze
        path, where the pending plan routes it to :meth:`_migrate`.
        With a pre-copy policy the plan first enters the live ``precopy``
        phase: one delta round per tick while the job keeps stepping,
        until the convergence controller calls freeze (residual fits the
        blackout budget) or fallback (a cap tripped) — only then is the
        PREEMPT sent, and :meth:`_migrate` pushes just the residual."""
        job_id = rec.spec.job_id
        plan = self.migrations.get(job_id)
        if plan is None:
            return
        wl = self.workloads.get(job_id)
        if plan.state == "pending" and rec.step >= plan.at_step:
            policy = self.cfg.resolved_transfer_policy()
            if (policy.precopy_enabled
                    and getattr(wl, "session", None) is not None
                    and len(self.hosts) >= 2):
                self._begin_precopy(rec, wl, plan, policy)
            else:
                plan.state = "signalled"
                self.channel.send(job_id, Signal.PREEMPT)
                return
        if plan.state == "precopy" and wl is not None:
            self._advance_precopy(rec, wl, plan)

    def _begin_precopy(self, rec: JobRecord, wl, plan: MigrationPlan,
                       policy) -> None:
        """Open the live pre-copy phase: pick the destination now (rounds
        need a stable target CAS), build the round-capable replicator,
        and seed the convergence controller from any ledger a previous
        source incarnation left in that CAS — resumed rounds re-negotiate
        have/want and ship nothing twice."""
        from repro.orchestrator.workloads import host_cas_dir, job_dir_for
        from repro.transfer import DeltaReplicator, PrecopyController
        job_id = rec.spec.job_id
        plan.src_host = rec.host
        plan.dst_host = Scheduler.place(self.hosts, self._host_load(),
                                        avoid=rec.host)
        rep = DeltaReplicator(
            job_dir_for(self.run_dir, job_id, plan.dst_host),
            cas_dir=host_cas_dir(self.run_dir, plan.dst_host),
            workers=policy.workers)
        if not rep.supports_rounds:     # Replicator-protocol capability
            plan.state = "signalled"    # gate, not isinstance
            self.channel.send(job_id, Signal.PREEMPT)
            return
        ctrl = PrecopyController(policy)
        tag = f"{job_id}-mig{plan.at_step}"
        ledger = rep.round_state(tag)
        if ledger:
            ctrl.seed(ledger)
            plan.rounds = [dict(r) for r in ledger
                           if not r.get("residual")]
        self._precopy[job_id] = {"rep": rep, "ctrl": ctrl, "tag": tag,
                                 "errors": 0}
        plan.state = "precopy"
        rec.events.append({"t": self.clock(), "precopy_begin": rec.step,
                           "dst_host": plan.dst_host,
                           "resumed_rounds": len(plan.rounds)})

    def _advance_precopy(self, rec: JobRecord, wl,
                         plan: MigrationPlan) -> None:
        """One live round: snapshot-while-running, push the delta to the
        destination CAS, feed the controller, and either keep stepping or
        send the freeze signal.  A round that dies (e.g. a CAS partition)
        is retried next tick — the CAS ledger plus have/want negotiation
        make the retry incremental; two consecutive failures abandon
        convergence and fall back to stop-and-copy."""
        from repro.orchestrator.workloads import job_dir_for
        job_id = rec.spec.job_id
        ctx = self._precopy[job_id]
        src_dir = job_dir_for(self.run_dir, job_id, rec.host)
        try:
            with obs_trace.context(job=job_id):
                wl.checkpoint_running(rec.step)
                # async engines commit in the background; a round can
                # only ship an image whose manifest has landed
                wl.session.wait_pending()
                rec.last_ckpt_step = rec.step
                record = ctx["rep"].push_round(src_dir, rec.step,
                                               ctx["tag"])
        except Exception as e:
            ctx["errors"] += 1
            rec.events.append({"t": self.clock(), "step": rec.step,
                               "precopy_round_error": repr(e)})
            if ctx["errors"] >= 2:
                # the transfer plane is not coming back this migration:
                # stop iterating and take the stop-and-copy freeze
                plan.outcome = "fallback"
                plan.stats["fallback_reason"] = (
                    f"{ctx['errors']} consecutive round failures: "
                    f"{e!r}")
                plan.state = "signalled"
                self.channel.send(job_id, Signal.PREEMPT)
            return
        ctx["errors"] = 0
        plan.rounds.append(record)
        ctx["ctrl"].observe(record)
        decision = ctx["ctrl"].decide()
        rec.events.append({"t": self.clock(), "step": rec.step,
                           "precopy_round": record["round"],
                           "bytes_sent": record["bytes_sent"],
                           "decision": decision.action})
        if decision.action == "continue":
            return
        plan.outcome = ("converged" if decision.action == "freeze"
                        else "fallback")
        plan.stats.update(
            {"decision_reason": decision.reason,
             "predicted_residual_bytes":
                 decision.predicted_residual_bytes,
             "predicted_blackout_ms": decision.predicted_blackout_ms})
        plan.state = "signalled"
        self.channel.send(job_id, Signal.PREEMPT)

    def _freeze_and_yield(self, rec: JobRecord, wl, out) -> None:
        job_id = rec.spec.job_id
        sig = self.channel.consume(job_id)
        rec.transition(JobState.FREEZING, signal=getattr(sig, "value", sig),
                       ckpt_path=out.get("ckpt_path"))
        try:
            with obs_trace.context(job=job_id):
                wl.finish()           # drain async writers: image committed
        except Exception as e:
            # the checkpoint-on-signal never landed: the job yields as
            # FAILED and its restore falls back to the previous image
            self._fail_write_error(rec, self.clock(), e)
            return
        rec.last_ckpt_step = rec.step
        plan = self.migrations.get(job_id)
        if plan is not None and plan.state == "signalled":
            self._migrate(rec, wl, plan)
            return
        now = self.clock()
        rec.recovery.open("preemption", t_interrupt=now, t_detect=now,
                          step_at_interrupt=rec.step,
                          last_ckpt_step=rec.step)
        rec.transition(JobState.PREEMPTED)
        self._evict(job_id)

    # ---------------------------------------------------------- migration
    def _migrate(self, rec: JobRecord, wl, plan: MigrationPlan) -> None:
        """The job is frozen with a committed image on its source host:
        pick a destination, delta-transfer the image there, and yield as
        PREEMPTED with ``rec.host`` rebound — the next scheduling round
        restores it on the new host, step-exact."""
        from repro.orchestrator.workloads import job_dir_for
        from repro.transfer.precopy import summarize_rounds
        job_id = rec.spec.job_id
        now = self.clock()
        rec.recovery.open("migration", t_interrupt=now, t_detect=now,
                          step_at_interrupt=rec.step,
                          last_ckpt_step=rec.step)
        ctx = self._precopy.pop(job_id, None)
        if ctx is None:
            plan.src_host = rec.host
            plan.dst_host = Scheduler.place(self.hosts, self._host_load(),
                                            avoid=rec.host)
        src_dir = job_dir_for(self.run_dir, job_id, plan.src_host)
        dst_dir = job_dir_for(self.run_dir, job_id, plan.dst_host)
        t0 = self.clock()
        try:
            with obs_trace.context(job=job_id):
                if ctx is not None:
                    # pre-copy handoff: the job is frozen, push only the
                    # residual delta (everything else landed live)
                    step = wl.session.latest_step()
                    if step is None:
                        raise FileNotFoundError(
                            f"no image to migrate under {src_dir}")
                    residual = ctx["rep"].push_round(
                        src_dir, step, ctx["tag"], residual=True)
                    plan.rounds.append(residual)
                    stats = dict(ctx["rep"].stats, mode="delta-precopy",
                                 outcome=plan.outcome,
                                 **summarize_rounds(plan.rounds))
                    ctx["rep"].clear_rounds(ctx["tag"])
                else:
                    stats = self._transfer_image(wl, src_dir, dst_dir,
                                                 plan.dst_host)
        except Exception as e:
            # the image never reached the destination: stay on the source
            # host (its image is intact) and recover like a preemption.
            # A pre-copy ledger (and every landed chunk) stays in the
            # destination CAS: a retried migration resumes the rounds.
            plan.state = "failed"
            plan.stats = dict(plan.stats, error=repr(e))
            rec.events.append({"t": self.clock(), "migration_error": repr(e)})
        else:
            plan.state = "transferred"
            plan.stats = dict(plan.stats, **stats)
            rounds = list(plan.rounds)
            if not rounds:
                # stop-and-copy: the whole transfer is one frozen
                # residual round — recorded in the same per-round shape
                rounds = [{"round": 0, "residual": True,
                           "bytes_sent": stats.get(
                               "bytes_sent", stats.get("bytes_copied", 0)),
                           "wall_s": self.clock() - t0}]
            rec.recovery.mark_transfer(
                t0, self.clock(), rounds=rounds,
                **{k: stats[k] for k in
                   ("bytes_sent", "bytes_reused", "bytes_copied",
                    "chunks_sent", "chunks_reused",
                    "precopy_bytes", "residual_bytes", "blackout_s",
                    "outcome") if k in stats})
            rec.host = plan.dst_host
            rec.events.append({
                "t": self.clock(), "step": rec.step,
                "migrated": {"from": plan.src_host, "to": plan.dst_host,
                             "bytes_sent": stats.get("bytes_sent",
                                                     stats.get("bytes", 0)),
                             "bytes_reused": stats.get("bytes_reused", 0),
                             "rounds": len(plan.rounds)}})
        rec.transition(JobState.PREEMPTED)
        self._evict(job_id)

    def _transfer_image(self, wl, src_dir: str, dst_dir: str,
                        dst_host: str) -> Dict[str, Any]:
        """Move one job's checkpoint state between host directories.
        Session-backed workloads go through the content-addressed
        :class:`DeltaReplicator` (or whole-file copy when configured);
        sessionless baselines (interception) copy their replay logs."""
        if getattr(wl, "session", None) is None:
            import shutil
            os.makedirs(dst_dir, exist_ok=True)
            nbytes, nfiles = 0, 0
            for name in sorted(os.listdir(src_dir)):
                p = os.path.join(src_dir, name)
                if os.path.isfile(p):
                    shutil.copy2(p, os.path.join(dst_dir, name))
                    nbytes += os.path.getsize(p)
                    nfiles += 1
            return {"mode": "full-copy", "bytes_copied": nbytes,
                    "files_copied": nfiles}
        step = wl.session.latest_step()
        if step is None:
            raise FileNotFoundError(f"no image to migrate under {src_dir}")
        policy = self.cfg.resolved_transfer_policy()
        if policy.mode == "delta":
            from repro.orchestrator.workloads import host_cas_dir
            from repro.transfer import DeltaReplicator
            rep = DeltaReplicator(
                dst_dir, cas_dir=host_cas_dir(self.run_dir, dst_host),
                workers=policy.workers)
            return dict(rep.push(src_dir, step), mode="delta")
        # whole-file copy: the closure still has to move (an incremental
        # child is unrestorable without its parents)
        from repro.core.replication import DirReplicator
        from repro.transfer.delta import transfer_closure
        rep = DirReplicator(dst_dir)
        total = {"mode": "copy", "bytes_copied": 0, "files_copied": 0,
                 "bytes_skipped": 0, "files_skipped": 0}
        for s in transfer_closure(wl.session.store, step):
            st = rep.push(src_dir, s)
            for k in ("bytes_copied", "files_copied",
                      "bytes_skipped", "files_skipped"):
                total[k] += st[k]
        return total

    # ----------------------------------------------------------- cadence
    def _maybe_checkpoint(self, rec: JobRecord, wl, out) -> None:
        job_id = rec.spec.job_id
        last = rec.last_ckpt_step or 0
        since = rec.step - last
        step_time = out["wall_s"] / max(out.get("steps", 1), 1)
        due = False
        jit = False
        if rec.spec.ckpt_every > 0:
            due = since >= rec.spec.ckpt_every
        else:
            # τ*-driven cadence: the planner's cost estimate tracks the
            # session's measured frozen windows (set_planner glue)
            due = since >= self.planners[job_id].steps_between_checkpoints(
                step_time)
        if self.stragglers[job_id].record(step_time):
            cool = rec.step - self._last_jit.get(job_id, -10**9)
            if cool >= self.cfg.jit_cooldown_steps:
                due = jit = True
                self._last_jit[job_id] = rec.step
        if due and since > 0:
            wl.checkpoint(rec.step)
            rec.last_ckpt_step = rec.step
            rec.events.append({"t": self.clock(), "checkpoint": rec.step,
                               "jit": jit})

    # ----------------------------------------------------------- summary
    def summary(self) -> Dict[str, Any]:
        now = self.clock()
        wall = now - (self.t0 if self.t0 is not None else now)
        jobs = {}
        useful_s = 0.0
        for job_id, rec in self.records.items():
            job_wall = ((rec.finished_t or now) - rec.created_t) or 1e-9
            useful_s += rec.goodput.useful_step_seconds()
            plan = self.migrations.get(job_id)
            jobs[job_id] = {
                "kind": rec.spec.kind,
                "priority": rec.spec.priority,
                "state": rec.state.value,
                "host": rec.host,
                "migration": (None if plan is None else
                              {"state": plan.state, "from": plan.src_host,
                               "to": plan.dst_host,
                               "outcome": plan.outcome,
                               "rounds": [dict(r) for r in plan.rounds],
                               **plan.stats}),
                "step": rec.step,
                "total_steps": rec.spec.total_steps,
                "attempts": rec.attempt + 1,
                "restarts": rec.restarts,
                "goodput": rec.goodput.goodput(job_wall),
                "recovery": rec.recovery.breakdown(),
                "recovery_totals": rec.recovery.totals(),
                "checkpoints": sum(1 for e in rec.events
                                   if "checkpoint" in e),
                "jit_checkpoints": (
                    sum(1 for e in rec.events if e.get("jit"))
                    + self.final.get(job_id, {}).get("jit_triggers", 0)),
                "last_ckpt_step": rec.last_ckpt_step,
                "digest": self.final.get(job_id, {}).get("digest"),
            }
        return {"wall_s": wall, "ticks": self.ticks,
                "capacity": self.cfg.capacity,
                "hosts": max(self.cfg.hosts, 1),
                "cluster_goodput": useful_s / wall if wall > 0 else 0.0,
                "all_done": all(r.state == JobState.DONE
                                for r in self.records.values()),
                "jobs": jobs}
