"""Workload adapters: what the orchestrator runs inside one job.

A workload is the in-process stand-in for "the container the scheduler
manages": it exposes progress (``step``/``done``), cooperates with
preemption (``run_slice(n, preempt=...)`` checkpoints-on-signal and
yields), and can be rebuilt from its image after the fact (``restore``)
— node-replacement semantics, a *fresh* object per attempt.

Three kinds, matching the bench's engine axis:

  * :class:`TrainWorkload` — ``runtime.Trainer`` on the session engine
    (sync or async+pipelined per :class:`CheckpointOptions`);
  * :class:`ServeWorkload` — ``runtime.DecodeServer`` decoding a batch,
    preempted and resumed token-exact mid-generation;
  * :class:`InterceptionWorkload` — the Cricket-style API-interception
    baseline: checkpoint = persist replay log, restore = re-execute it.

``digest()`` hashes the live state so tests can assert bit-exactness of a
preempted-and-recovered run against an undisturbed one.
"""
from __future__ import annotations

import hashlib
import os
import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import CheckpointOptions, CheckpointSession
from repro.orchestrator.job import JobSpec

PyTree = Any


def _default_mesh(mesh):
    if mesh is not None:
        return mesh
    from repro.launch.mesh import make_mesh
    return make_mesh((1,), ("data",))


def _tree_digest(*trees: PyTree) -> str:
    h = hashlib.sha256()
    from repro.core.device_plugin import flatten_with_paths
    for tree in trees:
        flat = flatten_with_paths(tree)
        for k in sorted(flat):
            h.update(k.encode())
            h.update(np.ascontiguousarray(np.asarray(flat[k])).tobytes())
    return h.hexdigest()


class TrainWorkload:
    kind = "train"

    def __init__(self, spec: JobSpec, run_dir: str, mesh,
                 options: Optional[CheckpointOptions] = None,
                 attempt: int = 0, seed: int = 0):
        from repro.configs import get_smoke_config
        from repro.runtime.trainer import TrainConfig, Trainer
        from repro.sharding import get_policy
        self.spec = spec
        cfg = get_smoke_config("qwen1.5-0.5b")
        tcfg = TrainConfig(batch_size=2, seq_len=32,
                           total_steps=max(spec.total_steps, 1),
                           lr=5e-3, warmup_steps=2, seed=seed,
                           compute_dtype=jnp.float32, remat=False,
                           ckpt=options)
        self.trainer = Trainer(cfg, tcfg, _default_mesh(mesh),
                               get_policy("baseline"), run_dir)
        # injected faults fire on the first incarnation only — a restarted
        # attempt replays past the fault point cleanly
        self._fail_at = spec.fail_at_step if attempt == 0 else None
        self._straggle_at = spec.straggle_at_step if attempt == 0 else None

    @property
    def session(self) -> CheckpointSession:
        return self.trainer.session

    @property
    def step(self) -> int:
        return self.trainer.step

    @property
    def done(self) -> bool:
        return self.trainer.step >= self.spec.total_steps

    def start(self) -> None:
        self.trainer.initialize()

    def run_slice(self, n_steps: int,
                  preempt: Optional[Callable[[], bool]] = None
                  ) -> Dict[str, Any]:
        target = min(self.trainer.step + n_steps, self.spec.total_steps)
        return self.trainer.run_until(target, preempt=preempt,
                                      fail_at=self._fail_at,
                                      straggle_at=self._straggle_at)

    def checkpoint(self, step: int) -> str:
        return self.session.checkpoint(step)

    def checkpoint_running(self, step: int) -> str:
        """Pre-copy round capture: commit a snapshot with the smallest
        pause the session's capture mode allows (soft-freeze pin+validate
        under capture="concurrent", an ordinary dump otherwise)."""
        return self.session.checkpoint_running(step)

    def restore(self) -> int:
        return self.trainer.restore()

    def finish(self) -> None:
        self.session.wait_pending()

    @property
    def jit_triggers(self) -> int:
        """Just-in-time checkpoints fired by the trainer's own straggler
        monitor (inside ``run_until``), invisible to the orchestrator's
        slice-level cadence — surfaced for the bench's straggler rows."""
        return len(self.trainer.jit_ckpt.triggered)

    def digest(self) -> str:
        return _tree_digest({"params": self.trainer.params,
                             "opt": self.trainer.opt_state})


class ServeWorkload:
    """Decode-serving job: total_steps = tokens to decode for the batch."""

    kind = "serve"

    def __init__(self, spec: JobSpec, run_dir: str, mesh,
                 options: Optional[CheckpointOptions] = None,
                 attempt: int = 0, seed: int = 0):
        from repro.configs import get_smoke_config
        from repro.runtime.server import DecodeServer
        from repro.sharding import get_policy
        self.spec = spec
        self.seed = seed
        cfg = get_smoke_config("qwen1.5-0.5b")
        self.server = DecodeServer(cfg, get_policy("baseline"),
                                   _default_mesh(mesh), run_dir,
                                   max_seq=64, options=options)
        self._prompt_len = 8
        self._fail_at = spec.fail_at_step if attempt == 0 else None
        self._straggle_at = spec.straggle_at_step if attempt == 0 else None

    @property
    def session(self) -> CheckpointSession:
        return self.server.session

    @property
    def step(self) -> int:
        """Tokens decoded since prefill."""
        return max(0, self.server.pos - self._prompt_len)

    @property
    def done(self) -> bool:
        return self.step >= self.spec.total_steps

    def start(self) -> None:
        rng = np.random.default_rng(self.seed)
        prompt = rng.integers(
            1, self.server.cfg.vocab_size,
            size=(2, self._prompt_len)).astype(np.int32)
        params = self.server.model.init(jax.random.key(self.seed))
        self.server.load(params)
        self.server.start({"tokens": prompt})

    def run_slice(self, n_steps: int,
                  preempt: Optional[Callable[[], bool]] = None
                  ) -> Dict[str, Any]:
        target = min(self.step + n_steps, self.spec.total_steps)
        out = self.server.decode_until(
            self._prompt_len + target, preempt=preempt,
            fail_at=(None if self._fail_at is None
                     else self._prompt_len + self._fail_at),
            straggle_at=(None if self._straggle_at is None
                         else self._prompt_len + self._straggle_at))
        out["step"] = self.step
        return out

    def checkpoint(self, step: int) -> str:
        return self.session.checkpoint(step)

    def checkpoint_running(self, step: int) -> str:
        """Pre-copy round capture: commit a snapshot with the smallest
        pause the session's capture mode allows (soft-freeze pin+validate
        under capture="concurrent", an ordinary dump otherwise)."""
        return self.session.checkpoint_running(step)

    def restore(self) -> int:
        # cold boot: the image carries params, cache, and cursor; the
        # server derives abstract skeletons from the model — no prefill
        # re-execution on a replacement node
        self.server.restore()
        return self.step

    def finish(self) -> None:
        self.session.wait_pending()

    def digest(self) -> str:
        h = hashlib.sha256()
        h.update(np.ascontiguousarray(
            np.asarray(self.server.tokens, np.int32)).tobytes())
        h.update(str(self.server.pos).encode())
        return h.hexdigest()


class InterceptionWorkload:
    """Cricket-style baseline driven through the same job lifecycle.

    Checkpoint persists the full intercept log; restore replays it call by
    call from the initial state — recovery time grows with progress, which
    is exactly the Table-2 contrast the bench measures against the
    CRIUgpu-style engines.
    """

    kind = "intercept"

    def __init__(self, spec: JobSpec, run_dir: str, mesh=None,
                 options: Optional[CheckpointOptions] = None,
                 attempt: int = 0, seed: int = 0):
        from repro.baselines.interception import InterceptionCheckpointer
        self.spec = spec
        self.run_dir = run_dir
        os.makedirs(run_dir, exist_ok=True)
        self.ic = InterceptionCheckpointer(run_dir)
        key = jax.random.key(seed)
        k1, k2 = jax.random.split(key)
        self._w0 = {"w1": jax.random.normal(k1, (10, 32)) * 0.1,
                    "w2": jax.random.normal(k2, (32, 1)) * 0.1}
        rng = np.random.default_rng(seed)
        self._x = rng.normal(size=(16, 10)).astype(np.float32)
        self._y = rng.normal(size=(16, 1)).astype(np.float32)
        self._step_fn = self._make_step()
        self.w: Optional[PyTree] = None
        self.step = 0
        self._last_ckpt: Optional[str] = None
        self._fail_at = spec.fail_at_step if attempt == 0 else None
        self._straggle_at = spec.straggle_at_step if attempt == 0 else None
        self.session = None             # no session engine underneath

    @staticmethod
    def _make_step():
        @jax.jit
        def step(w, x, y):
            def loss(w):
                h = jnp.tanh(x @ w["w1"])
                return jnp.mean((h @ w["w2"] - y) ** 2)
            g = jax.grad(loss)(w)
            return jax.tree.map(lambda a, b: a - 0.01 * b, w, g)
        return step

    @property
    def done(self) -> bool:
        return self.step >= self.spec.total_steps

    def start(self) -> None:
        self.w = self._w0
        self.ic.register_initial_state("w", self.w)
        self._wrapped = self.ic.wrap(self._step_fn, "step")

    def run_slice(self, n_steps: int,
                  preempt: Optional[Callable[[], bool]] = None
                  ) -> Dict[str, Any]:
        from repro.runtime.trainer import SimulatedFailure
        t0 = time.perf_counter()
        executed, preempted, ckpt_path = 0, False, None
        target = min(self.step + n_steps, self.spec.total_steps)
        while self.step < target:
            if preempt is not None and preempt():
                ckpt_path = self.checkpoint(self.step)
                preempted = True
                break
            if self._fail_at is not None and self.step == self._fail_at:
                raise SimulatedFailure(
                    f"injected failure at {self.step}")
            if (self._straggle_at is not None
                    and self.step == self._straggle_at):
                time.sleep(0.25)                   # injected straggler
            self.w = self._wrapped(self.w, self._x, self._y)
            self.step += 1
            executed += 1
        jax.block_until_ready(jax.tree.leaves(self.w))
        return {"steps": executed, "step": self.step,
                "preempted": preempted, "ckpt_path": ckpt_path,
                "wall_s": time.perf_counter() - t0}

    def checkpoint(self, step: int) -> str:
        self._last_ckpt = self.ic.checkpoint(step)
        return self._last_ckpt

    def restore(self) -> int:
        import glob
        import pickle
        paths = sorted(glob.glob(os.path.join(self.run_dir,
                                              "intercept_*.pkl")))
        if not paths:
            raise FileNotFoundError(
                f"no interception image under {self.run_dir}")
        path = paths[-1]
        self.start()
        results, stats = self.ic.restore(path, {"step": self._step_fn})
        with open(path, "rb") as f:
            payload = pickle.load(f)
        self.step = payload["step"]
        # the final weights are the last logged call's outputs (or the
        # initial state when nothing was logged before the dump)
        if payload["log"]:
            leaves = [results[h] for h in payload["log"][-1]["out_handles"]]
            treedef = jax.tree_util.tree_structure(self._w0)
            self.w = jax.tree_util.tree_unflatten(treedef, leaves)
        # replaying restored progress up to `step`; re-wrap so post-restore
        # steps keep extending a fresh log from the restored state
        self.ic = type(self.ic)(self.run_dir)
        self.ic.register_initial_state("w", self.w)
        self._wrapped = self.ic.wrap(self._step_fn, "step")
        self._restore_stats = stats
        return self.step

    def finish(self) -> None:
        pass

    def digest(self) -> str:
        return _tree_digest({"w": self.w})


WORKLOADS = {"train": TrainWorkload, "serve": ServeWorkload,
             "intercept": InterceptionWorkload}


def job_dir_for(base_run_dir: str, job_id: str,
                host: Optional[str] = None) -> str:
    """Where one job's images live.  Single-host clusters keep the flat
    ``job_<id>`` layout; multi-host clusters nest it under the simulated
    host (``<host>/job_<id>``) — the migration transfer moves images
    between exactly these directories."""
    if host is None:
        return os.path.join(base_run_dir, f"job_{job_id}")
    return os.path.join(base_run_dir, host, f"job_{job_id}")


def host_cas_dir(base_run_dir: str, host: str) -> str:
    """One content-addressed chunk store per simulated host: transfers
    to the same host share dedup state across jobs and steps (the
    warm-CAS recovery-time win)."""
    return os.path.join(base_run_dir, host, ".cas")


def make_workload_factory(base_run_dir: str,
                          options: Optional[CheckpointOptions] = None,
                          mesh=None) -> Callable[..., Any]:
    """Factory of factories: one job = one image dir under the run dir."""
    if mesh is None:
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((1,), ("data",))

    def factory(spec: JobSpec, attempt: int, host: Optional[str] = None):
        cls = WORKLOADS[spec.kind]
        job_dir = job_dir_for(base_run_dir, spec.job_id, host)
        return cls(spec, job_dir, mesh=mesh, options=options,
                   attempt=attempt)

    return factory
