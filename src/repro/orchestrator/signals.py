"""Injectable signal channel — the SIGTERM-of-the-cluster analogue.

Kubernetes sends SIGTERM and gives the pod a grace window; CRIUgpu's
answer is "dump inside the window, exit clean".  Here the scheduler posts
a :class:`Signal` onto the channel; delivery is two-tier:

  * an optional registered handler fires synchronously at send time (the
    signal-handler analogue — the orchestrator uses it to timestamp the
    delivery into the job's event record), and
  * the workload's step loop polls ``pending()`` between steps (the
    in-band check the dump actually hangs off — ``Trainer.run_until``'s
    ``preempt=`` callable).

Everything is in-process and deterministic so tests and the bench can
script exact preemption points, but the interface is what a real signal
path (signalfd / SIGTERM trap) would present to the orchestrator.
"""
from __future__ import annotations

import enum
from typing import Callable, Dict, List, Optional

from repro.chaos import hooks as chaos_hooks


class Signal(str, enum.Enum):
    PREEMPT = "SIGPREEMPT"          # checkpoint then yield the devices
    KILL = "SIGKILL"                # no grace: drop without dumping


class SignalChannel:
    def __init__(self) -> None:
        self._pending: Dict[str, List[Signal]] = {}
        self._handlers: Dict[str, Callable[[Signal], None]] = {}
        self.sent: List[tuple] = []          # (job_id, signal) audit trail

    def register(self, job_id: str,
                 handler: Callable[[Signal], None]) -> None:
        self._handlers[job_id] = handler

    def unregister(self, job_id: str) -> None:
        self._handlers.pop(job_id, None)
        self._pending.pop(job_id, None)

    def send(self, job_id: str, sig: Signal = Signal.PREEMPT) -> None:
        if chaos_hooks.INJECTOR is not None:
            # chaos: flaky-delivery site — a handler may duplicate this
            # signal (it appends the extra copy itself) or defer it
            # (returns "defer"; the injector redelivers it later)
            if chaos_hooks.fire("signal.send", channel=self,
                                job_id=job_id, sig=sig) == "defer":
                return
        self._pending.setdefault(job_id, []).append(sig)
        self.sent.append((job_id, sig))
        handler = self._handlers.get(job_id)
        if handler is not None:
            handler(sig)

    def pending(self, job_id: str) -> Optional[Signal]:
        """Peek (non-destructive): the oldest undelivered signal."""
        q = self._pending.get(job_id)
        return q[0] if q else None

    def consume(self, job_id: str) -> Optional[Signal]:
        """Pop the oldest signal (the workload acknowledged it)."""
        q = self._pending.get(job_id)
        if not q:
            return None
        sig = q.pop(0)
        if not q:
            self._pending.pop(job_id, None)
        return sig

    def checker(self, job_id: str) -> Callable[[], bool]:
        """Zero-arg predicate for ``Trainer.run_until(preempt=...)``."""
        return lambda: self.pending(job_id) is not None
