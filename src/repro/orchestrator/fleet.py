"""Snapshot-fork serving fleet: N decode replicas from one image.

The serving-scale consequence of driver-level snapshots (the paper's
"significantly reduce recovery times" claim, pushed to the multi-tenant
GPU-sharing setting of the MPS/PhoenixOS line in PAPERS.md): one
committed :class:`~repro.runtime.server.DecodeServer` image fans out
into K replicas cheaply because every piece of the restore path is
content-addressed and lazy.

  * one **source image**: a solo server prefills + decodes a few tokens
    and commits — that snapshot is the fleet's only artifact;
  * **delta-replicate once per host**: each simulated host owns a shared
    CAS (:func:`~repro.orchestrator.workloads.host_cas_dir`); the first
    replica on a host pays the cold chunk fill, every later replica
    negotiates have/want against the warm CAS and ships ~0 new bytes —
    total restore bytes grow sub-linearly in K;
  * **lazy cold boot**: each replica restores with the params-only
    critical set and decodes its first token while the KV cache streams
    behind it (the resume-before-read story, per replica);
  * **per-replica TTFT**: every boot is one
    :class:`~repro.orchestrator.recovery.RecoveryLog` incident
    (transfer -> schedule -> restore -> first token) and one
    ``fleet.boot`` span, so ``repro trace`` shows the fan-out timeline.

:meth:`ServingFleet.serve_trace` then drives a deterministic bursty
request trace with autoscale-on-queue-depth: a queue spike boots another
replica (through the same measured path), sustained idle drains one.

All replicas share one model object (and therefore one jit cache): the
fleet compiles prefill/decode exactly once, not K times.
"""
from __future__ import annotations

import os
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import numpy as np

from repro.api import CheckpointOptions
from repro.chaos import hooks
from repro.obs import journal as obs_journal
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.orchestrator.recovery import RecoveryLog
from repro.orchestrator.workloads import host_cas_dir, job_dir_for


@dataclass
class FleetConfig:
    """Knobs for one fleet run (see docs/ARCHITECTURE.md for the table)."""

    replicas: int = 8                 # initial fan-out target
    hosts: int = 2                    # simulated hosts (one CAS each)
    restore_mode: str = "lazy"        # "lazy" (params-critical) | "eager"
    arch: str = "qwen1.5-0.5b"
    batch: int = 2                    # prompt batch baked into the image
    prompt_len: int = 8
    warm_tokens: int = 4              # decoded before the image commits
    max_seq: int = 64
    seed: int = 0
    tokens_per_request: int = 4       # decode work per served request
    scale_up_depth: int = 2           # queue > depth*serving -> boot one
    drain_idle_ticks: int = 2         # idle ticks before draining one
    min_replicas: int = 1
    max_replicas: int = 64


@dataclass
class Replica:
    rid: str
    host: str
    status: str = "booting"           # booting|serving|dead|drained
    ttft_s: Optional[float] = None
    diagnosis: Optional[str] = None
    transfer: Dict[str, Any] = field(default_factory=dict)
    served_requests: int = 0
    served_tokens: int = 0
    autoscaled: bool = False
    server: Any = None
    recovery: Optional[RecoveryLog] = None


class ServingFleet:
    """K decode replicas forked from one committed image."""

    def __init__(self, run_dir: str, config: Optional[FleetConfig] = None,
                 mesh=None):
        from repro.configs import get_smoke_config
        from repro.models.encdec import build_model
        from repro.orchestrator.workloads import _default_mesh
        from repro.sharding import get_policy
        self.run_dir = run_dir
        self.config = config or FleetConfig()
        self.mesh = _default_mesh(mesh)
        self.cfg = get_smoke_config(self.config.arch)
        self.policy = get_policy("baseline")
        # one model, one jit cache, K replicas
        self.model = build_model(self.cfg, self.policy, self.mesh,
                                 remat=False)
        self.replicas: List[Replica] = []
        self.source = None                  # the solo (unforked) server
        self.source_dir = os.path.join(run_dir, "source")
        self.image_step: Optional[int] = None
        self.image_bytes: int = 0
        self.serve_stats: Dict[str, Any] = {}
        self._rr_host = 0

    # ---------------------------------------------------------- image
    def _options(self) -> CheckpointOptions:
        return CheckpointOptions(restore_mode=self.config.restore_mode)

    def _make_server(self, run_dir: str):
        from repro.runtime.server import DecodeServer
        return DecodeServer(self.cfg, self.policy, self.mesh, run_dir,
                            max_seq=self.config.max_seq,
                            options=self._options(), model=self.model)

    def build_source_image(self) -> Dict[str, Any]:
        """Boot the solo server, warm it, commit the fleet's one image."""
        import jax
        c = self.config
        srv = self._make_server(self.source_dir)
        rng = np.random.default_rng(c.seed)
        prompt = rng.integers(1, self.cfg.vocab_size,
                              size=(c.batch, c.prompt_len)).astype(np.int32)
        srv.load(self.model.init(jax.random.key(c.seed)))
        srv.start({"tokens": prompt})
        srv.decode(c.warm_tokens)
        srv.checkpoint(srv.pos)
        srv.session.wait_pending()
        self.source = srv
        self.image_step = srv.pos
        self.image_bytes = _dir_bytes(self._image_dir())
        obs_journal.emit("fleet", "image_committed", step=self.image_step,
                         bytes=self.image_bytes)
        return {"step": self.image_step, "bytes": self.image_bytes}

    def _image_dir(self) -> str:
        from repro.core.snapshot_io import snapshot_dir
        return snapshot_dir(self.source_dir, self.image_step)

    # ---------------------------------------------------------- boot
    def _next_host(self) -> str:
        host = f"h{self._rr_host % max(1, self.config.hosts)}"
        self._rr_host += 1
        return host

    def boot_replica(self, host: Optional[str] = None,
                     autoscaled: bool = False) -> Replica:
        """Fork one replica from the image: push -> cold restore -> first
        token.  The whole window is one ``fleet.boot`` span and one
        RecoveryLog incident whose ``total_s`` is the replica's TTFT."""
        if self.image_step is None:
            raise RuntimeError("build_source_image() first")
        from repro.transfer import DeltaReplicator
        rid = f"r{len(self.replicas):03d}"
        host = host if host is not None else self._next_host()
        rep = Replica(rid=rid, host=host, autoscaled=autoscaled)
        rep.recovery = RecoveryLog(job_id=rid)
        self.replicas.append(rep)
        rep_dir = job_dir_for(self.run_dir, rid, host)
        t0 = time.perf_counter()
        rep.recovery.open("fleet_boot", t0, t0,
                          step_at_interrupt=self.image_step,
                          last_ckpt_step=self.image_step)
        obs_metrics.counter_add("fleet.replicas_booted")
        try:
            with obs_trace.span("fleet.boot", replica=rid, host=host,
                                autoscaled=autoscaled) as sp:
                if hooks.INJECTOR is not None:
                    hooks.fire("fleet.boot", replica=rid, host=host)
                # one push per replica; the host CAS makes every push
                # after the host's first a ~0-byte negotiation
                t1 = time.perf_counter()
                stats = DeltaReplicator(
                    rep_dir, cas_dir=host_cas_dir(self.run_dir, host)
                ).push(self.source_dir, self.image_step)
                t2 = time.perf_counter()
                rep.transfer = stats
                rep.recovery.mark_transfer(
                    t1, t2, bytes_sent=stats["bytes_sent"],
                    chunks_reused=stats["chunks_reused"])
                obs_metrics.counter_add("fleet.restore_bytes",
                                        float(stats["bytes_sent"]))
                rep.recovery.mark_scheduled(t2)
                rep.server = self._make_server(rep_dir)
                rep.server.restore(step=self.image_step)
                t3 = time.perf_counter()
                rep.recovery.mark_restored(t3, self.image_step)
                rep.server.decode(1)          # first token (joins lazy)
                t4 = time.perf_counter()
                rep.recovery.mark_caught_up(t4)
                rep.recovery.mark_materialized(t4)
                rep.ttft_s = t4 - t0
                rep.status = "serving"
                sp.set(ttft_s=rep.ttft_s,
                       bytes_sent=stats["bytes_sent"])
        except Exception as e:                      # noqa: BLE001
            # a dead boot quarantines the replica, not the fleet: the
            # diagnosis is the audit record chaos tests assert on
            rep.status = "dead"
            rep.diagnosis = f"{type(e).__name__}: {e}"
            rep.server = None
            obs_journal.emit("fleet", "boot_failed", replica=rid,
                             host=host, diagnosis=rep.diagnosis)
        else:
            obs_metrics.observe("fleet.ttft_s", rep.ttft_s)
            obs_journal.emit("fleet", "replica_boot", replica=rid,
                             host=host, ttft_s=rep.ttft_s,
                             bytes_sent=stats["bytes_sent"])
        obs_metrics.gauge_set("fleet.replicas_serving",
                              float(len(self.serving())))
        return rep

    def boot_fleet(self, n: Optional[int] = None) -> List[Replica]:
        for _ in range(n if n is not None else self.config.replicas):
            self.boot_replica()
        return self.replicas

    # ---------------------------------------------------------- queries
    def serving(self) -> List[Replica]:
        return [r for r in self.replicas if r.status == "serving"]

    def quarantined(self) -> List[Replica]:
        return [r for r in self.replicas if r.status == "dead"]

    # ---------------------------------------------------------- serving
    def _has_capacity(self, rep: Replica) -> bool:
        return (rep.server.pos + self.config.tokens_per_request
                <= rep.server.max_seq)

    def _drain(self, rep: Replica, reason: str) -> None:
        rep.status = "drained"
        rep.diagnosis = reason
        rep.server = None
        obs_journal.emit("fleet", "replica_drained", replica=rep.rid,
                         reason=reason)
        obs_metrics.gauge_set("fleet.replicas_serving",
                              float(len(self.serving())))

    def serve_trace(self, trace: List[int],
                    max_drain_ticks: int = 200) -> Dict[str, Any]:
        """Drive a deterministic bursty request trace against the fleet.

        ``trace[i]`` requests arrive at tick ``i``; each serving replica
        completes at most one request (``tokens_per_request`` decoded
        tokens) per tick.  Queue depth above ``scale_up_depth x serving``
        boots one replica that tick; ``drain_idle_ticks`` consecutive
        empty-queue ticks drain one (never below ``min_replicas``).
        After the trace the loop keeps ticking until the queue is empty.

        Goodput here is deterministic — requests served per
        replica-tick of capacity — so the bench row is seed-stable.
        """
        c = self.config
        pending = 0
        served = 0
        idle_ticks = 0
        replica_ticks = 0
        autoscale_boots = 0
        drains = 0
        ticks = 0
        with obs_trace.span("fleet.serve", replicas=len(self.replicas),
                            trace_ticks=len(trace)) as sp:
            arrivals_iter = list(trace)
            while arrivals_iter or pending > 0:
                arrivals = arrivals_iter.pop(0) if arrivals_iter else 0
                ticks += 1
                if not arrivals_iter and ticks > len(trace) \
                        + max_drain_ticks:
                    break                       # wedged fleet backstop
                pending += arrivals
                live = self.serving()
                # scale up on spike: one measured boot per tick
                if (pending > c.scale_up_depth * max(1, len(live))
                        and len(live) < c.max_replicas):
                    rep = self.boot_replica(autoscaled=True)
                    if rep.status == "serving":
                        autoscale_boots += 1
                        live = self.serving()
                # dispatch: one request per serving replica per tick
                for rep in live:
                    if pending == 0:
                        break
                    if not self._has_capacity(rep):
                        self._drain(rep, "max_seq reached")
                        drains += 1
                        continue
                    rep.server.decode(c.tokens_per_request)
                    rep.served_requests += 1
                    rep.served_tokens += c.tokens_per_request
                    pending -= 1
                    served += 1
                replica_ticks += len(self.serving())
                # scale down on sustained idle
                idle_ticks = idle_ticks + 1 if pending == 0 else 0
                if idle_ticks >= c.drain_idle_ticks:
                    live = self.serving()
                    if len(live) > c.min_replicas:
                        self._drain(live[-1], "idle")
                        drains += 1
                    idle_ticks = 0
            goodput = served / replica_ticks if replica_ticks else 0.0
            sp.set(served=served, ticks=ticks, goodput=goodput)
        obs_metrics.counter_add("fleet.requests_served", float(served))
        self.serve_stats = {
            "requests_arrived": int(sum(trace)),
            "requests_served": served,
            "requests_unserved": pending,
            "ticks": ticks,
            "replica_ticks": replica_ticks,
            "goodput_requests_per_replica_tick": goodput,
            "autoscale_boots": autoscale_boots,
            "drains": drains,
        }
        return self.serve_stats

    # ---------------------------------------------------------- report
    def summary(self) -> Dict[str, Any]:
        ttfts = sorted(r.ttft_s for r in self.replicas
                       if r.ttft_s is not None)
        total_sent = sum(r.transfer.get("bytes_sent", 0)
                         for r in self.replicas)
        total_reused = sum(r.transfer.get("bytes_reused", 0)
                           for r in self.replicas)
        hosts: Dict[str, Dict[str, Any]] = {}
        for r in self.replicas:
            h = hosts.setdefault(r.host, {"replicas": 0, "bytes_sent": 0})
            h["replicas"] += 1
            h["bytes_sent"] += r.transfer.get("bytes_sent", 0)
        # cross-check our accounting against each host CAS's own
        # transfer log (the store records every push it served)
        from repro.transfer import ChunkStore
        for h, agg in hosts.items():
            cas = host_cas_dir(self.run_dir, h)
            if os.path.isdir(cas):
                agg["cas_log_bytes_sent"] = sum(
                    t.get("bytes_sent", 0)
                    for t in ChunkStore(cas).transfer_log())
        denom = total_sent + total_reused
        out = {
            "replicas": len(self.replicas),
            "serving": len(self.serving()),
            "dead": len(self.quarantined()),
            "drained": len([r for r in self.replicas
                            if r.status == "drained"]),
            "hosts": hosts,
            "image_step": self.image_step,
            "image_bytes": self.image_bytes,
            "total_restore_bytes": total_sent,
            "restore_bytes_per_replica": (total_sent / len(self.replicas)
                                          if self.replicas else 0.0),
            "restore_bytes_vs_image": (total_sent / self.image_bytes
                                       if self.image_bytes else 0.0),
            "dedup_ratio": (total_reused / denom) if denom else 0.0,
            "ttft_p50_s": _pct(ttfts, 0.50),
            "ttft_p99_s": _pct(ttfts, 0.99),
            "ttft_first_s": ttfts[0] if ttfts else None,
            "per_replica": [{
                "rid": r.rid, "host": r.host, "status": r.status,
                "ttft_s": r.ttft_s, "diagnosis": r.diagnosis,
                "autoscaled": r.autoscaled,
                "bytes_sent": r.transfer.get("bytes_sent"),
                "chunks_reused": r.transfer.get("chunks_reused"),
                "served_requests": r.served_requests,
                "recovery": (r.recovery.breakdown()
                             if r.recovery else []),
            } for r in self.replicas],
        }
        out.update(self.serve_stats)
        return out


def _pct(sorted_vals: List[float], q: float) -> Optional[float]:
    if not sorted_vals:
        return None
    i = min(len(sorted_vals) - 1, int(q * len(sorted_vals)))
    return sorted_vals[i]


def _dir_bytes(path: str) -> int:
    total = 0
    for root, _dirs, files in os.walk(path):
        for f in files:
            total += os.path.getsize(os.path.join(root, f))
    return total


def run_fleet(run_dir: str, config: Optional[FleetConfig] = None,
              trace: Optional[List[int]] = None,
              mesh=None) -> Dict[str, Any]:
    """One-call scenario: image -> K replicas -> bursty trace -> summary.

    ``trace=None`` picks a deterministic burst shaped to the fleet size
    (quiet -> spike -> quiet), exercising both autoscale directions.
    """
    fleet = ServingFleet(run_dir, config, mesh=mesh)
    c = fleet.config
    fleet.build_source_image()
    fleet.boot_fleet()
    if trace is None:
        k = max(1, len(fleet.serving()))
        trace = [1, 1, 3 * k, 3 * k, 1, 0, 0, 0]
    fleet.serve_trace(trace)
    summary = fleet.summary()
    summary["fleet"] = True
    return summary
