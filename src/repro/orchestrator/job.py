"""Job lifecycle: spec, state machine, and JSON persistence.

A job is one checkpointable workload (training run or decode-serving
session) owned by the orchestrator.  Its lifecycle mirrors what a cluster
scheduler sees of a CRIUgpu-managed container:

    pending -> running -> freezing -> preempted -> restoring -> running -> done
                      \\-> failed ----------------^

Every transition is timestamped and the whole record is persisted as one
JSON file under ``<run_dir>/jobs/<job_id>.json`` (atomic rename), so
``python -m repro jobs`` can inspect a cluster's jobs without the owning
process — the same offline-operability contract the image CLI gives
snapshots.
"""
from __future__ import annotations

import dataclasses
import enum
import os
import time
from typing import Any, Dict, List, Optional

from repro.obs import journal as obs_journal
from repro.orchestrator.recovery import GoodputMeter, RecoveryLog
from repro.serialization.integrity import atomic_write_json, read_json


class JobState(str, enum.Enum):
    PENDING = "pending"
    RUNNING = "running"
    FREEZING = "freezing"          # checkpoint-on-signal in progress
    PREEMPTED = "preempted"
    FAILED = "failed"
    RESTORING = "restoring"
    DONE = "done"


# state machine (ISSUE: pending → running → freezing → preempted/failed →
# restoring → running → done)
VALID_TRANSITIONS = {
    JobState.PENDING: {JobState.RUNNING},
    JobState.RUNNING: {JobState.FREEZING, JobState.FAILED, JobState.DONE},
    JobState.FREEZING: {JobState.PREEMPTED, JobState.FAILED},
    JobState.PREEMPTED: {JobState.RESTORING},
    JobState.FAILED: {JobState.RESTORING},
    JobState.RESTORING: {JobState.RUNNING, JobState.FAILED},
    JobState.DONE: set(),
}

TERMINAL_STATES = {JobState.DONE}


class InvalidTransition(RuntimeError):
    pass


@dataclasses.dataclass(frozen=True)
class JobSpec:
    """Immutable description of one job (the scheduler's admission unit)."""

    job_id: str
    kind: str = "train"             # "train" | "serve" | "intercept"
    priority: int = 0               # higher preempts lower
    devices: int = 1                # simulated device demand
    total_steps: int = 8            # steps to train / tokens to decode
    ckpt_every: int = 0             # 0 = planner-driven cadence
    arrive_tick: int = 0            # scheduler ignores the job before this
    fail_at_step: Optional[int] = None      # injected crash
    straggle_at_step: Optional[int] = None  # injected stall
    migrate_at_step: Optional[int] = None   # live-migrate to another host
    max_restarts: int = 3

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "JobSpec":
        names = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in names})


def jobs_dir(run_dir: str) -> str:
    return os.path.join(run_dir, "jobs")


def job_record_path(run_dir: str, job_id: str) -> str:
    return os.path.join(jobs_dir(run_dir), f"{job_id}.json")


class JobRecord:
    """Mutable runtime state of one job, persisted on every transition."""

    def __init__(self, spec: JobSpec, run_dir: Optional[str] = None,
                 clock=time.monotonic):
        self.spec = spec
        self.run_dir = run_dir          # orchestrator run dir (persistence)
        self.clock = clock
        self.state = JobState.PENDING
        self.step = 0
        self.host: Optional[str] = None  # placement (multi-host clusters)
        self.attempt = 0                # workload incarnations so far
        self.restarts = 0               # recoveries (preempt or failure)
        self.last_ckpt_step: Optional[int] = None
        self.events: List[Dict[str, Any]] = []
        self.recovery = RecoveryLog(job_id=spec.job_id)
        self.goodput = GoodputMeter()
        self.created_t = self.clock()
        self.finished_t: Optional[float] = None

    # ------------------------------------------------------- transitions
    def transition(self, to: JobState, **meta: Any) -> None:
        if to not in VALID_TRANSITIONS[self.state]:
            raise InvalidTransition(
                f"job {self.spec.job_id}: {self.state.value} -> {to.value} "
                f"is not a legal transition")
        now = self.clock()
        self.events.append({"t": now, "from": self.state.value,
                            "to": to.value, "step": self.step, **meta})
        obs_journal.emit("job", "transition", job=self.spec.job_id,
                         frm=self.state.value, to=to.value,
                         step=self.step)
        self.state = to
        if to == JobState.RESTORING:
            self.restarts += 1
        if to == JobState.DONE:
            self.finished_t = now
        self.save()

    @property
    def terminal(self) -> bool:
        return self.state in TERMINAL_STATES

    @property
    def exhausted(self) -> bool:
        """Failed with no restart budget left (effectively terminal)."""
        return (self.state == JobState.FAILED
                and self.restarts >= self.spec.max_restarts)

    # ------------------------------------------------------- persistence
    def to_dict(self) -> Dict[str, Any]:
        return {
            "format": 1,
            "spec": self.spec.to_dict(),
            "state": self.state.value,
            "step": self.step,
            "host": self.host,
            "attempt": self.attempt,
            "restarts": self.restarts,
            "last_ckpt_step": self.last_ckpt_step,
            "created_t": self.created_t,
            "finished_t": self.finished_t,
            "events": self.events,
            "recovery": self.recovery.to_list(),
            "goodput": self.goodput.to_dict(),
        }

    def save(self) -> None:
        if self.run_dir is None:
            return
        os.makedirs(jobs_dir(self.run_dir), exist_ok=True)
        atomic_write_json(job_record_path(self.run_dir, self.spec.job_id),
                          self.to_dict())

    @classmethod
    def load(cls, run_dir: str, job_id: str) -> "JobRecord":
        d = read_json(job_record_path(run_dir, job_id))
        rec = cls(JobSpec.from_dict(d["spec"]), run_dir=None)
        rec.run_dir = run_dir
        rec.state = JobState(d["state"])
        rec.step = d["step"]
        rec.host = d.get("host")
        rec.attempt = d["attempt"]
        rec.restarts = d["restarts"]
        rec.last_ckpt_step = d.get("last_ckpt_step")
        rec.created_t = d.get("created_t", 0.0)
        rec.finished_t = d.get("finished_t")
        rec.events = list(d.get("events", []))
        rec.recovery = RecoveryLog.from_list(d.get("recovery", []))
        rec.recovery.job_id = rec.spec.job_id
        rec.goodput = GoodputMeter.from_dict(d.get("goodput", {}))
        return rec


def list_job_records(run_dir: str) -> List[JobRecord]:
    """All persisted job records under `run_dir` (offline inspection)."""
    d = jobs_dir(run_dir)
    if not os.path.isdir(d):
        return []
    out = []
    for name in sorted(os.listdir(d)):
        if name.endswith(".json"):
            out.append(JobRecord.load(run_dir, name[:-len(".json")]))
    return out
