"""Recovery-time accounting: per-incident phase breakdown + goodput.

The paper's headline numbers are *recovery time* and *steady-state
overhead*; a multi-tenant cluster adds the phases around the mechanism.
Each interruption (preemption, failure, straggler-triggered JIT dump that
turned into a reschedule) becomes one ``incident`` with four measured
phases:

    detect_s    interruption happened -> orchestrator noticed
                (signal delivery is ~0; heartbeat death costs the deadline)
    transfer_s  image moved to the host the job restarts on (cross-host
                migration: the delta-replication push; zero-width when the
                job comes back where its image already is)
    schedule_s  noticed -> scheduler found capacity again
    restore_s   restore started -> the job RESUMED.  Under a lazy
                (resume-before-read) restore this is the *critical* set
                only — the job is running again while the cold tail
                still streams; also surfaced as ``restore_critical_s``
                in the breakdown
    restore_background_s
                resumed -> the background stream finished materializing
                the rest of the image (zero-width for eager restores).
                Overlaps replay, which is exactly why GoodputMeter
                credits the earlier resume: replayed steps start
                accruing at t_restored, not at full materialization
    replay_s    restored step -> step at interruption re-reached (work
                lost since the last checkpoint, re-executed)

Goodput is useful-step-seconds / wall-clock: a step's cost counts as
useful once — re-executions of replayed steps count only against the
denominator.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional

from repro.obs import journal as obs_journal
from repro.obs import trace as obs_trace

PHASES = ("detect_s", "transfer_s", "schedule_s", "restore_s",
          "restore_background_s", "replay_s")


class RecoveryLog:
    """Timestamped incidents for one job; at most one open at a time.

    Every phase mark doubles as a retroactive span (``recovery.detect``,
    ``recovery.transfer``, ``recovery.schedule``, ``recovery.restore``,
    ``recovery.restore_background``, ``recovery.replay``) when the obs
    plane is installed — the incident dict stays the persisted record,
    but the *trace* is the first-class timeline: ``repro trace --chrome``
    shows each phase as a block, attributed to ``job_id``."""

    def __init__(self, job_id: Optional[str] = None) -> None:
        self.incidents: List[Dict[str, Any]] = []
        self.job_id = job_id

    def _span(self, inc: Dict[str, Any], name: str,
              ta: Optional[float], tb: Optional[float],
              **attrs: Any) -> None:
        if obs_trace.TRACER is None or ta is None or tb is None:
            return
        obs_trace.record(name, ta, tb, job=self.job_id,
                         cause=inc.get("cause"), **attrs)

    # ------------------------------------------------------------ record
    def open(self, cause: str, t_interrupt: float, t_detect: float,
             step_at_interrupt: int,
             last_ckpt_step: Optional[int]) -> Dict[str, Any]:
        inc = {"cause": cause,
               "t_interrupt": t_interrupt,
               "t_detect": t_detect,
               "t_transfer_start": None,
               "t_transfer_end": None,
               "t_scheduled": None,
               "t_restored": None,
               "t_materialized": None,
               "t_caught_up": None,
               "step_at_interrupt": step_at_interrupt,
               "last_ckpt_step": last_ckpt_step,
               "restored_step": None,
               "meta": {}}
        self.incidents.append(inc)
        self._span(inc, "recovery.detect", t_interrupt, t_detect,
                   step=step_at_interrupt)
        obs_journal.emit("recovery", "incident_open", job=self.job_id,
                         cause=cause, step=step_at_interrupt,
                         last_ckpt_step=last_ckpt_step)
        return inc

    @property
    def current(self) -> Optional[Dict[str, Any]]:
        if self.incidents and self.incidents[-1]["t_caught_up"] is None:
            return self.incidents[-1]
        return None

    def mark_transfer(self, t_start: float, t_end: float,
                      rounds: Optional[List[Dict[str, Any]]] = None,
                      **meta: Any) -> None:
        """Record the cross-host image-transfer window (between detect
        and schedule: the orchestrator pre-stages the image on the
        destination before the scheduler re-admits the job).

        ``rounds`` attributes the window: one entry per transfer round
        ({"round", "bytes_sent", "wall_s", "residual", ...}).  Pre-copy
        migrations record every live round plus the frozen residual;
        stop-and-copy records a single residual round.  The per-round
        ledger is what makes a blackout regression attributable — which
        round grew, not just that the lump sum did."""
        if self.current is not None:
            inc = self.current
            inc["t_transfer_start"] = t_start
            inc["t_transfer_end"] = t_end
            if rounds is not None:
                inc["transfer_rounds"] = [dict(r) for r in rounds]
            inc["meta"].update(meta)
            self._span(inc, "recovery.transfer", t_start, t_end,
                       rounds=len(rounds) if rounds else 0)

    def mark_scheduled(self, t: float) -> None:
        if self.current is not None:
            inc = self.current
            inc["t_scheduled"] = t
            # transfer (if any) happens inside the detect->schedule
            # window; the schedule span starts where it ended so the
            # trace rows butt up instead of overlapping
            anchor = (inc["t_transfer_end"]
                      if inc.get("t_transfer_end") is not None
                      else inc["t_detect"])
            self._span(inc, "recovery.schedule", anchor, t)

    def mark_restored(self, t: float, restored_step: int,
                      **meta: Any) -> None:
        if self.current is not None:
            inc = self.current
            inc["t_restored"] = t
            inc["restored_step"] = restored_step
            inc["meta"].update(meta)
            self._span(inc, "recovery.restore", inc.get("t_scheduled"), t,
                       restored_step=restored_step)

    def mark_materialized(self, t: float, **meta: Any) -> None:
        """The lazy background stream finished: the whole image is on
        devices.  May legitimately land *after* catch-up (replay overlaps
        the stream), so this targets the newest incident that restored
        but has no materialization timestamp yet."""
        for inc in reversed(self.incidents):
            if inc.get("t_restored") is not None \
                    and inc.get("t_materialized") is None:
                inc["t_materialized"] = t
                inc["meta"].update(meta)
                self._span(inc, "recovery.restore_background",
                           inc["t_restored"], t)
                return

    def mark_caught_up(self, t: float) -> None:
        if self.current is not None:
            inc = self.current
            inc["t_caught_up"] = t
            self._span(inc, "recovery.replay", inc.get("t_restored"), t,
                       step=inc["step_at_interrupt"])
            obs_journal.emit("recovery", "incident_closed",
                             job=self.job_id, cause=inc["cause"],
                             step=inc["step_at_interrupt"],
                             restored_step=inc["restored_step"])

    # ------------------------------------------------------------ report
    @staticmethod
    def _breakdown(inc: Dict[str, Any]) -> Dict[str, Any]:
        def gap(a, b):
            # .get: records persisted before the transfer phase existed
            # have no t_transfer_* keys
            ta, tb = inc.get(a), inc.get(b)
            if ta is None or tb is None:
                return None
            return max(0.0, tb - ta)

        transfer_s = gap("t_transfer_start", "t_transfer_end")
        # the transfer (if any) happens inside the detect→schedule window;
        # account it separately so schedule_s stays pure queueing time
        schedule_anchor = ("t_transfer_end"
                           if inc.get("t_transfer_end") is not None
                           else "t_detect")
        restore_s = gap("t_scheduled", "t_restored")
        out = {"cause": inc["cause"],
               "detect_s": gap("t_interrupt", "t_detect"),
               "transfer_s": transfer_s,
               "schedule_s": gap(schedule_anchor, "t_scheduled"),
               # restore_s ends at RESUME: under a lazy restore that is
               # the critical set only (alias restore_critical_s);
               # the background tail is accounted separately and
               # overlaps replay
               "restore_s": restore_s,
               "restore_critical_s": restore_s,
               "restore_background_s": gap("t_restored",
                                           "t_materialized"),
               "replay_s": gap("t_restored", "t_caught_up"),
               "total_s": gap("t_interrupt", "t_caught_up"),
               "steps_replayed": None,
               # per-round transfer attribution (pre-copy migrations);
               # [] for incidents recorded before rounds existed
               "transfer_rounds": [dict(r) for r in
                                   inc.get("transfer_rounds", [])],
               "meta": dict(inc["meta"])}
        if inc["restored_step"] is not None:
            out["steps_replayed"] = (inc["step_at_interrupt"]
                                     - inc["restored_step"])
        return out

    def breakdown(self) -> List[Dict[str, Any]]:
        return [self._breakdown(i) for i in self.incidents]

    def totals(self) -> Dict[str, float]:
        """Phase sums across closed incidents (the bench's table rows)."""
        tot = {k: 0.0 for k in PHASES + ("total_s",)}
        tot["incidents"] = 0
        for b in self.breakdown():
            if b["total_s"] is None:
                continue
            tot["incidents"] += 1
            for k in PHASES + ("total_s",):
                if b[k] is not None:
                    tot[k] += b[k]
        return tot

    # ------------------------------------------------------- persistence
    def to_list(self) -> List[Dict[str, Any]]:
        return [dict(i) for i in self.incidents]

    @classmethod
    def from_list(cls, items: List[Dict[str, Any]]) -> "RecoveryLog":
        log = cls()
        log.incidents = [dict(i) for i in items]
        return log


class GoodputMeter:
    """Useful-step-seconds / wall-clock, replay-aware.

    ``record_slice(start_step, end_step, wall_s)`` attributes the slice's
    wall time to the steps in ``[start_step, end_step)``; a step index
    executed more than once (replay after restoring to an older
    checkpoint) is useful only once.
    """

    def __init__(self) -> None:
        self.step_seconds = 0.0         # cost of every executed step
        self.steps_executed = 0         # including re-executions
        self.max_step = 0               # highest step index completed

    def record_slice(self, start_step: int, end_step: int,
                     wall_s: float) -> None:
        n = max(0, end_step - start_step)
        if n == 0:
            return
        self.steps_executed += n
        self.step_seconds += wall_s
        self.max_step = max(self.max_step, end_step)

    @property
    def useful_steps(self) -> int:
        return self.max_step

    def useful_step_seconds(self) -> float:
        if self.steps_executed == 0:
            return 0.0
        return self.step_seconds * (self.useful_steps
                                    / self.steps_executed)

    def goodput(self, wall_clock_s: float) -> float:
        if wall_clock_s <= 0:
            return 0.0
        return self.useful_step_seconds() / wall_clock_s

    # ------------------------------------------------------- persistence
    def to_dict(self) -> Dict[str, float]:
        return {"step_seconds": self.step_seconds,
                "steps_executed": self.steps_executed,
                "max_step": self.max_step}

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "GoodputMeter":
        m = cls()
        m.step_seconds = d.get("step_seconds", 0.0)
        m.steps_executed = d.get("steps_executed", 0)
        m.max_step = d.get("max_step", 0)
        return m
