"""Reproducible scenario matrix — the bench/CI entry points.

A scenario is a deterministic multi-tenant script: which jobs exist, who
arrives when, and which faults are injected.  The same matrix drives
``python -m repro orchestrate``, ``benchmarks/bench_orchestrator.py``,
and the CI smoke job, so the paper-style comparison (engine × scenario)
is one function call from anywhere.
"""
from __future__ import annotations

from typing import Dict, List, Optional

from repro.orchestrator.job import JobSpec
from repro.orchestrator.orchestrator import (Orchestrator,
                                             OrchestratorConfig)
from repro.orchestrator.workloads import make_workload_factory

SCENARIOS = ("preemption", "failure", "straggler", "migrate", "mixed")


def scenario_specs(name: str, total_steps: int = 10,
                   kind: str = "train") -> List[JobSpec]:
    """Job set for one named scenario (deterministic by construction)."""
    if name == "preemption":
        # low-priority job is mid-run when a high-priority job arrives;
        # capacity 1 forces checkpoint-on-signal + reschedule
        return [
            JobSpec("lo", kind=kind, priority=0, total_steps=total_steps,
                    ckpt_every=0),
            JobSpec("hi", kind=kind, priority=5,
                    total_steps=max(total_steps // 2, 2), arrive_tick=2),
        ]
    if name == "failure":
        # periodic checkpoints + a mid-run crash; heartbeat detection,
        # restore from the newest image, replay the gap
        return [
            JobSpec("crashy", kind=kind, priority=1,
                    total_steps=total_steps, ckpt_every=2,
                    fail_at_step=total_steps // 2 + 1),
        ]
    if name == "straggler":
        # injected stall -> StragglerMonitor flags it -> JIT checkpoint;
        # the stall lands late enough that the monitors have their minimum
        # sample history (8 steps) but with slices to spare afterwards so
        # the orchestrator-level trigger also gets a turn
        return [
            JobSpec("slowpoke", kind=kind, priority=1,
                    total_steps=max(total_steps, 12),
                    straggle_at_step=8),
        ]
    if name == "migrate":
        # live cross-host migration: the job checkpoints-on-signal on
        # host A mid-run, its image delta-transfers to host B's CAS, and
        # it restores there step-exact (periodic checkpoints beforehand
        # build the incremental chain the delta transfer dedups against)
        return [
            JobSpec("mover", kind=kind, priority=1,
                    total_steps=max(total_steps, 6), ckpt_every=2,
                    migrate_at_step=max(total_steps // 2, 3)),
        ]
    if name == "mixed":
        # the CI smoke: one preemption + one injected failure sharing
        # the cluster — both must recover step-exact
        return [
            JobSpec("lo", kind=kind, priority=0, total_steps=total_steps,
                    ckpt_every=2, fail_at_step=None),
            JobSpec("crashy", kind=kind, priority=1,
                    total_steps=total_steps, ckpt_every=2,
                    fail_at_step=total_steps // 2 + 1),
            JobSpec("hi", kind=kind, priority=5,
                    total_steps=max(total_steps // 2, 2), arrive_tick=2),
        ]
    raise ValueError(f"unknown scenario {name!r}; pick from {SCENARIOS}")


def run_scenario(name: str, run_dir: str, options=None, mesh=None,
                 total_steps: int = 10, kind: str = "train",
                 capacity: Optional[int] = None, hosts: Optional[int] = None,
                 config: Optional[OrchestratorConfig] = None,
                 transfer_policy=None) -> Dict:
    """Build and run one scenario; returns the orchestrator summary.

    ``transfer_policy`` (an :class:`repro.api.TransferPolicy`) configures
    the migration data path of the default-built config — e.g. pre-copy
    live migration with a blackout budget for the ``migrate`` scenario.
    Ignored when an explicit ``config`` is passed (set it there)."""
    from repro.orchestrator.job import jobs_dir
    import os
    if os.path.isdir(jobs_dir(run_dir)):
        # stale job records + images from a previous invocation would be
        # restored silently (restore picks the newest image in the job's
        # dir) — a scenario is only reproducible in a fresh run_dir
        raise ValueError(
            f"{run_dir!r} already holds an orchestrator run "
            f"({jobs_dir(run_dir)} exists); pick a fresh run_dir")
    specs = scenario_specs(name, total_steps=total_steps, kind=kind)
    if config is None:
        # capacity 1 for single-job scenarios exercises nothing extra but
        # keeps wall time down; preemption scenarios need contention;
        # migration needs somewhere else to land (hosts >= 2)
        cap = capacity if capacity is not None else (
            1 if name in ("preemption", "failure", "straggler", "migrate")
            else 2)
        n_hosts = hosts if hosts is not None else (
            2 if name == "migrate" else 1)
        config = OrchestratorConfig(capacity=cap, slice_steps=2,
                                    hosts=n_hosts,
                                    transfer_policy=transfer_policy)
    orch = Orchestrator(run_dir, specs,
                        workload_factory=make_workload_factory(
                            run_dir, options=options, mesh=mesh),
                        config=config)
    return orch.run()
