"""Priority scheduler over simulated device capacity.

The cluster is abstracted to one number — ``capacity`` device slots — and
jobs demand ``spec.devices`` of them.  Policy (the common preemptive
priority discipline GPU clusters run):

  * admission: waiting jobs (pending / preempted / failed-with-budget) in
    priority order, FIFO within a priority, first-fit into free capacity;
  * preemption: a waiting job may evict strictly-lower-priority running
    jobs when evicting the *lowest*-priority victims frees enough slots.
    Victims get a :class:`Signal.PREEMPT` on the injectable channel and
    keep their slots until they acknowledge (checkpoint-on-signal takes
    real time; capacity is released only after the dump commits).

The scheduler owns no job state beyond the allocation table — lifecycle
transitions stay in the orchestrator, so the policy is unit-testable with
bare :class:`JobRecord`-likes.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

from repro.orchestrator.job import JobRecord, JobState
from repro.orchestrator.signals import Signal, SignalChannel


@dataclasses.dataclass
class Decision:
    """One planning round: who to admit, who was signalled to yield."""
    admit: List[str] = dataclasses.field(default_factory=list)
    preempt: List[str] = dataclasses.field(default_factory=list)


class Scheduler:
    def __init__(self, capacity: int, channel: SignalChannel):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = capacity
        self.channel = channel
        self.allocations: Dict[str, int] = {}     # job_id -> devices held
        self._preempting: set = set()             # signalled, not yet freed
        self._arrival: Dict[str, int] = {}        # job_id -> FIFO order
        self._next_arrival = 0

    # ------------------------------------------------------- accounting
    def free_capacity(self) -> int:
        return self.capacity - sum(self.allocations.values())

    def allocate(self, job_id: str, devices: int) -> None:
        if devices > self.free_capacity():
            raise RuntimeError(
                f"allocating {devices} for {job_id} exceeds free capacity "
                f"{self.free_capacity()}/{self.capacity}")
        self.allocations[job_id] = devices

    def release(self, job_id: str) -> None:
        self.allocations.pop(job_id, None)
        self._preempting.discard(job_id)

    # ------------------------------------------------------- placement
    @staticmethod
    def place(hosts: Sequence[str], load: Dict[str, int],
              avoid: Optional[str] = None) -> str:
        """Pick the host a (re)started job lands on: least-loaded wins,
        ties broken by host order (deterministic).  `avoid` excludes a
        host — a migration must restore somewhere *else* — unless it is
        the only one."""
        candidates = [h for h in hosts if h != avoid] or list(hosts)
        return min(candidates, key=lambda h: (load.get(h, 0),
                                              list(hosts).index(h)))

    # ------------------------------------------------------- planning
    def _waiting(self, records: Dict[str, JobRecord],
                 tick: int) -> List[JobRecord]:
        out = []
        for rec in records.values():
            if rec.spec.arrive_tick > tick:
                continue
            if rec.state == JobState.PENDING or \
               rec.state == JobState.PREEMPTED or \
               (rec.state == JobState.FAILED and not rec.exhausted):
                if rec.spec.job_id not in self._arrival:
                    self._arrival[rec.spec.job_id] = self._next_arrival
                    self._next_arrival += 1
                out.append(rec)
        out.sort(key=lambda r: (-r.spec.priority,
                                self._arrival[r.spec.job_id]))
        return out

    def plan(self, records: Dict[str, JobRecord], tick: int = 0) -> Decision:
        """One scheduling round; sends PREEMPT signals for chosen victims."""
        decision = Decision()
        free = self.free_capacity()
        # slots held by signalled-but-not-yet-frozen victims are already
        # on their way back — count them as incoming, never evict for
        # capacity that an in-flight preemption will free anyway
        incoming = sum(self.allocations.get(v, 0)
                       for v in self._preempting)
        for rec in self._waiting(records, tick):
            need = rec.spec.devices
            if need > self.capacity:
                continue                        # can never fit; skip
            if need <= free:
                decision.admit.append(rec.spec.job_id)
                free -= need
                continue
            shortfall = need - free - incoming
            if shortfall <= 0:
                # served from free + in-flight slots: reserve both sides
                take = min(free, need)
                free -= take
                incoming -= need - take
                continue                        # wait for the freeze
            # try preemption: evict lowest-priority strictly-below us
            victims = self._pick_victims(records, rec.spec.priority,
                                         shortfall)
            if victims:
                for v in victims:
                    self._preempting.add(v)
                    self.channel.send(v, Signal.PREEMPT)
                    decision.preempt.append(v)
                    incoming += self.allocations.get(v, 0)
                take = min(free, need)          # reserve for this job
                free -= take
                incoming -= need - take
                # capacity arrives only after the victims freeze; the
                # waiting job is admitted on a later round
        return decision

    def _pick_victims(self, records: Dict[str, JobRecord],
                      priority: int, needed: int) -> List[str]:
        candidates = [
            (records[j].spec.priority, self._arrival.get(j, 0), j, dev)
            for j, dev in self.allocations.items()
            if j not in self._preempting
            and j in records and records[j].spec.priority < priority
        ]
        candidates.sort()                       # lowest priority first
        victims, freed = [], 0
        for _, _, j, dev in candidates:
            victims.append(j)
            freed += dev
            if freed >= needed:
                return victims
        return []                               # cannot free enough
