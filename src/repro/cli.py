"""``python -m repro`` — operate on snapshot images from outside the
training process (the CRIT analogue).

CRIUgpu images are plain files that CRIT can decode, verify, and edit
without the checkpointed process; schedulers and CI lean on that.  Our
images (``<run_dir>/snapshots/step_*/`` with a MANIFEST.json + pack files)
get the same treatment:

  python -m repro check [--run-dir D]        `criu check`: preflight
  python -m repro inspect RUN_DIR [--step N] manifest / size / parent chain
  python -m repro verify RUN_DIR [--step N]  CRC-verify every entry
  python -m repro gc RUN_DIR --keep N        retire old images (chain-safe)
  python -m repro restore RUN_DIR --dry-run  full restore path, host backend
  python -m repro jobs RUN_DIR [--job ID]    inspect orchestrator job records
  python -m repro orchestrate RUN_DIR        run a preemption scenario
  python -m repro migrate SRC DST            delta-transfer images to a peer
  python -m repro transfer-stats DST         CAS contents + transfer history
                                             (--fsck --repair quarantines
                                             corrupt objects)
  python -m repro chaos-campaign RUN_DIR     seeded fault-injection campaign
                                             over a simulated fleet
  python -m repro trace RUN_DIR --chrome     run journal -> Chrome trace
                                             JSON (Perfetto-loadable)
  python -m repro events RUN_DIR [--job J]   filtered run-journal timeline
                                             [--class dump|restore|...]
  python -m repro metrics RUN_DIR --json     final metrics snapshot, flat

Exit status is 0 on success, 1 on any problem — scriptable from cron,
GitHub Actions, or a cluster scheduler's health hook.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import List, Optional


# ------------------------------------------------------------------ util
def _fmt_bytes(n: float) -> str:
    for unit in ("B", "KiB", "MiB", "GiB", "TiB"):
        if abs(n) < 1024 or unit == "TiB":
            return f"{n:.1f}{unit}" if unit != "B" else f"{int(n)}B"
        n /= 1024
    return f"{n:.1f}TiB"


def _fmt_time(ts: Optional[float]) -> str:
    if not ts:
        return "-"
    import datetime
    return datetime.datetime.fromtimestamp(ts).strftime("%Y-%m-%d %H:%M:%S")


def _table(rows: List[List[str]], header: List[str]) -> str:
    widths = [max(len(str(r[i])) for r in [header] + rows)
              for i in range(len(header))]
    def line(cells):
        return "  ".join(str(c).ljust(w) for c, w in zip(cells, widths))
    out = [line(header), line(["-" * w for w in widths])]
    out.extend(line(r) for r in rows)
    return "\n".join(out)


def _store(run_dir: str):
    from repro.core.snapshot_io import SnapshotStore
    if not os.path.isdir(run_dir):
        raise SystemExit(f"error: {run_dir!r} is not a directory")
    store = SnapshotStore(run_dir)
    if not store.list_steps():
        raise SystemExit(f"error: no snapshots under {run_dir!r} "
                         f"(expected {run_dir}/snapshots/step_*)")
    return store


def _parent_chain(store, step: int, limit: int = 16) -> List[int]:
    """step -> [step, parent, grandparent, ...] (incremental delta chain)."""
    chain = [step]
    seen = {step}
    while len(chain) < limit:
        parent = store.manifest(chain[-1]).get("parent")
        if parent is None or parent in seen:
            break
        chain.append(parent)
        seen.add(parent)
    return chain


# ------------------------------------------------------------------ check
def cmd_check(args) -> int:
    from repro.api import check
    report = check(run_dir=args.run_dir)
    if args.json:
        print(json.dumps({"ok": report.ok, "problems": report.problems,
                          "warnings": report.warnings,
                          "capabilities": report.capabilities},
                         indent=2, default=str))
    else:
        caps = report.capabilities
        print(report.summary())
        print(f"  jax {caps['jax']['version']} "
              f"({caps['jax']['platform']}, "
              f"{caps['jax']['device_count']} device(s))")
        print(f"  plugin api v{caps['plugin_api_version']}; backends: "
              + ", ".join(f"{n} (v{b['api_version']})"
                          for n, b in caps["backends"].items()))
    return 0 if report.ok else 1


def _print_stripe_layout(store, m) -> None:
    """Chunk/stripe layout of a v2 image: per-stripe file sizes and the
    chunk population of this step's own pack (refs resolved elsewhere)."""
    if m.get("format", 1) < 2:
        return
    from repro.core.snapshot_io import snapshot_dir
    d = snapshot_dir(store.run_dir, m["step"])
    sizes = []
    for name in m.get("files", []):
        p = os.path.join(d, name)
        sizes.append(os.path.getsize(p) if os.path.exists(p) else 0)
    if sizes:
        total = sum(sizes)
        util = min(sizes) / max(sizes) if max(sizes) else 0.0
        print("  stripes:     "
              + "  ".join(f"[{k}] {_fmt_bytes(s)}"
                          for k, s in enumerate(sizes))
              + f"   (total {_fmt_bytes(total)}, balance {util:.2f})")
    try:
        from repro.serialization.pack import open_pack
        base = os.path.join(d, m["files"][0].rsplit(".", 1)[0])
        with open_pack(base, verify=False) as r:
            n_chunks = sum(len(e.get("chunks", []))
                           for e in r.index.values())
            n_ref = sum(1 for e in r.index.values()
                        for c in e.get("chunks", []) if c.get("ref"))
        print(f"  chunks:      {n_chunks} in {len(r.index)} entries"
              + (f" ({n_ref} deduped into parent packs)" if n_ref else ""))
    except Exception:
        pass                      # layout detail is best-effort cosmetics


def _print_restore_schedule(m) -> None:
    """Per-state restore-order breakdown: sizes, chunk counts, and
    priority spans, grouped by state/top-level-subtree — the data an
    operator needs to choose (and audit) the lazy critical set."""
    order = m.get("restore_order") or []
    sizes = m.get("entry_bytes") or {}
    if not order:
        return
    chunk_bytes = m.get("chunk_bytes", 0)
    groups: dict = {}
    for i, name in enumerate(order):
        if name == "__host__":
            key = "(host blobs)"
        else:
            state, path = name.split("::", 1)[0], name.split("::")[1]
            key = f"{state}/{path.split('/')[0]}" if "/" in path else state
        g = groups.setdefault(key, {"entries": 0, "bytes": 0,
                                    "chunks": 0, "lo": i, "hi": i})
        g["entries"] += 1
        nbytes = int(sizes.get(name, 0))
        g["bytes"] += nbytes
        g["chunks"] += (max(1, -(-nbytes // chunk_bytes))
                        if chunk_bytes else 1)
        g["lo"], g["hi"] = min(g["lo"], i), max(g["hi"], i)
    rows = []
    for key, g in sorted(groups.items(), key=lambda kv: kv[1]["lo"]):
        rows.append([key, g["entries"], _fmt_bytes(g["bytes"]),
                     g["chunks"], f"{g['lo']}-{g['hi']}"])
    print("  restore schedule (priority = dump-time registration order;")
    print("  lazy critical set defaults to the first state):")
    for line in _table(rows, ["subtree", "entries", "bytes", "chunks",
                              "priority"]).splitlines():
        print(f"    {line}")


# ---------------------------------------------------------------- inspect
def cmd_inspect(args) -> int:
    store = _store(args.run_dir)
    if args.step is not None:
        m = store.manifest(args.step)
        if args.json:
            print(json.dumps(m, indent=2, default=str))
            return 0
        print(f"snapshot step {m['step']}  ({_fmt_time(m.get('timestamp'))})")
        print(f"  dir:         snapshots/step_{m['step']:08d}")
        print(f"  format:      pack v{m.get('format', 1)}"
              + (f"   chunk: {_fmt_bytes(m['chunk_bytes'])}   "
                 f"stripes: {m.get('stripes', 1)}"
                 if m.get("format", 1) >= 2 else ""))
        print(f"  mode:        {m.get('mode', '-')}   "
              f"incremental: {m.get('incremental', False)}")
        print(f"  capture:     {m.get('capture', 'sync')}")
        cs = m.get("capture_stats") or {}
        if cs:
            print(f"    frozen window: {cs.get('frozen_s', 0.0) * 1e3:.1f} ms"
                  f"  (pin {cs.get('pin_pause_s', 0.0) * 1e3:.1f} ms + "
                  f"validate {cs.get('validate_pause_s', 0.0) * 1e3:.1f} ms); "
                  f"speculated {cs.get('speculate_s', 0.0) * 1e3:.1f} ms "
                  f"unfrozen")
            print(f"    speculated:  {cs.get('speculated_entries', 0)} "
                  f"entries   dirty: {cs.get('dirty_entries', 0)}   "
                  f"re-captured: {cs.get('recaptured_entries', 0)} "
                  f"({_fmt_bytes(cs.get('recaptured_bytes', 0))}, "
                  f"{_fmt_bytes(cs.get('superseded_bytes', 0))} superseded)")
        print(f"  states:      {', '.join(m.get('states', []))}")
        print(f"  written:     {_fmt_bytes(m.get('written_bytes', 0))}   "
              f"reused: {_fmt_bytes(m.get('reused_bytes', 0))}")
        _print_stripe_layout(store, m)
        _print_restore_schedule(m)
        chain = _parent_chain(store, args.step)
        print(f"  parent chain: {' -> '.join(map(str, chain))}")
        topo = m.get("topology") or {}
        if topo:
            print(f"  topology:    {topo.get('n_devices', '?')} device(s), "
                  f"axes {topo.get('mesh_axes')} shape "
                  f"{topo.get('mesh_shape')}")
        entries = m.get("locations", {})
        print(f"  entries:     {len(entries)} "
              f"({sum(1 for v in entries.values() if not v.startswith('step_' + format(m['step'], '08d')))} "
              f"inherited from parents)")
        for w in m.get("warnings", []) or []:
            print(f"  warning:     {w}")
        return 0

    rows = []
    for s in store.list_steps():
        m = store.manifest(s)
        chain = _parent_chain(store, s)
        rows.append([
            s, _fmt_time(m.get("timestamp")), m.get("mode", "-"),
            ",".join(m.get("states", [])),
            _fmt_bytes(m.get("written_bytes", 0)),
            _fmt_bytes(m.get("reused_bytes", 0)),
            " -> ".join(map(str, chain)) if len(chain) > 1 else "-",
        ])
    if args.json:
        hdr = ["step", "time", "mode", "states", "written", "reused",
               "parent_chain"]
        print(json.dumps([dict(zip(hdr, r)) for r in rows], indent=2))
    else:
        print(f"{args.run_dir}: {len(rows)} snapshot(s)")
        print(_table(rows, ["step", "time", "mode", "states", "written",
                            "reused", "parent chain"]))
    return 0


# ----------------------------------------------------------------- verify
def cmd_verify(args) -> int:
    from repro.api.options import auto_io_threads
    store = _store(args.run_dir)
    steps = [args.step] if args.step is not None else store.list_steps()
    bad = 0
    for s in steps:
        try:
            # parallel reader: chunk reads + CRC fan out across stripes
            reader = store.reader(s, verify=True,
                                  io_threads=auto_io_threads())
            try:
                reader.verify_all()
            finally:
                reader.close()
            n = len(store.manifest(s).get("locations", {}))
            print(f"step {s}: OK ({n} entries CRC-verified)")
        except Exception as e:
            bad += 1
            print(f"step {s}: CORRUPT — {e}")
    if bad:
        print(f"{bad}/{len(steps)} snapshot(s) failed verification")
    return 1 if bad else 0


# --------------------------------------------------------------------- gc
def cmd_gc(args) -> int:
    store = _store(args.run_dir)
    steps = store.list_steps()
    if args.keep < 1:
        raise SystemExit("error: --keep must be >= 1")
    if args.dry_run:
        # mirror SnapshotStore.gc's keep-set without deleting: a snapshot
        # survives if kept directly or if any kept manifest still points
        # into its pack files (delta chains reference packs at entry or
        # chunk granularity, not parents)
        keep = set(steps[-args.keep:])
        changed = True
        while changed:
            changed = False
            for s in list(keep):
                for n in store.referenced_steps(store.manifest(s)):
                    if n not in keep:
                        keep.add(n)
                        changed = True
        removable = [s for s in steps if s not in keep]
        print(f"would remove {len(removable)} snapshot(s): {removable}")
        print(f"would keep: {sorted(keep)}")
        return 0
    removed = store.gc(args.keep)
    print(f"removed {len(removed)} snapshot(s): {removed}")
    print(f"remaining: {store.list_steps()}")
    return 0


# ---------------------------------------------------------------- restore
def cmd_restore(args) -> int:
    if not args.dry_run:
        raise SystemExit(
            "error: only --dry-run restores are supported from the CLI; a "
            "real restore needs the owning process (use "
            "repro.api.CheckpointSession.restore there)")
    # Full restore pipeline on the host-numpy backend: manifest selection,
    # CRC verification, entry loading, tree reassembly — everything except
    # device placement.  What `criu restore --check-only` would be.
    from repro.core.engine import SnapshotEngine
    from repro.core.plugins import Plugin

    class _RestoreProbe(Plugin):
        """Observes what the restore pipeline actually loaded."""
        name = "cli-probe"
        host_names: List[str] = []
        step = None

        def restore_ext_state(self, ctx):
            _RestoreProbe.host_names = sorted(ctx.host_state)
            _RestoreProbe.step = ctx.step

    _store(args.run_dir)                              # friendly errors first
    options = None
    if args.lazy:
        from repro.api import CheckpointOptions
        options = CheckpointOptions(
            restore_mode="lazy",
            critical_states=tuple(args.critical) or None)
    eng = SnapshotEngine(args.run_dir, backend="host", options=options)
    eng.add_plugin(_RestoreProbe())
    import time as _time
    t0 = _time.perf_counter()
    restored = eng.restore(step=args.step, verify=True,
                           wait="critical" if args.lazy else None)
    t_resume = _time.perf_counter() - t0
    if args.lazy:
        restored = eng.restore_barrier()
        t_full = _time.perf_counter() - t0
    print(f"step {_RestoreProbe.step}: restore pipeline ran on the "
          f"'host' backend")
    if args.lazy:
        st = eng.last_stats
        print(f"  lazy:        resumed on the critical set in "
              f"{t_resume*1e3:.1f}ms "
              f"({int(st.get('critical_entries', 0))} entries, "
              f"{_fmt_bytes(st.get('critical_bytes', 0))}); "
              f"full materialization {t_full*1e3:.1f}ms "
              f"({int(st.get('background_entries', 0))} background "
              f"entries, {_fmt_bytes(st.get('background_bytes', 0))})")
        print(f"  resume-before-read: job runnable after "
              f"{t_resume/t_full:.0%} of the restore wall")
    host_names = _RestoreProbe.host_names
    total = 0
    rows = []
    import numpy as np
    for state, tree in restored.items():
        leaves = [(k, v) for k, v in _iter_leaves(tree)]
        nbytes = sum(v.nbytes for _, v in leaves
                     if isinstance(v, np.ndarray))
        total += nbytes
        rows.append([state, len(leaves), _fmt_bytes(nbytes)])
    print(_table(rows, ["state", "leaves", "bytes"]))
    print(f"host state present: {host_names}")
    print(f"restore --dry-run OK: {_fmt_bytes(total)} reassembled on the "
          f"host backend (no device placement)")
    return 0


# ------------------------------------------------------------------- jobs
def cmd_jobs(args) -> int:
    """Inspect a cluster's persisted job records without the owning
    process (the `repro inspect` of the orchestrator plane)."""
    from repro.orchestrator.job import JobState, list_job_records
    recs = list_job_records(args.run_dir)
    if not recs:
        raise SystemExit(f"error: no job records under {args.run_dir!r} "
                         f"(expected {args.run_dir}/jobs/*.json)")
    if args.state is not None:
        try:
            want = JobState(args.state)
        except ValueError:
            raise SystemExit(
                f"error: unknown state {args.state!r} (choose from "
                f"{', '.join(s.value for s in JobState)})")
        recs = [r for r in recs if r.state == want]
    if args.job is not None:
        matching = [r for r in recs if r.spec.job_id == args.job]
        if not matching:
            raise SystemExit(f"error: no job {args.job!r} "
                             f"(have: {[r.spec.job_id for r in recs]})")
        rec = matching[0]
        if args.json:
            print(json.dumps(rec.to_dict(), indent=2, default=str))
            return 0
        print(f"job {rec.spec.job_id}  [{rec.spec.kind}]  "
              f"priority {rec.spec.priority}")
        print(f"  state:       {rec.state.value}")
        print(f"  progress:    step {rec.step}/{rec.spec.total_steps}   "
              f"attempts: {rec.attempt + 1}   restarts: {rec.restarts}")
        print(f"  last ckpt:   "
              f"{'-' if rec.last_ckpt_step is None else rec.last_ckpt_step}")
        for i, b in enumerate(rec.recovery.breakdown()):
            phases = "  ".join(
                f"{k}={b[k]*1e3:.1f}ms" for k in
                ("detect_s", "transfer_s", "schedule_s", "restore_s",
                 "restore_background_s", "replay_s")
                if b[k] is not None)
            print(f"  incident {i}:  {b['cause']}  {phases}"
                  + (f"  replayed={b['steps_replayed']}"
                     if b["steps_replayed"] is not None else "")
                  + (f"  transfer_rounds={len(b['transfer_rounds'])}"
                     if b["transfer_rounds"] else ""))
        for e in rec.events[-8:]:
            desc = (f"{e['from']} -> {e['to']}" if "to" in e
                    else ", ".join(f"{k}={v}" for k, v in e.items()
                                   if k not in ("t", "step")))
            print(f"  event:       t={e['t']:.3f} step={e.get('step', '-')} "
                  f" {desc}")
        return 0

    if args.json:
        # raw values, not display strings — scripts consume this
        print(json.dumps([{
            "job": rec.spec.job_id, "kind": rec.spec.kind,
            "priority": rec.spec.priority, "state": rec.state.value,
            "host": rec.host,
            "step": rec.step, "total_steps": rec.spec.total_steps,
            "restarts": rec.restarts,
            "exhausted": rec.exhausted,
            "incidents": rec.recovery.totals()["incidents"],
            "recovery_s": rec.recovery.totals()["total_s"],
            # per-round migration transfer records (pre-copy rounds +
            # frozen residual); [] for jobs that never moved hosts
            "transfer_rounds": [r for b in rec.recovery.breakdown()
                                for r in b["transfer_rounds"]],
        } for rec in recs], indent=2))
        return 0
    rows = []
    for rec in recs:
        tot = rec.recovery.totals()
        rows.append([
            rec.spec.job_id, rec.spec.kind, rec.spec.priority,
            rec.state.value,
            f"{rec.step}/{rec.spec.total_steps}",
            rec.restarts, tot["incidents"],
            f"{tot['total_s']:.2f}s" if tot["incidents"] else "-",
        ])
    print(f"{args.run_dir}: {len(rows)} job(s)")
    print(_table(rows, ["job", "kind", "prio", "state", "progress",
                        "restarts", "incidents", "recovery"]))
    return 0


# ------------------------------------------------------------ orchestrate
def cmd_orchestrate(args) -> int:
    """Run a deterministic multi-tenant scenario and assert recovery."""
    import contextlib

    from repro.api import CheckpointOptions, TransferPolicy
    from repro.obs.plane import observed
    from repro.orchestrator import run_scenario
    scenario = {"preempt": "preemption"}.get(args.scenario, args.scenario)
    opts = CheckpointOptions(mode=args.mode, pack_format=args.pack_format,
                             io_threads=args.io_threads,
                             incremental=args.incremental)
    policy = None
    if args.max_rounds:
        # live pre-copy migration path: delta rounds while the job steps,
        # freeze only when the residual fits the blackout budget
        policy = TransferPolicy(mode="delta",
                                precopy_rounds=args.max_rounds,
                                max_blackout_ms=args.max_blackout_ms)
    elif args.max_blackout_ms is not None:
        raise SystemExit("error: --max-blackout-ms needs --max-rounds")
    plane = (contextlib.nullcontext() if args.no_trace
             else observed(args.run_dir, detail=args.trace_detail))
    with plane:
        summary = run_scenario(scenario, args.run_dir, options=opts,
                               total_steps=args.steps, kind=args.kind,
                               capacity=args.capacity, hosts=args.hosts,
                               transfer_policy=policy)
    if not args.no_trace:
        jpath = os.path.join(args.run_dir, "obs", "journal.jsonl")
        print(f"run journal -> {jpath} "
              f"(python -m repro trace {args.run_dir} --chrome)")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(summary, f, indent=2, default=str)
    print(f"scenario {args.scenario!r} ({args.mode} engine, "
          f"capacity {summary['capacity']}, "
          f"{summary.get('hosts', 1)} host(s)): "
          f"{summary['ticks']} ticks, {summary['wall_s']:.2f}s wall, "
          f"cluster goodput {summary['cluster_goodput']:.2f}")
    bad = 0
    for job_id, j in sorted(summary["jobs"].items()):
        ok = j["state"] == "done" and j["step"] == j["total_steps"]
        bad += not ok
        tot = j["recovery_totals"]
        rec = (f"  recovery {tot['total_s']*1e3:.0f}ms over "
               f"{tot['incidents']} incident(s)" if tot["incidents"] else "")
        mig = j.get("migration")
        mig_s = ""
        if mig:
            moved = mig.get("bytes_sent", 0) + mig.get("bytes_copied", 0)
            mig_s = (f"  migrated {mig['from']}->{mig['to']} "
                     f"({_fmt_bytes(moved)} moved, "
                     f"{_fmt_bytes(mig.get('bytes_reused', 0))} deduped)"
                     if mig["state"] == "transferred"
                     else f"  migration {mig['state']}")
            if mig.get("outcome"):
                mig_s += (f"  [pre-copy {mig['outcome']}: "
                          f"{mig.get('rounds_completed', 0)} live "
                          f"round(s), blackout "
                          f"{mig.get('blackout_s', 0.0)*1e3:.1f}ms]")
        print(f"  {job_id:10s} [{j['kind']}] prio {j['priority']}: "
              f"{j['state']} at {j['step']}/{j['total_steps']} "
              f"({j['restarts']} restart(s), goodput {j['goodput']:.2f})"
              + rec + mig_s)
    if bad:
        print(f"error: {bad} job(s) did not recover to completion",
              file=sys.stderr)
    return 1 if bad else 0


# ------------------------------------------------------------ serve-fleet
def cmd_serve_fleet(args) -> int:
    """Boot K decode replicas from one image and serve a bursty trace."""
    import contextlib

    from repro.obs.plane import observed
    from repro.orchestrator.fleet import FleetConfig, run_fleet
    cfg = FleetConfig(replicas=args.replicas, hosts=args.hosts,
                      restore_mode=args.restore_mode, seed=args.seed,
                      max_replicas=max(args.max_replicas, args.replicas))
    trace = None
    if args.trace:
        trace = [int(x) for x in args.trace.split(",")]
    plane = (contextlib.nullcontext() if args.no_trace
             else observed(args.run_dir))
    with plane:
        summary = run_fleet(args.run_dir, cfg, trace=trace)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(summary, f, indent=2, default=str)
    print(f"fleet: {summary['replicas']} replica(s) over "
          f"{len(summary['hosts'])} host(s) from one "
          f"{_fmt_bytes(summary['image_bytes'])} image "
          f"({args.restore_mode} restore)")
    print(f"  restore bytes: {_fmt_bytes(summary['total_restore_bytes'])} "
          f"total = {summary['restore_bytes_vs_image']:.2f}x image "
          f"({_fmt_bytes(summary['restore_bytes_per_replica'])}/replica, "
          f"dedup ratio {summary['dedup_ratio']:.2f})")
    p50, p99 = summary["ttft_p50_s"], summary["ttft_p99_s"]
    if p50 is not None:
        print(f"  TTFT: p50 {p50*1e3:.1f}ms  p99 {p99*1e3:.1f}ms")
    print(f"  served {summary['requests_served']}/"
          f"{summary['requests_arrived']} request(s) in "
          f"{summary['ticks']} tick(s), goodput "
          f"{summary['goodput_requests_per_replica_tick']:.2f} "
          f"req/replica-tick, {summary['autoscale_boots']} autoscale "
          f"boot(s), {summary['drains']} drain(s)")
    for rep in summary["per_replica"]:
        if rep["status"] == "dead":
            print(f"  {rep['rid']} [{rep['host']}] quarantined: "
                  f"{rep['diagnosis']}", file=sys.stderr)
    bad = summary["requests_unserved"] > 0 or summary["dead"] > 0
    if bad:
        print(f"error: {summary['dead']} dead replica(s), "
              f"{summary['requests_unserved']} unserved request(s)",
              file=sys.stderr)
    return 1 if bad else 0


# ---------------------------------------------------------------- migrate
def _verify_dest(dest: str, step: int) -> None:
    # the transferred image must be restorable *now*, while the source
    # still exists — a corrupt target fails here, not at restore time
    from repro.api.options import auto_io_threads
    from repro.core.snapshot_io import SnapshotStore
    reader = SnapshotStore(dest).reader(step, verify=True,
                                        io_threads=auto_io_threads())
    try:
        reader.verify_all()
    finally:
        reader.close()


def _migrate_precopy(args, store, step: int) -> int:
    """Offline pre-copy replay: walk the image's parent chain oldest ->
    newest as live rounds, let the convergence controller pick the freeze
    point, and measure the frozen residual push — the blackout — as the
    final round.  Resumable: the round ledger lives in the target CAS."""
    from repro.api import TransferPolicy
    from repro.transfer import (DeltaReplicator, PrecopyController,
                                summarize_rounds)
    from repro.transfer.delta import transfer_closure
    policy = TransferPolicy(mode="delta", workers=args.workers,
                            precopy_rounds=args.max_rounds,
                            max_blackout_ms=args.max_blackout_ms)
    rep = DeltaReplicator(args.dest, workers=args.workers)
    tag = (f"cli-{os.path.basename(os.path.abspath(args.run_dir))}"
           f"-{step}")
    ctrl = PrecopyController(policy)
    ctrl.seed(rep.round_state(tag))
    chain = transfer_closure(store, step)
    outcome, reason = None, ""
    for s in chain[:-1]:                      # live rounds: the history
        if len(ctrl.rounds) >= policy.precopy_rounds:
            outcome, reason = "fallback", (f"round cap "
                                           f"{policy.precopy_rounds} hit")
            break
        ctrl.observe(rep.push_round(args.run_dir, s, tag))
        d = ctrl.decide()
        if d.action != "continue":
            outcome = "converged" if d.action == "freeze" else "fallback"
            reason = d.reason
            break
    if outcome is None:
        outcome, reason = "converged", "history exhausted"
    # frozen residual: the target step itself — the measured blackout
    resid = rep.push_round(args.run_dir, step, tag, residual=True)
    ledger = rep.round_state(tag)
    _verify_dest(args.dest, step)
    rep.clear_rounds(tag)
    stats = dict(resid)
    stats.update(summarize_rounds(ledger))
    stats["outcome"] = outcome
    stats["reason"] = reason
    stats["rounds"] = ledger
    if args.json:
        print(json.dumps(stats, indent=2, default=str))
        return 0
    print(f"migrated step {step}: {args.run_dir} -> {args.dest} "
          f"(pre-copy, {outcome}: {reason})")
    rows = [[r["round"], r["step"],
             "residual" if r.get("residual") else "live",
             _fmt_bytes(r["bytes_sent"]), _fmt_bytes(r["bytes_reused"]),
             f"{r['wall_s']*1e3:.1f}ms"] for r in ledger]
    print(_table(rows, ["round", "step", "kind", "sent", "deduped",
                        "wall"]))
    print(f"  pre-copied:  {_fmt_bytes(stats['precopy_bytes'])} over "
          f"{stats['rounds_completed']} live round(s)")
    print(f"  blackout:    {stats['blackout_s']*1e3:.1f}ms "
          f"({_fmt_bytes(stats['residual_bytes'])} residual)")
    print(f"  verified:    step {step} CRC-clean at destination")
    return 0


def cmd_migrate(args) -> int:
    """Push snapshot image(s) from a run dir to a peer store, delta or
    full-copy, then prove the transferred image restorable (CRC)."""
    store = _store(args.run_dir)
    step = args.step if args.step is not None else store.latest_step()
    if args.max_rounds and args.transfer != "delta":
        raise SystemExit("error: --max-rounds needs --transfer delta")
    if args.max_blackout_ms is not None and not args.max_rounds:
        raise SystemExit("error: --max-blackout-ms needs --max-rounds")
    if args.max_rounds:
        return _migrate_precopy(args, store, step)
    if args.transfer == "delta":
        from repro.transfer import DeltaReplicator
        rep = DeltaReplicator(args.dest, workers=args.workers)
        stats = rep.push(args.run_dir, step)
    else:
        from repro.core.replication import DirReplicator
        from repro.transfer.delta import transfer_closure
        rep = DirReplicator(args.dest)
        stats = {"bytes_copied": 0, "files_copied": 0, "bytes_skipped": 0,
                 "files_skipped": 0, "step": step}
        for s in transfer_closure(store, step):
            st = rep.push(args.run_dir, s)
            for k in ("bytes_copied", "files_copied",
                      "bytes_skipped", "files_skipped"):
                stats[k] += st[k]
    _verify_dest(args.dest, step)
    if args.json:
        print(json.dumps(stats, indent=2, default=str))
        return 0
    print(f"migrated step {step}: {args.run_dir} -> {args.dest} "
          f"({args.transfer})")
    if args.transfer == "delta":
        moved = stats["bytes_sent"] + stats["bytes_copied"]
        print(f"  sent:        {_fmt_bytes(moved)} in "
              f"{stats['chunks_sent']} chunk(s)"
              + (f" + {stats['files_copied']} v1 file(s)"
                 if stats["files_copied"] else ""))
        print(f"  deduped:     {_fmt_bytes(stats['bytes_reused'])} "
              f"({stats['chunks_reused']} chunk(s) already in the "
              f"target CAS)")
        print(f"  steps:       {stats['steps_transferred']} transferred, "
              f"{stats['steps_skipped']} already present")
        if stats.get("corrupt_objects_healed"):
            print(f"  healed:      {stats['corrupt_objects_healed']} "
                  f"corrupt CAS object(s) re-fetched from source")
        print(f"  wall:        {stats['push_s']*1e3:.1f}ms")
    else:
        print(f"  copied:      {_fmt_bytes(stats['bytes_copied'])} "
              f"({stats['files_copied']} file(s))")
        print(f"  skipped:     {_fmt_bytes(stats['bytes_skipped'])} "
              f"({stats['files_skipped']} unchanged file(s))")
    print(f"  verified:    step {step} CRC-clean at destination")
    return 0


def cmd_transfer_stats(args) -> int:
    """Inspect a peer store's CAS and transfer history offline."""
    from repro.transfer.cas import ChunkStore, default_cas_dir
    cas_dir = default_cas_dir(args.dest)
    if not os.path.isdir(cas_dir):
        raise SystemExit(f"error: no chunk store under {args.dest!r} "
                         f"(expected {cas_dir})")
    store = ChunkStore(cas_dir)
    st = store.stats()
    log = store.transfer_log()
    if args.repair:
        args.fsck = True
    if args.fsck:
        bad = store.fsck(repair=args.repair)
        st["corrupt_objects"] = len(bad)
        if args.repair:
            st["quarantined_objects"] = len(bad)
            st.update(store.stats())       # post-repair object count
    # exit 1 only when corruption is left in place: a --repair run that
    # quarantined everything leaves a clean store behind
    bad_left = st.get("corrupt_objects", 0) if not args.repair else 0
    if args.json:
        print(json.dumps({"cas": st, "transfers": log}, indent=2,
                         default=str))
        return 1 if bad_left else 0
    print(f"{args.dest}: {st['objects']} CAS object(s), "
          f"{_fmt_bytes(st['bytes'])}")
    if st.get("quarantined_objects"):
        print(f"  quarantine:  {st['quarantined_objects']} object(s) "
              f"moved aside this run")
    if args.fsck:
        if not st["corrupt_objects"]:
            print("  fsck:        all objects CRC-clean")
        elif args.repair:
            print(f"  fsck:        {st['corrupt_objects']} corrupt "
                  f"object(s) moved to quarantine/ — the next transfer "
                  f"heals them from source")
        else:
            print(f"  fsck:        {st['corrupt_objects']} corrupt "
                  f"object(s)! (re-run with --repair to quarantine)")
    if log:
        rows = []
        for r in log[-12:]:
            rows.append([
                _fmt_time(r.get("t")), r.get("step", "-"),
                _fmt_bytes(r.get("bytes_sent", 0)
                           + r.get("bytes_copied", 0)),
                _fmt_bytes(r.get("bytes_reused", 0)),
                r.get("steps_transferred", 0),
                f"{r.get('push_s', 0)*1e3:.1f}ms",
            ])
        print(_table(rows, ["time", "step", "sent", "deduped",
                            "steps", "wall"]))
    else:
        print("  (no transfers logged)")
    return 1 if bad_left else 0


# --------------------------------------------------------- chaos-campaign
def cmd_chaos_campaign(args) -> int:
    """Run a seeded fault-injection campaign over a simulated fleet and
    hold it to the survivability invariant: every job recovers bit-exact
    or lands in diagnosable quarantine."""
    import contextlib
    import hashlib

    from repro.chaos import run_campaign
    from repro.chaos.campaign import write_bench_json
    from repro.obs.plane import observed
    modes = (["sync", "concurrent"] if args.capture == "sweep"
             else [args.capture])
    sweep = len(modes) > 1
    reports = {}
    for mode in modes:
        run_dir = os.path.join(args.run_dir, mode) if sweep \
            else args.run_dir
        # one journal per campaign dir: injected faults land as
        # cls="fault" events, so `repro events RUN --class fault` lines
        # them up against the incident spans they caused
        plane = (contextlib.nullcontext() if args.no_trace
                 else observed(run_dir))
        with plane:
            reports[mode] = run_campaign(
                run_dir, jobs=args.jobs, hosts=args.hosts, seed=args.seed,
                faults=args.faults, max_ticks=args.max_ticks, capture=mode,
                log=lambda m, _mode=mode: print(f"  [{_mode}] {m}"))
    for mode in modes:
        print()
        print(reports[mode].table_markdown())
    if sweep:
        # one identity string for the whole sweep: same seed -> both
        # campaigns reproduce -> same combined fingerprint
        combined = hashlib.sha256("\n".join(
            reports[m].fingerprint() for m in modes).encode()).hexdigest()
        print(f"\nfingerprint: {combined}")
    else:
        print(f"\nfingerprint: {reports[modes[0]].fingerprint()}")
    if args.json:
        if sweep:
            # sync metrics keep the historical unprefixed names (so the
            # committed baseline keeps gating them); the concurrent
            # campaign lands under chaos.concurrent.*
            merged = dict(reports["sync"].metrics())
            for k, v in reports["concurrent"].metrics().items():
                merged["chaos.concurrent." + k[len("chaos."):]] = v
            tmp = args.json + ".tmp"
            with open(tmp, "w") as f:
                json.dump(merged, f, indent=2, sort_keys=True)
                f.write("\n")
            os.replace(tmp, args.json)
        else:
            write_bench_json(reports[modes[0]], args.json)
        print(f"bench metrics -> {args.json}")
    if args.report:
        if sweep:
            payload = {"format": 1, "capture": "sweep",
                       "fingerprint": combined,
                       "sync": reports["sync"].to_dict(),
                       "concurrent": reports["concurrent"].to_dict()}
        else:
            payload = reports[modes[0]].to_dict()
        with open(args.report, "w") as f:
            json.dump(payload, f, indent=2, default=str)
        print(f"full report   -> {args.report}")
    violations = 0
    for mode in modes:
        for v in reports[mode].violations:
            violations += 1
            print(f"VIOLATION [{mode}] [{v['reason']}] {v['job']}: "
                  f"{v['detail']}", file=sys.stderr)
    if violations:
        print(f"error: campaign invariant violated "
              f"({violations} violation(s))", file=sys.stderr)
    return 0 if not violations else 1


# ---------------------------------------------------------- observability
def _load_journal_or_die(run_dir: str):
    from repro.obs import export
    from repro.obs.journal import journal_path
    events = export.load_journal(run_dir)
    if not events:
        raise SystemExit(
            f"error: no run journal under {run_dir!r} (expected "
            f"{journal_path(run_dir)}; produced by orchestrate / "
            f"chaos-campaign unless --no-trace)")
    return events


def cmd_trace(args) -> int:
    """Export the run journal as Chrome trace-event JSON (Perfetto)."""
    from repro.obs import export
    events = _load_journal_or_die(args.run_dir)
    problems = export.validate_journal(events)
    if problems:
        for p in problems[:10]:
            print(f"warning: {p}", file=sys.stderr)
    trace = export.to_chrome_trace(
        events, process_name=os.path.basename(args.run_dir.rstrip("/"))
        or "repro")
    out = args.out or os.path.join(args.run_dir, "obs", "trace.json")
    with open(out, "w") as f:
        json.dump(trace, f)
    n_spans = sum(1 for e in events if e.get("kind") == "span")
    print(f"{out}: {len(trace['traceEvents'])} trace event(s), "
          f"{n_spans} span(s) — open in ui.perfetto.dev or "
          f"chrome://tracing")
    return 0


def _event_row(ev) -> List[str]:
    skip = {"v", "cls", "kind", "t", "wall", "name", "ts", "dur",
            "thread", "span_id", "parent_id", "job"}
    if ev.get("kind") == "span":
        t, dur = ev.get("ts", 0.0), ev.get("dur", 0.0)
        what = ev.get("name", "?")
        src = dict(ev.get("attrs") or {})
        job = (ev.get("attrs") or {}).get("job")
    else:
        t, dur = ev.get("t", 0.0), None
        what = f"{ev.get('cls')}/{ev.get('kind')}"
        src = {k: v for k, v in ev.items()}
        job = ev.get("job")
    detail = " ".join(f"{k}={v}" for k, v in sorted(src.items())
                      if k not in skip and v is not None)
    return [f"{t * 1e3:.1f}",
            f"{dur * 1e3:.1f}" if dur is not None else "-",
            ev.get("cls", "?"), what, job or "-", detail[:60]]


def cmd_events(args) -> int:
    """Filtered run-journal timeline (by job and/or event class)."""
    from repro.obs import export
    events = _load_journal_or_die(args.run_dir)
    evs = export.filter_events(events, job=args.job, cls=args.cls)
    if args.json:
        for ev in evs:
            print(json.dumps(ev, default=str))
        return 0
    if not evs:
        print("(no matching events)")
        return 0
    rows = [_event_row(ev) for ev in evs]
    print(_table(rows, ["t_ms", "dur_ms", "class", "event", "job",
                        "detail"]))
    return 0


def cmd_metrics(args) -> int:
    """Final metrics snapshot from the run journal, flat name->value."""
    from repro.obs import export
    events = _load_journal_or_die(args.run_dir)
    metrics = export.metrics_from_journal(events)
    if not metrics:
        raise SystemExit("error: journal holds no metrics snapshot "
                         "(run did not close its observability plane?)")
    if args.json is not None:
        payload = json.dumps(metrics, indent=2, sort_keys=True)
        if args.json == "-":
            print(payload)
        else:
            with open(args.json, "w") as f:
                f.write(payload + "\n")
            print(f"metrics -> {args.json}")
        return 0
    rows = [[k, f"{v:g}" if isinstance(v, (int, float)) else str(v)]
            for k, v in sorted(metrics.items())]
    print(_table(rows, ["metric", "value"]))
    return 0


def _iter_leaves(node, prefix=""):
    if isinstance(node, dict):
        for k, v in node.items():
            yield from _iter_leaves(v, f"{prefix}/{k}" if prefix else str(k))
    else:
        yield prefix, node


# ------------------------------------------------------------------- main
def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro",
        description="Operate on repro snapshot images (the CRIT analogue).")
    sub = ap.add_subparsers(dest="command", required=True)

    p = sub.add_parser("check", help="preflight: can checkpointing work "
                       "here? (`criu check`)")
    p.add_argument("--run-dir", default=None,
                   help="also prove this image directory is writable")
    p.add_argument("--json", action="store_true")
    p.set_defaults(fn=cmd_check)

    p = sub.add_parser("inspect", help="list snapshots / show one manifest")
    p.add_argument("run_dir")
    p.add_argument("--step", type=int, default=None)
    p.add_argument("--json", action="store_true")
    p.set_defaults(fn=cmd_inspect)

    p = sub.add_parser("verify", help="CRC-verify image entries")
    p.add_argument("run_dir")
    p.add_argument("--step", type=int, default=None)
    p.set_defaults(fn=cmd_verify)

    p = sub.add_parser("gc", help="remove old snapshots (parent-chain safe)")
    p.add_argument("run_dir")
    p.add_argument("--keep", type=int, required=True)
    p.add_argument("--dry-run", action="store_true")
    p.set_defaults(fn=cmd_gc)

    p = sub.add_parser("restore", help="dry-run the restore path on the "
                       "host backend")
    p.add_argument("run_dir")
    p.add_argument("--step", type=int, default=None)
    p.add_argument("--dry-run", action="store_true")
    p.add_argument("--lazy", action="store_true",
                   help="priority-ordered lazy restore: time the "
                        "critical-set resume vs full materialization")
    p.add_argument("--critical", action="append", default=[],
                   metavar="STATE[/SUBTREE]",
                   help="critical-set spec (repeatable); default: the "
                        "image's first recorded state")
    p.set_defaults(fn=cmd_restore)

    p = sub.add_parser("jobs", help="inspect orchestrator job records "
                       "(offline, no owning process)")
    p.add_argument("run_dir")
    p.add_argument("--job", default=None, help="show one job in full")
    p.add_argument("--state", default=None, metavar="STATE",
                   help="only jobs in this lifecycle state "
                        "(e.g. failed, done, running)")
    p.add_argument("--json", action="store_true")
    p.set_defaults(fn=cmd_jobs)

    p = sub.add_parser("orchestrate", help="run a deterministic "
                       "multi-tenant preemption/failure/migration scenario")
    p.add_argument("run_dir")
    p.add_argument("--scenario", default="mixed",
                   choices=["preemption", "preempt", "failure", "straggler",
                            "migrate", "mixed"])
    p.add_argument("--steps", type=int, default=10,
                   help="steps per low-priority job")
    p.add_argument("--kind", default="train",
                   choices=["train", "serve", "intercept"])
    p.add_argument("--mode", default="async", choices=["sync", "async"])
    p.add_argument("--pack-format", type=int, default=2, choices=[1, 2])
    p.add_argument("--io-threads", type=int, default=0)
    p.add_argument("--capacity", type=int, default=None)
    p.add_argument("--hosts", type=int, default=None,
                   help="simulated hosts (migrate defaults to 2)")
    p.add_argument("--incremental", action="store_true",
                   help="delta images (what the migrate transfer dedups)")
    p.add_argument("--max-rounds", type=int, default=0, metavar="N",
                   help="migrate via live pre-copy: up to N delta rounds "
                        "while the job steps, then a frozen residual")
    p.add_argument("--max-blackout-ms", type=float, default=None,
                   metavar="MS",
                   help="freeze only once the predicted residual push "
                        "fits this budget (needs --max-rounds)")
    p.add_argument("--json", default=None, metavar="PATH",
                   help="also dump the full summary JSON here")
    p.add_argument("--no-trace", action="store_true",
                   help="skip the observability plane (no run journal)")
    p.add_argument("--trace-detail", action="store_true",
                   help="also record per-chunk spans (pack compress/"
                        "append, lazy entries) — bigger journal")
    p.set_defaults(fn=cmd_orchestrate)

    p = sub.add_parser("serve-fleet", help="boot K decode replicas from "
                       "one committed image (CAS dedup + lazy restore) "
                       "and drive a bursty autoscaling request trace")
    p.add_argument("run_dir")
    p.add_argument("--replicas", type=int, default=8,
                   help="initial fan-out (autoscale may add more)")
    p.add_argument("--hosts", type=int, default=2,
                   help="simulated hosts; one shared CAS each")
    p.add_argument("--restore-mode", default="lazy",
                   choices=["lazy", "eager"],
                   help="lazy = params-critical cold boot (default)")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--max-replicas", type=int, default=64,
                   help="autoscale ceiling")
    p.add_argument("--trace", default=None, metavar="N,N,...",
                   help="arrivals per tick (default: a burst shaped "
                        "to the fleet size)")
    p.add_argument("--json", default=None, metavar="PATH",
                   help="also dump the full summary JSON here")
    p.add_argument("--no-trace", action="store_true",
                   help="skip the observability plane (no run journal)")
    p.set_defaults(fn=cmd_serve_fleet)

    p = sub.add_parser("migrate", help="transfer snapshot images to a "
                       "peer store (content-addressed delta by default)")
    p.add_argument("run_dir", help="source run directory")
    p.add_argument("dest", help="destination peer store directory")
    p.add_argument("--step", type=int, default=None,
                   help="snapshot step (default: newest)")
    p.add_argument("--transfer", default="delta",
                   choices=["delta", "copy"])
    p.add_argument("--max-rounds", type=int, default=0, metavar="N",
                   help="pre-copy replay: push the image's parent chain "
                        "as up to N live rounds before the frozen "
                        "residual (delta only)")
    p.add_argument("--max-blackout-ms", type=float, default=None,
                   metavar="MS",
                   help="convergence budget for the pre-copy controller "
                        "(needs --max-rounds)")
    p.add_argument("--workers", type=int, default=0,
                   help="parallel ship lanes (0 = auto)")
    p.add_argument("--json", action="store_true")
    p.set_defaults(fn=cmd_migrate)

    p = sub.add_parser("transfer-stats", help="inspect a peer store's "
                       "chunk CAS and transfer history")
    p.add_argument("dest", help="peer store directory (holds .cas/)")
    p.add_argument("--fsck", action="store_true",
                   help="CRC-check every CAS object")
    p.add_argument("--repair", action="store_true",
                   help="with --fsck: move corrupt objects to "
                        "quarantine/ so the next transfer re-fetches "
                        "them from source (implies --fsck)")
    p.add_argument("--json", action="store_true")
    p.set_defaults(fn=cmd_transfer_stats)

    p = sub.add_parser("chaos-campaign", help="seeded fault-injection "
                       "campaign: N sim jobs × H hosts must recover "
                       "bit-exact or quarantine diagnosably")
    p.add_argument("run_dir")
    p.add_argument("--jobs", type=int, default=100)
    p.add_argument("--hosts", type=int, default=20)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--faults", default="all=1", metavar="SPEC",
                   help="fault mix, e.g. 'all=1' or "
                        "'host_kill=3,torn_write=2'")
    p.add_argument("--max-ticks", type=int, default=4000)
    p.add_argument("--capture", choices=("sync", "concurrent", "sweep"),
                   default="sync",
                   help="dump capture mode for the fleet; 'sweep' runs "
                        "both campaigns (sync + concurrent, the latter "
                        "with the dirty_burst class enabled) and merges "
                        "their metrics")
    p.add_argument("--json", default=None, metavar="PATH",
                   help="write flat BENCH metrics here "
                        "(gated by compare_bench)")
    p.add_argument("--report", default=None, metavar="PATH",
                   help="write the full report (rows, outcomes, "
                        "violations, fingerprint) here")
    p.add_argument("--no-trace", action="store_true",
                   help="skip the observability plane (no run journal)")
    p.set_defaults(fn=cmd_chaos_campaign)

    p = sub.add_parser("trace", help="export a run's journal as Chrome "
                       "trace-event JSON (Perfetto-loadable)")
    p.add_argument("run_dir")
    p.add_argument("--chrome", action="store_true",
                   help="Chrome trace-event JSON (the default and "
                        "currently only format)")
    p.add_argument("-o", "--out", default=None, metavar="PATH",
                   help="output path (default: RUN_DIR/obs/trace.json)")
    p.set_defaults(fn=cmd_trace)

    p = sub.add_parser("events", help="filtered run-journal timeline")
    p.add_argument("run_dir")
    p.add_argument("--job", default=None, help="only this job's events")
    p.add_argument("--class", dest="cls", default=None,
                   choices=["dump", "restore", "transfer", "fault", "job",
                            "recovery", "pack", "orch", "fleet", "metrics"],
                   help="only events of this class")
    p.add_argument("--json", action="store_true",
                   help="one JSON object per line instead of a table")
    p.set_defaults(fn=cmd_events)

    p = sub.add_parser("metrics", help="final metrics snapshot from a "
                       "run's journal (flat name -> value)")
    p.add_argument("run_dir")
    p.add_argument("--json", nargs="?", const="-", default=None,
                   metavar="PATH",
                   help="emit JSON (to PATH, or stdout with no PATH)")
    p.set_defaults(fn=cmd_metrics)
    return ap


def main(argv: Optional[List[str]] = None) -> int:
    args = build_parser().parse_args(argv)
    try:
        return args.fn(args)
    except SystemExit:
        raise
    except KeyboardInterrupt:                          # pragma: no cover
        return 130
    except Exception as e:
        print(f"error: {e}", file=sys.stderr)
        return 1


if __name__ == "__main__":                             # pragma: no cover
    sys.exit(main())
