"""Run journal: append-only JSONL event log per run directory.

One file — ``<run_dir>/obs/journal.jsonl`` — captures everything the
plane sees: finished spans, chaos injections, job state transitions,
stall events, and a metrics snapshot at close, so a post-mortem can
reconstruct an incident timeline with zero live telemetry.

Each line is one JSON object.  Common fields:

    v       journal format version (1)
    cls     event class: dump | restore | transfer | fault | job |
            recovery | pack | metrics | meta
    kind    event kind within the class ("span", "transition",
            "injection", "pending_stall", "snapshot", ...)
    t       seconds since journal open (monotonic clock)
    wall    absolute unix time (float seconds)

Span events add ``name/ts/dur/thread/span_id/parent_id/attrs`` where
``ts`` is span start in journal-relative monotonic seconds.  The opening
``meta/journal_open`` line records ``t0_perf`` (the journal's monotonic
epoch) so timestamps taken elsewhere on the same clock — the
orchestrator's incident marks — can be translated into journal time.

Crash-safety: every event is written and ``flush()``ed as one line, so
an abrupt process death loses at most the final partial line; the reader
tolerates (and reports) a torn tail.

No imports from outside ``repro.obs`` — every layer may depend on this
module without cycles.
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import Any, Callable, Dict, Iterator, Optional

JOURNAL: Optional["RunJournal"] = None

VERSION = 1
CLASSES = ("dump", "restore", "transfer", "fault", "job", "recovery",
           "pack", "orch", "metrics", "meta")


class RunJournal:
    def __init__(self, run_dir: str,
                 clock: Callable[[], float] = time.perf_counter) -> None:
        self.run_dir = run_dir
        self.path = os.path.join(run_dir, "obs", "journal.jsonl")
        os.makedirs(os.path.dirname(self.path), exist_ok=True)
        self.clock = clock
        self.t0 = clock()
        self._lock = threading.Lock()
        self._f = open(self.path, "a", encoding="utf-8")
        self.event("meta", "journal_open", v=VERSION, t0_perf=self.t0,
                   pid=os.getpid())

    def event(self, cls: str, kind: str, **fields: Any) -> None:
        rec = {"v": VERSION, "cls": cls, "kind": kind,
               "t": self.clock() - self.t0, "wall": time.time()}
        rec.update(fields)
        line = json.dumps(rec, sort_keys=False, default=repr)
        with self._lock:
            f = self._f
            if f.closed:
                return
            f.write(line + "\n")
            f.flush()

    def sync(self) -> None:
        with self._lock:
            if not self._f.closed:
                self._f.flush()
                os.fsync(self._f.fileno())

    def close(self) -> None:
        with self._lock:
            if not self._f.closed:
                self._f.flush()
                os.fsync(self._f.fileno())
                self._f.close()


# ------------------------------------------------------------- module API
def emit(cls: str, kind: str, **fields: Any) -> None:
    """Append one event to the installed journal (no-op when off).

    Events emitted inside a ``trace.context(job=...)`` block inherit
    the job attribution unless the caller passed ``job=`` explicitly —
    so ``repro events --job J`` lines journal events up with spans."""
    j = JOURNAL
    if j is None:
        return
    if "job" not in fields:
        from repro.obs import trace as _trace
        tr = _trace.TRACER
        if tr is not None:
            job = tr._ctx().get("job")
            if job is not None:
                fields["job"] = job
    j.event(cls, kind, **fields)


def install(jrn: RunJournal) -> None:
    global JOURNAL
    if JOURNAL is not None and JOURNAL is not jrn:
        raise RuntimeError("a run journal is already installed; "
                           "uninstall it first")
    JOURNAL = jrn


def uninstall() -> None:
    global JOURNAL
    JOURNAL = None


# --------------------------------------------------------------- reading
def journal_path(run_dir: str) -> str:
    return os.path.join(run_dir, "obs", "journal.jsonl")


def read_events(run_dir: str) -> Iterator[Dict[str, Any]]:
    """Yield journal events; a torn final line (crash mid-write) is
    skipped rather than fatal."""
    path = journal_path(run_dir)
    with open(path, encoding="utf-8") as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                yield json.loads(line)
            except json.JSONDecodeError:
                # torn tail from a crashed writer: drop it
                continue
