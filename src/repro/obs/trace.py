"""Phase tracing: nested spans with thread attribution, zero-overhead
when disabled.

Mirrors ``repro.chaos.hooks``: a module-global ``TRACER`` that is
``None`` until ``install()``.  ``span()`` is safe to call unconditionally
on warm paths — when no tracer is installed it returns a shared no-op
singleton (one function call, one attribute load, no per-call state).
Hot per-chunk paths (the pack writer's worker loops) additionally guard
with ``if trace.TRACER is not None and trace.TRACER.detail:`` so the
disabled cost there is a single pointer read.

Spans nest per-thread: a span opened while another is live on the same
thread records that span as its parent, which is what makes the pack
pipeline legible — each compress/append worker carries its own stack, and
the exporter lays them out as Chrome trace rows keyed by thread name.

``record()`` emits a retroactive span from explicit timestamps; the
orchestrator's ``RecoveryLog`` uses it so every recovery phase
(detect/transfer/schedule/restore/background/replay) appears in the
trace as a first-class span instead of parallel bookkeeping.

This module deliberately imports nothing from ``repro`` so every layer
(serialization, transfer, orchestrator) can depend on it without cycles.
"""
from __future__ import annotations

import itertools
import threading
import time
from typing import Any, Callable, Dict, List, Optional

TRACER: Optional["Tracer"] = None

# span name -> (layer, description); the stable schema the docs table and
# the exporter's class filter (`repro events --class`) key off.  A span's
# event class is its name's first dotted component.
SPAN_SCHEMA: Dict[str, tuple] = {
    "dump.pause": ("engine", "device quiesce: PAUSE_DEVICES hooks"),
    "dump.capture": ("engine", "device->host state capture"),
    "dump.ext_state": ("engine", "host-side external state dump"),
    "dump.write": ("engine", "serialize + commit to storage"),
    "dump.wait_pending": ("engine", "join of the async writer thread"),
    "dump.speculate": ("engine", "concurrent capture: speculative pass"),
    "dump.validate": ("engine", "concurrent capture: validate pause"),
    "dump.patch": ("engine", "concurrent capture: dirty-entry recapture"),
    "dump.commit": ("engine", "manifest + meta commit"),
    "dump.replicate": ("engine", "post-commit replication push"),
    "pack.compress": ("serialization", "one chunk through the codec "
                                       "(detail mode only)"),
    "pack.append": ("serialization", "one chunk appended to its stripe "
                                     "(detail mode only)"),
    "pack.flush": ("serialization", "pipeline drain barrier"),
    "restore.critical": ("engine", "restore() critical path: scan, read, "
                                   "place, resume"),
    "restore.critical_place": ("engine", "critical-set entry placement "
                                         "(inside restore.critical)"),
    "restore.background": ("engine", "lazy background stream"),
    "restore.entry": ("engine", "one background entry "
                                "(detail mode only)"),
    "transfer.push": ("transfer", "full delta-replication push"),
    "transfer.round": ("transfer", "one pre-copy migration round "
                                   "(live or frozen residual)"),
    "transfer.negotiate": ("transfer", "CAS have/want round"),
    "transfer.ship": ("transfer", "missing chunks over the wire"),
    "transfer.materialize": ("transfer", "peer-side pack rebuild"),
    "recovery.detect": ("orchestrator", "interrupt -> noticed"),
    "recovery.transfer": ("orchestrator", "image pre-stage to new host"),
    "recovery.schedule": ("orchestrator", "noticed -> capacity found"),
    "recovery.restore": ("orchestrator", "restore start -> RUNNING"),
    "recovery.restore_background": ("orchestrator",
                                    "resume -> fully materialized"),
    "recovery.replay": ("orchestrator", "restored step -> caught up"),
    "fleet.boot": ("orchestrator", "image -> serving replica "
                                   "(the TTFT window)"),
    "fleet.serve": ("orchestrator", "bursty request trace against the "
                                    "fleet (autoscale inside)"),
}


class _NoopSpan:
    """Shared do-nothing span returned when no tracer is installed."""

    __slots__ = ()

    def __enter__(self) -> "_NoopSpan":
        return self

    def __exit__(self, *exc: Any) -> bool:
        return False

    def set(self, **attrs: Any) -> "_NoopSpan":
        return self


NOOP_SPAN = _NoopSpan()


class Span:
    """One live span; finished (and sunk) when its ``with`` block exits."""

    __slots__ = ("name", "attrs", "span_id", "parent_id", "thread",
                 "t_start", "t_end", "_tracer")

    def __init__(self, tracer: "Tracer", name: str, attrs: Dict[str, Any],
                 span_id: int, parent_id: Optional[int]) -> None:
        self._tracer = tracer
        self.name = name
        self.attrs = attrs
        self.span_id = span_id
        self.parent_id = parent_id
        self.thread = threading.current_thread().name
        self.t_start = tracer.clock()
        self.t_end: Optional[float] = None

    def set(self, **attrs: Any) -> "Span":
        self.attrs.update(attrs)
        return self

    def __enter__(self) -> "Span":
        return self

    def __exit__(self, exc_type: Any, exc: Any, tb: Any) -> bool:
        if exc_type is not None:
            self.attrs.setdefault("error", exc_type.__name__)
        self._tracer._finish(self)
        return False


class Tracer:
    """Collects spans; per-thread stacks give nesting, ``sink`` (set by
    the plane) forwards each finished span to the run journal."""

    def __init__(self, sink: Optional[Callable[[Span], None]] = None,
                 detail: bool = False,
                 clock: Callable[[], float] = time.perf_counter) -> None:
        self.sink = sink
        self.detail = detail       # opt-in per-chunk spans on hot paths
        self.clock = clock
        self.t0 = clock()
        self.spans: List[Span] = []
        self._lock = threading.Lock()
        self._tls = threading.local()
        self._ids = itertools.count(1)

    # ------------------------------------------------------------- stacks
    def _stack(self) -> List[Span]:
        st = getattr(self._tls, "stack", None)
        if st is None:
            st = self._tls.stack = []
        return st

    def _ctx(self) -> Dict[str, Any]:
        ctx = getattr(self._tls, "ctx", None)
        if ctx is None:
            ctx = self._tls.ctx = {}
        return ctx

    # -------------------------------------------------------------- spans
    def begin(self, name: str, attrs: Dict[str, Any]) -> Span:
        ctx = self._ctx()
        if ctx:
            merged = dict(ctx)
            merged.update(attrs)
            attrs = merged
        stack = self._stack()
        parent = stack[-1].span_id if stack else None
        sp = Span(self, name, attrs, next(self._ids), parent)
        stack.append(sp)
        return sp

    def _finish(self, sp: Span) -> None:
        sp.t_end = self.clock()
        stack = self._stack()
        if sp in stack:                      # tolerate exits out of order
            stack.remove(sp)
        with self._lock:
            self.spans.append(sp)
        if self.sink is not None:
            self.sink(sp)

    def record(self, name: str, t_start: float, t_end: float,
               attrs: Dict[str, Any]) -> Span:
        """Retroactive span from explicit (tracer-clock) timestamps."""
        sp = Span(self, name, dict(attrs), next(self._ids), None)
        sp.t_start = t_start
        sp.t_end = max(t_start, t_end)
        with self._lock:
            self.spans.append(sp)
        if self.sink is not None:
            self.sink(sp)
        return sp

    # ------------------------------------------------------------ context
    class _Ctx:
        __slots__ = ("_tracer", "_saved")

        def __init__(self, tracer: "Tracer", attrs: Dict[str, Any]) -> None:
            self._tracer = tracer
            ctx = tracer._ctx()
            self._saved = dict(ctx)
            ctx.update(attrs)

        def __enter__(self) -> "Tracer._Ctx":
            return self

        def __exit__(self, *exc: Any) -> bool:
            self._tracer._tls.ctx = self._saved
            return False


class _NoopCtx:
    __slots__ = ()

    def __enter__(self) -> "_NoopCtx":
        return self

    def __exit__(self, *exc: Any) -> bool:
        return False


_NOOP_CTX = _NoopCtx()


# ------------------------------------------------------------- module API
def span(name: str, **attrs: Any):
    """Open a span, or return the shared no-op when tracing is off."""
    tr = TRACER
    if tr is None:
        return NOOP_SPAN
    return tr.begin(name, attrs)


def record(name: str, t_start: float, t_end: float, **attrs: Any) -> None:
    """Emit a retroactive span (no-op when tracing is off)."""
    tr = TRACER
    if tr is not None:
        tr.record(name, t_start, t_end, attrs)


def context(**attrs: Any):
    """Attach attrs (e.g. ``job=...``) to every span opened on this
    thread inside the ``with`` block.  No-op when tracing is off."""
    tr = TRACER
    if tr is None:
        return _NOOP_CTX
    return Tracer._Ctx(tr, attrs)


def current_context() -> Dict[str, Any]:
    """Copy of the calling thread's span context — capture it before
    spawning a worker thread, re-apply inside with ``context(**saved)``
    so spans the worker emits keep e.g. their job attribution."""
    tr = TRACER
    if tr is None:
        return {}
    return dict(tr._ctx())


def install(tracer: Tracer) -> None:
    global TRACER
    if TRACER is not None and TRACER is not tracer:
        raise RuntimeError("a tracer is already installed; "
                           "uninstall it first")
    TRACER = tracer


def uninstall() -> None:
    global TRACER
    TRACER = None
