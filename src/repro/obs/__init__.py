"""repro.obs — unified observability plane: spans, metrics, run journal.

Three module-global sinks, each mirroring the chaos-hook pattern
(``repro.chaos.hooks``): ``None`` until installed, and every call site
guards with a single module-attribute load so the steady-state cost of a
*disabled* plane is one pointer read + ``None`` check — the paper's
zero-overhead claim survives instrumentation.

  ``obs.trace``     span("dump.capture", step=...) context managers with
                    nesting, thread attribution, monotonic timestamps.
  ``obs.metrics``   counters / gauges / histograms behind a stable
                    name -> schema table (METRIC_SCHEMA).
  ``obs.journal``   append-only JSONL event log per run directory:
                    spans, metric snapshots, chaos injections, job state
                    transitions.

``ObservabilityPlane`` bundles all three for one run directory and wires
the tracer's sink into the journal; ``observed(run_dir)`` is the
context-manager form the CLI uses::

    from repro.obs import observed
    with observed(run_dir):
        ...   # dumps/restores/orchestration in here are traced

Exporters (``repro.obs.export``) turn the journal back into a Chrome
trace-event file (Perfetto-loadable), a filtered event timeline, or a
flat metrics dict — the substrate behind ``repro trace``, ``repro
events`` and ``repro metrics``.
"""
from repro.obs import journal, metrics, trace
from repro.obs.journal import RunJournal
from repro.obs.metrics import METRIC_SCHEMA, MetricsRegistry
from repro.obs.plane import ObservabilityPlane, observed
from repro.obs.trace import SPAN_SCHEMA, Span, Tracer, span

__all__ = [
    "trace", "metrics", "journal",
    "span", "Span", "Tracer", "SPAN_SCHEMA",
    "MetricsRegistry", "METRIC_SCHEMA",
    "RunJournal",
    "ObservabilityPlane", "observed",
]
