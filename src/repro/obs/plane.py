"""ObservabilityPlane: one run directory's tracer + registry + journal,
wired together and installed/uninstalled as a unit.

The tracer's sink is the journal, so every finished span becomes one
JSONL event immediately (crash-safe: the journal flushes per line).  At
``close()`` the plane journals a final metrics snapshot — the flat dict
``repro metrics`` and ``make_tables.py`` read back.
"""
from __future__ import annotations

from contextlib import contextmanager
from typing import Iterator, Optional

from repro.obs import journal as journal_mod
from repro.obs import metrics as metrics_mod
from repro.obs import trace as trace_mod
from repro.obs.journal import RunJournal
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import Span, Tracer


class ObservabilityPlane:
    def __init__(self, run_dir: str, detail: bool = False) -> None:
        self.run_dir = run_dir
        self.journal = RunJournal(run_dir)
        self.tracer = Tracer(sink=self._on_span, detail=detail,
                             clock=self.journal.clock)
        self.registry = MetricsRegistry()
        self._installed = False

    # ------------------------------------------------------------- wiring
    def _on_span(self, sp: Span) -> None:
        j = self.journal
        cls = sp.name.split(".", 1)[0]
        if cls not in journal_mod.CLASSES:
            cls = "orch"
        t_end: float = sp.t_end if sp.t_end is not None else sp.t_start
        j.event(cls, "span", name=sp.name,
                ts=sp.t_start - j.t0, dur=t_end - sp.t_start,
                thread=sp.thread, span_id=sp.span_id,
                parent_id=sp.parent_id, attrs=sp.attrs)

    # ---------------------------------------------------------- lifecycle
    def install(self) -> "ObservabilityPlane":
        trace_mod.install(self.tracer)
        metrics_mod.install(self.registry)
        journal_mod.install(self.journal)
        self._installed = True
        return self

    def close(self) -> None:
        snap = self.registry.snapshot()
        self.journal.event("metrics", "snapshot", **snap)
        if self._installed:
            trace_mod.uninstall()
            metrics_mod.uninstall()
            journal_mod.uninstall()
            self._installed = False
        self.journal.close()


@contextmanager
def observed(run_dir: str,
             detail: bool = False) -> Iterator[Optional[ObservabilityPlane]]:
    """Install a plane for ``run_dir`` for the duration of the block."""
    plane = ObservabilityPlane(run_dir, detail=detail)
    plane.install()
    try:
        yield plane
    finally:
        plane.close()
