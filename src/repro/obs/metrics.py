"""Metrics registry: counters / gauges / histograms behind a stable
name schema, zero-overhead when disabled.

Same discipline as ``repro.obs.trace``: the module-global ``REGISTRY``
is ``None`` until installed, and the module-level helpers
(``counter_add`` / ``gauge_set`` / ``observe``) are safe to call
unconditionally — disabled cost is one attribute load + ``None`` check.

Histograms are summaries (count/sum/min/max), not bucketed: the journal
stores one snapshot per plane lifetime and the consumers (bench tables,
``repro metrics``) want totals and extremes, not percentiles.

``warn_once`` is the one piece that works without installation: it
flags configuration holes (a replicator with no ``last_stats``) exactly
once per process instead of silently dropping counters.

No ``repro`` imports — every layer may depend on this module.
"""
from __future__ import annotations

import threading
import warnings
from typing import Any, Dict, Optional

REGISTRY: Optional["MetricsRegistry"] = None

# name -> (type, unit, description): the stable schema table.  Docs and
# tests key off this; add the row when adding a call site.
METRIC_SCHEMA: Dict[str, tuple] = {
    "dump.count": ("counter", "dumps", "checkpoints committed"),
    "dump.bytes_written": ("counter", "bytes", "new pack bytes on disk"),
    "dump.bytes_deduped": ("counter", "bytes",
                           "chunk-grain dedup savings at commit"),
    "dump.frozen_s": ("histogram", "s", "stop-the-world frozen window"),
    "dump.pending_stall_s": ("histogram", "s",
                             "async writer join timeouts "
                             "(PendingWriteStalled)"),
    "pack.chunks": ("counter", "chunks", "chunks through the pipeline"),
    "pack.queue_depth": ("gauge", "chunks",
                         "compress-queue depth at last sample"),
    "restore.count": ("counter", "restores", "restores completed"),
    "restore.critical_s": ("histogram", "s",
                           "lock-held critical restore phase"),
    "restore.heal_events": ("counter", "events",
                            "corrupt entries healed during lazy "
                            "materialization"),
    "replica.push_count": ("counter", "pushes",
                           "replication pushes attempted"),
    "replica.missing_stats": ("counter", "pushes",
                              "pushes whose replicator exposed no "
                              "last_stats (silent-loss guard)"),
    # replica.<k> mirrors every numeric counter a replicator reports in
    # last_stats (bytes_sent, chunks_reused, ...): dynamic keys, one
    # schema row.
    "replica.*": ("counter", "mixed", "replicator last_stats mirror"),
    "chaos.injections": ("counter", "events", "faults actually armed"),
    "fleet.replicas_booted": ("counter", "replicas",
                              "fleet boots attempted"),
    "fleet.replicas_serving": ("gauge", "replicas",
                               "replicas currently serving"),
    "fleet.ttft_s": ("histogram", "s",
                     "per-replica time-to-first-token"),
    "fleet.restore_bytes": ("counter", "bytes",
                            "delta bytes shipped booting replicas"),
    "fleet.requests_served": ("counter", "requests",
                              "requests completed by the fleet"),
}


class MetricsRegistry:
    """Thread-safe in-process registry; ``snapshot()`` is what the plane
    journals at close."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self.counters: Dict[str, float] = {}
        self.gauges: Dict[str, float] = {}
        self.hists: Dict[str, Dict[str, float]] = {}

    def counter_add(self, name: str, v: float = 1.0) -> None:
        with self._lock:
            self.counters[name] = self.counters.get(name, 0.0) + v

    def gauge_set(self, name: str, v: float) -> None:
        with self._lock:
            self.gauges[name] = v

    def observe(self, name: str, v: float) -> None:
        with self._lock:
            h = self.hists.get(name)
            if h is None:
                h = self.hists[name] = {"count": 0, "sum": 0.0,
                                        "min": v, "max": v}
            h["count"] += 1
            h["sum"] += v
            h["min"] = min(h["min"], v)
            h["max"] = max(h["max"], v)

    def snapshot(self) -> Dict[str, Any]:
        with self._lock:
            return {"counters": dict(self.counters),
                    "gauges": dict(self.gauges),
                    "histograms": {k: dict(v)
                                   for k, v in self.hists.items()}}


# ------------------------------------------------------------- module API
def counter_add(name: str, v: float = 1.0) -> None:
    reg = REGISTRY
    if reg is not None:
        reg.counter_add(name, v)


def gauge_set(name: str, v: float) -> None:
    reg = REGISTRY
    if reg is not None:
        reg.gauge_set(name, v)


def observe(name: str, v: float) -> None:
    reg = REGISTRY
    if reg is not None:
        reg.observe(name, v)


_warned: set = set()
_warned_lock = threading.Lock()


def warn_once(key: str, message: str) -> None:
    """Emit ``message`` as a RuntimeWarning once per process per key.

    Works with or without an installed registry: the silent-stats-loss
    guard must fire even when observability is off."""
    with _warned_lock:
        if key in _warned:
            return
        _warned.add(key)
    warnings.warn(message, RuntimeWarning, stacklevel=3)


def install(registry: MetricsRegistry) -> None:
    global REGISTRY
    if REGISTRY is not None and REGISTRY is not registry:
        raise RuntimeError("a metrics registry is already installed; "
                           "uninstall it first")
    REGISTRY = registry


def uninstall() -> None:
    global REGISTRY
    REGISTRY = None
