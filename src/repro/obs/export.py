"""Exporters: run journal -> Chrome trace / filtered timeline / metrics.

The journal (``obs/journal.jsonl``) is the single substrate; everything
here is a pure read-side transform:

  ``to_chrome_trace``     trace-event JSON (``{"traceEvents": [...]}``)
                          loadable in Perfetto / chrome://tracing.  Spans
                          become complete ("X") events laid out per
                          thread; faults and job transitions become
                          instant ("i") markers.
  ``filter_events``       the ``repro events`` timeline: by job and/or
                          event class (dump|restore|transfer|fault|...).
  ``metrics_from_journal``the final metrics snapshot flattened to one
                          ``{name: value}`` dict (``repro metrics
                          --json``, consumed by make_tables.py).
  ``validate_journal``    schema check CI's obs-smoke job runs.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional

from repro.obs.journal import CLASSES, VERSION, read_events


def load_journal(run_dir: str) -> List[Dict[str, Any]]:
    return list(read_events(run_dir))


def _event_job(ev: Dict[str, Any]) -> Optional[str]:
    job = ev.get("job")
    if job is not None:
        return job
    attrs = ev.get("attrs")
    if isinstance(attrs, dict):
        return attrs.get("job")
    return None


def _event_t(ev: Dict[str, Any]) -> float:
    ts = ev.get("ts")           # spans: start time beats emit time
    if isinstance(ts, (int, float)):
        return ts
    return ev.get("t", 0.0)


def filter_events(events: List[Dict[str, Any]],
                  job: Optional[str] = None,
                  cls: Optional[str] = None) -> List[Dict[str, Any]]:
    out = []
    for ev in events:
        if ev.get("cls") == "meta":
            continue
        if cls is not None and ev.get("cls") != cls:
            continue
        if job is not None and _event_job(ev) != job:
            continue
        out.append(ev)
    out.sort(key=_event_t)
    return out


# --------------------------------------------------------- chrome export
def to_chrome_trace(events: List[Dict[str, Any]],
                    process_name: str = "repro") -> Dict[str, Any]:
    """Chrome trace-event JSON.  Timestamps are journal-relative
    microseconds; one tid per producing thread plus marker rows for
    faults and job transitions."""
    trace_events: List[Dict[str, Any]] = []
    tids: Dict[str, int] = {}

    def tid_for(thread: str) -> int:
        if thread not in tids:
            tids[thread] = len(tids) + 1
            trace_events.append({
                "ph": "M", "pid": 1, "tid": tids[thread],
                "name": "thread_name", "args": {"name": thread}})
        return tids[thread]

    trace_events.append({"ph": "M", "pid": 1, "tid": 0,
                         "name": "process_name",
                         "args": {"name": process_name}})

    for ev in events:
        cls = ev.get("cls")
        kind = ev.get("kind")
        if kind == "span":
            attrs = ev.get("attrs") or {}
            trace_events.append({
                "name": ev.get("name", "?"),
                "cat": cls or "span",
                "ph": "X",
                "ts": round(float(ev.get("ts", 0.0)) * 1e6, 3),
                "dur": round(float(ev.get("dur", 0.0)) * 1e6, 3),
                "pid": 1,
                "tid": tid_for(ev.get("thread", "?")),
                "args": dict(attrs, span_id=ev.get("span_id"),
                             parent_id=ev.get("parent_id")),
            })
        elif cls == "fault":
            trace_events.append({
                "name": f"fault:{kind}",
                "cat": "fault",
                "ph": "i", "s": "g",
                "ts": round(float(ev.get("t", 0.0)) * 1e6, 3),
                "pid": 1,
                "tid": tid_for("faults"),
                "args": {k: v for k, v in ev.items()
                         if k not in ("v", "cls", "kind", "wall")},
            })
        elif cls == "job" and kind == "transition":
            trace_events.append({
                "name": f"{ev.get('job', '?')}: "
                        f"{ev.get('frm', '?')}->{ev.get('to', '?')}",
                "cat": "job",
                "ph": "i", "s": "t",
                "ts": round(float(ev.get("t", 0.0)) * 1e6, 3),
                "pid": 1,
                "tid": tid_for("jobs"),
                "args": {"job": ev.get("job"), "step": ev.get("step")},
            })
    return {"traceEvents": trace_events, "displayTimeUnit": "ms"}


# --------------------------------------------------------------- metrics
def metrics_from_journal(events: List[Dict[str, Any]]) -> Dict[str, float]:
    """Flatten the last metrics snapshot into ``{name: value}``."""
    snap: Optional[Dict[str, Any]] = None
    for ev in events:
        if ev.get("cls") == "metrics" and ev.get("kind") == "snapshot":
            snap = ev
    if snap is None:
        return {}
    out: Dict[str, float] = {}
    for name, v in (snap.get("counters") or {}).items():
        out[f"obs.counter.{name}"] = v
    for name, v in (snap.get("gauges") or {}).items():
        out[f"obs.gauge.{name}"] = v
    for name, h in (snap.get("histograms") or {}).items():
        for stat in ("count", "sum", "min", "max"):
            out[f"obs.hist.{name}.{stat}"] = h.get(stat)
    return out


# ------------------------------------------------------------ validation
def validate_journal(events: List[Dict[str, Any]]) -> List[str]:
    """Schema problems (empty list = valid).  CI's obs-smoke gate."""
    problems: List[str] = []
    if not events:
        return ["journal is empty"]
    head = events[0]
    if head.get("cls") != "meta" or head.get("kind") != "journal_open":
        problems.append("first event is not meta/journal_open")
    elif head.get("v") != VERSION:
        problems.append(f"unknown journal version {head.get('v')!r}")
    for i, ev in enumerate(events):
        where = f"event {i}"
        cls = ev.get("cls")
        if cls not in CLASSES:
            problems.append(f"{where}: unknown cls {cls!r}")
        if not isinstance(ev.get("kind"), str):
            problems.append(f"{where}: missing kind")
        if not isinstance(ev.get("t"), (int, float)):
            problems.append(f"{where}: missing monotonic t")
        if ev.get("kind") == "span":
            for field in ("name", "ts", "dur", "thread", "span_id"):
                if field not in ev:
                    problems.append(f"{where}: span missing {field!r}")
    return problems
