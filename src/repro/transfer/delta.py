"""DeltaReplicator — ship only the chunks the target doesn't have.

The copy-everything :class:`repro.core.replication.DirReplicator` moves
O(image) bytes per push.  This replicator upgrades the same ``push`` /
``pull_latest`` contract into a three-phase delta protocol against the
target host's :class:`~repro.transfer.cas.ChunkStore`:

  1. **closure** — an incremental snapshot references parent packs (entry
     locations and chunk-level ``ref``\\ s), so the unit of transfer is the
     delta-chain closure, oldest step first;
  2. **negotiate** — for each v2 pack, the chunk index is exported and the
     target answers have/want by CAS key (the raw-CRC content hash pack v2
     already computes); only *wanted* chunks ship, read stripe-parallel
     from the source and landed as CAS objects (the CAS is also the resume
     log: a retried transfer re-negotiates and skips everything received);
  3. **materialize** — stripes are rebuilt byte-identically from the CAS
     (:func:`repro.serialization.pack.write_pack_v2_from_chunks`), the
     manifest is copied last, so the target only ever sees committed,
     restorable images.  A corrupt CAS object is detected by its CRC
     during materialization and healed from the source.

v1 single-file packs have no chunk index — they fall back to whole-file
copy (counted in ``bytes_copied``), so mixed v1/v2 chains still transfer.
"""
from __future__ import annotations

import os
import shutil
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Dict, List, Optional

from repro.core.snapshot_io import MANIFEST, SnapshotStore, snapshot_dir
from repro.obs import journal as obs_journal
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.serialization.integrity import atomic_write_json, read_json
from repro.serialization.pack import (PackReaderV2, open_pack,
                                      write_pack_v2_from_chunks)
from repro.transfer.cas import (CASCorruption, ChunkStore, chunk_key,
                                default_cas_dir)


def transfer_closure(store: SnapshotStore, step: int) -> List[int]:
    """Every step whose packs `step`'s image reads from, transitively,
    oldest first — the unit of a cross-host transfer."""
    need = [step]
    seen = {step}
    i = 0
    while i < len(need):
        for ref in store.referenced_steps(store.manifest(need[i])):
            if ref not in seen:
                seen.add(ref)
                need.append(ref)
        i += 1
    return sorted(need)


def _fresh_stats() -> Dict[str, Any]:
    return {"bytes_sent": 0, "bytes_reused": 0, "bytes_copied": 0,
            "chunks_sent": 0, "chunks_reused": 0, "files_copied": 0,
            "steps_transferred": 0, "steps_skipped": 0,
            "corrupt_objects_healed": 0, "push_s": 0.0}


class DeltaReplicator:
    """Content-addressed replication into a peer snapshot store.

    Drop-in for :class:`DirReplicator` (same ``push``/``pull_latest``
    surface, same peer-directory layout), so
    ``TransferPolicy(mode="delta")`` swaps the data path without touching
    the engine's commit ordering.  ``supports_rounds`` advertises the
    extra pre-copy surface (:meth:`push_round` / :meth:`round_state`)
    that content-addressing makes possible — callers discover it through
    the :class:`repro.core.replication.Replicator` protocol, never via
    isinstance.
    """

    supports_rounds = True

    def __init__(self, peer_dir: str, cas_dir: Optional[str] = None,
                 workers: int = 0):
        self.peer_dir = peer_dir
        os.makedirs(peer_dir, exist_ok=True)
        self.store = ChunkStore(cas_dir or default_cas_dir(peer_dir))
        if workers <= 0:
            from repro.api.options import auto_io_threads
            workers = auto_io_threads()
        self.workers = workers
        self.last_stats: Dict[str, Any] = _fresh_stats()

    @property
    def stats(self) -> Dict[str, Any]:
        return self.last_stats

    # -------------------------------------------------------------- push
    def push(self, run_dir: str, step: int) -> Dict[str, Any]:
        """Transfer `step`'s delta-chain closure from `run_dir` into the
        peer store; returns (and records) the transfer stats."""
        t0 = time.perf_counter()
        stats = _fresh_stats()
        src = SnapshotStore(run_dir)
        with obs_trace.span("transfer.push", step=step) as sp:
            for s in transfer_closure(src, step):
                self._push_step(run_dir, s, stats)
            sp.set(bytes_sent=stats["bytes_sent"],
                   chunks_sent=stats["chunks_sent"],
                   chunks_reused=stats["chunks_reused"])
        stats["push_s"] = time.perf_counter() - t0
        stats["step"] = step
        stats["source"] = os.path.abspath(run_dir)
        self.last_stats = stats
        self.store.log_transfer(stats)
        for k in ("bytes_sent", "bytes_reused", "chunks_sent",
                  "chunks_reused", "corrupt_objects_healed"):
            obs_metrics.counter_add(f"transfer.{k}", stats[k])
        obs_journal.emit("transfer", "push", step=step,
                         bytes_sent=stats["bytes_sent"],
                         bytes_reused=stats["bytes_reused"],
                         chunks_sent=stats["chunks_sent"],
                         chunks_reused=stats["chunks_reused"],
                         push_s=stats["push_s"])
        return stats

    # ------------------------------------------------------ pre-copy rounds
    def push_round(self, run_dir: str, step: int, tag: str,
                   residual: bool = False) -> Dict[str, Any]:
        """One pre-copy round: push `step`'s closure, then append the
        round's byte/wall record to the CAS-side ledger keyed by `tag`.

        The round's *delta* falls out of the ordinary push protocol —
        chunks whose raw-CRC content hashes already landed in a previous
        round negotiate away as ``chunks_reused``, whole steps already
        committed on the target skip as ``steps_skipped`` — so round i
        ships exactly what changed since round i-1.  The ledger lives in
        the destination CAS (`round_state`), making an interrupted
        migration resumable from the target's own record.
        """
        round_idx = len(self.store.round_state(tag))
        with obs_trace.span("transfer.round", round=round_idx, step=step,
                            residual=residual) as sp:
            stats = self.push(run_dir, step)
            sp.set(bytes_sent=stats["bytes_sent"],
                   bytes_reused=stats["bytes_reused"],
                   chunks_sent=stats["chunks_sent"])
        record = {"round": round_idx, "step": step, "residual": residual,
                  "bytes_sent": stats["bytes_sent"],
                  "bytes_reused": stats["bytes_reused"],
                  "chunks_sent": stats["chunks_sent"],
                  "chunks_reused": stats["chunks_reused"],
                  "wall_s": stats["push_s"]}
        self.store.append_round(tag, record)
        obs_metrics.counter_add("transfer.round_bytes",
                                stats["bytes_sent"])
        if residual:
            obs_metrics.counter_add("transfer.residual_bytes",
                                    stats["bytes_sent"])
        obs_journal.emit("transfer", "round", tag=tag, round=round_idx,
                         step=step, residual=residual,
                         bytes_sent=stats["bytes_sent"],
                         wall_s=stats["push_s"])
        return record

    def round_state(self, tag: str) -> List[Dict[str, Any]]:
        """The CAS-persisted round ledger for one migration tag."""
        return self.store.round_state(tag)

    def clear_rounds(self, tag: str) -> None:
        self.store.clear_rounds(tag)

    def _push_step(self, run_dir: str, step: int,
                   stats: Dict[str, Any]) -> None:
        src_dir = snapshot_dir(run_dir, step)
        dst_dir = snapshot_dir(self.peer_dir, step)
        manifest = read_json(os.path.join(src_dir, MANIFEST))
        dst_manifest = os.path.join(dst_dir, MANIFEST)
        if os.path.exists(dst_manifest):
            try:
                if read_json(dst_manifest) == manifest:
                    stats["steps_skipped"] += 1
                    return                 # already transferred + committed
            except Exception:
                pass                       # torn target manifest: redo
        os.makedirs(dst_dir, exist_ok=True)
        # group physical files into pack bases: "host0000.pack.0" and
        # siblings are one v2 pack; a bare "host0000.pack" is v1
        names = manifest.get("files")
        if not names:                      # pre-"files" manifest: scan disk
            names = sorted(n for n in os.listdir(src_dir) if n != MANIFEST)
        bases: Dict[str, bool] = {}
        for name in names:
            if name.rsplit(".", 1)[-1].isdigit():
                bases[name.rsplit(".", 1)[0]] = True      # v2 stripe set
            else:
                bases[name] = False                       # v1 single file
        for base, is_v2 in sorted(bases.items()):
            if is_v2:
                self._push_pack_v2(os.path.join(src_dir, base),
                                   os.path.join(dst_dir, base), stats)
            else:
                self._copy_file(os.path.join(src_dir, base),
                                os.path.join(dst_dir, base), stats)
        # manifest last: commit ordering preserved across the wire
        atomic_write_json(dst_manifest, manifest)
        stats["steps_transferred"] += 1

    def _copy_file(self, src: str, dst: str, stats: Dict[str, Any]) -> None:
        """v1 fallback: no chunk index to negotiate over — full copy."""
        tmp = dst + ".tmp"
        shutil.copy2(src, tmp)
        os.replace(tmp, dst)
        stats["files_copied"] += 1
        stats["bytes_copied"] += os.path.getsize(src)

    def _push_pack_v2(self, src_base: str, dst_base: str,
                      stats: Dict[str, Any]) -> None:
        reader = open_pack(src_base, verify=False)
        if not isinstance(reader, PackReaderV2):       # sniffed as v1
            reader.close()
            self._copy_file(src_base, dst_base, stats)
            return
        with reader:
            with obs_trace.span("transfer.negotiate") as sp:
                chunks = [c for _n, _j, c in reader.own_chunks()]
                keys = [chunk_key(c) for c in chunks]
                have = self.store.have(keys)           # negotiate
                want = [c for c, k in zip(chunks, keys) if k not in have]
                sp.set(chunks=len(chunks), have=len(have),
                       want=len(want))
            for c, k in zip(chunks, keys):
                if k in have:
                    stats["chunks_reused"] += 1
                    stats["bytes_reused"] += c["nbytes"]
            with obs_trace.span("transfer.ship", chunks=len(want)):
                self._ship(reader, want, stats)        # striped + parallel
            footer = {"format": 2, "stripes": reader.stripes,
                      "chunk_bytes": reader.chunk_bytes,
                      "entries": reader.index}
            with obs_trace.span("transfer.materialize"):
                write_pack_v2_from_chunks(
                    dst_base, footer,
                    fetch=lambda c: self._fetch(reader, c, stats))

    def _ship(self, reader: PackReaderV2, want: List[Dict[str, Any]],
              stats: Dict[str, Any]) -> None:
        """Move wanted chunks source→CAS, one worker per stripe lane so
        each lane reads its stripe file sequentially (the same
        parallelism shape as the PR-2 write pipeline)."""
        if not want:
            return
        lanes: Dict[int, List[Dict[str, Any]]] = {}
        for c in want:
            lanes.setdefault(c["stripe"], []).append(c)

        def ship_lane(lane: List[Dict[str, Any]]) -> int:
            n = 0
            for c in sorted(lane, key=lambda c: c["offset"]):
                self.store.put(chunk_key(c), reader.read_stored_chunk(c))
                n += c["nbytes"]
            return n

        if len(lanes) > 1 and self.workers > 1:
            with ThreadPoolExecutor(
                    max_workers=min(self.workers, len(lanes)),
                    thread_name_prefix="repro-transfer") as ex:
                sent = list(ex.map(ship_lane, lanes.values()))
        else:
            sent = [ship_lane(lane) for lane in lanes.values()]
        stats["bytes_sent"] += sum(sent)
        stats["chunks_sent"] += len(want)

    def _fetch(self, reader: PackReaderV2, c: Dict[str, Any],
               stats: Dict[str, Any]) -> bytes:
        """Materialization chunk source: the CAS, with source-side healing
        when an object fails its CRC (detected *before* any restore)."""
        key = chunk_key(c)
        try:
            return self.store.get(key)
        except (CASCorruption, KeyError):
            # corrupt on disk (CRC mismatch) or missing outright (e.g.
            # quarantined by fsck --repair): both heal from the source
            self.store.drop(key)
            data = reader.read_stored_chunk(c)
            self.store.put(key, data)
            stats["corrupt_objects_healed"] += 1
            stats["bytes_sent"] += c["nbytes"]
            return data

    # -------------------------------------------------------------- pull
    def pull(self, run_dir: str, step: int) -> Optional[int]:
        """Re-materialize one snapshot (plus its delta-chain closure)
        from the peer over the local copy — the heal path for a torn
        chunk caught by a lazy background stream."""
        peer = SnapshotStore(self.peer_dir)
        if step not in peer.list_steps():
            return None
        for s in transfer_closure(peer, step):
            src = snapshot_dir(self.peer_dir, s)
            dst = snapshot_dir(run_dir, s)
            if os.path.isdir(dst):
                shutil.rmtree(dst)
            os.makedirs(os.path.dirname(dst), exist_ok=True)
            shutil.copytree(src, dst)
        return step

    def pull_latest(self, run_dir: str) -> Optional[int]:
        """Materialize the newest peer snapshot into `run_dir` (the
        restore-side fallback the engine uses when the primary store has
        no valid image) — same contract as DirReplicator."""
        steps = SnapshotStore(self.peer_dir).list_steps()
        if not steps:
            return None
        return self.pull(run_dir, steps[-1])
