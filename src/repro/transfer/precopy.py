"""Pre-copy convergence controller — when to stop iterating and freeze.

VM-style iterative pre-copy (and CRIUgpu's preemption-with-a-deadline
motivation) turns migration blackout from O(image) into O(residual): the
job keeps stepping while delta rounds push changed chunks to the target
CAS, and the source only freezes for the *final residual* round once that
residual is predictably small.  The controller here makes exactly that
call after every round, from three observables the round ledger already
records — bytes shipped, wall time, and the bandwidth they imply:

  freeze     a round shipped zero new bytes (the target is current), or
             the predicted residual-push wall fits ``max_blackout_ms``,
             or (no budget set) the rounds stopped shrinking — more
             iteration cannot help.
  fallback   the round cap (``precopy_rounds``) or the cumulative byte
             cap (``residual_bytes_cap``) tripped: the workload dirties
             faster than the link drains, so iterating further only burns
             bandwidth.  The migration degrades to stop-and-copy — freeze
             now and push everything residual, correctness intact, budget
             not guaranteed.
  continue   none of the above; run another live round.

The prediction is deliberately simple and conservative: the next frozen
round ships roughly what the last live round shipped (the dirty rate is
step-driven and the job steps at a steady clip), at the bandwidth the
completed rounds actually achieved.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

from repro.api.options import TransferPolicy

# freezing is never byte-free: manifest commit + negotiation overhead make
# a zero-byte residual round still cost a (small) round-trip, so predicted
# blackout gets the observed minimum round wall as a floor
_MIN_WALL_FLOOR = True


@dataclasses.dataclass(frozen=True)
class RoundDecision:
    """What to do after a completed pre-copy round."""
    action: str                              # "continue" | "freeze" | "fallback"
    reason: str
    predicted_residual_bytes: int
    predicted_blackout_ms: Optional[float]   # None until bandwidth is known


class PrecopyController:
    """Feeds on per-round (bytes_sent, wall_s) records; answers
    continue / freeze / fallback after each one.

    Stateless with respect to the transfer itself — rehydrate one from a
    CAS round ledger (``seed()``) to resume an interrupted migration's
    convergence where it left off.
    """

    def __init__(self, policy: TransferPolicy):
        if not policy.precopy_enabled:
            raise ValueError(
                "PrecopyController needs TransferPolicy.precopy_rounds > 0 "
                f"and mode='delta', got {policy!r}")
        self.policy = policy
        self.rounds: List[Dict[str, Any]] = []

    def seed(self, ledger: List[Dict[str, Any]]) -> None:
        """Adopt previously completed rounds (resume from CAS state);
        residual rounds are convergence-terminal and are not replayed."""
        for rec in ledger:
            if not rec.get("residual"):
                self.observe(rec)

    def observe(self, record: Dict[str, Any]) -> None:
        """Record one completed live round ({"bytes_sent", "wall_s", ...})."""
        self.rounds.append({"bytes_sent": int(record.get("bytes_sent", 0)),
                            "wall_s": float(record.get("wall_s", 0.0))})

    # ------------------------------------------------------------ model
    def bandwidth_bytes_per_s(self) -> Optional[float]:
        """Achieved push bandwidth over rounds that moved bytes."""
        moved = [(r["bytes_sent"], r["wall_s"]) for r in self.rounds
                 if r["bytes_sent"] > 0 and r["wall_s"] > 0]
        if not moved:
            return None
        total_b = sum(b for b, _w in moved)
        total_w = sum(w for _b, w in moved)
        return total_b / total_w if total_w > 0 else None

    def predicted_residual_bytes(self) -> int:
        return self.rounds[-1]["bytes_sent"] if self.rounds else 0

    def predicted_blackout_ms(self) -> Optional[float]:
        bw = self.bandwidth_bytes_per_s()
        if bw is None:
            return None
        ms = self.predicted_residual_bytes() / bw * 1000.0
        if _MIN_WALL_FLOOR and self.rounds:
            floor = min(r["wall_s"] for r in self.rounds) * 1000.0
            ms = max(ms, floor)
        return ms

    def cumulative_bytes(self) -> int:
        return sum(r["bytes_sent"] for r in self.rounds)

    # --------------------------------------------------------- decision
    def decide(self) -> RoundDecision:
        pol = self.policy
        pred_b = self.predicted_residual_bytes()
        pred_ms = self.predicted_blackout_ms()
        last = self.rounds[-1] if self.rounds else None

        def _d(action: str, reason: str) -> RoundDecision:
            return RoundDecision(action=action, reason=reason,
                                 predicted_residual_bytes=pred_b,
                                 predicted_blackout_ms=pred_ms)

        if last is not None and last["bytes_sent"] == 0:
            return _d("freeze", "converged: last round shipped 0 bytes")
        if pol.max_blackout_ms is not None and pred_ms is not None \
                and pred_ms <= pol.max_blackout_ms:
            return _d("freeze",
                      f"predicted residual {pred_ms:.1f}ms fits the "
                      f"{pol.max_blackout_ms:.0f}ms blackout budget")
        if pol.residual_bytes_cap is not None \
                and self.cumulative_bytes() > pol.residual_bytes_cap:
            return _d("fallback",
                      f"cumulative pre-copy bytes "
                      f"{self.cumulative_bytes()} exceeded the "
                      f"{pol.residual_bytes_cap}-byte cap")
        if len(self.rounds) >= pol.precopy_rounds:
            return _d("fallback",
                      f"round cap {pol.precopy_rounds} reached without "
                      f"convergence")
        if pol.max_blackout_ms is None and len(self.rounds) >= 2 \
                and self.rounds[-1]["bytes_sent"] >= \
                self.rounds[-2]["bytes_sent"]:
            return _d("freeze",
                      "no budget set and rounds stopped shrinking — "
                      "further iteration cannot reduce the residual")
        return _d("continue", "residual still shrinking")


def summarize_rounds(ledger: List[Dict[str, Any]]) -> Dict[str, Any]:
    """Roll a round ledger up into the stats migration records expose."""
    live = [r for r in ledger if not r.get("residual")]
    resid = [r for r in ledger if r.get("residual")]
    out: Dict[str, Any] = {
        "rounds_completed": len(live),
        "precopy_bytes": sum(int(r.get("bytes_sent", 0)) for r in live),
        "residual_bytes": sum(int(r.get("bytes_sent", 0)) for r in resid),
        "blackout_s": sum(float(r.get("wall_s", 0.0)) for r in resid),
    }
    return out
