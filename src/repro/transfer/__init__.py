"""Cross-host checkpoint transfer: content-addressed chunk store + delta
replication + migration support.

CRIUgpu's recovery-time wins in a multi-tenant cluster depend on moving
checkpoint images *between hosts* fast — a preempted job usually comes
back somewhere else.  This package is that data path:

  * :class:`ChunkStore` — a content-addressed store (CAS) keyed by the
    raw-CRC content hashes pack v2 already computes per chunk; the
    target host's dedup index and the resume log of interrupted
    transfers.
  * :class:`DeltaReplicator` — a drop-in replacement for
    :class:`repro.core.replication.DirReplicator` that negotiates a
    have/want set with the target's CAS and ships only missing chunks
    (striped + parallel), then re-materializes byte-identical packs.
  * :func:`transfer_closure` — the delta-chain closure of one snapshot
    (incremental children need their parents on the target too).
  * :class:`PrecopyController` — the live-migration convergence
    controller: after each pre-copy round it decides continue / freeze
    (residual fits the blackout budget) / fallback (stop-and-copy).
"""
from repro.transfer.cas import CASCorruption, ChunkStore, chunk_key
from repro.transfer.delta import DeltaReplicator, transfer_closure
from repro.transfer.precopy import (PrecopyController, RoundDecision,
                                    summarize_rounds)

__all__ = ["CASCorruption", "ChunkStore", "chunk_key", "DeltaReplicator",
           "transfer_closure", "PrecopyController", "RoundDecision",
           "summarize_rounds"]
