"""Content-addressed chunk store (CAS) — the target side of delta transfer.

Every pack-v2 chunk already carries a ``raw_crc32`` content hash (computed
over the uncompressed bytes; it drives incremental chunk dedup).  The CAS
keys objects by that hash, qualified by the raw length and the stored-byte
CRC so a hit guarantees *byte-identical* re-materialization of the stripe
file::

    <root>/objects/<kk>/<raw_crc32>-<raw_nbytes>-<stored_crc32>

Objects hold the *stored* (possibly compressed) chunk bytes: transfer
never pays a recompression, and materialized packs reproduce the source
layout exactly (incremental ``ref`` offsets keep resolving).

Properties the transfer layer leans on:

  * idempotent ``put`` (tmp + atomic rename) — an interrupted transfer
    resumes by re-negotiating have/want; received chunks are never re-sent;
  * verifying ``get`` — a corrupt object raises :class:`CASCorruption`
    *before* any restore can read the bad bytes; the replicator heals it
    from the source while it still has one.
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import Any, Dict, Iterable, List, Set

from repro.chaos import hooks as chaos_hooks
from repro.serialization.integrity import crc32

TRANSFER_LOG = "transfers.json"
QUARANTINE_DIR = "quarantine"
ROUNDS_DIR = "rounds"


class CASCorruption(IOError):
    """A CAS object's bytes no longer match its content-hash key."""


def chunk_key(c: Dict[str, Any]) -> str:
    """CAS key of one pack-v2 chunk record.

    Primary key is the raw-CRC content hash the pack already computed;
    raw length and stored CRC qualify it so that (a) the 32-bit hash
    cannot silently alias across different-sized chunks and (b) a hit
    can be spliced into a rebuilt stripe byte-for-byte.
    """
    return f"{c['raw_crc32']:08x}-{c['raw_nbytes']:x}-{c['crc32']:08x}"


def _stored_crc_of(key: str) -> int:
    return int(key.rsplit("-", 1)[1], 16)


class ChunkStore:
    """One directory of content-addressed chunk objects."""

    def __init__(self, root: str):
        self.root = root
        self.objects = os.path.join(root, "objects")
        os.makedirs(self.objects, exist_ok=True)

    # ------------------------------------------------------------ lookup
    def path(self, key: str) -> str:
        return os.path.join(self.objects, key[:2], key)

    def has(self, key: str) -> bool:
        return os.path.exists(self.path(key))

    def have(self, keys: Iterable[str]) -> Set[str]:
        """The have/want negotiation: which of `keys` are already here."""
        return {k for k in keys if self.has(k)}

    # ------------------------------------------------------------ mutate
    def put(self, key: str, data: bytes) -> bool:
        """Store one chunk; returns False if it was already present.
        The stored-CRC qualifier in the key is verified on the way in,
        so a corrupted wire payload never lands.  Concurrency-safe for
        same-key racers (stripe lanes ship duplicate-content chunks):
        each writer uses its own tmp file and the atomic `os.replace`
        makes the last one win — both wrote identical bytes."""
        if chaos_hooks.INJECTOR is not None:
            # chaos: network-partition site — a handler may raise here to
            # model the host losing its route to the CAS mid-push
            chaos_hooks.fire("cas.put", key=key, nbytes=len(data))
        if crc32(data) != _stored_crc_of(key):
            raise CASCorruption(
                f"cas put {key}: payload CRC does not match the key "
                f"(corrupted in transit?)")
        dst = self.path(key)
        if os.path.exists(dst):
            return False
        os.makedirs(os.path.dirname(dst), exist_ok=True)
        tmp = dst + f".tmp.{os.getpid()}.{threading.get_ident()}"
        with open(tmp, "wb") as f:
            f.write(data)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, dst)
        if chaos_hooks.INJECTOR is not None:
            # chaos: bit-rot site — a handler may corrupt the object that
            # just landed; the verifying get/materialize must catch it
            chaos_hooks.fire("cas.landed", key=key, path=dst)
        return True

    def get(self, key: str) -> bytes:
        """Read one chunk, CRC-verified against its key — a bit-rotted
        object is detected here, before any restore consumes it."""
        try:
            with open(self.path(key), "rb") as f:
                data = f.read()
        except FileNotFoundError:
            raise KeyError(f"cas object {key} not found under {self.root}")
        if crc32(data) != _stored_crc_of(key):
            raise CASCorruption(
                f"cas object {key} is corrupt on disk "
                f"({self.path(key)})")
        return data

    def drop(self, key: str) -> None:
        try:
            os.remove(self.path(key))
        except OSError:
            pass

    # ------------------------------------------------------------ ingest
    def ingest_pack(self, base: str) -> int:
        """Index every locally-stored chunk of an existing v2 pack into
        the store (warming the CAS from snapshots the host already has).
        Returns the number of objects added."""
        from repro.serialization.pack import PackReaderV2
        added = 0
        with PackReaderV2(base, verify=False) as r:
            for _name, _j, c in r.own_chunks():
                key = chunk_key(c)
                if not self.has(key):
                    added += self.put(key, r.read_stored_chunk(c))
        return added

    # ------------------------------------------------------------ report
    def stats(self) -> Dict[str, Any]:
        n, nbytes = 0, 0
        for dirpath, _dirs, files in os.walk(self.objects):
            for name in files:
                if name.endswith(".tmp") or ".tmp." in name:
                    continue
                n += 1
                nbytes += os.path.getsize(os.path.join(dirpath, name))
        return {"objects": n, "bytes": nbytes, "root": self.root}

    def fsck(self, repair: bool = False) -> List[str]:
        """CRC-check every object; returns the corrupt keys.

        With ``repair=True`` each corrupt object is moved aside into
        ``<root>/quarantine/`` (outside the object tree, so ``stats`` and
        ``have`` no longer see it): the next ``get`` raises ``KeyError``
        instead of ``CASCorruption`` and the replicator's materializer
        heals the chunk from source — bad bytes can never be re-served.
        """
        bad = []
        for dirpath, _dirs, files in os.walk(self.objects):
            for name in files:
                if name.endswith(".tmp") or ".tmp." in name:
                    continue
                path = os.path.join(dirpath, name)
                with open(path, "rb") as f:
                    if crc32(f.read()) != _stored_crc_of(name):
                        bad.append(name)
                        if repair:
                            qdir = os.path.join(self.root, QUARANTINE_DIR)
                            os.makedirs(qdir, exist_ok=True)
                            os.replace(path, os.path.join(qdir, name))
        return sorted(bad)

    # ------------------------------------------------------- round state
    # Pre-copy migration rounds persist their ledger *in the destination
    # CAS* (beside the objects they shipped), so an interrupted migration
    # resumes from the target's own record: a fresh source process reads
    # round_state(tag), sees how far convergence got, and the next
    # push_round re-negotiates have/want against the already-landed
    # objects — nothing is re-sent, and the ledger survives a source kill.
    def _rounds_path(self, tag: str) -> str:
        safe = "".join(c if c.isalnum() or c in "._-" else "_"
                       for c in tag)
        return os.path.join(self.root, ROUNDS_DIR, f"{safe}.json")

    def round_state(self, tag: str) -> List[Dict[str, Any]]:
        """The persisted per-round ledger for one migration, oldest first
        (empty when no round has completed)."""
        path = self._rounds_path(tag)
        if not os.path.exists(path):
            return []
        try:
            with open(path) as f:
                return list(json.load(f))
        except Exception:
            return []

    def append_round(self, tag: str, record: Dict[str, Any]
                     ) -> List[Dict[str, Any]]:
        """Append one completed round to the ledger (atomic rewrite) and
        return the updated ledger."""
        state = self.round_state(tag)
        state.append(dict(record, t=time.time()))
        path = self._rounds_path(tag)
        os.makedirs(os.path.dirname(path), exist_ok=True)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(state, f, indent=2, default=str)
        os.replace(tmp, path)
        return state

    def clear_rounds(self, tag: str) -> None:
        """Drop a migration's round ledger (after a completed handoff)."""
        try:
            os.remove(self._rounds_path(tag))
        except OSError:
            pass

    # ------------------------------------------------------ transfer log
    def log_transfer(self, record: Dict[str, Any]) -> None:
        """Append one push's stats to the store's transfer log (what
        ``repro transfer-stats`` reads)."""
        path = os.path.join(self.root, TRANSFER_LOG)
        log = self.transfer_log()
        log.append(dict(record, t=time.time()))
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(log, f, indent=2, default=str)
        os.replace(tmp, path)

    def transfer_log(self) -> List[Dict[str, Any]]:
        path = os.path.join(self.root, TRANSFER_LOG)
        if not os.path.exists(path):
            return []
        try:
            with open(path) as f:
                return list(json.load(f))
        except Exception:
            return []


def default_cas_dir(peer_dir: str) -> str:
    return os.path.join(peer_dir, ".cas")
