"""Deterministic, checkpointable data pipeline.

The pipeline is a pure function of ``(seed, step, host_id)``, so its entire
runtime state is the tiny cursor dict returned by ``state()`` — exactly what
the CRIUgpu-style engine captures in the unified snapshot (the analogue of
the container's writable-layer/dataset offsets).  Restoring the cursor and
re-reading yields bitwise-identical batches, which is what makes the
engine's deterministic-restore guarantee (§6 of the paper) testable
end-to-end.

Synthetic corpus: a seeded Zipf-ish token stream (structured enough that a
model trained on it shows a falling loss).  Multimodal stubs (audio frames /
vision patches) are generated per the config's frontend-stub contract.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import numpy as np

from repro.models.config import ModelConfig


@dataclasses.dataclass
class TokenPipeline:
    cfg: ModelConfig
    batch_size: int
    seq_len: int
    seed: int = 0
    host_id: int = 0
    num_hosts: int = 1
    step: int = 0

    # ------------------------------------------------------------- state
    def state(self) -> Dict[str, Any]:
        return {"seed": self.seed, "step": self.step,
                "host_id": self.host_id, "num_hosts": self.num_hosts,
                "batch_size": self.batch_size, "seq_len": self.seq_len}

    def restore_state(self, st: Dict[str, Any]) -> None:
        for k, v in st.items():
            setattr(self, k, v)

    # ------------------------------------------------------------- batches
    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.host_id]))

    def peek(self, step: Optional[int] = None) -> Dict[str, np.ndarray]:
        """Batch for `step` without advancing the cursor."""
        step = self.step if step is None else step
        rng = self._rng(step)
        cfg = self.cfg
        B, S, V = self.batch_size, self.seq_len, cfg.vocab_size

        # successor stream: next = prev+1 (mod V) with 10% random resets —
        # low-entropy structure a model learns within tens of steps, so the
        # smoke/e2e runs can assert a falling loss.
        toks = np.empty((B, S), np.int32)
        toks[:, 0] = rng.integers(0, V, size=B)
        resets = rng.random((B, S)) < 0.1
        rand = rng.integers(0, V, size=(B, S))
        for t in range(1, S):
            nxt = (toks[:, t - 1] + 1) % V
            toks[:, t] = np.where(resets[:, t], rand[:, t], nxt)
        batch: Dict[str, np.ndarray] = {"tokens": toks}

        if cfg.vision_stub:
            P = cfg.num_patches
            batch["vision_embeds"] = rng.normal(
                0, 0.02, size=(B, P, cfg.d_model)).astype(np.float32)
            mask = np.ones((B, S), np.float32)
            mask[:, :min(P, S)] = 0.0
            batch["loss_mask"] = mask
        if cfg.encoder_layers > 0:
            batch["frames"] = rng.normal(
                0, 0.1, size=(B, cfg.num_audio_frames, cfg.d_model)
            ).astype(np.float32)
        return batch

    def next(self) -> Dict[str, np.ndarray]:
        b = self.peek()
        self.step += 1
        return b

    def __iter__(self):
        return self

    def __next__(self):
        return self.next()
