"""AdamW with global-norm clipping.

Optimizer moments inherit the parameter shardings, so under the baseline
policy (FSDP/ZeRO-3 over "data", TP over "model") the optimizer state is
fully sharded — the ZeRO posture falls out of the sharding policy rather
than special-cased code.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable[[jax.Array], jax.Array]
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0

    def init(self, params: PyTree) -> "OptState":
        zeros = lambda p: jnp.zeros_like(p)
        return OptState(step=jnp.zeros((), jnp.int32),
                        m=jax.tree.map(zeros, params),
                        v=jax.tree.map(zeros, params))

    def init_abstract(self, params: PyTree) -> "OptState":
        z = lambda p: jax.ShapeDtypeStruct(p.shape, p.dtype)
        return OptState(step=jax.ShapeDtypeStruct((), jnp.int32),
                        m=jax.tree.map(z, params),
                        v=jax.tree.map(z, params))

    def update(self, grads: PyTree, state: "OptState", params: PyTree
               ) -> Tuple[PyTree, "OptState", Dict[str, jax.Array]]:
        step = state.step + 1
        # global-norm clip
        gnorm = jnp.sqrt(sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree.leaves(grads)))
        scale = jnp.minimum(1.0, self.clip_norm / (gnorm + 1e-9))

        b1, b2 = self.b1, self.b2
        c1 = 1.0 - b1 ** step.astype(jnp.float32)
        c2 = 1.0 - b2 ** step.astype(jnp.float32)
        lr = self.lr(step)

        def upd(p, g, m, v):
            g = g.astype(jnp.float32) * scale
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * jnp.square(g)
            mh = m / c1
            vh = v / c2
            step_ = mh / (jnp.sqrt(vh) + self.eps)
            new_p = p.astype(jnp.float32) - lr * (
                step_ + self.weight_decay * p.astype(jnp.float32))
            return new_p.astype(p.dtype), m, v

        flat = jax.tree.map(upd, params, grads, state.m, state.v)
        new_params = jax.tree.map(lambda t: t[0], flat,
                                  is_leaf=lambda t: isinstance(t, tuple))
        new_m = jax.tree.map(lambda t: t[1], flat,
                             is_leaf=lambda t: isinstance(t, tuple))
        new_v = jax.tree.map(lambda t: t[2], flat,
                             is_leaf=lambda t: isinstance(t, tuple))
        return (new_params, OptState(step=step, m=new_m, v=new_v),
                {"grad_norm": gnorm, "lr": lr})


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class OptState:
    step: jax.Array
    m: PyTree
    v: PyTree
