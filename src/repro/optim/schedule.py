"""Learning-rate schedules (pure functions of the step counter)."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(base_lr: float, warmup_steps: int, total_steps: int,
                  min_ratio: float = 0.1):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / jnp.maximum(1.0, warmup_steps)
        frac = (step - warmup_steps) / jnp.maximum(
            1.0, total_steps - warmup_steps)
        frac = jnp.clip(frac, 0.0, 1.0)
        cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup_steps, warm, base_lr * cos)
    return lr


def constant(base_lr: float):
    def lr(step):
        return jnp.full((), base_lr, jnp.float32)
    return lr
