from repro.optim.adamw import AdamW, OptState  # noqa: F401
from repro.optim.schedule import warmup_cosine  # noqa: F401
