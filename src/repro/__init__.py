"""repro — CRIUgpu-style transparent checkpointing for JAX workloads.

Public surface: ``repro.api`` (CheckpointOptions / CheckpointSession),
``python -m repro`` (image CLI).  Kept import-light: pulling in the heavy
runtime (jax) is deferred until an API symbol is actually touched.
"""
__version__ = "0.2.0"

_API = ("CheckpointOptions", "CheckpointSession", "FrozenCheckpoint",
        "CheckReport", "OptionsError", "TransferPolicy", "capabilities",
        "check")

__all__ = list(_API) + ["__version__"]


def __getattr__(name):
    if name in _API:
        import repro.api as _api
        return getattr(_api, name)
    raise AttributeError(f"module 'repro' has no attribute {name!r}")
