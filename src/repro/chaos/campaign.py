"""Survivability campaigns: a seeded fault plan against a simulated fleet.

``run_campaign`` drives N sim jobs across H simulated hosts under the
orchestrator with a :class:`FaultInjector` installed, then holds the
fleet to the campaign invariant:

    every job either finishes **bit-exact** (its digest equals the
    digest of an unfaulted in-process replay) or lands in *diagnosable
    quarantine* (restart budget exhausted, with a complete RecoveryLog
    incident saying what happened and when it was detected).

Anything else — a hung job, a DONE job with the wrong digest (silent
corruption), a planned fault that never fired — is a **violation** and
fails the campaign.  The report aggregates per-fault-class survivability
(injected / recovered / healed / quarantined / MTTR) and exposes:

  * ``table_markdown()`` — the README survivability table,
  * ``metrics()`` — the flat ``BENCH_chaos.json`` dict
    (``*_miss_ratio`` metrics are 0-is-healthy and tight-gated by
    ``compare_bench``; a committed baseline of 0 forces fresh runs to 0),
  * ``fingerprint()`` — a digest over seed, per-class outcome counts and
    per-job digests (times excluded), so "same seed, same table" is one
    string comparison.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import time
from typing import Any, Callable, Dict, List, Optional, Sequence

from repro.api import TransferPolicy
from repro.orchestrator.job import JobSpec
from repro.orchestrator.orchestrator import Orchestrator, OrchestratorConfig
from repro.transfer.cas import ChunkStore, default_cas_dir

from .injector import FaultInjector
from .plan import ChaosConfig, generate_plan, parse_fault_spec
from .sim import make_sim_factory, reference_digest

DEFAULT_TOTAL_STEPS = 12
DEFAULT_CKPT_EVERY = 3
DEFAULT_MAX_RESTARTS = 6


def make_specs(jobs: int, total_steps: int = DEFAULT_TOTAL_STEPS,
               ckpt_every: int = DEFAULT_CKPT_EVERY,
               max_restarts: int = DEFAULT_MAX_RESTARTS) -> List[JobSpec]:
    return [JobSpec(job_id=f"j{i:03d}", kind="sim",
                    total_steps=total_steps, ckpt_every=ckpt_every,
                    max_restarts=max_restarts)
            for i in range(jobs)]


@dataclasses.dataclass
class CampaignReport:
    seed: int
    jobs: int
    hosts: int
    fault_spec: str
    wall_s: float
    ticks: int
    planned: Dict[str, int]                  # class -> events planned
    rows: Dict[str, Dict[str, Any]]          # class -> survivability row
    outcomes: Dict[str, str]                 # job -> recovered|quarantined|…
    digests: Dict[str, Optional[str]]        # job -> final digest (DONE only)
    violations: List[Dict[str, Any]]
    capture: str = "sync"                    # dump capture mode swept

    @property
    def ok(self) -> bool:
        return not self.violations

    def fingerprint(self) -> str:
        """Deterministic campaign identity: same seed -> same string.

        Covers per-class outcome counts, per-job outcomes and digests;
        excludes wall-clock, tick counts and MTTR (machine-speed noise).
        """
        stable_rows = {
            cls: {k: row[k] for k in
                  ("planned", "injected", "recovered", "healed",
                   "quarantined")}
            for cls, row in sorted(self.rows.items())}
        blob = json.dumps(
            {"seed": self.seed, "jobs": self.jobs, "hosts": self.hosts,
             "fault_spec": self.fault_spec, "capture": self.capture,
             "rows": stable_rows,
             "outcomes": self.outcomes, "digests": self.digests,
             "violation_reasons": sorted(
                 v["reason"] for v in self.violations)},
            sort_keys=True)
        return hashlib.sha256(blob.encode()).hexdigest()

    def table_markdown(self) -> str:
        out = ["| fault class | injected | recovered | healed | "
               "quarantined | MTTR (s) |",
               "|---|---|---|---|---|---|"]
        for cls in sorted(self.rows):
            r = self.rows[cls]
            mttr = "—" if r["mttr_s"] is None else f"{r['mttr_s']:.3f}"
            out.append(
                f"| {cls} | {r['injected']}/{r['planned']} | "
                f"{r['recovered']} | {r['healed']} | {r['quarantined']} | "
                f"{mttr} |")
        out.append(
            f"\n{self.jobs} jobs × {self.hosts} hosts, seed {self.seed}, "
            f"faults `{self.fault_spec}`, capture `{self.capture}`: "
            + ("**invariant held** (every job bit-exact or diagnosably "
               "quarantined)" if self.ok else
               f"**{len(self.violations)} invariant violation(s)**"))
        return "\n".join(out)

    def metrics(self) -> Dict[str, Any]:
        """Flat BENCH dict.  ``*_miss_ratio`` are the gated metrics:
        0 means healthy, and compare_bench's zero-baseline rule pins
        fresh runs to exactly 0."""
        m: Dict[str, Any] = {
            "chaos.workload.jobs": self.jobs,
            "chaos.workload.hosts": self.hosts,
            "chaos.workload.seed": self.seed,
            "chaos.invariant.violation_ratio":
                len(self.violations) / max(self.jobs, 1),
            "chaos.campaign.wall_s": self.wall_s,
        }
        for cls, r in sorted(self.rows.items()):
            planned, targets = r["planned"], max(r["targets"], 1)
            m[f"chaos.{cls}.missed_injection_ratio"] = (
                (planned - r["injected"]) / planned if planned else 0.0)
            survived = r["recovered"] + r["quarantined"]
            m[f"chaos.{cls}.unsurvived_ratio"] = (
                (r["targets"] - survived) / targets)
            m[f"chaos.{cls}.quarantined_ratio"] = r["quarantined"] / targets
            m[f"chaos.{cls}.injected"] = r["injected"]
            m[f"chaos.{cls}.healed"] = r["healed"]
            if r["mttr_s"] is not None:
                m[f"chaos.{cls}.mttr_s"] = r["mttr_s"]
        return m

    def to_dict(self) -> Dict[str, Any]:
        return {"format": 1,
                "seed": self.seed, "jobs": self.jobs, "hosts": self.hosts,
                "fault_spec": self.fault_spec, "capture": self.capture,
                "ok": self.ok,
                "wall_s": self.wall_s, "ticks": self.ticks,
                "fingerprint": self.fingerprint(),
                "rows": self.rows, "outcomes": self.outcomes,
                "digests": self.digests, "violations": self.violations}


def run_campaign(run_dir: str, jobs: int = 100, hosts: int = 20,
                 seed: int = 0, faults: str = "all=1",
                 total_steps: int = DEFAULT_TOTAL_STEPS,
                 ckpt_every: int = DEFAULT_CKPT_EVERY,
                 max_ticks: int = 4000,
                 capture: str = "sync",
                 log: Optional[Callable[[str], None]] = None
                 ) -> CampaignReport:
    """Run one seeded survivability campaign under ``run_dir``.

    ``capture="concurrent"`` sweeps the fleet's dumps through the
    soft-freeze path and enables the ``dirty_burst`` fault class; under
    sync capture that class is dropped from the plan (it can only fire
    inside a speculation window).  ``dirty_burst`` sits last in
    ``FAULT_CLASSES``, so dropping it leaves the seeded schedule of every
    other class bit-identical to a pre-concurrent campaign.
    """
    say = log or (lambda _msg: None)
    specs = make_specs(jobs, total_steps=total_steps,
                       ckpt_every=ckpt_every)
    counts = parse_fault_spec(faults)
    if capture != "concurrent":
        counts.pop("dirty_burst", None)
    plan = generate_plan(seed, specs, hosts, counts)

    # exhaust targets get a restart budget of exactly 1: two kills land
    # them in quarantine, which is the outcome the class asserts
    exhaust_jobs = set(plan.targets("exhaust"))
    specs = [dataclasses.replace(s, max_restarts=1)
             if s.job_id in exhaust_jobs else s for s in specs]

    # torn/dropped-write targets write self-contained images: a torn
    # historical pack must not be referenced by later incremental
    # children (see make_sim_factory)
    non_inc = set(plan.targets("torn_write")) | set(
        plan.targets("fsync_drop"))

    say(f"chaos plan: seed={seed} events={len(plan.events)} "
        f"classes={sorted(plan.counts)} capture={capture}")
    factory = make_sim_factory(run_dir, non_incremental=non_inc,
                               capture=capture)
    cfg = OrchestratorConfig(
        capacity=max(2, min(jobs, 2 * hosts)), slice_steps=2,
        heartbeat_deadline_s=0.05, hosts=hosts,
        transfer_policy=TransferPolicy(mode="delta", workers=1),
        max_ticks=max_ticks)
    injector = FaultInjector(plan, clock=time.perf_counter)
    orch = Orchestrator(run_dir, specs, workload_factory=factory,
                        config=cfg)
    with injector.installed():
        summary = orch.run()

    say(f"fleet settled after {summary['ticks']} ticks "
        f"({summary['wall_s']:.2f}s); evaluating {jobs} jobs")
    report = _evaluate(run_dir, plan, injector, orch, summary,
                       {s.job_id: s for s in specs},
                       jobs=jobs, hosts=hosts, seed=seed,
                       fault_spec=faults, capture=capture)
    return report


# --------------------------------------------------------------- evaluate
def _evaluate(run_dir: str, plan: ChaosConfig, injector: FaultInjector,
              orch: Orchestrator, summary: Dict[str, Any],
              by_id: Dict[str, JobSpec], jobs: int, hosts: int,
              seed: int, fault_spec: str,
              capture: str = "sync") -> CampaignReport:
    outcomes: Dict[str, str] = {}
    digests: Dict[str, Optional[str]] = {}
    violations: List[Dict[str, Any]] = []

    for job_id, spec in sorted(by_id.items()):
        ref = reference_digest(spec)
        info = summary["jobs"][job_id]
        digests[job_id] = info["digest"]
        if info["state"] == "done":
            if info["digest"] == ref:
                outcomes[job_id] = "recovered"
            else:
                outcomes[job_id] = "corrupt"
                violations.append({
                    "job": job_id, "reason": "silent_corruption",
                    "detail": f"digest {info['digest']} != reference "
                              f"{ref} after recovery"})
        elif _is_quarantined(orch.records[job_id]):
            inc = orch.records[job_id].recovery.incidents[-1]
            if _diagnosable(inc):
                outcomes[job_id] = "quarantined"
            else:
                outcomes[job_id] = "undiagnosed"
                violations.append({
                    "job": job_id, "reason": "undiagnosed_quarantine",
                    "detail": f"incomplete RecoveryLog incident: {inc}"})
        else:
            outcomes[job_id] = "hung"
            violations.append({
                "job": job_id, "reason": "hung",
                "detail": f"state={info['state']} step={info['step']}/"
                          f"{info['total_steps']} after "
                          f"{summary['ticks']} ticks"})

    for ev in plan.events:
        if ev.state == "pending":
            violations.append({
                "job": ev.job_id, "reason": "event_never_fired",
                "detail": ev.key()})

    rows = {cls: _class_row(cls, plan, orch, outcomes, run_dir)
            for cls in sorted(plan.counts)}
    return CampaignReport(
        seed=seed, jobs=jobs, hosts=hosts, fault_spec=fault_spec,
        capture=capture,
        wall_s=summary["wall_s"], ticks=summary["ticks"],
        planned={cls: len(plan.events_for(cls)) for cls in plan.counts},
        rows=rows, outcomes=outcomes, digests=digests,
        violations=violations)


def _is_quarantined(rec) -> bool:
    return rec.exhausted


def _diagnosable(inc: Dict[str, Any]) -> bool:
    """A quarantine incident must say *what* (cause), *where*
    (step_at_interrupt) and *when it was noticed* (t_detect)."""
    return (inc.get("cause") is not None
            and inc.get("t_detect") is not None
            and inc.get("t_interrupt") is not None
            and inc.get("step_at_interrupt") is not None)


def _class_row(cls: str, plan: ChaosConfig, orch, outcomes: Dict[str, str],
               run_dir: str) -> Dict[str, Any]:
    events = plan.events_for(cls)
    targets = plan.targets(cls)
    injected = sum(1 for e in events if e.state != "pending")
    recovered = sum(1 for j in targets if outcomes.get(j) == "recovered")
    quarantined = sum(1 for j in targets
                      if outcomes.get(j) == "quarantined")
    healed = _healed_count(cls, targets, orch, run_dir)
    mttrs = [m for m in (_event_mttr(e, orch) for e in events)
             if m is not None]
    return {"planned": len(events), "targets": len(targets),
            "injected": injected, "recovered": recovered,
            "healed": healed, "quarantined": quarantined,
            "mttr_s": (sum(mttrs) / len(mttrs)) if mttrs else None}


def _healed_count(cls: str, targets: Sequence[str], orch,
                  run_dir: str) -> int:
    """Self-healing events that recovered data *without* a job restart:
    CAS objects healed from source during materialization (cas_corrupt)
    and restores served from the replica store (fsync_drop)."""
    healed = 0
    if cls == "cas_corrupt":
        for job_id in targets:
            rec = orch.records[job_id]
            replica = _job_dir(run_dir, job_id, rec.host) + "_replica"
            store = ChunkStore(default_cas_dir(replica))
            healed += sum(int(t.get("corrupt_objects_healed", 0))
                          for t in store.transfer_log())
    else:
        for job_id in targets:
            for inc in orch.records[job_id].recovery.incidents:
                if inc.get("meta", {}).get("restored_from_replica"):
                    healed += 1
    return healed


def _job_dir(run_dir: str, job_id: str, host: Optional[str]) -> str:
    from repro.orchestrator.workloads import job_dir_for
    return job_dir_for(run_dir, job_id, host)


def _event_mttr(ev, orch) -> Optional[float]:
    """Injection -> recovered (caught up) or diagnosed (detected), using
    the injector's clock == the orchestrator's clock."""
    if ev.t_injected is None:
        return None
    rec = orch.records.get(ev.job_id)
    if rec is None:
        return None
    eps = 1e-6
    for inc in rec.recovery.incidents:
        if inc.get("t_detect") is None or \
                inc["t_detect"] < ev.t_injected - eps:
            continue
        if inc.get("t_caught_up") is not None:
            return max(0.0, inc["t_caught_up"] - ev.t_injected)
        if rec.exhausted and inc is rec.recovery.incidents[-1]:
            return max(0.0, inc["t_detect"] - ev.t_injected)
    return None


def write_bench_json(report: CampaignReport, path: str) -> None:
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(report.metrics(), f, indent=2, sort_keys=True)
        f.write("\n")
    os.replace(tmp, path)
