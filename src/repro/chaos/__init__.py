"""repro.chaos — seeded fault injection + fleet survivability campaigns.

Layers:

  * :mod:`repro.chaos.hooks` — the zero-overhead instrumentation seam
    (production code guards every firing on ``hooks.INJECTOR is None``);
  * :mod:`repro.chaos.plan` — fault taxonomy + seeded plan generation;
  * :mod:`repro.chaos.injector` — matches hook firings against the plan
    and mutates real state (torn bytes, kills, partitions, signals);
  * :mod:`repro.chaos.sim` — a cheap deterministic session-backed
    workload whose bit-exact reference digest is computable in-process;
  * :mod:`repro.chaos.campaign` — drives an orchestrator fleet through a
    fault schedule and asserts the survivability invariant.

This ``__init__`` stays import-light (submodules load lazily): the hook
plane is imported by hot production modules (engine, pack, CAS) and must
not drag the orchestrator stack in with it.
"""
from __future__ import annotations

from repro.chaos import hooks  # noqa: F401  (dependency-free hook plane)

_LAZY = {
    "FAULT_CLASSES": "plan", "ChaosConfig": "plan", "FaultEvent": "plan",
    "ChaosInjectedFault": "plan", "ChaosPartition": "plan",
    "parse_fault_spec": "plan", "generate_plan": "plan",
    "FaultInjector": "injector",
    "SimWorkload": "sim", "make_sim_factory": "sim",
    "reference_digest": "sim",
    "run_campaign": "campaign", "CampaignReport": "campaign",
}

__all__ = ["hooks"] + sorted(_LAZY)


def __getattr__(name: str):
    mod = _LAZY.get(name)
    if mod is None:
        raise AttributeError(f"module 'repro.chaos' has no attribute "
                             f"{name!r}")
    import importlib
    return getattr(importlib.import_module(f"repro.chaos.{mod}"), name)
