"""Chaos hook plane — the zero-overhead seam the fault injector plugs into.

Production code calls ``fire(site, **ctx)`` at a handful of narrow
instrumentation points (pack stripe writes, snapshot commit, CAS put/get,
signal delivery, the orchestrator tick).  Every call site guards with

    if hooks.INJECTOR is not None:
        hooks.fire("site.name", ...)

so the steady-state cost is one module-attribute load and a ``None``
check — the same design discipline as the paper's no-interception
argument: when no :class:`~repro.chaos.plan.ChaosConfig` is installed,
the dump/restore path is byte-for-byte the code that ran before the
chaos subsystem existed, and injection adds zero entries to any stats.

This module deliberately imports nothing from ``repro`` so that every
layer (serialization, transfer, core, orchestrator) can import it
without cycles.
"""
from __future__ import annotations

from typing import Any, Optional

# The installed FaultInjector, or None (chaos disabled — the default).
INJECTOR: Optional[Any] = None


def fire(site: str, **ctx: Any) -> Any:
    """Dispatch one hook to the installed injector (no-op when none).

    Returns whatever the injector's handler returns; call sites that
    honor a return value (e.g. ``"defer"`` from ``signal.send``) document
    it at the site.  Handlers may also raise — an injected fault
    propagates exactly like the real failure it models.
    """
    inj = INJECTOR
    if inj is None:
        return None
    return inj.on(site, **ctx)


def install(injector: Any) -> None:
    global INJECTOR
    if INJECTOR is not None and INJECTOR is not injector:
        raise RuntimeError("a chaos injector is already installed; "
                           "uninstall it first")
    INJECTOR = injector


def uninstall() -> None:
    global INJECTOR
    INJECTOR = None
