"""SimWorkload — a cheap, bit-deterministic fleet workload for campaigns.

A chaos campaign needs hundreds of jobs, each with an *unfaulted
reference digest* computable in-process: :func:`reference_digest` runs
the pure step function to completion, and a job that recovered
bit-exact (no matter how many kills/restores it survived) must land on
the identical digest — numpy float64 ops replayed over the exact bytes
a pack round-trip preserves.

The workload drives the same :class:`~repro.api.CheckpointSession`
machinery as the real trainer workloads — sync commits, incremental pack
v2, delta replication to a per-job replica store — so injected faults
exercise the production dump/transfer/restore paths, not a mock.
"""
from __future__ import annotations

import hashlib
import time
import zlib
from typing import Any, Callable, Dict, Optional

import numpy as np

from repro.api import CheckpointOptions, CheckpointSession, TransferPolicy
from repro.api.session import SnapshotWriteFailed
from repro.orchestrator.job import JobSpec
from repro.orchestrator.workloads import job_dir_for

from . import hooks

VEC_LEN = 2048


def _job_seed(job_id: str) -> int:
    return zlib.crc32(job_id.encode())


def _init_vec(job_id: str) -> np.ndarray:
    rng = np.random.default_rng(_job_seed(job_id))
    return rng.standard_normal(VEC_LEN).astype(np.float64)


def _sim_step(vec: np.ndarray, step: int) -> np.ndarray:
    # pure f(vec, step): nonlinear enough that a wrong restore diverges,
    # bounded so hundreds of steps stay finite, bitwise-reproducible
    return np.sin(vec) * np.float64(1.0001) + np.float64((step % 7) * 1e-3)


def _digest(vec: np.ndarray, step: int) -> str:
    h = hashlib.sha256()
    h.update(np.ascontiguousarray(vec).tobytes())
    h.update(int(step).to_bytes(8, "little"))
    return h.hexdigest()


def reference_digest(spec: JobSpec) -> str:
    """Digest of the job's final state in an unfaulted world."""
    vec = _init_vec(spec.job_id)
    for step in range(spec.total_steps):
        vec = _sim_step(vec, step)
    return _digest(vec, spec.total_steps)


class SimWorkload:
    """Orchestrator workload protocol over a deterministic numpy state."""

    kind = "sim"

    def __init__(self, spec: JobSpec, run_dir: str,
                 options: Optional[CheckpointOptions] = None,
                 attempt: int = 0, mesh=None):
        self.spec = spec
        self.run_dir = run_dir
        self.attempt = attempt
        self.vec: Optional[np.ndarray] = None
        self.step = 0
        self.session = CheckpointSession(
            run_dir, options if options is not None else CheckpointOptions(),
            backend="host")
        self.session.attach(lambda: {"sim_state": {"vec": self.vec}})
        self.session.register_host_state(
            "cursor", lambda: {"step": self.step}, self._set_cursor)

    def _set_cursor(self, value: Dict[str, Any]) -> None:
        self.step = int(value["step"])

    @property
    def done(self) -> bool:
        return self.step >= self.spec.total_steps

    def start(self) -> None:
        self.vec = _init_vec(self.spec.job_id)
        self.step = 0

    def run_slice(self, n_steps: int,
                  preempt: Optional[Callable[[], bool]] = None
                  ) -> Dict[str, Any]:
        if hooks.INJECTOR is not None:
            hooks.fire("sim.slice", job_id=self.spec.job_id, step=self.step)
        t0 = time.perf_counter()
        executed, preempted, ckpt_path = 0, False, None
        target = min(self.step + n_steps, self.spec.total_steps)
        while self.step < target:
            if preempt is not None and preempt():
                try:
                    ckpt_path = self.checkpoint(self.step)
                except SnapshotWriteFailed:
                    raise
                except Exception as e:
                    # the orchestrator only recognizes SnapshotWriteFailed
                    # around run_slice; a raw dump failure here must fail
                    # this job, never the whole loop
                    raise SnapshotWriteFailed(
                        f"checkpoint-on-signal failed: {e!r}") from e
                preempted = True
                break
            if hooks.INJECTOR is not None:
                delay = hooks.fire("sim.step", job_id=self.spec.job_id,
                                   step=self.step)
                if delay:              # degraded-I/O straggler window
                    time.sleep(delay)
            self.vec = _sim_step(self.vec, self.step)
            self.step += 1
            executed += 1
        return {"steps": executed, "step": self.step,
                "preempted": preempted, "ckpt_path": ckpt_path,
                "wall_s": time.perf_counter() - t0}

    def checkpoint(self, step: int) -> str:
        if hooks.INJECTOR is not None:
            hooks.fire("sim.checkpoint", job_id=self.spec.job_id, step=step)
        return self.session.checkpoint(step)

    def restore(self) -> int:
        if hooks.INJECTOR is not None:
            hooks.fire("sim.restore", job_id=self.spec.job_id)
        out = self.session.restore()
        self.vec = np.asarray(out["sim_state"]["vec"],
                              dtype=np.float64).copy()
        return self.step           # cursor setter ran during the restore

    def finish(self) -> None:
        self.session.wait_pending()

    def digest(self) -> str:
        return _digest(self.vec, self.step)


def make_sim_factory(base_run_dir: str,
                     non_incremental: Any = (),
                     replicate: bool = True,
                     capture: str = "sync") -> Callable[..., SimWorkload]:
    """Workload factory for campaigns.

    Every job gets sync pack-v2 commits and (by default) delta
    replication to a per-job ``<job_dir>_replica`` store.  Jobs listed in
    `non_incremental` write self-contained images: a torn historical
    image must not poison later incremental children (their re-push would
    keep re-reading the torn chunk), which is exactly the configuration a
    fleet operator would pick for hosts with suspect storage.

    ``capture="concurrent"`` runs every *incremental* job's dumps through
    the soft-freeze path (pin → speculate → validate → commit);
    non-incremental jobs stay on sync capture, which concurrent mode
    requires anyway.
    """
    non_incremental = set(non_incremental)

    def factory(spec: JobSpec, attempt: int,
                host: Optional[str] = None) -> SimWorkload:
        job_dir = job_dir_for(base_run_dir, spec.job_id, host)
        incremental = spec.job_id not in non_incremental
        opts = CheckpointOptions(
            mode="sync", pack_format=2, stripes=2, chunk_mb=1,
            io_threads=1,
            incremental=incremental,
            capture=capture if incremental else "sync",
            replicate_to=(job_dir + "_replica") if replicate else None,
            transfer_policy=TransferPolicy(mode="delta", workers=1),
            verify_restore=True)
        return SimWorkload(spec, job_dir, options=opts, attempt=attempt)

    return factory
