"""The fault injector: matches hook firings against a seeded plan.

One :class:`FaultInjector` is installed (``hooks.install``) for the
duration of a campaign.  Hook sites call :meth:`on`, which dispatches to
a per-site handler; handlers consult the plan's pending events and, when
a trigger condition is met, mutate real state — flip a byte in a pack
stripe, raise where a kill would land, corrupt a CAS object on disk,
duplicate or defer a signal — then record the injection in an audit
trail the campaign evaluates afterwards.

Trigger conditions anchor on *job progress* (``rec.step``, commit step),
never on wall-clock or tick numbers, so the same seed reproduces the
same injections regardless of machine speed.

Thread-safety: the orchestrator loop is single-threaded, but pack stripe
appenders and transfer lanes run in worker threads; every handler that
mutates event state takes ``self.lock``.
"""
from __future__ import annotations

import contextlib
import glob
import os
import threading
import time
from typing import Any, Dict, List, Optional

from repro.obs import journal as obs_journal
from repro.obs import metrics as obs_metrics
from repro.orchestrator.job import JobState
from repro.orchestrator.signals import Signal

from . import hooks
from .plan import (ChaosConfig, ChaosInjectedFault, ChaosPartition,
                   FaultEvent)

# Events driven from the orchestrator tick (vs. fired inside commits).
DRIVER_KINDS = ("host_kill", "exhaust", "eviction_wall",
                "signal_dup", "signal_delay")


def _flip_byte(path: str, offset: int) -> None:
    with open(path, "r+b") as f:
        f.seek(offset)
        b = f.read(1)
        f.seek(offset)
        f.write(bytes([(b[0] if b else 0) ^ 0xFF]))


class FaultInjector:
    def __init__(self, config: ChaosConfig, clock=time.monotonic):
        self.config = config
        self.clock = clock
        self.lock = threading.RLock()
        self.injections: List[Dict] = []     # audit trail, in fire order
        # job context, maintained by the sim.* hooks (the orchestrator
        # runs jobs serially, so this is stable across one slice/commit)
        self.current_job: Optional[str] = None
        self.current_ckpt_step: Optional[int] = None
        self._deferred: List[Dict] = []      # delayed signal deliveries
        self._tick = 0

    # ------------------------------------------------------------------
    @contextlib.contextmanager
    def installed(self):
        hooks.install(self)
        try:
            yield self
        finally:
            hooks.uninstall()

    def on(self, site: str, **ctx: Any) -> Any:
        h = getattr(self, "_on_" + site.replace(".", "_"), None)
        return h(**ctx) if h is not None else None

    def injected_counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for rec in self.injections:
            out[rec["kind"]] = out.get(rec["kind"], 0) + 1
        return out

    # -- bookkeeping ----------------------------------------------------
    def _audit(self, rec: Dict[str, Any]) -> None:
        """Every injection lands in the audit trail *and* the run journal
        (cls="fault"), so ``repro events --class fault`` lines injected
        faults up against the incident spans they caused.  The journal is
        a side channel: campaign fingerprints hash the audit trail only,
        so observability never perturbs a seeded campaign."""
        self.injections.append(rec)
        obs_metrics.counter_add("chaos.injections")
        obs_journal.emit("fault", rec["kind"],
                         **{k: v for k, v in rec.items() if k != "kind"})

    def _record(self, ev: FaultEvent, **extra: Any) -> None:
        ev.state = "injected"
        ev.t_injected = self.clock()
        ev.injected_step = extra.get("step")
        self._audit({
            "kind": ev.kind, "job": ev.job_id, "seq": ev.seq,
            "at_step": ev.at_step, "t": ev.t_injected, **extra})

    def _match_commit(self, kind: str) -> Optional[FaultEvent]:
        """Pending event of `kind` triggered by the commit in progress."""
        job, step = self.current_job, self.current_ckpt_step
        if job is None or step is None:
            return None
        for ev in self.config.events:
            if (ev.kind == kind and ev.state == "pending"
                    and ev.job_id == job and step >= ev.at_step):
                return ev
        return None

    # -- sim workload context -------------------------------------------
    def _on_sim_slice(self, job_id, step=None, **_):
        with self.lock:
            self.current_job = job_id
            self.current_ckpt_step = None

    def _on_sim_checkpoint(self, job_id, step, **_):
        with self.lock:
            self.current_job = job_id
            self.current_ckpt_step = step

    def _on_sim_restore(self, job_id, **_):
        with self.lock:
            self.current_job = job_id
            self.current_ckpt_step = None

    def _on_sim_step(self, job_id, step, **_):
        """degraded_io: return a per-step delay (seconds) or None."""
        with self.lock:
            for ev in self.config.events:
                if (ev.kind == "degraded_io" and ev.job_id == job_id
                        and ev.state in ("pending", "injected")):
                    window = ev.detail.get("window", 4)
                    if ev.at_step <= step < ev.at_step + window:
                        if ev.state == "pending":
                            self._record(ev, step=step)
                        return ev.detail.get("delay_s", 0.12)
        return None

    # -- dump path ------------------------------------------------------
    def _on_pack_chunk(self, file, offset, data, dtype=None, **_):
        """torn_write: flip one byte of a freshly written array chunk."""
        if dtype is None:        # only corrupt per-step array payloads
            return None
        with self.lock:
            ev = self._match_commit("torn_write")
            if ev is None:
                return None
            pos = file.tell()
            file.seek(offset)
            file.write(bytes([data[0] ^ 0xFF]))
            file.seek(pos)
            self._record(ev, step=self.current_ckpt_step, offset=offset)
        return None

    def _on_snapshot_pre_manifest(self, step, path, **_):
        """commit_kill: die after payload rename, before MANIFEST."""
        with self.lock:
            ev = self._match_commit("commit_kill")
            if ev is None:
                return None
            self._record(ev, step=step, path=path)
        raise ChaosInjectedFault(
            f"chaos: killed mid-commit (phase-2 payload on disk, "
            f"no manifest) for step {step}")

    def _on_engine_dump_done(self, run_dir, step, path, **_):
        """fsync_drop: corrupt the committed local image post-push."""
        with self.lock:
            ev = self._match_commit("fsync_drop")
            if ev is None:
                return None
            packs = sorted(glob.glob(os.path.join(path, "*.pack*")),
                           key=os.path.getsize, reverse=True)
            if not packs:
                return None
            target = packs[0]
            size = os.path.getsize(target)
            _flip_byte(target, max(16, size // 3))
            ev.state = "armed"       # follow-up kill from _on_orch_tick
            ev.t_injected = self.clock()
            ev.injected_step = step
            self._audit({
                "kind": ev.kind, "job": ev.job_id, "seq": ev.seq,
                "at_step": ev.at_step, "t": ev.t_injected,
                "step": step, "path": target})
        return None

    # -- concurrent (soft-freeze) capture --------------------------------
    def _on_engine_speculate(self, key, leaf, note, step=None, **_):
        """dirty_burst: mutate a live leaf mid-speculation.

        Models the step loop racing the snapshot: the leaf's bytes change
        after the pin, and — exactly like a retiring stream op — the
        mutation is signalled through the dirty protocol via ``note``.
        The validate pause must re-hash the entry, spot the stale
        speculated copy, and re-capture it; the mutation is reverted at
        the validate site so the job's own trajectory stays bit-exact.
        """
        import numpy as np
        if not isinstance(leaf, np.ndarray) or leaf.size == 0:
            return None
        with self.lock:
            ev = self._match_commit("dirty_burst")
            if ev is None:
                return None
            try:
                leaf.view(np.uint8).reshape(-1)[0] ^= 0xFF
            except (ValueError, AttributeError):
                return None          # non-contiguous / read-only leaf
            note(key)
            ev.state = "armed"       # reverted+recorded at engine.validate
            ev.detail["mutated"] = {"key": key, "leaf": leaf}
        return None

    def _on_engine_validate(self, step=None, **_):
        """Revert armed dirty_burst mutations at the commit point."""
        import numpy as np
        with self.lock:
            for ev in self.config.events:
                if (ev.kind != "dirty_burst" or ev.state != "armed"
                        or ev.job_id != self.current_job):
                    continue
                mut = ev.detail.pop("mutated", None)
                if mut is not None:
                    mut["leaf"].view(np.uint8).reshape(-1)[0] ^= 0xFF
                self._record(ev, step=step,
                             key=mut["key"] if mut else None)
        return None

    # -- transfer path --------------------------------------------------
    def _on_cas_put(self, key, nbytes=0, **_):
        """cas_partition: cut the host off from the CAS mid-push."""
        with self.lock:
            ev = self._match_commit("cas_partition")
            if ev is None:
                return None
            landed = ev.detail.setdefault("puts_before_cut", 1)
            if landed > 0:
                ev.detail["puts_before_cut"] = landed - 1
                return None
            self._record(ev, step=self.current_ckpt_step, key=key)
        raise ChaosPartition(
            f"chaos: host partitioned from CAS while putting {key}")

    def _on_cas_landed(self, key, path, **_):
        """cas_corrupt: corrupt the object on disk right after it lands."""
        with self.lock:
            ev = self._match_commit("cas_corrupt")
            if ev is None:
                return None
            size = os.path.getsize(path)
            _flip_byte(path, max(0, size // 2))
            self._record(ev, step=self.current_ckpt_step, key=key)
        return None

    # -- fleet path -----------------------------------------------------
    def _on_fleet_boot(self, replica, host=None, **_):
        """host_kill at a fleet boot site: the replica's host dies
        mid-boot.  Matches a pending host_kill targeting the replica id
        or (via ``detail={"host": ...}``) the whole simulated host; the
        fleet quarantines the dead replica and keeps serving."""
        with self.lock:
            for ev in self.config.events:
                if (ev.kind == "host_kill" and ev.state == "pending"
                        and (ev.job_id == replica
                             or ev.detail.get("host") == host)):
                    self._record(ev, replica=replica, host=host)
                    break
            else:
                return None
        raise ChaosInjectedFault(
            f"chaos: host {host} killed while booting replica {replica}")

    # -- signal path ----------------------------------------------------
    def _on_signal_send(self, channel, job_id, sig, **_):
        """Armed signal events: duplicate or defer this delivery."""
        with self.lock:
            for ev in self.config.events:
                if ev.state != "armed" or ev.job_id != job_id:
                    continue
                if ev.kind == "signal_dup":
                    # one extra copy now; the normal path appends the
                    # original, so the job sees the signal twice.
                    channel._pending.setdefault(job_id, []).append(sig)
                    channel.sent.append((job_id, sig))
                    self._record(ev, sig=str(sig.value))
                    return None
                if ev.kind == "signal_delay":
                    self._deferred.append({
                        "channel": channel, "job_id": job_id, "sig": sig,
                        "due": self._tick + 2})
                    self._record(ev, sig=str(sig.value))
                    return "defer"
        return None

    # -- orchestrator driver --------------------------------------------
    def _on_orch_tick(self, orch, tick, **_):
        with self.lock:
            self._tick = tick
            self._deliver_due(tick)
            for ev in self.config.events:
                rec = orch.records.get(ev.job_id)
                if rec is None:
                    continue
                # a crashed job stays RUNNING until the heartbeat deadline
                # but its workload is gone: a signal sent into that window
                # is dropped by the eviction's channel.unregister, so only
                # target jobs that are actually alive
                alive = (rec.state == JobState.RUNNING
                         and ev.job_id in orch.workloads)
                if ev.state == "pending" and ev.kind in DRIVER_KINDS:
                    if alive and rec.step >= ev.at_step:
                        self._trigger(ev, orch, rec)
                elif ev.state == "armed" and ev.kind == "fsync_drop":
                    if alive:
                        orch.channel.send(ev.job_id, Signal.KILL)
                        ev.state = "injected"
                elif ev.state == "armed" and ev.kind == "exhaust":
                    if alive and rec.step >= ev.at_step:
                        orch.channel.send(ev.job_id, Signal.KILL)
                        left = ev.detail.get("kills_left", 0) - 1
                        ev.detail["kills_left"] = left
                        if left <= 0:
                            ev.state = "injected"
        return None

    def _deliver_due(self, tick):
        for d in list(self._deferred):
            if tick >= d["due"]:
                ch, job, sig = d["channel"], d["job_id"], d["sig"]
                # replicate SignalChannel.send without re-firing the hook
                ch._pending.setdefault(job, []).append(sig)
                ch.sent.append((job, sig))
                handler = ch._handlers.get(job)
                if handler is not None:
                    handler(sig)
                self._deferred.remove(d)

    def _trigger(self, ev: FaultEvent, orch, rec) -> None:
        if ev.kind == "host_kill":
            host = rec.host
            if host is None:        # single-host fleet: kill the target
                victims = [ev.job_id]
            else:
                victims = [j for j, r in orch.records.items()
                           if r.state == JobState.RUNNING
                           and j in orch.workloads and r.host == host]
            for j in victims:
                orch.channel.send(j, Signal.KILL)
            self._record(ev, step=rec.step, host=host,
                         victims=sorted(victims))
        elif ev.kind == "exhaust":
            orch.channel.send(ev.job_id, Signal.KILL)
            ev.state = "armed"       # second kill from _on_orch_tick
            ev.detail["kills_left"] = 1
            ev.t_injected = self.clock()
            ev.injected_step = rec.step
            self._audit({
                "kind": ev.kind, "job": ev.job_id, "seq": ev.seq,
                "at_step": ev.at_step, "t": ev.t_injected,
                "step": rec.step})
        elif ev.kind == "eviction_wall":
            from repro.orchestrator.orchestrator import MigrationPlan
            # _migrate picks the destination host via Scheduler.place
            orch.migrations[ev.job_id] = MigrationPlan(
                job_id=ev.job_id, at_step=rec.step, src_host=rec.host)
            self._record(ev, step=rec.step, src_host=rec.host)
        elif ev.kind in ("signal_dup", "signal_delay"):
            ev.state = "armed"       # _on_signal_send completes it
            orch.channel.send(ev.job_id, Signal.PREEMPT)
