"""Seeded fault plans: what to break, where, and when.

A :class:`FaultPlan` is generated from ``(seed, job specs, hosts, fault
spec)`` by a deterministic PRNG — the same seed always produces the same
events against the same fleet, which is what makes a chaos campaign a
*regression test* rather than a dice roll.

Fault taxonomy (one event class per failure mode the stack claims to
survive):

=================  ============================================================
``torn_write``     flip a byte of a freshly written pack chunk mid-dump
                   (detected by per-chunk CRC at the replication read)
``commit_kill``    kill the dump between phase-2 payload rename and the
                   MANIFEST write (image must be invisible to restore)
``fsync_drop``     corrupt a *committed* local pack after the replica push
                   (models lost writeback; restore falls back to an older
                   image) followed by a host kill
``cas_corrupt``    corrupt a CAS object on the replica right after it lands
                   (healed from source by the materializer)
``cas_partition``  fail a CAS put mid-push (models a network partition;
                   the next push resumes from the chunks that landed)
``host_kill``      correlated kill of every job on one simulated host
``degraded_io``    slow every sim step on one job for a window (straggler;
                   the JIT checkpoint policy should fire)
``eviction_wall``  HTCondor-style eviction: freeze + migrate the job to
                   another simulated host (requires >= 2 hosts)
``signal_dup``     the PREEMPT signal for one job is delivered twice
``signal_delay``   the PREEMPT signal for one job is delayed two ticks
``exhaust``        repeated kills against a job with ``max_restarts=1``
                   until it lands in diagnosable quarantine
``dirty_burst``    mutate a live state leaf *during* a concurrent
                   (soft-freeze) capture's speculation window — the dirty
                   protocol must invalidate the stale shard and the commit
                   must stay bit-exact (only fires when the campaign runs
                   with ``capture="concurrent"``)
=================  ============================================================
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

FAULT_CLASSES = (
    "torn_write",
    "commit_kill",
    "fsync_drop",
    "cas_corrupt",
    "cas_partition",
    "host_kill",
    "degraded_io",
    "eviction_wall",
    "signal_dup",
    "signal_delay",
    "exhaust",
    # keep dirty_burst last: sync campaigns zero its count, and a
    # trailing zero-count class leaves the PRNG draw order (and so every
    # pre-existing seeded plan) unchanged
    "dirty_burst",
)

# Classes that anchor on a checkpoint commit: the event fires inside the
# first commit whose step is >= at_step (commit hooks), so at_step must
# leave at least one earlier committed image to fall back to.
COMMIT_ANCHORED = ("torn_write", "commit_kill", "fsync_drop",
                   "cas_corrupt", "cas_partition", "dirty_burst")

# Classes that cost the target job a restart when they fire.
KILLING = ("torn_write", "commit_kill", "fsync_drop", "cas_partition",
           "host_kill")


class ChaosInjectedFault(RuntimeError):
    """Raised by the injector where the modelled fault would crash."""


class ChaosPartition(IOError):
    """Raised by the injector where the modelled fault is a network cut."""


@dataclasses.dataclass
class FaultEvent:
    """One planned incident against one target job."""
    kind: str
    job_id: str
    at_step: int                 # trigger: target job reaches this step
    seq: int                     # stable ordinal within the plan
    detail: Dict = dataclasses.field(default_factory=dict)
    # -- mutable runtime bookkeeping (owned by the injector) --
    state: str = "pending"       # pending -> (armed ->) injected
    injected_step: Optional[int] = None
    t_injected: Optional[float] = None

    def key(self) -> str:
        return f"{self.kind}#{self.seq}@{self.job_id}"


@dataclasses.dataclass
class ChaosConfig:
    """A fully materialized, seeded fault schedule."""
    seed: int
    hosts: int
    counts: Dict[str, int]
    events: List[FaultEvent] = dataclasses.field(default_factory=list)

    def events_for(self, kind: str) -> List[FaultEvent]:
        return [e for e in self.events if e.kind == kind]

    def targets(self, kind: str) -> List[str]:
        return sorted({e.job_id for e in self.events if e.kind == kind})


def parse_fault_spec(spec: str) -> Dict[str, int]:
    """``"all=1"`` / ``"host_kill=3,torn_write=2"`` -> {class: count}.

    ``all=N`` seeds every class with N and may be refined by later
    entries; unknown classes are an error so typos fail loudly.
    """
    counts: Dict[str, int] = {}
    spec = (spec or "all=1").strip()
    for part in spec.split(","):
        part = part.strip()
        if not part:
            continue
        if "=" in part:
            name, _, num = part.partition("=")
            name, num = name.strip(), int(num)
        else:
            name, num = part, 1
        if name == "all":
            for cls in FAULT_CLASSES:
                counts[cls] = num
        elif name in FAULT_CLASSES:
            counts[name] = num
        else:
            raise ValueError(
                f"unknown fault class {name!r}; choose from "
                f"{', '.join(FAULT_CLASSES)} or 'all'")
    return {k: v for k, v in counts.items() if v > 0}


def generate_plan(seed: int, specs: Sequence, hosts: int,
                  counts: Dict[str, int]) -> ChaosConfig:
    """Deterministically assign fault events to jobs.

    ``specs`` are orchestrator JobSpecs (only ``job_id`` / ``total_steps``
    / ``ckpt_every`` / ``max_restarts`` are consulted).  Rules that keep
    every planned event actually injectable:

    * ``exhaust`` targets are exclusive — no other event may hit them
      (their restart budget is 1 by construction).
    * killing events are capped per job below its restart budget.
    * commit-anchored events pick ``at_step`` so the triggering commit
      has at least one earlier committed image to fall back to.
    * ``eviction_wall`` events are dropped (with a note in ``counts``)
      when the fleet has fewer than two hosts.
    * ``dirty_burst`` events avoid ``torn_write``/``fsync_drop`` targets:
      those jobs write self-contained (non-incremental) images, which
      forces ``capture="sync"`` where a burst could never fire.
    """
    rng = np.random.default_rng(seed)
    counts = dict(counts)
    if hosts < 2 and counts.get("eviction_wall"):
        counts["eviction_wall"] = 0

    by_id = {s.job_id: s for s in specs}
    order = sorted(by_id)
    rng.shuffle(order)

    events: List[FaultEvent] = []
    seq = 0
    kill_load: Dict[str, int] = {j: 0 for j in order}
    exhaust_jobs: List[str] = []

    # exhaust targets first, so they can be excluded from everything else
    for _ in range(counts.get("exhaust", 0)):
        pool = [j for j in order if j not in exhaust_jobs]
        if not pool:
            break
        job = pool[int(rng.integers(len(pool)))]
        exhaust_jobs.append(job)
        spec = by_id[job]
        lo, hi = _kill_window(spec)
        events.append(FaultEvent("exhaust", job,
                                 int(rng.integers(lo, hi + 1)), seq))
        seq += 1

    cursor = 0
    for kind in FAULT_CLASSES:
        if kind == "exhaust":
            continue
        avoid: set = set()
        if kind == "dirty_burst":
            # torn_write/fsync_drop targets run non-incremental
            # (self-contained images), which forces capture="sync" on
            # them — a burst planned there could never fire
            avoid = {e.job_id for e in events
                     if e.kind in ("torn_write", "fsync_drop")}
        for _ in range(counts.get(kind, 0)):
            job = None
            for _probe in range(len(order)):
                cand = order[cursor % len(order)]
                cursor += 1
                if cand in exhaust_jobs or cand in avoid:
                    continue
                if kind in KILLING and \
                        kill_load[cand] + 1 >= by_id[cand].max_restarts:
                    continue
                job = cand
                break
            if job is None:       # fleet too small for the spec
                counts[kind] = counts.get(kind, 0) - 1
                continue
            spec = by_id[job]
            if kind in COMMIT_ANCHORED:
                lo, hi = _commit_window(spec)
            elif kind == "degraded_io":
                lo, hi = max(2, spec.total_steps - 5), spec.total_steps - 4
            else:
                lo, hi = _kill_window(spec)
            at = int(rng.integers(lo, max(lo, hi) + 1))
            detail: Dict = {}
            if kind == "degraded_io":
                detail = {"window": 4, "delay_s": 0.12}
            events.append(FaultEvent(kind, job, at, seq, detail))
            if kind in KILLING:
                kill_load[job] += 1
            seq += 1

    cfg = ChaosConfig(seed=seed, hosts=hosts,
                      counts={k: v for k, v in counts.items() if v > 0},
                      events=events)
    return cfg


def _commit_window(spec):
    """at_step range targeting a commit that is not the job's first.

    With slice-quantised stepping the triggering commit is the first one
    at step >= at_step; keeping at_step past the first checkpoint
    guarantees a fallback image exists.
    """
    lo = spec.ckpt_every + 2
    hi = max(lo, spec.total_steps - 5)
    return lo, hi


def _kill_window(spec):
    """at_step range for driver-triggered events (kills, signals, walls).

    Lower bound past the first checkpoint so the restart restores rather
    than cold-starts; upper bound leaves slack before completion so the
    trigger is observed while the job is still RUNNING.
    """
    lo = spec.ckpt_every + 2
    hi = max(lo, spec.total_steps - 3)
    return lo, hi
