"""Device plugin: transparent capture/restore of accelerator state.

This is the cuda-checkpoint/KFD analogue.  The JAX runtime owns every byte
of device state as ``jax.Array`` shards; the plugin:

  PAUSE_DEVICES        — quiesce: drain async dispatch (DeviceLock), count
                         unregistered live device arrays (the NVML-leftover
                         analogue of paper §4.4) and record them;
  CHECKPOINT_DEVICES   — device→host: copy every addressable shard
                         (replica 0 only — replicated shards are deduped the
                         way CRIU dedups COW pages) into host memory along
                         with avals + sharding descriptors;
  RESUME_DEVICES_LATE  — host→device: rebuild arrays, fast-path 1:1 shard
                         placement when the topology fingerprint matches,
                         reassemble + reshard otherwise (elastic restore).
"""
from __future__ import annotations

import time
from typing import Any, Dict, Tuple

import jax
import numpy as np

from repro.core.backends import DirtyTrackingMixin, JAX_BACKEND_FEATURES
from repro.core.lock import DeviceLock
from repro.core.plugins import HookContext, Plugin
from repro.core.topology import (resolve_sharding, sharding_descriptor)
from repro.serialization.pack import dtype_to_str, dtype_from_str

PyTree = Any


# ---------------------------------------------------------------- paths
def _key_str(path) -> str:
    from jax.tree_util import (DictKey, FlattenedIndexKey, GetAttrKey,
                               SequenceKey)
    parts = []
    for k in path:
        if isinstance(k, DictKey):
            parts.append(str(k.key))
        elif isinstance(k, GetAttrKey):
            parts.append(k.name)
        elif isinstance(k, SequenceKey):
            parts.append(str(k.idx))
        elif isinstance(k, FlattenedIndexKey):
            parts.append(str(k.key))
        else:
            parts.append(str(k))
    return "/".join(parts)


def _index_to_json(index: Tuple[slice, ...], shape) -> list:
    out = []
    for sl, dim in zip(index, shape):
        start = 0 if sl.start is None else int(sl.start)
        stop = dim if sl.stop is None else int(sl.stop)
        out.append([start, stop])
    return out


def _index_from_json(j) -> Tuple[slice, ...]:
    return tuple(slice(a, b) for a, b in j)


# ---------------------------------------------------------------- capture
def _start_transfer(arr) -> None:
    """Kick off the device→host DMA for `arr` without blocking.  Issuing
    every shard's copy before the first `np.asarray` materialization makes
    the capture loop double-buffered: while one shard's bytes are being
    turned into a host ndarray, the next shards' copies are already in
    flight, so the frozen window shrinks to roughly the copy itself."""
    try:
        arr.copy_to_host_async()
    except Exception:                                  # pragma: no cover
        pass                   # backend without async transfer: sync copy


def capture_array(arr: jax.Array) -> Dict[str, Any]:
    """Snapshot one device array into host memory (shards deduped)."""
    shards = []
    for sh in arr.addressable_shards:
        if sh.replica_id != 0:
            continue
        shards.append({
            "index": _index_to_json(sh.index, arr.shape),
            "data": np.asarray(sh.data),
        })
    return {
        "kind": "device_array",
        "shape": [int(s) for s in arr.shape],
        "dtype": dtype_to_str(arr.dtype),
        "sharding": sharding_descriptor(arr),
        "shards": shards,
    }


def capture_pytree(tree: PyTree) -> Dict[str, Dict[str, Any]]:
    """name(path) -> captured entry.  Host (non-jax) leaves pass through.

    Two passes: the first starts every shard's device→host transfer
    asynchronously, the second materializes host ndarrays (by which time
    the copies have been overlapping each other — the double-buffered
    capture of the pipelined data plane)."""
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    for _, leaf in flat:
        if isinstance(leaf, jax.Array):
            for sh in leaf.addressable_shards:
                if sh.replica_id == 0:
                    _start_transfer(sh.data)
    out: Dict[str, Dict[str, Any]] = {}
    for path, leaf in flat:
        key = _key_str(path)
        if isinstance(leaf, jax.Array):
            out[key] = capture_array(leaf)
        elif isinstance(leaf, np.ndarray):
            out[key] = {"kind": "np", "data": leaf}
        else:
            out[key] = {"kind": "host", "value": leaf}
    return out


def assemble_global(entry: Dict[str, Any]) -> np.ndarray:
    """Reassemble the full logical array from saved shards."""
    shape = tuple(entry["shape"])
    out = np.empty(shape, dtype=dtype_from_str(entry["dtype"]))
    for sh in entry["shards"]:
        idx = _index_from_json(sh["index"])
        piece_shape = tuple(s.stop - s.start for s in idx)
        out[idx] = np.asarray(sh["data"]).reshape(piece_shape)
    return out


def restore_array(entry: Dict[str, Any], target_mesh=None,
                  target_sharding=None) -> jax.Array:
    """Rebuild one device array.

    Fast path: the target sharding's shard indices match the saved shard
    index set exactly — place each saved buffer on its device directly.
    Slow (elastic) path: reassemble the global array and device_put with
    the new layout.
    """
    shape = tuple(entry["shape"])
    dtype_from_str(entry["dtype"])      # validates the stored dtype
    sharding = target_sharding
    if sharding is None:
        sharding = resolve_sharding(entry["sharding"], target_mesh)

    if sharding is None:
        return jax.device_put(assemble_global(entry))

    saved = {tuple(map(tuple, sh["index"])): sh["data"]
             for sh in entry["shards"]}
    try:
        index_map = sharding.devices_indices_map(shape)
        pieces = []
        ok = True
        for dev, idx in index_map.items():
            key = tuple(_index_to_json(idx, shape))
            key = tuple(map(tuple, key))
            if key not in saved:
                ok = False
                break
            pieces.append(jax.device_put(saved[key], dev))
        if ok:
            return jax.make_array_from_single_device_arrays(
                shape, sharding, pieces)
    except Exception:
        pass
    # elastic / mismatched layout: reassemble then reshard
    return jax.device_put(assemble_global(entry), sharding)


# ---------------------------------------------------------------- plugin
class DevicePlugin(DirtyTrackingMixin, Plugin):
    """The "jax" device backend (see ``repro.core.backends``)."""

    name = "device"
    api_version = 1
    features = JAX_BACKEND_FEATURES

    def __init__(self, lock_timeout_s: float = 10.0,
                 restore_threads: int = 0):
        self.lock = DeviceLock(lock_timeout_s)
        self.restore_threads = restore_threads
        self.streams = None

    def capture_entry(self, leaf) -> Dict[str, Any]:
        """Single-leaf capture for the concurrent speculation loop.
        Raises if the leaf was donated away (deleted) — the engine notes
        it dirty and re-captures the live value at the validate pause."""
        if isinstance(leaf, jax.Array):
            return capture_array(leaf)
        if isinstance(leaf, np.ndarray):
            return {"kind": "np", "data": leaf}
        return {"kind": "host", "value": leaf}

    # --- dump ---
    def pause_devices(self, ctx: HookContext) -> None:
        roots = getattr(ctx, "roots", {})
        arrays = [l for l in jax.tree.leaves(roots)
                  if isinstance(l, jax.Array)]
        t = self.lock.lock(arrays)
        ctx.stats["lock_s"] = t
        self.drain_streams()       # CRAC boundary: may raise UnsafeOp
        # leftover-reference detection (NVML analogue, paper §4.4)
        root_ids = {id(a) for a in arrays}
        leftover = 0
        for a in jax.live_arrays():
            if id(a) not in root_ids and not a.is_deleted():
                leftover += a.nbytes
        ctx.stats["leftover_device_bytes"] = float(leftover)
        if leftover:
            ctx.warnings.append(
                f"{leftover} bytes of live device arrays outside the "
                f"registered roots (jit-cache constants / temporaries); "
                f"these are re-creatable and excluded from the image")

    def checkpoint_devices(self, ctx: HookContext) -> None:
        t0 = time.perf_counter()
        dev_bytes = 0
        for name, tree in getattr(ctx, "roots", {}).items():
            cap = capture_pytree(tree)
            ctx.device_snapshot[name] = cap
            for e in cap.values():
                if e["kind"] == "device_array":
                    dev_bytes += sum(s["data"].nbytes for s in e["shards"])
        ctx.stats["device_to_host_s"] = time.perf_counter() - t0
        ctx.stats["capture_s"] = ctx.stats["device_to_host_s"]
        ctx.stats["device_bytes"] = float(dev_bytes)

    # --- restore ---
    def update_topology_map(self, ctx: HookContext) -> None:
        from repro.core.topology import compatibility, mesh_fingerprint
        saved = ctx.manifest.get("topology", {})
        target = mesh_fingerprint(ctx.target_mesh)
        ctx.topology_map["mode"] = compatibility(saved, target)
        ctx.topology_map["target"] = target

    def _flat_shardings(self, ctx: HookContext, state: str) -> Dict[str, Any]:
        cache = getattr(ctx, "_flat_sh_cache", None)
        if cache is None:
            cache = ctx._flat_sh_cache = {}
        if state not in cache:
            flat: Dict[str, Any] = {}
            shardings = ctx.target_shardings.get(state)
            if shardings is not None:
                for path, leaf in jax.tree_util.tree_flatten_with_path(
                        shardings)[0]:
                    flat[_key_str(path)] = leaf
            cache[state] = flat
        return cache[state]

    def _place_entry(self, ctx: HookContext, reader, state: str,
                     path: str):
        """Load + rebuild one logical leaf — the unit the lazy
        materializer streams, so arrays come back incrementally as their
        shards land."""
        entry = reader.load_entry(state, path)
        if entry["kind"] == "device_array":
            return restore_array(entry, ctx.target_mesh,
                                 self._flat_shardings(ctx, state).get(path))
        if entry["kind"] == "np":
            return entry["data"]
        return entry["value"]

    def resume_devices_late(self, ctx: HookContext) -> None:
        """host→device restore, with on-demand parallel entry loading (the
        paper cites this optimization from Yang et al. SoCC'24): worker
        threads stream pack entries from storage while the main thread
        places shards on devices.

        Lazy mode (resume-before-read): only the critical set is placed
        here; the rest of the image is handed to a LazyMaterializer the
        engine starts after the job is unlocked, and arrays rebuild
        incrementally as their shards land."""
        t0 = time.perf_counter()
        reader = ctx.reader
        threads = getattr(ctx, "restore_threads", 0) or self.restore_threads
        if getattr(ctx, "lazy", False):
            from repro.core.lazy import resume_with_schedule
            resume_with_schedule(
                ctx, lambda r, s, p: self._place_entry(ctx, r, s, p),
                threads)
            self.lock.unlock()                        # resume on criticals
            ctx.stats["host_to_device_s"] = time.perf_counter() - t0
            ctx.stats["place_s"] = ctx.stats.get("place_critical_s", 0.0)
            return
        place_s = 0.0
        for name in reader.state_names():
            flat_sh = self._flat_shardings(ctx, name)
            keys = reader.entry_names(name)
            if threads > 1 and len(keys) > 1:
                from concurrent.futures import ThreadPoolExecutor
                with ThreadPoolExecutor(max_workers=threads) as ex:
                    entries = list(ex.map(
                        lambda k: reader.load_entry(name, k), keys))
            else:
                entries = [reader.load_entry(name, k) for k in keys]
            restored: Dict[str, Any] = {}
            t_place = time.perf_counter()
            for key, entry in zip(keys, entries):
                if entry["kind"] == "device_array":
                    arr = restore_array(entry, ctx.target_mesh,
                                        flat_sh.get(key))
                elif entry["kind"] == "np":
                    arr = entry["data"]
                else:
                    arr = entry["value"]
                restored[key] = arr
            place_s += time.perf_counter() - t_place
            ctx.restored[name] = _unflatten_paths(restored)
        self.lock.unlock()
        ctx.stats["host_to_device_s"] = time.perf_counter() - t0
        ctx.stats["place_s"] = place_s


def _unflatten_paths(flat: Dict[str, Any]) -> Dict[str, Any]:
    """'a/b/c' -> nested dicts (CRIU-image-style raw view of the tree)."""
    out: Dict[str, Any] = {}
    for key, val in flat.items():
        node = out
        parts = key.split("/")
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val
    return out


def flatten_with_paths(tree: PyTree) -> Dict[str, Any]:
    return {_key_str(p): l
            for p, l in jax.tree_util.tree_flatten_with_path(tree)[0]}
