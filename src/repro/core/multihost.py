"""Multi-host unified snapshots: barrier + two-phase manifest commit.

The paper's multiprocess container trees (§4.2) need every process frozen
before the image is cut; our 1000-node analogue is every *host* dumping its
addressable shards, with the image valid only once ALL hosts have written.
Protocol (coordinator = host 0, the CRIU "main" process):

  phase 1  every host writes  host{i:04}.pack  +  PREPARED.{i}  (atomic)
  barrier  coordinator waits for all PREPARED markers (with deadline)
  phase 2  coordinator writes MANIFEST.json (atomic rename = commit point)

A crash before phase 2 leaves no manifest → the image does not exist and
restore falls back to the previous committed snapshot (the same torn-image
guarantee as the single-host path, extended across hosts).  The barrier is
filesystem-based (shared checkpoint directory — the common case for
GCS/NFS-backed training clusters); `jax.experimental.multihost_utils`
supplies the in-band barrier when a jax distributed client is initialised.

On restore every host reads only the entries whose shards it will hold
(the manifest's locations table is global), so restore bandwidth scales
with host count — the paper's per-GPU restore parallelism, at host
granularity.
"""
from __future__ import annotations

import os
import time
from typing import Any, Dict, List, Optional

from repro.serialization.integrity import atomic_write_bytes, read_json
from repro.core.snapshot_io import MANIFEST, snapshot_dir


class BarrierTimeout(RuntimeError):
    pass


def _prepared_path(dir_: str, host_id: int) -> str:
    return os.path.join(dir_, f"PREPARED.{host_id:04d}")


class MultiHostCommit:
    """Two-phase commit for one snapshot step across `num_hosts` hosts."""

    def __init__(self, run_dir: str, step: int, host_id: int,
                 num_hosts: int, deadline_s: float = 300.0):
        self.run_dir = run_dir
        self.step = step
        self.host_id = host_id
        self.num_hosts = num_hosts
        self.deadline_s = deadline_s
        self.dir = snapshot_dir(run_dir, step)

    # ------------------------------------------------------------ phase 1
    def prepare(self, meta: Optional[Dict[str, Any]] = None) -> None:
        """Mark this host's pack as durably written (called after the
        host's SnapshotWriter has fsync'd its pack)."""
        import json
        payload = json.dumps({"host": self.host_id,
                              "time": time.time(),
                              "meta": meta or {}}).encode()
        atomic_write_bytes(_prepared_path(self.dir, self.host_id), payload)

    def prepared_hosts(self) -> List[int]:
        if not os.path.isdir(self.dir):
            return []
        out = []
        for n in os.listdir(self.dir):
            if n.startswith("PREPARED."):
                out.append(int(n.split(".")[1]))
        return sorted(out)

    # ------------------------------------------------------------ barrier
    def wait_all_prepared(self, poll_s: float = 0.05) -> List[int]:
        t0 = time.monotonic()
        while True:
            hosts = self.prepared_hosts()
            if len(hosts) >= self.num_hosts:
                return hosts
            if time.monotonic() - t0 > self.deadline_s:
                raise BarrierTimeout(
                    f"step {self.step}: only {len(hosts)}/{self.num_hosts} "
                    f"hosts prepared within {self.deadline_s}s "
                    f"(missing: {sorted(set(range(self.num_hosts)) - set(hosts))})")
            time.sleep(poll_s)

    # ------------------------------------------------------------ phase 2
    @property
    def is_coordinator(self) -> bool:
        return self.host_id == 0

    def commit(self, manifest_writer) -> str:
        """Coordinator only: barrier on all hosts, then cut the manifest.
        `manifest_writer` is a zero-arg callable that atomically writes
        MANIFEST.json and returns the snapshot path."""
        assert self.is_coordinator, "only host 0 commits"
        self.wait_all_prepared()
        path = manifest_writer()
        # clean the markers (manifest presence is the commit record)
        for h in self.prepared_hosts():
            try:
                os.remove(_prepared_path(self.dir, h))
            except OSError:
                pass
        return path

    def committed(self) -> bool:
        return os.path.exists(os.path.join(self.dir, MANIFEST))

    def wait_committed(self, poll_s: float = 0.05) -> None:
        """Non-coordinator hosts: block until the coordinator commits (or
        the deadline passes — after which the snapshot must be treated as
        aborted and the host resumes)."""
        t0 = time.monotonic()
        while not self.committed():
            if time.monotonic() - t0 > self.deadline_s:
                raise BarrierTimeout(
                    f"step {self.step}: coordinator did not commit within "
                    f"{self.deadline_s}s")
            time.sleep(poll_s)


def merge_host_manifests(run_dir: str, step: int, num_hosts: int,
                         topology: Dict[str, Any],
                         per_host_meta: Dict[int, Dict[str, Any]]
                         ) -> Dict[str, Any]:
    """Build the global manifest from per-host metadata (coordinator side).
    Each host's `meta` maps its entry names to pack locations; the merged
    manifest's locations table is their disjoint union."""
    locations: Dict[str, str] = {}
    entry_crcs: Dict[str, int] = {}
    states = set()
    files = []
    for h in range(num_hosts):
        m = per_host_meta.get(h, {})
        locations.update(m.get("locations", {}))
        entry_crcs.update(m.get("entry_crcs", {}))
        states.update(m.get("states", []))
        files.extend(m.get("files", []))
    return {
        "format": 1,
        "step": step,
        "timestamp": time.time(),
        "topology": topology,
        "has_device_state": True,
        "num_hosts": num_hosts,
        "states": sorted(states),
        "locations": locations,
        "entry_crcs": entry_crcs,
        "files": sorted(files),
        "parent": None,
        "stats": {},
        "reused_bytes": 0,
        "written_bytes": 0,
    }
