"""Priority-ordered lazy restore — the "resume-before-read" data plane.

The paper's second headline claim is recovery time; PhoenixOS (PAPERS.md)
shows that most of a restore's wall clock is spent reading state the first
iteration never touches.  This module is the mechanism: the image's
``restore_order`` hint (recorded at dump time from the order states were
registered — params/opt first, host blobs and cold optimizer slots last)
splits into a *critical set* that is placed before ``restore()`` returns
and a *background schedule* that a :class:`LazyMaterializer` keeps
streaming into the restored tree while the job is already running.

Corruption guarantees are unchanged: every chunk read re-checks its stored
CRC, so a torn background chunk raises inside the stream; the failure
surfaces at :meth:`LazyMaterializer.join` (the engine's
``restore_barrier()``), the image is quarantined, and a retry falls back
to an eager restore of the previous committed step.  When the engine has a
replicator, a corrupt background entry is first *healed* from the replica
and the stream continues.
"""
from __future__ import annotations

import threading
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.obs import journal as obs_journal
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace

Spec = str                    # "state" or "state/path-prefix"
WorkItem = Tuple[str, str]    # (state, path)


class LazyRestoreError(RuntimeError):
    """The background materializer died; the restored tree is incomplete."""


def match_critical(state: str, path: str, specs: Sequence[Spec]) -> bool:
    """Does entry (state, path) belong to the critical set?

    A spec is ``"state"`` (every entry of that state) or
    ``"state/path-prefix"`` (that subtree only) — e.g.
    ``"train_state/params"`` makes the parameters critical while the
    optimizer slots stream in the background.
    """
    for spec in specs:
        if "/" not in spec:
            if state == spec:
                return True
            continue
        s, prefix = spec.split("/", 1)
        if state == s and (path == prefix
                           or path.startswith(prefix + "/")):
            return True
    return False


def split_schedule(reader, critical_specs: Optional[Sequence[Spec]]
                   ) -> Tuple[List[WorkItem], List[WorkItem]]:
    """Partition the image's priority-ordered entry schedule into
    (critical, background) work lists.

    With no explicit specs the critical set defaults to the first state in
    the image's recorded restore order — the state registered first at
    dump time, conventionally the one the job cannot take a step without.
    """
    specs: Tuple[Spec, ...]
    if critical_specs:
        specs = tuple(critical_specs)
    else:
        first = None
        for name in reader.restore_order():
            if name != "__host__":
                first = name.split("::", 1)[0]
                break
        specs = (first,) if first else ()
    critical: List[WorkItem] = []
    background: List[WorkItem] = []
    for state, path in reader.entry_schedule():
        if match_critical(state, path, specs):
            critical.append((state, path))
        else:
            background.append((state, path))
    return critical, background


def critical_pack_names(reader, critical: Sequence[WorkItem]) -> List[str]:
    """Pack-entry names the lazy pre-verify must cover before the job
    resumes on the critical set: the critical leaves plus the blobs the
    restore machinery itself reads eagerly (`__meta__`, `__host__`)."""
    names: List[str] = []
    for state, path in critical:
        names.extend(reader.pack_entries(state, path))
    for blob in ("__meta__", "__host__"):
        if blob in reader.manifest.get("locations", {}):
            names.append(blob)
    return names


def insert_leaf(root: Dict[str, Any], state: str, path: str,
                leaf: Any) -> None:
    """Place one restored leaf into the nested {state: tree} dict —
    the incremental version of ``_unflatten_paths`` (arrays rebuild one
    at a time as their shards land)."""
    node = root.setdefault(state, {})
    parts = path.split("/")
    for p in parts[:-1]:
        node = node.setdefault(p, {})
    node[parts[-1]] = leaf


class LazyMaterializer:
    """Streams the background schedule into the restored tree.

    One daemon thread walks `work` in priority order, loading each entry
    through the snapshot reader (chunk CRCs verified on read, chunk
    fan-out on the reader's I/O pool) and placing the rebuilt leaf via
    `place_fn`.  Consumers block per-entry (:meth:`wait_entry`) or on the
    whole stream (:meth:`join`); the engine exposes the latter as
    ``restore_barrier()``.

    `heal(state, path, exc)` — optional: invoked once per failed entry;
    returning True means the underlying image was repaired (e.g. re-pulled
    from a replica) and the entry should be retried through a fresh reader
    from `reopen()`.
    """

    def __init__(self, reader, work: Sequence[WorkItem],
                 place_fn: Callable[[Any, str, str], Any],
                 restored: Dict[str, Any], *,
                 reopen: Optional[Callable[[], Any]] = None,
                 heal: Optional[Callable[[str, str, BaseException],
                                         bool]] = None,
                 on_done: Optional[Callable[[], None]] = None):
        self._reader = reader
        self._work = list(work)
        self._place = place_fn
        self._restored = restored
        self._reopen = reopen
        self._heal = heal
        self._on_done = on_done
        self._lock = threading.Lock()
        self._events = {item: threading.Event() for item in self._work}
        self._done = threading.Event()
        self._cancelled = False
        self._thread: Optional[threading.Thread] = None
        self.error: Optional[BaseException] = None
        self.failed_item: Optional[WorkItem] = None
        self.stats: Dict[str, float] = {
            "background_entries": 0.0, "background_bytes": 0.0,
            "background_s": 0.0, "healed_entries": 0.0}

    # ------------------------------------------------------------ control
    def start(self) -> "LazyMaterializer":
        self._obs_ctx = obs_trace.current_context()
        self._thread = threading.Thread(
            target=self._run, daemon=True,
            name="repro-lazy-materializer")
        self._thread.start()
        return self

    def cancel(self) -> None:
        """Abandon the stream (a newer restore supersedes this one).  The
        current entry finishes; nothing further is placed."""
        self._cancelled = True

    # -------------------------------------------------------------- wait
    def wait_entry(self, state: str, path: str,
                   timeout: Optional[float] = None) -> None:
        """Block until one background leaf has landed (first-touch wait)."""
        ev = self._events.get((state, path))
        if ev is None:                     # not background: already placed
            return
        if not ev.wait(timeout):
            raise TimeoutError(f"lazy restore of {state}/{path} did not "
                               f"land within {timeout}s")
        self._raise_if_failed()

    def wait_done(self, timeout: Optional[float] = None) -> bool:
        """Wait for the stream to stop (success, failure, or cancel)
        without raising — the abandon path of a superseding restore."""
        return self._done.wait(timeout)

    def join(self, timeout: Optional[float] = None) -> None:
        """Block until the whole background stream has landed; raises
        :class:`LazyRestoreError` if it died (torn chunk, lost pack)."""
        if not self._done.wait(timeout):
            raise TimeoutError(f"lazy restore stream still running after "
                               f"{timeout}s")
        self._raise_if_failed()
        if self._cancelled:
            raise LazyRestoreError(
                "lazy restore stream was cancelled before completing")

    def _raise_if_failed(self) -> None:
        if self.error is not None:
            state, path = self.failed_item or ("?", "?")
            raise LazyRestoreError(
                f"background materializer failed at {state}/{path}: "
                f"{self.error!r}") from self.error

    @property
    def done(self) -> bool:
        return self._done.is_set()

    @property
    def ok(self) -> bool:
        return self._done.is_set() and self.error is None \
            and not self._cancelled

    # -------------------------------------------------------------- loop
    def _load_one(self, state: str, path: str) -> Any:
        return self._place(self._reader, state, path)

    def _stream(self) -> None:
        for item in self._work:
            if self._cancelled:
                break
            state, path = item
            tr = obs_trace.TRACER
            if tr is not None and tr.detail:
                with tr.begin("restore.entry",
                              {"state": state, "path": path}):
                    ok = self._stream_one(item, state, path)
            else:
                ok = self._stream_one(item, state, path)
            if not ok:
                break

    def _stream_one(self, item: WorkItem, state: str, path: str) -> bool:
        try:
            leaf = self._load_one(state, path)
        except BaseException as e:
            if not self._try_heal(state, path, e):
                self.error = e
                self.failed_item = item
                return False
            try:
                leaf = self._load_one(state, path)
            except BaseException as e2:
                self.error = e2
                self.failed_item = item
                return False
        with self._lock:
            insert_leaf(self._restored, state, path, leaf)
        try:
            self.stats["background_bytes"] += \
                self._reader.entry_nbytes(state, path)
        except Exception:
            pass
        self.stats["background_entries"] += 1
        self._events[item].set()
        return True

    def _run(self) -> None:
        t0 = time.perf_counter()
        try:
            with obs_trace.context(**getattr(self, "_obs_ctx", {})), \
                    obs_trace.span("restore.background",
                                   entries=len(self._work)) as sp:
                self._stream()
                sp.set(placed=self.stats["background_entries"],
                       healed=self.stats["healed_entries"])
        finally:
            self.stats["background_s"] = time.perf_counter() - t0
            for ev in self._events.values():
                ev.set()                   # unblock every first-touch wait
            try:
                self._reader.close()
            except Exception:
                pass
            if self._on_done is not None:
                try:
                    self._on_done()
                except Exception:
                    pass
            self._done.set()

    # ------------------------------------------------------------- heal
    def _try_heal(self, state: str, path: str, exc: BaseException) -> bool:
        if self._heal is None or self._cancelled:
            return False
        try:
            healed = self._heal(state, path, exc)
        except Exception:
            return False
        if not healed:
            return False
        # the image under the reader changed on disk: cached stripe
        # handles may hold pre-heal inodes, so reopen before retrying
        if self._reopen is not None:
            try:
                fresh = self._reopen()
            except Exception:
                return False
            old, self._reader = self._reader, fresh
            try:
                old.close()
            except Exception:
                pass
        self.stats["healed_entries"] += 1
        obs_metrics.counter_add("restore.heal_events")
        obs_journal.emit("restore", "heal", state=state, path=path,
                         error=repr(exc))
        return True


def resume_with_schedule(ctx, place_fn: Callable[[Any, str, str], Any],
                         threads: int) -> LazyMaterializer:
    """The lazy half of RESUME_DEVICES_LATE, shared by the device
    backends: place the critical set now (parallel entry loads, priority
    order), hand everything else to a materializer the engine will start
    once the job is unlocked.  `place_fn(reader, state, path)` loads one
    logical leaf through the reader and rebuilds it for this backend."""
    reader = ctx.reader
    critical, background = split_schedule(
        reader, getattr(ctx, "critical_specs", None))
    t0 = time.perf_counter()
    with obs_trace.span("restore.critical_place",
                        entries=len(critical), threads=threads):
        if threads > 1 and len(critical) > 1:
            from concurrent.futures import ThreadPoolExecutor
            with ThreadPoolExecutor(max_workers=threads) as ex:
                leaves = list(ex.map(lambda it: place_fn(reader, *it),
                                     critical))
        else:
            leaves = [place_fn(reader, *it) for it in critical]
        for (state, path), leaf in zip(critical, leaves):
            insert_leaf(ctx.restored, state, path, leaf)
    ctx.stats["place_critical_s"] = time.perf_counter() - t0
    ctx.stats["critical_entries"] = float(len(critical))
    ctx.stats["background_entries_planned"] = float(len(background))
    try:
        ctx.stats["critical_bytes"] = float(
            sum(reader.entry_nbytes(s, p) for s, p in critical))
    except Exception:                                  # pragma: no cover
        pass
    ctx.materializer = LazyMaterializer(
        reader, background, place_fn, ctx.restored,
        reopen=getattr(ctx, "lazy_reopen", None),
        heal=getattr(ctx, "lazy_heal", None),
        on_done=getattr(ctx, "lazy_on_done", None))
    return ctx.materializer
