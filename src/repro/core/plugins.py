"""CRIU-style plugin/hook architecture (paper §3.1, §3.1.3).

CRIUgpu extends CRIU with plugins that implement well-defined hooks invoked
at fixed stages of the checkpoint/restore workflow.  We keep the same hook
vocabulary and ordering contract:

  dump:    PAUSE_DEVICES → (host freeze) → CHECKPOINT_DEVICES →
           DUMP_EXT_STATE → (write + commit) → resume
  restore: RESTORE_EXT_STATE → RESUME_DEVICES_LATE

Every plugin also gets CRIU's init/exit contract: ``init(op)`` when loaded
(op is "dump" | "restore"), ``exit(success)`` at the end — the exit hook is
where a failed dump rolls the target back to its original running state.
"""
from __future__ import annotations

import enum
from typing import Any, Callable, Dict, FrozenSet, List, Optional

#: Version of the plugin/hook contract (hook vocabulary + HookContext
#: fields + init/exit semantics).  Bump on incompatible change; the
#: registry rejects plugins stamped with a different major version the way
#: CRIU rejects plugins built against a different plugin API.
PLUGIN_API_VERSION = 1


class PluginVersionError(RuntimeError):
    """Plugin was built against an incompatible plugin API version."""


class Hook(enum.Enum):
    PAUSE_DEVICES = "pause_devices"            # before host-state freeze
    CHECKPOINT_DEVICES = "checkpoint_devices"  # device -> host memory
    DUMP_EXT_STATE = "dump_ext_state"          # host-side external state
    RESTORE_EXT_STATE = "restore_ext_state"
    UPDATE_TOPOLOGY_MAP = "update_topology_map"  # GPUID-translation analogue
    RESUME_DEVICES_LATE = "resume_devices_late"  # host -> device + unlock


class Plugin:
    """Base plugin.  Subclasses override the hooks they care about.

    Every plugin is stamped with the ``api_version`` it was written against
    and a set of ``features`` it provides (capability flags surfaced by
    ``repro.api`` capabilities reports and checked by backend selection).
    """

    name = "plugin"
    api_version: int = PLUGIN_API_VERSION
    features: FrozenSet[str] = frozenset()

    def init(self, op: str) -> None:               # "dump" | "restore"
        pass

    def exit(self, op: str, success: bool) -> None:
        pass

    def pause_devices(self, ctx: "HookContext") -> None:
        pass

    def checkpoint_devices(self, ctx: "HookContext") -> None:
        pass

    def dump_ext_state(self, ctx: "HookContext") -> None:
        pass

    def restore_ext_state(self, ctx: "HookContext") -> None:
        pass

    def update_topology_map(self, ctx: "HookContext") -> None:
        pass

    def resume_devices_late(self, ctx: "HookContext") -> None:
        pass

    def dispatch(self, hook: Hook, ctx: "HookContext") -> None:
        getattr(self, hook.value)(ctx)


class HookContext:
    """Mutable bag threaded through one checkpoint or restore operation."""

    def __init__(self, op: str, step: Optional[int] = None):
        self.op = op                       # "dump" | "restore"
        self.step = step
        self.roots: Dict[str, Any] = {}              # live state pytrees
        self.device_snapshot: Dict[str, Any] = {}   # name -> captured state
        self.host_state: Dict[str, Any] = {}        # name -> msgpack-able
        self.restored: Dict[str, Any] = {}          # name -> restored pytree
        self.target_mesh = None
        self.target_shardings: Dict[str, Any] = {}
        self.topology_map: Dict[str, Any] = {}      # translation table
        self.manifest: Dict[str, Any] = {}
        self.reader = None                           # snapshot reader (restore)
        self.warnings: List[str] = []
        self.stats: Dict[str, float] = {}


class PluginRegistry:
    def __init__(self, plugins: Optional[List[Plugin]] = None):
        self.plugins: List[Plugin] = []
        for p in plugins or []:
            self.add(p)

    def add(self, plugin: Plugin) -> None:
        version = getattr(plugin, "api_version", None)
        if version != PLUGIN_API_VERSION:
            raise PluginVersionError(
                f"plugin {getattr(plugin, 'name', plugin)!r} declares "
                f"api_version={version!r}; this engine speaks "
                f"api_version={PLUGIN_API_VERSION}")
        self.plugins.append(plugin)

    def features(self) -> FrozenSet[str]:
        out: set = set()
        for p in self.plugins:
            out |= getattr(p, "features", frozenset())
        return frozenset(out)

    def init_all(self, op: str) -> None:
        for p in self.plugins:
            p.init(op)

    def exit_all(self, op: str, success: bool) -> None:
        for p in self.plugins:
            try:
                p.exit(op, success)
            except Exception:                        # exit must not mask errors
                pass

    def run(self, hook: Hook, ctx: HookContext) -> None:
        for p in self.plugins:
            p.dispatch(hook, ctx)


class CallbackPlugin(Plugin):
    """Host-state plugin built from getter/setter callbacks — the mechanism
    the trainer uses to expose its data-pipeline cursor, RNG, and metric
    accumulators (the paper's DUMP_EXT_FILE/RESTORE_EXT_FILE analogue)."""

    def __init__(self, name: str, getter: Callable[[], Any],
                 setter: Callable[[Any], None]):
        self.name = name
        self._get = getter
        self._set = setter

    def dump_ext_state(self, ctx: HookContext) -> None:
        ctx.host_state[self.name] = self._get()

    def restore_ext_state(self, ctx: HookContext) -> None:
        if self.name in ctx.host_state:
            self._set(ctx.host_state[self.name])
