"""CRIUgpu-adapted unified transparent checkpointing for JAX workloads.

Public API:
  SnapshotEngine   — lock → checkpoint → dump → unlock; restore (+elastic)
  Plugin / Hook    — CRIU-style plugin hooks
  DeviceLock       — cuda-checkpoint lock/unlock analogue
  Replicator       — the replication protocol (capability dispatch via
                     supports_rounds, never isinstance)
  DirReplicator / MemReplicator — Gemini-style peer replication
  MultiHostCommit  — two-phase manifest commit across hosts
"""
from repro.core.engine import SnapshotEngine, CheckpointAborted  # noqa: F401
from repro.core.lock import DeviceLock, LockTimeout  # noqa: F401
from repro.core.plugins import (Plugin, Hook, HookContext,  # noqa: F401
                                CallbackPlugin, PluginRegistry,
                                PLUGIN_API_VERSION, PluginVersionError)
from repro.core.device_plugin import DevicePlugin  # noqa: F401
from repro.core.backends import (DeviceBackend, BackendError,  # noqa: F401
                                 HostNumpyBackend, available_backends,
                                 create_backend, register_backend)
from repro.core.snapshot_io import SnapshotStore  # noqa: F401
from repro.core.replication import (DirReplicator,  # noqa: F401
                                    MemReplicator, Replicator)
from repro.core.multihost import (MultiHostCommit,  # noqa: F401
                                  BarrierTimeout)
