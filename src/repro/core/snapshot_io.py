"""Snapshot store: on-disk layout, writer (with incremental mode), reader.

Layout:
  run_dir/snapshots/step_00000123/
    MANIFEST.json         — committed last (atomic rename) = the image is valid
    host0000.pack         — this host's shard payloads + host-state blob

Incremental mode (beyond-paper, Check-N-Run-style): unchanged entries
(by content CRC) are not rewritten; the manifest's ``locations`` table points
them at the pack file of an earlier snapshot, forming a delta chain that the
reader resolves transparently.
"""
from __future__ import annotations

import os
import time
from typing import Any, Dict, List, Optional

import msgpack
import numpy as np

from repro.serialization.integrity import atomic_write_json, read_json
from repro.serialization.pack import PackReader, PackWriter

MANIFEST = "MANIFEST.json"


# ------------------------------------------------------------- msgpack np
def _mp_default(obj):
    if isinstance(obj, np.ndarray):
        return {"__np__": True, "dtype": obj.dtype.str,
                "shape": list(obj.shape), "data": obj.tobytes()}
    if isinstance(obj, (np.integer,)):
        return int(obj)
    if isinstance(obj, (np.floating,)):
        return float(obj)
    raise TypeError(f"not msgpack-able: {type(obj)}")


def _mp_hook(obj):
    if "__np__" in obj:
        return np.frombuffer(obj["data"], np.dtype(obj["dtype"])
                             ).reshape(obj["shape"]).copy()
    return obj


def pack_host_blob(obj: Any) -> bytes:
    return msgpack.packb(obj, default=_mp_default, use_bin_type=True)


def unpack_host_blob(raw: bytes) -> Any:
    return msgpack.unpackb(raw, object_hook=_mp_hook, raw=False,
                           strict_map_key=False)


def snapshot_dir(run_dir: str, step: int) -> str:
    return os.path.join(run_dir, "snapshots", f"step_{step:08d}")


# ---------------------------------------------------------------- writer
class SnapshotWriter:
    def __init__(self, run_dir: str, step: int, host_id: int = 0,
                 compress: bool = False,
                 prev_manifest: Optional[Dict[str, Any]] = None):
        self.run_dir = run_dir
        self.step = step
        self.host_id = host_id
        self.dir = snapshot_dir(run_dir, step)
        os.makedirs(self.dir, exist_ok=True)
        self.pack_name = f"host{host_id:04d}.pack"
        self._writer = PackWriter(os.path.join(self.dir, self.pack_name),
                                  compress=compress)
        self.locations: Dict[str, str] = {}
        self.meta: Dict[str, Any] = {}
        # incremental: map entry -> (crc, location) from the parent image
        self._prev: Dict[str, Any] = {}
        self.parent_step: Optional[int] = None
        if prev_manifest is not None:
            self.parent_step = prev_manifest["step"]
            self._prev = {
                name: {"crc": crc, "loc": prev_manifest["locations"][name]}
                for name, crc in prev_manifest.get("entry_crcs", {}).items()}
        self.entry_crcs: Dict[str, int] = {}
        self.reused_bytes = 0
        self.written_bytes = 0

    def _put(self, name: str, data: np.ndarray) -> None:
        from repro.serialization.integrity import crc32
        raw = np.asarray(data, order="C")
        c = crc32(raw.tobytes())
        self.entry_crcs[name] = c
        prev = self._prev.get(name)
        if prev is not None and prev["crc"] == c:
            self.locations[name] = prev["loc"]          # delta: reuse
            self.reused_bytes += raw.nbytes
            return
        self._writer.add(name, raw)
        self.locations[name] = os.path.join(
            f"step_{self.step:08d}", self.pack_name)
        self.written_bytes += raw.nbytes

    def write_states(self, device_snapshot: Dict[str, Dict[str, Any]]) -> None:
        """device_snapshot: state_name -> {leafpath -> captured entry}."""
        for state, entries in device_snapshot.items():
            meta: Dict[str, Any] = {}
            for path, e in entries.items():
                if e["kind"] == "device_array":
                    meta[path] = {
                        "kind": "device_array", "shape": e["shape"],
                        "dtype": e["dtype"], "sharding": e["sharding"],
                        "shards": [s["index"] for s in e["shards"]],
                    }
                    for i, s in enumerate(e["shards"]):
                        self._put(f"{state}::{path}::s{i}", s["data"])
                elif e["kind"] == "np":
                    meta[path] = {"kind": "np"}
                    self._put(f"{state}::{path}::np", e["data"])
                else:
                    meta[path] = {"kind": "host", "value": e["value"]}
            self.meta[state] = meta

    def write_host_state(self, host_state: Dict[str, Any]) -> None:
        self._writer.add_bytes("__host__", pack_host_blob(host_state))
        self.locations["__host__"] = os.path.join(
            f"step_{self.step:08d}", self.pack_name)

    def commit(self, topology: Dict[str, Any],
               stats: Optional[Dict[str, Any]] = None,
               extra: Optional[Dict[str, Any]] = None) -> str:
        self._writer.add_bytes("__meta__", pack_host_blob(self.meta))
        self.locations["__meta__"] = os.path.join(
            f"step_{self.step:08d}", self.pack_name)
        self._writer.close()
        manifest = {
            "format": 1,
            "step": self.step,
            "timestamp": time.time(),
            "topology": topology,
            "has_device_state": True,          # inventory flag (paper §3.1.1)
            "states": sorted(self.meta),
            "parent": self.parent_step,
            "locations": self.locations,
            "entry_crcs": self.entry_crcs,
            "files": [self.pack_name],
            "stats": dict(stats or {}),
            "reused_bytes": self.reused_bytes,
            "written_bytes": self.written_bytes,
        }
        if extra:
            manifest.update(extra)
        atomic_write_json(os.path.join(self.dir, MANIFEST), manifest)
        return self.dir

    def abort(self) -> None:
        try:
            self._writer.__exit__(RuntimeError, None, None)
        except Exception:
            pass


# ---------------------------------------------------------------- reader
class SnapshotReader:
    """Thread-safe: each thread gets its own PackReader per pack file, so
    parallel restore (the on-demand-parallelism optimization the paper
    cites from Yang et al. SoCC'24) reads entries concurrently."""

    def __init__(self, run_dir: str, step: int, verify: bool = True):
        import threading
        self.run_dir = run_dir
        self.step = step
        self.dir = snapshot_dir(run_dir, step)
        self.manifest = read_json(os.path.join(self.dir, MANIFEST))
        self._tls = threading.local()
        self._all_packs: List[PackReader] = []
        self._packs_lock = threading.Lock()
        self._verify = verify
        meta_raw = self._read("__meta__")
        self.meta: Dict[str, Any] = unpack_host_blob(meta_raw)

    def _pack_for(self, loc: str) -> PackReader:
        packs = getattr(self._tls, "packs", None)
        if packs is None:
            packs = self._tls.packs = {}
        if loc not in packs:
            path = os.path.join(self.run_dir, "snapshots", loc)
            r = PackReader(path, verify=self._verify)
            packs[loc] = r
            with self._packs_lock:
                self._all_packs.append(r)
        return packs[loc]

    def _read(self, name: str) -> bytes:
        loc = self.manifest["locations"][name]
        return self._pack_for(loc).read_bytes(name)

    def _read_array(self, name: str) -> np.ndarray:
        loc = self.manifest["locations"][name]
        return self._pack_for(loc).read_array(name)

    # --- API used by the device plugin ---
    def state_names(self) -> List[str]:
        return list(self.manifest["states"])

    def entry_names(self, state: str) -> List[str]:
        return list(self.meta[state])

    def load_entry(self, state: str, path: str) -> Dict[str, Any]:
        m = self.meta[state][path]
        if m["kind"] == "device_array":
            shards = []
            for i, idx in enumerate(m["shards"]):
                shards.append({"index": idx,
                               "data": self._read_array(
                                   f"{state}::{path}::s{i}")})
            return {"kind": "device_array", "shape": m["shape"],
                    "dtype": m["dtype"], "sharding": m["sharding"],
                    "shards": shards}
        if m["kind"] == "np":
            return {"kind": "np",
                    "data": self._read_array(f"{state}::{path}::np")}
        return {"kind": "host", "value": m["value"]}

    def host_state(self) -> Dict[str, Any]:
        return unpack_host_blob(self._read("__host__"))

    def verify_all(self) -> None:
        """CRC-check every entry the manifest references (the CRIU image
        check: a torn/corrupt image must be rejected *before* restore
        chooses it, so the engine can fall back to an older snapshot)."""
        for name in self.manifest["locations"]:
            self._read(name)

    def close(self):
        with self._packs_lock:
            for p in self._all_packs:
                p.close()
            self._all_packs.clear()


# ---------------------------------------------------------------- store
class SnapshotStore:
    def __init__(self, run_dir: str):
        self.run_dir = run_dir
        self.root = os.path.join(run_dir, "snapshots")

    def list_steps(self) -> List[int]:
        if not os.path.isdir(self.root):
            return []
        steps = []
        for d in sorted(os.listdir(self.root)):
            if d.startswith("step_") and os.path.exists(
                    os.path.join(self.root, d, MANIFEST)):
                steps.append(int(d[5:]))
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        s = self.list_steps()
        return s[-1] if s else None

    def reader(self, step: Optional[int] = None, verify: bool = True
               ) -> SnapshotReader:
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no snapshots under {self.root}")
        return SnapshotReader(self.run_dir, step, verify=verify)

    def manifest(self, step: int) -> Dict[str, Any]:
        return read_json(os.path.join(snapshot_dir(self.run_dir, step),
                                      MANIFEST))

    def gc(self, keep: int = 3) -> List[int]:
        """Remove old snapshots, never breaking incremental parent chains
        that newer snapshots still reference."""
        import shutil
        steps = self.list_steps()
        if len(steps) <= keep:
            return []
        keep_steps = set(steps[-keep:])
        # chase parent links of kept snapshots
        changed = True
        while changed:
            changed = False
            for s in list(keep_steps):
                p = self.manifest(s).get("parent")
                needed = {
                    int(loc.split("/")[0][5:])
                    for loc in self.manifest(s)["locations"].values()}
                for n in needed:
                    if n not in keep_steps:
                        keep_steps.add(n)
                        changed = True
        removed = []
        for s in steps:
            if s not in keep_steps:
                shutil.rmtree(snapshot_dir(self.run_dir, s),
                              ignore_errors=True)
                removed.append(s)
        return removed
