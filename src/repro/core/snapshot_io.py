"""Snapshot store: on-disk layout, writer (with incremental mode), reader.

Layout:
  run_dir/snapshots/step_00000123/
    MANIFEST.json         — committed last (atomic rename) = the image is valid
    host0000.pack.0..N-1  — this host's shard payloads, striped (pack v2)
    host0000.pack         — legacy v1 single-file layout (still readable)

Incremental mode (beyond-paper, Check-N-Run-style): unchanged entries
(by content CRC) are not rewritten; the manifest's ``locations`` table points
them at the pack file of an earlier snapshot, forming a delta chain that the
reader resolves transparently.  With pack v2, *partially* changed entries
dedup at chunk granularity: unchanged chunks (matched by their raw CRC,
which doubles as the content hash) become refs into the parent's stripes.

The writer is the serialization stage of the pipelined data plane: entries
are chunked and handed to `serialization.pack.PackWriterV2`, whose
compress workers and per-stripe appenders overlap CRC/compression with
file I/O.  The reader drives the streaming restore: a shared chunk-read
executor (``io_threads``) fans chunk reads out per stripe and places them
directly into preallocated buffers.
"""
from __future__ import annotations

import os
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import msgpack
import numpy as np

from repro.chaos import hooks as chaos_hooks
from repro.obs import trace as obs_trace
from repro.serialization.integrity import atomic_write_json, read_json
from repro.serialization.pack import (DEFAULT_CHUNK_BYTES, PackWriter,
                                      PackWriterV2, open_pack)

MANIFEST = "MANIFEST.json"


def _auto_io_threads() -> int:
    # lazy: repro.api.options is dependency-free, but importing it at
    # module scope would recurse through repro.api.__init__ -> engine
    from repro.api.options import auto_io_threads
    return auto_io_threads()


# ------------------------------------------------------------- msgpack np
def _mp_default(obj):
    if isinstance(obj, np.ndarray):
        return {"__np__": True, "dtype": obj.dtype.str,
                "shape": list(obj.shape), "data": obj.tobytes()}
    if isinstance(obj, (np.integer,)):
        return int(obj)
    if isinstance(obj, (np.floating,)):
        return float(obj)
    raise TypeError(f"not msgpack-able: {type(obj)}")


def _mp_hook(obj):
    if "__np__" in obj:
        return np.frombuffer(obj["data"], np.dtype(obj["dtype"])
                             ).reshape(obj["shape"]).copy()
    return obj


def pack_host_blob(obj: Any) -> bytes:
    return msgpack.packb(obj, default=_mp_default, use_bin_type=True)


def unpack_host_blob(raw: bytes) -> Any:
    return msgpack.unpackb(raw, object_hook=_mp_hook, raw=False,
                           strict_map_key=False)


def snapshot_dir(run_dir: str, step: int) -> str:
    return os.path.join(run_dir, "snapshots", f"step_{step:08d}")


def _loc_step(loc: str) -> int:
    """'step_00000042/host0000.pack' -> 42."""
    return int(loc.split("/")[0][5:])


# ---------------------------------------------------------------- writer
_NEVER_SPECULATED = object()


class SnapshotWriter:
    def __init__(self, run_dir: str, step: int, host_id: int = 0,
                 compress: bool = False,
                 prev_manifest: Optional[Dict[str, Any]] = None,
                 pack_format: int = 2,
                 chunk_bytes: int = DEFAULT_CHUNK_BYTES,
                 stripes: int = 2,
                 io_threads: int = 0):
        if pack_format not in (1, 2):
            raise ValueError(f"pack_format must be 1 or 2, got {pack_format}")
        self.run_dir = run_dir
        self.step = step
        self.host_id = host_id
        self.format = pack_format
        self.dir = snapshot_dir(run_dir, step)
        os.makedirs(self.dir, exist_ok=True)
        self.pack_name = f"host{host_id:04d}.pack"
        base = os.path.join(self.dir, self.pack_name)
        if pack_format == 1:
            self._writer: Any = PackWriter(base, compress=compress)
            self.files = [self.pack_name]
        else:
            workers = io_threads or _auto_io_threads()
            self._writer = PackWriterV2(base, compress=compress,
                                        chunk_bytes=chunk_bytes,
                                        stripes=stripes, workers=workers)
            self.files = [f"{self.pack_name}.{k}" for k in range(stripes)]
        self.chunk_bytes = chunk_bytes
        self.stripes = stripes if pack_format == 2 else 1
        self.locations: Dict[str, str] = {}
        self.meta: Dict[str, Any] = {}
        # incremental: map entry -> (crc, location) from the parent image
        self._prev: Dict[str, Any] = {}
        self.parent_step: Optional[int] = None
        if prev_manifest is not None:
            self.parent_step = prev_manifest["step"]
            self._prev = {
                name: {"crc": crc, "loc": prev_manifest["locations"][name]}
                for name, crc in prev_manifest.get("entry_crcs", {}).items()}
        self._parent_packs: Dict[str, Any] = {}      # loc -> reader | None
        self.entry_crcs: Dict[str, int] = {}
        self.reused_bytes = 0
        self.written_bytes = 0
        # restore-priority hint: entry names in registration order (the
        # order states were handed to _put — params/opt first, host blobs
        # last), plus per-entry raw sizes so the critical-set choice is
        # auditable offline (`repro inspect`)
        self.restore_order: List[str] = []
        self.entry_bytes: Dict[str, int] = {}
        # per-entry chunk CRCs as speculated/written — the concurrent
        # validate pass compares live bytes against these (None marks a
        # v1-parent reuse where only the whole-entry CRC is known)
        self.spec_crcs: Dict[str, Optional[List[int]]] = {}

    # --------------------------------------------------- chunk-level dedup
    def _parent_entry(self, name: str):
        """(parent entry record, parent pack loc) if the parent holds this
        entry in a v2 pack with matching chunking, else None."""
        if self.format != 2:
            return None
        prev = self._prev.get(name)
        if prev is None:
            return None
        loc = prev["loc"]
        if loc not in self._parent_packs:
            reader = None
            try:
                r = open_pack(os.path.join(self.run_dir, "snapshots", loc))
                if (getattr(r, "format", 1) == 2
                        and r.chunk_bytes == self.chunk_bytes):
                    reader = r
                else:
                    r.close()
            except Exception:
                reader = None
            self._parent_packs[loc] = reader
        reader = self._parent_packs[loc]
        if reader is None or name not in reader.index:
            return None
        return reader.entry(name), loc

    def _put(self, name: str, data: np.ndarray) -> None:
        from repro.serialization.integrity import crc32
        raw = np.asarray(data, order="C")
        self.restore_order.append(name)
        self.entry_bytes[name] = int(raw.nbytes)
        prev = self._prev.get(name)
        if self.format == 1:
            c = crc32(raw.tobytes())
            if prev is not None and prev["crc"] == c:
                self.entry_crcs[name] = c
                self.locations[name] = prev["loc"]      # delta: entry reuse
                self.reused_bytes += raw.nbytes
                return
            self._writer.add(name, raw)
            self._record_written(name, raw, crc=c)
            return

        # v2: hash once, at chunk grain, and make both reuse decisions
        # from that single pass (whole-entry reuse = every chunk matches;
        # partial = the pack writer refs the matching chunks)
        rawb = raw.tobytes()
        parent = self._parent_entry(name) if prev is not None else None
        if parent is not None:
            C = self.chunk_bytes
            mv = memoryview(rawb)
            crcs = [crc32(mv[o:o + C]) for o in range(0, len(rawb), C)]
            pchunks = parent[0]["chunks"]
            if (parent[0]["raw_nbytes"] == len(rawb)
                    and len(crcs) == len(pchunks)
                    and all(c == p.get("raw_crc32")
                            for c, p in zip(crcs, pchunks))):
                self.entry_crcs[name] = parent[0]["crc32"]
                self.locations[name] = prev["loc"]      # delta: entry reuse
                self.reused_bytes += raw.nbytes
                self.spec_crcs[name] = crcs
                return
            self._writer.add(name, raw, parent=parent, raw_bytes=rawb,
                             chunk_crcs=crcs)
        elif prev is not None:
            # parent exists but is v1 / differently chunked: whole-entry
            # CRC pre-check is all the dedup available
            c = crc32(rawb)
            if prev["crc"] == c:
                self.entry_crcs[name] = c
                self.locations[name] = prev["loc"]
                self.reused_bytes += raw.nbytes
                self.spec_crcs[name] = None
                return
            self._writer.add(name, raw, raw_bytes=rawb)
        else:
            self._writer.add(name, raw, raw_bytes=rawb)
        self._record_written(name, raw)
        self.spec_crcs[name] = self._writer.raw_crcs(name)

    def _record_written(self, name: str, raw: np.ndarray,
                        crc: Optional[int] = None) -> None:
        # raw content CRC: the v2 writer accumulates it while chunking;
        # the v1 writer's index CRC covers stored bytes, so pass it in
        self.entry_crcs[name] = (crc if crc is not None
                                 else self._writer.entry_crc(name))
        self.locations[name] = os.path.join(
            f"step_{self.step:08d}", self.pack_name)
        self.written_bytes += raw.nbytes

    def put_state_entry(self, state: str, path: str,
                        e: Dict[str, Any]) -> None:
        """Write one captured leaf.  The concurrent speculation loop
        streams entries one at a time as it captures them; write_states
        is the batch form."""
        meta = self.meta.setdefault(state, {})
        if e["kind"] == "device_array":
            meta[path] = {
                "kind": "device_array", "shape": e["shape"],
                "dtype": e["dtype"], "sharding": e["sharding"],
                "shards": [s["index"] for s in e["shards"]],
            }
            for i, s in enumerate(e["shards"]):
                self._put(f"{state}::{path}::s{i}", s["data"])
        elif e["kind"] == "np":
            meta[path] = {"kind": "np"}
            self._put(f"{state}::{path}::np", e["data"])
        else:
            meta[path] = {"kind": "host", "value": e["value"]}

    def write_states(self, device_snapshot: Dict[str, Dict[str, Any]]) -> None:
        """device_snapshot: state_name -> {leafpath -> captured entry}."""
        for state, entries in device_snapshot.items():
            self.meta.setdefault(state, {})
            for path, e in entries.items():
                self.put_state_entry(state, path, e)

    def flush(self) -> None:
        """Drain the pack pipeline without closing it: every speculated
        chunk record is populated, the stripe set stays open for
        re-capture (concurrent capture's validate/patch boundary)."""
        fl = getattr(self._writer, "flush", None)
        if fl is not None:
            fl()

    def _entry_names(self, state: str, path: str,
                     e: Dict[str, Any]) -> List[str]:
        if e["kind"] == "device_array":
            return [f"{state}::{path}::s{i}"
                    for i in range(len(e["shards"]))]
        if e["kind"] == "np":
            return [f"{state}::{path}::np"]
        return []

    def reput_state_entry(self, state: str, path: str,
                          e: Dict[str, Any]) -> int:
        """Validate one dirtied leaf against the speculated image and
        patch only the pieces whose content hash actually changed (the
        patch phase of concurrent capture).  Returns the number of raw
        bytes re-captured (0 = the speculation validated bit-exact).

        Call flush() first so speculated chunk records are populated.
        """
        from repro.serialization.integrity import crc32
        if e["kind"] == "host":
            # host leaves are tiny python values: always refresh
            self.meta.setdefault(state, {})[path] = {
                "kind": "host", "value": e["value"]}
            return 0
        assert self.format == 2, "reput requires a v2 pack"
        recaptured = 0
        own_loc = os.path.join(f"step_{self.step:08d}", self.pack_name)
        datas = ([s["data"] for s in e["shards"]]
                 if e["kind"] == "device_array" else [e["data"]])
        names = self._entry_names(state, path, e)
        for name, data in zip(names, datas):
            raw = np.asarray(data, order="C")
            rawb = raw.tobytes()
            C = self.chunk_bytes
            mv = memoryview(rawb)
            crcs = [crc32(mv[o:o + C]) for o in range(0, len(rawb), C)]
            spec = self.spec_crcs.get(name, _NEVER_SPECULATED)
            if (spec is not _NEVER_SPECULATED and spec is not None
                    and crcs == spec
                    and self.entry_bytes.get(name) == raw.nbytes):
                continue                     # speculation validated
            if spec is None and crc32(rawb) == self.entry_crcs.get(name):
                continue                     # v1-parent reuse still valid
            if spec is _NEVER_SPECULATED:
                # structural drift: a leaf that did not exist at pin
                self._put(name, raw)
                recaptured += raw.nbytes
            elif not self.locations.get(name, "").startswith(
                    f"step_{self.step:08d}"):
                # was reused from the parent image: pull it into this
                # pack now (the parent copy no longer matches)
                self.reused_bytes -= self.entry_bytes.get(name, raw.nbytes)
                parent = self._parent_entry(name)
                self._writer.add(name, raw, parent=parent, raw_bytes=rawb,
                                 chunk_crcs=crcs)
                self._record_written(name, raw)
                self.spec_crcs[name] = crcs
                recaptured += raw.nbytes
            else:
                # was speculated into this pack: append-only patch, with
                # the old record as dedup parent so untouched chunks
                # stay as self-references
                self._writer.replace(name, raw, own_loc=own_loc,
                                     raw_bytes=rawb, chunk_crcs=crcs)
                self.entry_crcs[name] = self._writer.entry_crc(name)
                self.spec_crcs[name] = crcs
                recaptured += raw.nbytes
            self.entry_bytes[name] = int(raw.nbytes)
        # refresh shape/sharding metadata alongside the patched bytes
        meta = self.meta.setdefault(state, {})
        if e["kind"] == "device_array":
            meta[path] = {
                "kind": "device_array", "shape": e["shape"],
                "dtype": e["dtype"], "sharding": e["sharding"],
                "shards": [s["index"] for s in e["shards"]],
            }
        else:
            meta[path] = {"kind": "np"}
        return recaptured

    def drop_state_entry(self, state: str, path: str) -> None:
        """Remove a leaf from the image metadata (concurrent capture:
        the entry vanished from the live tree between pin and validate).
        Any speculated bytes stay in the pack as dead data; restore
        only follows the metadata."""
        self.meta.get(state, {}).pop(path, None)

    @property
    def superseded_bytes(self) -> int:
        return getattr(self._writer, "superseded_bytes", 0)

    def write_host_state(self, host_state: Dict[str, Any]) -> None:
        blob = pack_host_blob(host_state)
        self._writer.add_bytes("__host__", blob)
        self.locations["__host__"] = os.path.join(
            f"step_{self.step:08d}", self.pack_name)
        # host blobs restore last in the lazy schedule (coldest priority)
        self.restore_order.append("__host__")
        self.entry_bytes["__host__"] = len(blob)

    def _close_parent_packs(self) -> None:
        for r in self._parent_packs.values():
            if r is not None:
                try:
                    r.close()
                except Exception:
                    pass
        self._parent_packs.clear()

    def commit(self, topology: Dict[str, Any],
               stats: Optional[Dict[str, Any]] = None,
               extra: Optional[Dict[str, Any]] = None) -> str:
        with obs_trace.span("dump.commit", step=self.step):
            return self._commit(topology, stats, extra)

    def _commit(self, topology: Dict[str, Any],
                stats: Optional[Dict[str, Any]],
                extra: Optional[Dict[str, Any]]) -> str:
        self._writer.add_bytes("__meta__", pack_host_blob(self.meta))
        self.locations["__meta__"] = os.path.join(
            f"step_{self.step:08d}", self.pack_name)
        self._writer.close()
        self._close_parent_packs()
        reused_chunks = getattr(self._writer, "reused_chunk_bytes", 0)
        self.written_bytes -= reused_chunks
        self.reused_bytes += reused_chunks
        # every step this image's bytes live in (locations = entry-level
        # reuse; chunk refs = chunk-level reuse) — GC keeps them all
        ref_steps = {_loc_step(loc) for loc in self.locations.values()}
        ref_steps.update(_loc_step(loc)
                         for loc in getattr(self._writer, "ref_locs", ()))
        manifest = {
            "format": self.format,
            "step": self.step,
            "timestamp": time.time(),
            "topology": topology,
            "has_device_state": True,          # inventory flag (paper §3.1.1)
            "states": sorted(self.meta),
            "parent": self.parent_step,
            "locations": self.locations,
            "entry_crcs": self.entry_crcs,
            "files": self.files,
            "stats": dict(stats or {}),
            "reused_bytes": self.reused_bytes,
            "written_bytes": self.written_bytes,
            "ref_steps": sorted(ref_steps),
            "restore_order": self.restore_order,
            "entry_bytes": self.entry_bytes,
        }
        if self.format == 2:
            manifest["chunk_bytes"] = self.chunk_bytes
            manifest["stripes"] = self.stripes
        if extra:
            manifest.update(extra)
        if chaos_hooks.INJECTOR is not None:
            # chaos: commit-kill site — the phase-2 payload is renamed
            # into place but the manifest does not exist yet; a raise
            # here must leave an image that restore scans skip entirely
            chaos_hooks.fire("snapshot.pre_manifest", step=self.step,
                             path=self.dir)
        atomic_write_json(os.path.join(self.dir, MANIFEST), manifest)
        return self.dir

    # ------------------------------------------------------ pipeline stats
    @property
    def compress_s(self) -> float:
        return getattr(self._writer, "compress_s", 0.0)

    @property
    def io_s(self) -> float:
        return getattr(self._writer, "io_s", 0.0)

    @property
    def stripe_bytes(self) -> List[int]:
        return list(getattr(self._writer, "stripe_bytes", []))

    def abort(self) -> None:
        self._close_parent_packs()
        try:
            self._writer.__exit__(RuntimeError, None, None)
        except Exception:
            pass


# ---------------------------------------------------------------- reader
class SnapshotReader:
    """Thread-safe: v1 packs get one reader per thread (their single file
    handle seeks), v2 packs share one reader (per-thread stripe handles
    inside), so parallel restore (the on-demand-parallelism optimization
    the paper cites from Yang et al. SoCC'24) reads entries concurrently.
    `io_threads` > 1 additionally fans the chunks of each v2 entry out to
    a shared executor — the streaming-restore read-ahead/decompress pool.
    """

    def __init__(self, run_dir: str, step: int, verify: bool = True,
                 io_threads: int = 0):
        self.run_dir = run_dir
        self.step = step
        self.dir = snapshot_dir(run_dir, step)
        self.manifest = read_json(os.path.join(self.dir, MANIFEST))
        self._tls = threading.local()
        self._all_packs: List[Any] = []
        self._shared_packs: Dict[str, Any] = {}
        self._packs_lock = threading.Lock()
        self._verify = verify
        self._io_threads = io_threads
        self._executor = None
        if io_threads > 1:
            from concurrent.futures import ThreadPoolExecutor
            self._executor = ThreadPoolExecutor(
                max_workers=io_threads,
                thread_name_prefix="repro-chunk-io")
        meta_raw = self._read("__meta__")
        self.meta: Dict[str, Any] = unpack_host_blob(meta_raw)

    def _pack_for(self, loc: str):
        with self._packs_lock:
            shared = self._shared_packs.get(loc)
        if shared is not None:
            return shared
        packs = getattr(self._tls, "packs", None)
        if packs is None:
            packs = self._tls.packs = {}
        if loc not in packs:
            path = os.path.join(self.run_dir, "snapshots", loc)
            r = open_pack(path, verify=self._verify,
                          executor=self._executor)
            order = self.manifest.get("restore_order")
            if order and hasattr(r, "set_priorities"):
                r.set_priorities(order)
            if getattr(r, "format", 1) == 2:
                # v2 readers are thread-safe; share one (index read once)
                with self._packs_lock:
                    if loc in self._shared_packs:
                        r.close()
                        return self._shared_packs[loc]
                    self._shared_packs[loc] = r
                    self._all_packs.append(r)
                return r
            packs[loc] = r
            with self._packs_lock:
                self._all_packs.append(r)
        return packs[loc]

    def _read(self, name: str) -> bytes:
        loc = self.manifest["locations"][name]
        return self._pack_for(loc).read_bytes(name)

    def _read_array(self, name: str) -> np.ndarray:
        loc = self.manifest["locations"][name]
        return self._pack_for(loc).read_array(name)

    # --- API used by the device plugin ---
    def state_names(self) -> List[str]:
        return list(self.manifest["states"])

    def entry_names(self, state: str) -> List[str]:
        return list(self.meta[state])

    # ------------------------------------------------------- lazy schedule
    def restore_order(self) -> List[str]:
        """Pack-entry names, most-critical first: the manifest's
        ``restore_order`` hint (dump-time registration order), derived
        from the meta tables for legacy images that predate the hint."""
        order = self.manifest.get("restore_order")
        if order:
            return list(order)
        out: List[str] = []
        for state in self.state_names():
            for path, m in self.meta[state].items():
                if m["kind"] == "device_array":
                    out.extend(f"{state}::{path}::s{i}"
                               for i in range(len(m["shards"])))
                elif m["kind"] == "np":
                    out.append(f"{state}::{path}::np")
        out.append("__host__")
        return out

    def pack_entries(self, state: str, path: str) -> List[str]:
        """The pack-entry names backing one logical (state, path) leaf."""
        m = self.meta[state][path]
        if m["kind"] == "device_array":
            return [f"{state}::{path}::s{i}"
                    for i in range(len(m["shards"]))]
        if m["kind"] == "np":
            return [f"{state}::{path}::np"]
        return []                          # host value: lives in the meta

    def entry_schedule(self) -> List[Tuple[str, str]]:
        """Every logical (state, path) leaf, ordered by restore priority —
        the streaming order of the lazy materializer.  Meta-resident host
        values sort first (they cost no I/O)."""
        prio = {n: i for i, n in enumerate(self.restore_order())}
        items: List[Tuple[str, str, int]] = []
        for state in self.state_names():
            for path in self.meta[state]:
                names = self.pack_entries(state, path)
                if not names:
                    items.append((state, path, -1))
                else:
                    items.append((state, path,
                                  min(prio.get(n, len(prio))
                                      for n in names)))
        items.sort(key=lambda t: t[2])
        return [(s, p) for s, p, _ in items]

    def entry_nbytes(self, state: str, path: str) -> int:
        """Raw payload bytes of one logical leaf (0 for meta-resident
        host values)."""
        sizes = self.manifest.get("entry_bytes", {})
        total = 0
        for n in self.pack_entries(state, path):
            if n in sizes:
                total += int(sizes[n])
            else:                          # legacy image: ask the pack
                loc = self.manifest["locations"][n]
                pack = self._pack_for(loc)
                total += int(getattr(pack, "entry_nbytes",
                                     lambda _n: 0)(n))
        return total

    def load_entry(self, state: str, path: str) -> Dict[str, Any]:
        m = self.meta[state][path]
        if m["kind"] == "device_array":
            shards = []
            for i, idx in enumerate(m["shards"]):
                shards.append({"index": idx,
                               "data": self._read_array(
                                   f"{state}::{path}::s{i}")})
            return {"kind": "device_array", "shape": m["shape"],
                    "dtype": m["dtype"], "sharding": m["sharding"],
                    "shards": shards}
        if m["kind"] == "np":
            return {"kind": "np",
                    "data": self._read_array(f"{state}::{path}::np")}
        return {"kind": "host", "value": m["value"]}

    def host_state(self) -> Dict[str, Any]:
        return unpack_host_blob(self._read("__host__"))

    def _verify_one(self, name: str) -> None:
        loc = self.manifest["locations"][name]
        pack = self._pack_for(loc)
        if hasattr(pack, "verify_entry"):
            pack.verify_entry(name)       # v2: CRC stored chunks, no decode
        else:
            pack.read_bytes(name)         # v1: CRC implies full decode

    def verify_all(self) -> None:
        """CRC-check every entry the manifest references (the CRIU image
        check: a torn/corrupt image must be rejected *before* restore
        chooses it, so the engine can fall back to an older snapshot).
        v2 packs verify without decompressing (chunk CRCs cover the
        stored bytes); entries run in parallel when the reader has an
        I/O pool."""
        self.verify_entries(list(self.manifest["locations"]))

    def verify_entries(self, names: List[str]) -> None:
        """CRC-check a subset of pack entries.  The lazy restore path
        pre-verifies only the critical set (plus ``__host__``/``__meta__``)
        before resuming the job; background entries keep the same
        guarantee because every chunk read re-checks its stored CRC."""
        if self._io_threads > 1 and len(names) > 1:
            from concurrent.futures import ThreadPoolExecutor
            # a pool distinct from the chunk executor: entry tasks block
            # on chunk futures, so sharing one pool could starve itself
            with ThreadPoolExecutor(
                    max_workers=min(4, self._io_threads)) as ex:
                for _ in ex.map(self._verify_one, names):
                    pass
        else:
            for name in names:
                self._verify_one(name)

    def io_stats(self) -> Dict[str, float]:
        """Aggregated chunk-read/decompress timings across this image's
        packs (v2 only; v1 packs report nothing)."""
        out = {"read_s": 0.0, "decompress_s": 0.0, "read_bytes": 0.0}
        with self._packs_lock:
            packs = list(self._all_packs)
        for p in packs:
            for k, v in p.io_stats().items():
                out[k] = out.get(k, 0.0) + v
        return out

    def close(self):
        with self._packs_lock:
            for p in self._all_packs:
                p.close()
            self._all_packs.clear()
            self._shared_packs.clear()
        if self._executor is not None:
            self._executor.shutdown(wait=False)
            self._executor = None


# ---------------------------------------------------------------- store
class SnapshotStore:
    def __init__(self, run_dir: str):
        self.run_dir = run_dir
        self.root = os.path.join(run_dir, "snapshots")
        # serializes gc against concurrent restore scans on this store
        # (the async-writer thread gc's while restore() may be reading)
        self.lock = threading.RLock()
        # steps a background lazy materializer is still streaming from;
        # gc treats them (and their delta-chain parents) as kept.  The
        # stream cannot hold the store lock for its whole lifetime — a
        # concurrent checkpoint's gc would block behind a restore that is
        # deliberately long-running — so it pins instead.
        self._pins: Dict[int, int] = {}

    def pin(self, step: int) -> None:
        with self.lock:
            self._pins[step] = self._pins.get(step, 0) + 1

    def unpin(self, step: int) -> None:
        with self.lock:
            n = self._pins.get(step, 0) - 1
            if n <= 0:
                self._pins.pop(step, None)
            else:
                self._pins[step] = n

    def list_steps(self) -> List[int]:
        if not os.path.isdir(self.root):
            return []
        steps = []
        try:
            names = sorted(os.listdir(self.root))
        except FileNotFoundError:            # raced with a concurrent gc
            return []
        for d in names:
            if d.startswith("step_") and os.path.exists(
                    os.path.join(self.root, d, MANIFEST)):
                steps.append(int(d[5:]))
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        s = self.list_steps()
        return s[-1] if s else None

    def reader(self, step: Optional[int] = None, verify: bool = True,
               io_threads: int = 0) -> SnapshotReader:
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no snapshots under {self.root}")
        return SnapshotReader(self.run_dir, step, verify=verify,
                              io_threads=io_threads)

    def manifest(self, step: int) -> Dict[str, Any]:
        return read_json(os.path.join(snapshot_dir(self.run_dir, step),
                                      MANIFEST))

    def referenced_steps(self, manifest: Dict[str, Any]) -> set:
        """Every step whose packs this image reads from (entry locations
        plus chunk-level refs)."""
        refs = {_loc_step(loc) for loc in manifest["locations"].values()}
        refs.update(manifest.get("ref_steps", []))
        return refs

    def gc(self, keep: int = 3) -> List[int]:
        """Remove old snapshots, never breaking incremental parent chains
        that newer snapshots still reference (entry- or chunk-level).

        Holds the store lock so a concurrent restore scan on the *same
        store instance* never sees a half-deleted image (other processes
        and other store instances are not serialized — for those, the
        manifest is unlinked before the payload, so they see the
        snapshot disappear atomically rather than turn corrupt, and the
        newest-valid restore scan falls back past it)."""
        import shutil
        with self.lock:
            steps = self.list_steps()
            if len(steps) <= keep:
                return []
            keep_steps = set(steps[-keep:])
            keep_steps.update(s for s in self._pins if s in set(steps))
            # chase pack references of kept snapshots
            changed = True
            while changed:
                changed = False
                for s in list(keep_steps):
                    try:
                        needed = self.referenced_steps(self.manifest(s))
                    except FileNotFoundError:          # pragma: no cover
                        continue                       # raced external gc
                    for n in needed:
                        if n not in keep_steps:
                            keep_steps.add(n)
                            changed = True
            removed = []
            for s in steps:
                if s not in keep_steps:
                    d = snapshot_dir(self.run_dir, s)
                    try:
                        os.remove(os.path.join(d, MANIFEST))
                    except OSError:
                        pass
                    shutil.rmtree(d, ignore_errors=True)
                    removed.append(s)
            return removed
